//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU client.
//!
//! The interchange format is HLO *text* (see DESIGN.md): `HloModuleProto::
//! from_text_file` re-parses and re-ids the module, sidestepping the 64-bit
//! instruction-id protos that jax >= 0.5 emits and xla_extension 0.5.1 rejects.

mod artifact;
mod client;
mod manifest;

pub use artifact::Artifact;
pub use client::Runtime;
pub use manifest::{ArgSpec, ArtifactSpec, Manifest};
// `ProblemSpec` moved to the backend-neutral `pde` module in the native-
// backend refactor; re-exported here for existing call sites.
pub use crate::pde::ProblemSpec;
