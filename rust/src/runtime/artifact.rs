//! A compiled artifact: shape-checked f64 execution with tuple unpacking.

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::ArtifactSpec;

/// A compiled XLA executable plus its manifest signature.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// (calls, cumulative seconds) — feeds the coordinator's perf report.
    stats: std::cell::RefCell<(u64, f64)>,
}

impl Artifact {
    pub fn new(spec: ArtifactSpec, exe: xla::PjRtLoadedExecutable) -> Self {
        Artifact {
            spec,
            exe,
            stats: std::cell::RefCell::new((0, 0.0)),
        }
    }

    /// Execute with flat f64 buffers in manifest argument order.
    ///
    /// Each `args[i]` must have exactly the element count of the manifest
    /// shape (scalars are 1-element slices). Returns the flat f64 contents of
    /// each tuple output, in manifest output order.
    pub fn call(&self, args: &[&[f64]]) -> Result<Vec<Vec<f64>>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "artifact {}: got {} args, manifest says {}",
                self.spec.name,
                args.len(),
                self.spec.args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (a, spec) in args.iter().zip(&self.spec.args) {
            let want: usize = spec.len().max(1);
            if a.len() != want {
                bail!(
                    "artifact {}: arg '{}' has {} elements, manifest shape {:?} wants {}",
                    self.spec.name,
                    spec.name,
                    a.len(),
                    spec.shape,
                    want
                );
            }
            let lit = xla::Literal::vec1(a);
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = if spec.shape.is_empty() {
                lit.reshape(&[])
                    .with_context(|| format!("scalar reshape for {}", spec.name))?
            } else {
                lit.reshape(&dims)
                    .with_context(|| format!("reshape {:?} for {}", dims, spec.name))?
            };
            literals.push(lit);
        }

        let t0 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact {}", self.spec.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: got {}-tuple, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (p, ospec) in parts.into_iter().zip(&self.spec.outputs) {
            let v = p
                .to_vec::<f64>()
                .with_context(|| format!("output '{}' to_vec", ospec.name))?;
            if v.len() != ospec.len().max(1) {
                bail!(
                    "artifact {}: output '{}' has {} elements, expected {:?}",
                    self.spec.name,
                    ospec.name,
                    v.len(),
                    ospec.shape
                );
            }
            out.push(v);
        }
        let mut s = self.stats.borrow_mut();
        s.0 += 1;
        s.1 += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    /// (number of calls, cumulative execute seconds).
    pub fn stats(&self) -> (u64, f64) {
        *self.stats.borrow()
    }

    /// Index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("artifact {} has no output '{}'", self.spec.name, name))
    }
}
