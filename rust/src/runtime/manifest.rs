//! Artifact manifest (`artifacts/manifest.json`) parsing.
//!
//! The manifest is written by `python/compile/aot.py` and fully describes
//! every artifact: file path, argument order/shapes, output shapes. The
//! runtime is manifest-driven — no shapes are hard-coded in Rust.
//!
//! Problem definitions parse into the backend-neutral
//! [`crate::pde::ProblemSpec`]; the artifact sets (a PJRT-only concern)
//! are kept here, keyed by problem name.
//!
//! Parsing uses our own minimal JSON reader (`crate::config::json`) since
//! serde is not available offline.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::json::JsonValue;
use crate::pde::{PdeOperator, ProblemSpec};

/// One artifact argument: name + static shape (scalars have empty shape).
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ArgSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One lowered computation: file + typed signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// The parsed manifest: problem specs plus per-problem artifact sets.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub problems: BTreeMap<String, ProblemSpec>,
    /// problem name → artifact name → spec.
    artifact_sets: BTreeMap<String, BTreeMap<String, ArtifactSpec>>,
}

fn parse_shape(v: &JsonValue) -> Result<Vec<usize>> {
    v.as_array()
        .ok_or_else(|| anyhow!("shape is not an array"))?
        .iter()
        .map(|d| {
            d.as_f64()
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("non-numeric dim"))
        })
        .collect()
}

fn parse_arg_list(v: &JsonValue) -> Result<Vec<ArgSpec>> {
    v.as_array()
        .ok_or_else(|| anyhow!("args is not an array"))?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a
                    .get("name")
                    .and_then(JsonValue::as_str)
                    .ok_or_else(|| anyhow!("arg missing name"))?
                    .to_string(),
                shape: parse_shape(
                    a.get("shape").ok_or_else(|| anyhow!("arg missing shape"))?,
                )?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `root/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = crate::config::json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;

        let mut problems = BTreeMap::new();
        let mut artifact_sets = BTreeMap::new();
        let probs = v
            .get("problems")
            .and_then(JsonValue::as_object)
            .ok_or_else(|| anyhow!("manifest missing 'problems'"))?;
        for (pname, pv) in probs {
            let grab = |k: &str| -> Result<f64> {
                pv.get(k)
                    .and_then(JsonValue::as_f64)
                    .ok_or_else(|| anyhow!("problem {pname} missing '{k}'"))
            };
            let mut artifacts = BTreeMap::new();
            let arts = pv
                .get("artifacts")
                .and_then(JsonValue::as_object)
                .ok_or_else(|| anyhow!("problem {pname} missing artifacts"))?;
            for (aname, av) in arts {
                artifacts.insert(
                    aname.clone(),
                    ArtifactSpec {
                        name: aname.clone(),
                        file: root.join(
                            av.get("file")
                                .and_then(JsonValue::as_str)
                                .ok_or_else(|| anyhow!("artifact missing file"))?,
                        ),
                        args: parse_arg_list(
                            av.get("args")
                                .ok_or_else(|| anyhow!("artifact missing args"))?,
                        )?,
                        outputs: parse_arg_list(
                            av.get("outputs")
                                .ok_or_else(|| anyhow!("artifact missing outputs"))?,
                        )?,
                    },
                );
            }
            let arch = pv
                .get("arch")
                .map(parse_shape)
                .transpose()?
                .ok_or_else(|| anyhow!("problem {pname} missing arch"))?;
            let pde = pv
                .get("pde")
                .and_then(JsonValue::as_str)
                .unwrap_or("")
                .to_string();
            // Older manifests carry no explicit operator; infer it from the
            // exact-solution family tag.
            let operator = match pv.get("operator").and_then(JsonValue::as_str) {
                Some(s) => PdeOperator::parse(s)
                    .with_context(|| format!("problem {pname} operator"))?,
                None => PdeOperator::from_pde_tag(&pde),
            };
            problems.insert(
                pname.clone(),
                ProblemSpec {
                    name: pname.clone(),
                    dim: grab("dim")? as usize,
                    arch,
                    n_params: grab("n_params")? as usize,
                    n_interior: grab("n_interior")? as usize,
                    n_boundary: grab("n_boundary")? as usize,
                    n_eval: grab("n_eval")? as usize,
                    interior_weight: grab("interior_weight")?,
                    boundary_weight: grab("boundary_weight")?,
                    pde,
                    operator,
                },
            );
            artifact_sets.insert(pname.clone(), artifacts);
        }
        Ok(Manifest {
            root,
            problems,
            artifact_sets,
        })
    }

    pub fn problem(&self, name: &str) -> Result<&ProblemSpec> {
        self.problems.get(name).ok_or_else(|| {
            anyhow!(
                "manifest has no problem '{}' (have: {:?})",
                name,
                self.problems.keys().collect::<Vec<_>>()
            )
        })
    }

    /// The artifact spec for `problem/name`.
    pub fn artifact(&self, problem: &str, name: &str) -> Result<&ArtifactSpec> {
        let set = self.artifact_sets.get(problem).ok_or_else(|| {
            anyhow!(
                "manifest has no problem '{}' (have: {:?})",
                problem,
                self.artifact_sets.keys().collect::<Vec<_>>()
            )
        })?;
        set.get(name).ok_or_else(|| {
            anyhow!(
                "problem '{}' has no artifact '{}' (have: {:?})",
                problem,
                name,
                set.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Names of the artifacts lowered for `problem` (empty when unknown).
    pub fn artifact_names(&self, problem: &str) -> Vec<String> {
        self.artifact_sets
            .get(problem)
            .map(|set| set.keys().cloned().collect())
            .unwrap_or_default()
    }
}
