//! The PJRT CPU client wrapper: compile-once, execute-many artifact registry.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::artifact::Artifact;
use super::manifest::{ArtifactSpec, Manifest};

/// Owns the PJRT client and a cache of compiled executables.
///
/// Compilation happens lazily on first use and is cached by artifact file
/// path, so a training run pays HLO→executable compilation exactly once per
/// artifact (the AOT analogue of jit warm-up, but in Rust and off the
/// per-step path).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<Artifact>>>,
    /// Cumulative wall time spent in PJRT compilation (startup cost metric).
    pub compile_seconds: RefCell<f64>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_seconds: RefCell::new(0.0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) the executable for `problem/artifact`.
    pub fn artifact(&self, problem: &str, name: &str) -> Result<std::rc::Rc<Artifact>> {
        let spec = self.manifest.artifact(problem, name)?.clone(); // lint: allow(alloc) — small spec copy
        self.compile_spec(&spec)
    }

    fn compile_spec(&self, spec: &ArtifactSpec) -> Result<std::rc::Rc<Artifact>> {
        let key = spec.file.display().to_string();
        if let Some(a) = self.cache.borrow().get(&key) {
            return Ok(a.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parsing HLO text {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT-compiling {}", spec.file.display()))?;
        *self.compile_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        let artifact = std::rc::Rc::new(Artifact::new(spec.clone(), exe));
        self.cache.borrow_mut().insert(key, artifact.clone());
        Ok(artifact)
    }
}
