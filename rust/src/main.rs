//! `engd` — the training framework CLI.
//!
//! Commands:
//!   train      train a PINN (from --config TOML or --problem + flags)
//!   sweep      random-search hyperparameters (paper Appendix A.1 protocol)
//!   eff-dim    track the kernel's effective dimension over training (Fig. 6)
//!   list       show the problems (and artifacts, on PJRT) of the backend
//!   smoke      end-to-end sanity check of the training pipeline
//!
//! Every command takes `--backend {pjrt,native,sharded[:N],process[:N],auto}`
//! (default auto): the PJRT backend executes AOT artifacts from
//! `--artifacts DIR`; the native backend evaluates the model in pure Rust
//! and needs no artifacts at all; `sharded:N` splits every collocation
//! batch across N inner native evaluators; `process:N` runs the same
//! split across N worker *processes* respawned from this binary (both are
//! bitwise-identical to native, and a killed worker process is respawned
//! with its ranges requeued).
//!
//! The hidden `--shard-worker` flag re-enters the binary as a shard
//! worker serving the `backend::process` frame protocol on stdin/stdout;
//! it is spawned by the process-tier supervisor, never by hand.
//!
//! The native kernel tiers take `--numerics {bitwise,fast}` (default:
//! the `ENGD_NUMERICS` environment variable, else bitwise; the flag
//! overrides the `numerics` TOML key): `bitwise` preserves the scalar per-point FP
//! operation order exactly; `fast` enables the relaxed-numerics SIMD tier
//! (FMA + reassociated reductions, runtime-dispatched per CPU, `ENGD_SIMD`
//! overridable) — faster, per-point deterministic, tolerance-checked
//! rather than bitwise. Checkpoints record the mode; resume refuses a
//! silent switch.
//!
//! Examples:
//!   engd train --problem poisson5d --opt spring --steps 300 --echo
//!   engd train --problem poisson2d --backend native --opt engd_w --steps 200
//!   engd train --config configs/spring_5d.toml --echo
//!   engd sweep --problem poisson5d --opt engd_w --trials 10 --steps 100
//!   engd eff-dim --problem poisson5d --steps 50 --damping 1e-8

use anyhow::{bail, Result};

use engd::backend::{Evaluator, NumericsMode};
use engd::cli::Args;
use engd::config::run::{BiasMode, ExecPath, OptimizerKind, SolveMode};
use engd::config::RunConfig;
use engd::coordinator::train;

const SWITCHES: &[&str] = &["echo", "line-search", "diag", "help"];

fn main() {
    // Worker-mode re-entry for the process-tier supervisor
    // (`engd::backend::process`): checked before CLI parsing so the hidden
    // flag can never collide with a command. Stdout belongs to the frame
    // protocol from here on.
    if std::env::args().any(|a| a == "--shard-worker") {
        std::process::exit(match engd::backend::process::worker_main() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("shard worker error: {e:#}");
                1
            }
        });
    }
    let args = match Args::parse(SWITCHES) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    if args.has("help") || args.command.is_empty() || args.command == "help" {
        print_help();
        return Ok(());
    }
    match args.command.as_str() {
        "train" => cmd_train(args),
        "sweep" => cmd_sweep(args),
        "eff-dim" => cmd_eff_dim(args),
        "list" => cmd_list(args),
        "smoke" => cmd_smoke(args),
        "report" => cmd_report(args),
        other => bail!("unknown command '{other}' (try 'engd help')"),
    }
}

fn print_help() {
    println!(
        "engd — Improving Energy Natural Gradient Descent through Woodbury, \
         Momentum, and Randomization (NeurIPS 2025) — full-system reproduction\n\
         \n\
         USAGE: engd <command> [flags]\n\
         \n\
         COMMANDS\n\
         \x20 train     train a PINN\n\
         \x20 sweep     random-search hyperparameters (paper A.1 protocol)\n\
         \x20 eff-dim   track kernel effective dimension (paper Fig. 6)\n\
         \x20 list      show the backend's problems (and artifacts on PJRT)\n\
         \x20 smoke     end-to-end pipeline sanity check\n\
         \x20 report    summarize results/ CSVs as a markdown table\n\
         \n\
         COMMON FLAGS\n\
         \x20 --backend KIND    pjrt|native|sharded[:N]|process[:N]|auto\n\
         \x20                   (default auto: PJRT when artifacts exist,\n\
         \x20                   else pure-Rust native AD; sharded:N splits\n\
         \x20                   each batch across N in-process evaluators;\n\
         \x20                   process:N across N worker processes with\n\
         \x20                   work-stealing + crash respawn — both\n\
         \x20                   bitwise-identical to native)\n\
         \x20 --numerics MODE   bitwise|fast (default bitwise, or ENGD_NUMERICS;\n\
         \x20                   fast enables the relaxed-numerics SIMD kernel\n\
         \x20                   tier on the native/sharded backends)\n\
         \x20 --artifacts DIR   artifact directory for PJRT (default: artifacts)\n\
         \x20 --config FILE     TOML run config (train)\n\
         \x20 --problem NAME    problem name (manifest or built-in catalogue)\n\
         \x20 --opt KIND        sgd|adam|engd_dense|engd_w|spring|hessian_free\n\
         \x20 --steps N         training steps\n\
         \x20 --lr X --damping X --momentum X --sketch X\n\
         \x20 --solve MODE      exact|nystrom_gpu|nystrom_stable|nystrom_pcg\n\
         \x20 --path MODE       fused|decomposed (fused is PJRT-only and\n\
         \x20                   falls back to decomposed elsewhere)\n\
         \x20 --bias MODE       adam|overwrite|none\n\
         \x20 --line-search     use the grid line search\n\
         \x20 --seed N --eval-every N --time-budget S --out DIR --name NAME\n\
         \x20 --echo            print per-step progress"
    );
}

/// Build a RunConfig from --config and/or command-line overrides.
fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        RunConfig::from_toml_file(path)?
    } else {
        RunConfig::default()
    };
    if let Some(p) = args.get("problem") {
        cfg.problem = p.to_string();
    }
    if let Some(b) = args.get("backend") {
        // Fail malformed selectors (sharded:0, process:0, typos) here at
        // parse time, not at backend construction.
        engd::backend::validate_backend(b)?;
        cfg.backend = b.to_string();
    }
    if let Some(a) = args.get("artifacts") {
        cfg.artifacts_dir = a.to_string();
    }
    if let Some(m) = args.get("numerics") {
        cfg.numerics = NumericsMode::parse(m)?;
    }
    if let Some(n) = args.get("name") {
        cfg.name = n.to_string();
    } else if args.get("config").is_none() {
        cfg.name = format!("{}-{}", cfg.problem, args.get_or("opt", "spring"));
    }
    if let Some(s) = args.get_usize("steps")? {
        cfg.steps = s;
    }
    if let Some(s) = args.get_usize("seed")? {
        cfg.seed = s as u64;
    }
    if let Some(s) = args.get_usize("eval-every")? {
        cfg.eval_every = s;
    }
    if let Some(t) = args.get_f64("time-budget")? {
        cfg.time_budget_s = t;
    }
    if let Some(o) = args.get("out") {
        cfg.out_dir = o.to_string();
    }
    if let Some(n) = args.get_usize("checkpoint-every")? {
        cfg.checkpoint_every = n;
    }
    if let Some(p) = args.get("resume") {
        cfg.resume_from = Some(p.to_string());
    }
    let opt = &mut cfg.optimizer;
    if let Some(kind) = args.get("opt") {
        opt.kind = OptimizerKind::parse(kind)?;
    }
    if let Some(x) = args.get_f64("lr")? {
        opt.lr = x;
    }
    if let Some(x) = args.get_f64("damping")? {
        opt.damping = x;
    }
    if let Some(x) = args.get_f64("momentum")? {
        opt.momentum = x;
    }
    if let Some(x) = args.get_f64("sketch")? {
        opt.sketch_ratio = x;
    }
    if let Some(m) = args.get("solve") {
        opt.solve = SolveMode::parse(m)?;
        if opt.solve != SolveMode::Exact {
            opt.path = ExecPath::Decomposed;
        }
    }
    if let Some(m) = args.get("path") {
        opt.path = ExecPath::parse(m)?;
    }
    if let Some(m) = args.get("bias") {
        opt.bias = BiasMode::parse(m)?;
    }
    if args.has("line-search") {
        opt.line_search = true;
    }
    if let Some(x) = args.get_usize("cg-iters")? {
        opt.cg_iters = x;
    }
    if let Some(x) = args.get_f64("ema")? {
        opt.ema = x;
    }
    opt.validate()?;
    Ok(cfg)
}

/// The backend named by the config (pjrt | native | auto).
fn backend_for(cfg: &RunConfig) -> Result<Box<dyn Evaluator>> {
    engd::backend::select_with_numerics(&cfg.backend, &cfg.artifacts_dir, cfg.numerics)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let backend = backend_for(&cfg)?;
    let opt_desc = engd::optim::build_optimizer(&cfg)?.describe();
    println!(
        "[train] {} on {} ({} steps, seed {}, backend {})",
        opt_desc,
        cfg.problem,
        cfg.steps,
        cfg.seed,
        backend.backend_name()
    );
    let report = train(cfg, backend.as_ref(), args.has("echo"))?;
    println!(
        "[train] done: {} steps in {:.1}s (+{:.1}s compile, {:.1}s eval) — \
         final loss {:.4e}, best L2 {:.4e}",
        report.steps_done,
        report.wall_s,
        report.compile_s,
        report.eval_s,
        report.final_loss,
        report.best_l2
    );
    for (thr, s) in &report.time_to {
        println!("[train]   reached L2 <= {thr:.0e} at t = {s:.2}s");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    if args.get("name").is_none() {
        cfg.name = format!("sweep-{}-{}", cfg.problem, cfg.optimizer.kind.name());
    }
    let trials = args.get_usize("trials")?.unwrap_or(10);
    let backend = backend_for(&cfg)?;
    println!(
        "[sweep] {} trials of {} on {} ({} steps each, backend {})",
        trials,
        cfg.optimizer.kind.name(),
        cfg.problem,
        cfg.steps,
        backend.backend_name()
    );
    let trials = engd::sweep::run_sweep(&cfg, backend.as_ref(), trials, true)?;
    println!("\n[sweep] ranking (best L2 ascending):");
    for t in trials.iter().take(5) {
        println!(
            "  #{:<3} L2={:.3e}  damping={:.3e} momentum={:.3} lr={:.3e}  ({} steps, {:.1}s)",
            t.index,
            t.report.best_l2,
            t.optimizer.damping,
            t.optimizer.momentum,
            t.optimizer.lr,
            t.report.steps_done,
            t.report.wall_s
        );
    }
    Ok(())
}

fn cmd_eff_dim(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    // d_eff tracking needs the decomposed path + diagnostics at every eval.
    cfg.optimizer.path = ExecPath::Decomposed;
    let backend = backend_for(&cfg)?;
    println!(
        "[eff-dim] tracking d_eff of (K + lambda*I), lambda = {:.3e}, problem {}",
        cfg.optimizer.damping, cfg.problem
    );
    cfg.eval_every = args.get_usize("eval-every")?.unwrap_or(5);
    cfg.name = format!("effdim-{}", cfg.problem);
    let report = train(cfg, backend.as_ref(), true)?;
    println!(
        "[eff-dim] done; per-step d_eff is in results/{}.csv (d_eff, d_eff_ratio columns)",
        report.name
    );
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let backend = engd::backend::select_from_args(args)?;
    match backend.as_pjrt() {
        Some(rt) => {
            println!("backend: pjrt (platform {})", rt.platform());
            for (name, p) in &rt.manifest().problems {
                println!(
                    "{name}: d={} arch={:?} P={} N={}+{} eval={} pde={}",
                    p.dim, p.arch, p.n_params, p.n_interior, p.n_boundary, p.n_eval, p.pde
                );
                println!(
                    "   artifacts: {}",
                    rt.manifest().artifact_names(name).join(", ")
                );
            }
        }
        None => {
            println!("backend: {} (built-in problem catalogue)", backend.backend_name());
            for name in backend.problem_names() {
                let p = backend.problem(&name)?;
                println!(
                    "{name}: d={} arch={:?} P={} N={}+{} eval={} pde={} op={}",
                    p.dim,
                    p.arch,
                    p.n_params,
                    p.n_interior,
                    p.n_boundary,
                    p.n_eval,
                    p.pde,
                    p.operator.name()
                );
            }
        }
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let dir = args.get_or("dir", "results");
    let rows = engd::metrics::report::summarize_dir(dir)?;
    if rows.is_empty() {
        println!("no run CSVs found under {dir}");
        return Ok(());
    }
    print!("{}", engd::metrics::report::markdown_table(&rows));
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let backend = engd::backend::select_from_args(args)?;
    println!("[smoke] backend = {}", backend.backend_name());
    let problem = args.get_or("problem", "poisson2d");
    let mut cfg = RunConfig {
        problem: problem.to_string(),
        artifacts_dir: args.get_or("artifacts", "artifacts").to_string(),
        name: "smoke".into(),
        steps: 10,
        eval_every: 5,
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.line_search = true;
    cfg.optimizer.momentum = 0.8;
    cfg.optimizer.damping = 1e-6;
    let report = train(cfg, backend.as_ref(), true)?;
    anyhow::ensure!(report.steps_done == 10, "expected 10 steps");
    anyhow::ensure!(report.final_loss.is_finite(), "loss diverged");
    println!(
        "[smoke] OK — loss {:.4e}, L2 {:.4e}",
        report.final_loss, report.best_l2
    );
    Ok(())
}
