//! Effective dimension of the regularized kernel (paper §3.4, Fig. 6):
//!
//! `d_eff(A) = Tr(A (A + λI)⁻¹) = Σ_i λ_i / (λ_i + λ)`
//!
//! The paper tracks d_eff/N over training to explain why sketch sizes of
//! 10 % N lose accuracy: the kernel's regularized rank plateaus above 50 % N.

use anyhow::Result;

use crate::linalg::{eigh, Cholesky, Matrix};

/// Exact d_eff via the identity `Tr(A(A+λI)⁻¹) = n − λ·Tr((A+λI)⁻¹)`,
/// evaluated with a Cholesky inverse-trace (no eigendecomposition needed).
pub fn effective_dimension(a: &Matrix, lambda: f64) -> Result<f64> {
    let n = a.rows();
    let ch = Cholesky::factor(&a.add_diag(lambda))?;
    Ok(n as f64 - lambda * ch.inverse_trace())
}

/// Spectral form Σ λ_i/(λ_i+λ) — O(n³) with a much larger constant (Jacobi);
/// used to cross-validate the Cholesky path and for spectrum dumps.
pub fn effective_dimension_spectral(a: &Matrix, lambda: f64) -> f64 {
    let e = eigh(a);
    e.eigenvalues
        .iter()
        .map(|&w| {
            let w = w.max(0.0);
            w / (w + lambda)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn cholesky_and_spectral_paths_agree() {
        let mut rng = Rng::seed_from(1);
        let mut g = Matrix::zeros(30, 50);
        rng.fill_normal(g.data_mut());
        let k = g.gram();
        for lam in [1e-6, 1e-2, 1.0, 100.0] {
            let a = effective_dimension(&k, lam).unwrap();
            let b = effective_dimension_spectral(&k, lam);
            assert!((a - b).abs() < 1e-6, "lam={lam}: {a} vs {b}");
        }
    }

    #[test]
    fn limits() {
        // λ → 0: d_eff → rank(A). λ → ∞: d_eff → 0.
        let mut rng = Rng::seed_from(2);
        let mut g = Matrix::zeros(20, 8); // rank ≤ 8
        rng.fill_normal(g.data_mut());
        let k = g.gram(); // 20×20, rank 8
        let low = effective_dimension(&k, 1e-12).unwrap();
        assert!((low - 8.0).abs() < 0.05, "low-λ d_eff = {low}");
        let high = effective_dimension(&k, 1e12).unwrap();
        assert!(high < 1e-6, "high-λ d_eff = {high}");
    }

    #[test]
    fn identity_matrix_d_eff() {
        let k = Matrix::identity(10);
        // d_eff = 10 · 1/(1+λ).
        let d = effective_dimension(&k, 1.0).unwrap();
        assert!((d - 5.0).abs() < 1e-10);
    }

    #[test]
    fn monotone_decreasing_in_lambda() {
        let mut rng = Rng::seed_from(3);
        let mut g = Matrix::zeros(15, 15);
        rng.fill_normal(g.data_mut());
        let k = g.gram();
        let mut prev = f64::INFINITY;
        for lam in [1e-8, 1e-4, 1e-2, 1.0, 10.0] {
            let d = effective_dimension(&k, lam).unwrap();
            assert!(d <= prev + 1e-9);
            prev = d;
        }
    }
}
