//! Adaptive sketch-rank selection — the paper's §5 future-work item
//! ("our current analysis focuses on fixed Nyström rank, leaving open
//! questions about how sketch dimension and adaptive rank selection affect
//! performance").
//!
//! Heuristic: a sketch of rank ℓ is *sufficient* when the weakest direction
//! it captured is already at the damping floor — i.e. the smallest retained
//! Nyström eigenvalue λ̂_ℓ ≲ c·λ. If instead λ̂_ℓ ≫ λ, the spectrum has not
//! decayed into the regularizer yet and the sketch is truncating live
//! directions (this is exactly the d_eff/N > sketch/N failure mode of
//! Fig. 6); double ℓ and retry, up to `max_ratio·N`.
//!
//! The retained-eigenvalue probe is free on the GPU-efficient factorization:
//! λ̂ bounds follow from the Cholesky pivots of `R = BᵀB + λI` — pivots
//! satisfy `λ_min(R) ≤ min_i L_ii²`, so `min-pivot² − λ` is a monotone
//! upper bound on the smallest retained eigenvalue `λ_min(BᵀB)`, and it
//! reaching the damping floor certifies the captured spectrum has decayed
//! into the regularizer (no extra matvecs, no extra storage).
//!
//! Like every Nyström builder, the adaptive scheme consumes a [`KernelOp`]
//! plus a [`Workspace`]: rejected sketches recycle their factors before the
//! next (doubled) attempt, so even the growth loop allocates nothing after
//! the first step at each rank.

use anyhow::Result;

use super::gpu_efficient::GpuNystrom;
use crate::linalg::Workspace;
use crate::optim::kernel::KernelOp;
use crate::rng::Rng;

/// Outcome of the adaptive construction.
pub struct AdaptiveNystrom {
    pub approx: GpuNystrom,
    /// Sketch sizes tried (last = used).
    pub schedule: Vec<usize>,
}

/// Upper bound on the smallest retained Nyström eigenvalue `λ_min(BᵀB)`,
/// from the Cholesky pivots of `R = BᵀB + λI` the factorization already
/// holds: `λ_min(BᵀB) = λ_min(R) − λ ≤ min-pivot² − λ` (clamped at 0 —
/// rank-deficient sketches drive the pivot to the √λ floor). Loose but
/// monotone, which is all the order-of-magnitude growth trigger needs.
fn min_captured_eigenvalue(nys: &GpuNystrom, lambda: f64) -> f64 {
    let pivot = nys.min_r_pivot();
    (pivot * pivot - lambda).max(0.0)
}

/// Build a GPU-efficient Nyström approximation of the operator's kernel
/// (via sketches `Y = J(JᵀΩ)`, never forming K) growing the rank until the
/// captured tail reaches the damping floor.
pub fn adaptive_nystrom(
    op: &dyn KernelOp,
    lambda: f64,
    start_ratio: f64,
    max_ratio: f64,
    tail_factor: f64,
    rng: &mut Rng,
    ws: &mut Workspace,
) -> Result<AdaptiveNystrom> {
    let n = op.size();
    let mut ell = ((n as f64 * start_ratio).round() as usize).clamp(1, n);
    let max_ell = ((n as f64 * max_ratio).round() as usize).clamp(ell, n);
    let mut schedule = Vec::new();
    loop {
        schedule.push(ell);
        let mut omega = ws.take_matrix_scratch(n, ell);
        rng.fill_normal(omega.data_mut());
        let y = op.sketch_y(&omega, ws);
        let approx = GpuNystrom::from_sketch(omega, y, lambda, ws)?;
        let tail = min_captured_eigenvalue(&approx, lambda);
        if tail <= tail_factor * lambda || ell >= max_ell {
            return Ok(AdaptiveNystrom { approx, schedule });
        }
        approx.recycle(ws);
        ell = (ell * 2).min(max_ell);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::nystrom::NystromApprox;
    use crate::optim::kernel::JacobianKernel;

    fn adaptive_from_jacobian(
        j: &Matrix,
        lambda: f64,
        start_ratio: f64,
        max_ratio: f64,
    ) -> AdaptiveNystrom {
        let mut rng = Rng::seed_from(1 + j.rows() as u64);
        let mut ws = Workspace::new();
        adaptive_nystrom(
            &JacobianKernel::new(j),
            lambda,
            start_ratio,
            max_ratio,
            10.0,
            &mut rng,
            &mut ws,
        )
        .unwrap()
    }

    /// Low-rank J: the adaptive scheme should stop quickly (tail hits the
    /// floor once rank is covered).
    #[test]
    fn stops_early_on_low_rank_kernels() {
        let mut rng = Rng::seed_from(1);
        let mut j = Matrix::zeros(64, 8); // K has rank ≤ 8
        rng.fill_normal(j.data_mut());
        let out = adaptive_from_jacobian(&j, 1e-6, 0.25, 1.0);
        // Started at 16 ≥ rank: no growth needed beyond at most one doubling.
        assert!(out.schedule.len() <= 2, "schedule {:?}", out.schedule);
    }

    /// Full-rank, slowly decaying kernel at tiny damping: the scheme must
    /// grow the sketch toward the cap.
    #[test]
    fn grows_on_heavy_tailed_kernels() {
        let mut rng = Rng::seed_from(2);
        let mut j = Matrix::zeros(48, 200);
        rng.fill_normal(j.data_mut());
        let out = adaptive_from_jacobian(&j, 1e-10, 0.1, 0.75);
        assert!(
            out.schedule.len() >= 2,
            "expected growth, schedule {:?}",
            out.schedule
        );
        let last = *out.schedule.last().unwrap();
        assert!(last > out.schedule[0]);
        assert_eq!(out.approx.sketch_size(), last);
    }

    /// PSD kernel with a spectral cliff: `head` eigenvalues at `head_val`,
    /// the rest at `tail_val` (K = Q diag(w) Qᵀ).
    fn cliff_psd(rng: &mut Rng, n: usize, head: usize, head_val: f64, tail_val: f64) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let q = crate::linalg::thin_qr(&g);
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let w = if j < head { head_val } else { tail_val };
            for i in 0..n {
                k[(i, j)] = q[(i, j)] * w;
            }
        }
        k.matmul_nt(&q)
    }

    /// The pivot probe is the documented bound: an *upper* bound on the
    /// smallest retained eigenvalue λ_min(BᵀB) (pivots satisfy
    /// λ_min(R) ≤ min L_ii², R = BᵀB + λI), and it must actually consume
    /// λ — the pre-fix probe ignored its `lambda` argument entirely.
    #[test]
    fn pivot_probe_upper_bounds_smallest_retained_eigenvalue() {
        let mut rng = Rng::seed_from(11);
        let a = cliff_psd(&mut rng, 24, 6, 1.0, 1e-7);
        let lam = 1e-5;
        let mut ws = Workspace::new();
        let nys =
            GpuNystrom::build(&crate::optim::kernel::DenseKernel::new(&a), 12, lam, &mut rng, &mut ws)
                .unwrap();
        let tail = min_captured_eigenvalue(&nys, lam);
        let gram = nys.factor().gram();
        let min_eig = crate::linalg::eigh(&gram)
            .eigenvalues
            .iter()
            .fold(f64::INFINITY, |m, &w| m.min(w));
        assert!(
            tail >= min_eig - 1e-9 * (1.0 + min_eig.abs()),
            "pivot bound {tail:.3e} below λ_min(BᵀB) {min_eig:.3e}"
        );
        // λ is subtracted: at huge damping the bound collapses to the
        // clamp floor instead of reporting the raw pivot.
        let big = nys.min_r_pivot().powi(2) * 2.0;
        assert_eq!(min_captured_eigenvalue(&nys, big), 0.0);
    }

    /// Stopping pins to the damping floor: with λ above the kernel's tail
    /// the first (head-covering) sketch suffices; with λ far below the
    /// tail the same kernel must grow the sketch to the cap.
    #[test]
    fn stopping_pins_to_the_damping_floor() {
        let n = 32;
        let head = 6;
        let tail_val = 1e-9;

        // λ well above the tail: captured spectrum has decayed into the
        // regularizer at the first ℓ = 16 ≥ head sketch — no growth.
        let mut rng = Rng::seed_from(21);
        let a = cliff_psd(&mut rng, n, head, 1.0, tail_val);
        let mut ws = Workspace::new();
        let stopped = adaptive_nystrom(
            &crate::optim::kernel::DenseKernel::new(&a),
            1e-6,
            0.5,
            1.0,
            10.0,
            &mut rng,
            &mut ws,
        )
        .unwrap();
        assert_eq!(
            stopped.schedule,
            vec![16],
            "λ=1e-6 > tail {tail_val:.0e}: must stop at the first sketch"
        );

        // Same kernel, λ far below the tail: every retained direction is
        // still live, so the schedule must double to the cap.
        let mut rng = Rng::seed_from(21);
        let a = cliff_psd(&mut rng, n, head, 1.0, tail_val);
        let grown = adaptive_nystrom(
            &crate::optim::kernel::DenseKernel::new(&a),
            1e-12,
            0.5,
            1.0,
            10.0,
            &mut rng,
            &mut ws,
        )
        .unwrap();
        assert_eq!(
            grown.schedule,
            vec![16, 32],
            "λ=1e-12 ≪ tail {tail_val:.0e}: must grow to the cap"
        );
    }

    /// The returned approximation must still be a valid solver.
    #[test]
    fn final_approximation_is_usable() {
        let mut rng = Rng::seed_from(3);
        let mut j = Matrix::zeros(32, 100);
        rng.fill_normal(j.data_mut());
        let lam = 1e-4;
        let out = adaptive_from_jacobian(&j, lam, 0.25, 1.0);
        let mut v = vec![0.0; 32];
        rng.fill_normal(&mut v);
        let x = out.approx.inv_apply(&v);
        assert!(x.iter().all(|xi| xi.is_finite()));
        // PD check: vᵀ(Â+λI)⁻¹v > 0.
        assert!(crate::linalg::dot(&v, &x) > 0.0);
    }
}
