//! Adaptive sketch-rank selection — the paper's §5 future-work item
//! ("our current analysis focuses on fixed Nyström rank, leaving open
//! questions about how sketch dimension and adaptive rank selection affect
//! performance").
//!
//! Heuristic: a sketch of rank ℓ is *sufficient* when the weakest direction
//! it captured is already at the damping floor — i.e. the smallest retained
//! Nyström eigenvalue λ̂_ℓ ≲ c·λ. If instead λ̂_ℓ ≫ λ, the spectrum has not
//! decayed into the regularizer yet and the sketch is truncating live
//! directions (this is exactly the d_eff/N > sketch/N failure mode of
//! Fig. 6); double ℓ and retry, up to `max_ratio·N`.
//!
//! The retained-eigenvalue probe is free on the GPU-efficient factorization:
//! λ̂ bounds follow from the Cholesky pivots of `R = BᵀB + λI`, whose
//! smallest squared pivot tracks the smallest eigenvalue of `BᵀB` within a
//! factor of the (well-conditioned, Gaussian-sketch) basis.
//!
//! Like every Nyström builder, the adaptive scheme consumes a [`KernelOp`]
//! plus a [`Workspace`]: rejected sketches recycle their factors before the
//! next (doubled) attempt, so even the growth loop allocates nothing after
//! the first step at each rank.

use anyhow::Result;

use super::gpu_efficient::GpuNystrom;
use crate::linalg::Workspace;
use crate::optim::kernel::KernelOp;
use crate::rng::Rng;

/// Outcome of the adaptive construction.
pub struct AdaptiveNystrom {
    pub approx: GpuNystrom,
    /// Sketch sizes tried (last = used).
    pub schedule: Vec<usize>,
}

/// Smallest eigenvalue estimate of `BᵀB` from the factorization.
fn min_captured_eigenvalue(nys: &GpuNystrom, lambda: f64, ws: &mut Workspace) -> f64 {
    // R = BᵀB + λI; eigenvalues of BᵀB ≥ min-pivot² of chol(R) − λ (loose but
    // monotone; we only need an order-of-magnitude trigger).
    let b = nys.factor();
    // Rayleigh probe with the last column of B (cheap, deterministic):
    // one strided gather into pooled scratch, then contiguous math.
    let ell = b.cols();
    let mut col = ws.take_scratch(b.rows());
    b.copy_col_into(ell - 1, &mut col);
    let denom = crate::linalg::dot(&col, &col);
    if denom == 0.0 {
        ws.recycle(col);
        return 0.0;
    }
    // ‖B(Bᵀc)‖/‖c‖ underestimates λ_max but for the *trailing* basis vector
    // tracks the tail magnitude; combine with the exact trace/ℓ average.
    let bt_c = b.tr_matvec(&col);
    ws.recycle(col);
    let quad = crate::linalg::dot(&bt_c, &bt_c) / denom;
    let _ = lambda;
    quad.min(denom / ell as f64)
}

/// Build a GPU-efficient Nyström approximation of the operator's kernel
/// (via sketches `Y = J(JᵀΩ)`, never forming K) growing the rank until the
/// captured tail reaches the damping floor.
pub fn adaptive_nystrom(
    op: &dyn KernelOp,
    lambda: f64,
    start_ratio: f64,
    max_ratio: f64,
    tail_factor: f64,
    rng: &mut Rng,
    ws: &mut Workspace,
) -> Result<AdaptiveNystrom> {
    let n = op.size();
    let mut ell = ((n as f64 * start_ratio).round() as usize).clamp(1, n);
    let max_ell = ((n as f64 * max_ratio).round() as usize).clamp(ell, n);
    let mut schedule = Vec::new();
    loop {
        schedule.push(ell);
        let mut omega = ws.take_matrix_scratch(n, ell);
        rng.fill_normal(omega.data_mut());
        let y = op.sketch_y(&omega, ws);
        let approx = GpuNystrom::from_sketch(omega, y, lambda, ws)?;
        let tail = min_captured_eigenvalue(&approx, lambda, ws);
        if tail <= tail_factor * lambda || ell >= max_ell {
            return Ok(AdaptiveNystrom { approx, schedule });
        }
        approx.recycle(ws);
        ell = (ell * 2).min(max_ell);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::nystrom::NystromApprox;
    use crate::optim::kernel::JacobianKernel;

    fn adaptive_from_jacobian(
        j: &Matrix,
        lambda: f64,
        start_ratio: f64,
        max_ratio: f64,
    ) -> AdaptiveNystrom {
        let mut rng = Rng::seed_from(1 + j.rows() as u64);
        let mut ws = Workspace::new();
        adaptive_nystrom(
            &JacobianKernel::new(j),
            lambda,
            start_ratio,
            max_ratio,
            10.0,
            &mut rng,
            &mut ws,
        )
        .unwrap()
    }

    /// Low-rank J: the adaptive scheme should stop quickly (tail hits the
    /// floor once rank is covered).
    #[test]
    fn stops_early_on_low_rank_kernels() {
        let mut rng = Rng::seed_from(1);
        let mut j = Matrix::zeros(64, 8); // K has rank ≤ 8
        rng.fill_normal(j.data_mut());
        let out = adaptive_from_jacobian(&j, 1e-6, 0.25, 1.0);
        // Started at 16 ≥ rank: no growth needed beyond at most one doubling.
        assert!(out.schedule.len() <= 2, "schedule {:?}", out.schedule);
    }

    /// Full-rank, slowly decaying kernel at tiny damping: the scheme must
    /// grow the sketch toward the cap.
    #[test]
    fn grows_on_heavy_tailed_kernels() {
        let mut rng = Rng::seed_from(2);
        let mut j = Matrix::zeros(48, 200);
        rng.fill_normal(j.data_mut());
        let out = adaptive_from_jacobian(&j, 1e-10, 0.1, 0.75);
        assert!(
            out.schedule.len() >= 2,
            "expected growth, schedule {:?}",
            out.schedule
        );
        let last = *out.schedule.last().unwrap();
        assert!(last > out.schedule[0]);
        assert_eq!(out.approx.sketch_size(), last);
    }

    /// The returned approximation must still be a valid solver.
    #[test]
    fn final_approximation_is_usable() {
        let mut rng = Rng::seed_from(3);
        let mut j = Matrix::zeros(32, 100);
        rng.fill_normal(j.data_mut());
        let lam = 1e-4;
        let out = adaptive_from_jacobian(&j, lam, 0.25, 1.0);
        let mut v = vec![0.0; 32];
        rng.fill_normal(&mut v);
        let x = out.approx.inv_apply(&v);
        assert!(x.iter().all(|xi| xi.is_finite()));
        // PD check: vᵀ(Â+λI)⁻¹v > 0.
        assert!(crate::linalg::dot(&v, &x) > 0.0);
    }
}
