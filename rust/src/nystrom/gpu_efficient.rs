//! GPU-efficient randomized Nyström approximation — paper Algorithm 2.
//!
//! Given a PSD kernel operator `A ∈ R^{n×n}`, target rank ℓ and regularizer
//! λ:
//!
//! ```text
//! 1: Ω ← randn(n, ℓ)
//! 2: Y ← A Ω
//! 3: ν ← √n · ulp(‖Y‖_F)          (tiny shift; embeds A + νI)
//! 4: Y_ν ← Y + ν Ω
//! 5: C ← chol(Ωᵀ Y_ν)
//! 6: B ← Y_ν C⁻¹
//! 7: R ← Bᵀ B + λI
//! 8: L ← chol(R)
//! ```
//!
//! yielding `Â = B Bᵀ` (a Nyström approximation of `A + νI`) and the
//! Woodbury-form inverse
//! `(Â + λI)⁻¹ v = v/λ − B (L⁻ᵀ (L⁻¹ (Bᵀ v)))/λ`.
//!
//! Relative to the standard stable algorithm this skips the QR of Ω (Gaussian
//! matrices are well-conditioned w.h.p.) and the SVD of the sketch — the two
//! steps the paper found to dominate wall time on GPU. Everything here is two
//! ℓ×ℓ Cholesky factorizations plus matmuls.
//!
//! The builder consumes a [`KernelOp`] (line 2 is `op.sketch_y`, i.e.
//! `J(JᵀΩ)` on the training path — the kernel is never formed) and draws
//! every buffer from the caller's [`Workspace`]: `Y_ν` is turned into `B` by
//! an in-place triangular solve, the cores are pooled ℓ×ℓ matrices, and
//! [`GpuNystrom::recycle`] returns the factors for the next step.
//!
//! Note on line 3: the paper prints `ν ← exp(‖Y‖_F)`, which cannot be meant
//! literally (it would overwhelm A); following Frangella–Tropp–Udell (whose
//! stable algorithm the paper modifies) we read it as the machine-epsilon
//! shift `ν = √n · eps(‖Y‖_F)`, where `eps(x)` is the ulp spacing at x.

use anyhow::{Context, Result};

use super::NystromApprox;
use crate::linalg::{Cholesky, Matrix, Workspace};
use crate::optim::kernel::KernelOp;
use crate::rng::Rng;

/// Factorized GPU-efficient Nyström approximation.
pub struct GpuNystrom {
    /// `B = Y_ν C⁻¹` (n × ℓ).
    b: Matrix,
    /// Cholesky of `R = BᵀB + λI` (ℓ × ℓ).
    l: Cholesky,
    lambda: f64,
    /// The embedded shift ν (diagnostics).
    pub nu: f64,
}

impl GpuNystrom {
    /// Build from a kernel operator: sample Ω, sketch `Y = AΩ` through the
    /// operator, factorize. Buffers come from (and should eventually return
    /// to) `ws` — see [`GpuNystrom::recycle`].
    // lint: hot-path — per-step Nyström rebuilds draw from the pool (R4).
    pub fn build(
        op: &dyn KernelOp,
        sketch: usize,
        lambda: f64,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<Self> {
        let n = op.size();
        let sketch = sketch.clamp(1, n);

        // 1: Gaussian test matrix Ω (n × ℓ).
        let mut omega = ws.take_matrix_scratch(n, sketch);
        rng.fill_normal(omega.data_mut());

        // 2: Y = A Ω (two tall products on the Jacobian path).
        let y = op.sketch_y(&omega, ws);
        Self::from_sketch(omega, y, lambda, ws)
    }

    /// Build from a precomputed sketch pair (Ω, Y = AΩ). This is the entry
    /// point used by the optimizers on the decomposed path, where `Y = J(JᵀΩ)`
    /// is formed without materializing the kernel (two O(NPℓ) products
    /// instead of the O(N²P) kernel build — the whole point of sketching).
    ///
    /// Consumes both inputs; their storage is recycled into `ws`.
    // lint: hot-path — per-step Nyström rebuilds draw from the pool (R4).
    pub fn from_sketch(
        omega: Matrix,
        y: Matrix,
        lambda: f64,
        ws: &mut Workspace,
    ) -> Result<Self> {
        let n = y.rows();
        let sketch = y.cols();

        // 3–6: the shared ν-escalation core (see `super::sketch_to_factor`):
        // when rank(A) < ℓ the core ΩᵀYν is numerically singular and the ulp
        // shift may not suffice for a strict Cholesky; ν escalates by 10³
        // per retry (still ≪ any eigenvalue of interest) — low-rank inputs
        // are legitimate (Appendix B's test matrix is low-rank by
        // construction). The pooled Y_ν buffer comes back as B = Y_ν C⁻¹.
        let (b, nu) = super::sketch_to_factor(omega, y, "Nyström", ws)?;

        // 7–8: R = BᵀB + λI (fused, pooled), L = chol(R).
        let mut r = ws.take_matrix_scratch(sketch, sketch);
        b.matmul_tn_into(&b, &mut r);
        r.add_diag_in_place(lambda);
        let l = Cholesky::factor_from(r).context("Nyström R = BᵀB+λI is not PD")?;

        debug_assert_eq!(b.rows(), n);
        debug_assert_eq!(b.cols(), sketch);
        Ok(GpuNystrom { b, l, lambda, nu })
    }

    /// The low-rank factor B (n × ℓ).
    pub fn factor(&self) -> &Matrix {
        &self.b
    }

    /// Smallest Cholesky pivot of `R = BᵀB + λI` (the diagonal of L).
    /// Pivots satisfy `λ_min(R) ≤ min_i L_ii²`, so `min-pivot² − λ` is a
    /// monotone upper bound on the smallest retained Nyström eigenvalue
    /// `λ_min(BᵀB)` — free from the factorization, no extra passes. The
    /// adaptive rank schedule ([`super::adaptive`]) triggers on it.
    pub fn min_r_pivot(&self) -> f64 {
        let l = self.l.factor_matrix();
        (0..l.rows()).map(|i| l[(i, i)]).fold(f64::INFINITY, f64::min)
    }

    /// Return the factor storage to the workspace pool (call when the step
    /// is done with the approximation).
    pub fn recycle(self, ws: &mut Workspace) {
        ws.recycle_matrix(self.b);
        ws.recycle_matrix(self.l.into_factor());
    }
}

impl NystromApprox for GpuNystrom {
    /// `(BBᵀ + λI)⁻¹ v = v/λ − B ((BᵀB + λI)⁻¹ Bᵀ v)/λ` (Woodbury again).
    fn inv_apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        let mut ws = Workspace::new();
        self.inv_apply_into(v, &mut out, &mut ws);
        out
    }

    /// Pooled Woodbury application: `Bᵀv`, the ℓ×ℓ solve, and `Bz` all live
    /// in workspace scratch; the final combine runs in place on `out`. Same
    /// per-element arithmetic as the allocating path, so the PCG hot loop
    /// gets the identical preconditioner bitwise with zero allocations.
    fn inv_apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let ell = self.b.cols();
        let mut btv = ws.take_scratch(ell);
        self.b.tr_matvec_into(v, &mut btv);
        let mut z = ws.take_scratch(ell);
        self.l.solve_into(&btv, &mut z);
        self.b.matvec_into(&z, out);
        for (o, vi) in out.iter_mut().zip(v) {
            *o = (vi - *o) / self.lambda;
        }
        ws.recycle(z);
        ws.recycle(btv);
    }

    fn sketch_size(&self) -> usize {
        self.b.cols()
    }

    fn dense_approx(&self) -> Matrix {
        // B Bᵀ is symmetric: gram() does half the flops of matmul_nt(self).
        self.b.gram()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;
    use crate::optim::kernel::DenseKernel;

    /// PSD test matrix with controlled spectral decay: K = G diag(w) Gᵀ.
    fn decaying_psd(rng: &mut Rng, n: usize, decay: f64) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let q = crate::linalg::thin_qr(&g);
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let w = (-decay * j as f64).exp();
            for i in 0..n {
                k[(i, j)] = q[(i, j)] * w;
            }
        }
        k.matmul_nt(&q)
    }

    fn build_dense(
        a: &Matrix,
        sketch: usize,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<GpuNystrom> {
        let mut ws = Workspace::new();
        GpuNystrom::build(&DenseKernel::new(a), sketch, lambda, rng, &mut ws)
    }

    #[test]
    fn full_rank_sketch_is_nearly_exact() {
        let mut rng = Rng::seed_from(1);
        let a = decaying_psd(&mut rng, 40, 0.3);
        let lam = 1e-6;
        let nys = build_dense(&a, 40, lam, &mut rng).unwrap();
        // With ℓ = n the approximation is essentially exact: compare the
        // inverse application against a direct damped solve.
        let mut v = vec![0.0; 40];
        rng.fill_normal(&mut v);
        let direct = Cholesky::factor(&a.add_diag(lam)).unwrap().solve(&v);
        let approx = nys.inv_apply(&v);
        let rel: f64 = direct
            .iter()
            .zip(&approx)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
            / direct.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(rel < 1e-6, "rel={rel}");
    }

    #[test]
    fn approximation_error_decreases_with_sketch() {
        let mut rng = Rng::seed_from(2);
        let a = decaying_psd(&mut rng, 60, 0.25);
        let mut errs = Vec::new();
        for sketch in [5, 15, 40] {
            let nys = build_dense(&a, sketch, 1e-8, &mut rng).unwrap();
            errs.push(a.max_abs_diff(&nys.dense_approx()));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "errs={errs:?}");
    }

    #[test]
    fn dense_approx_is_psd_and_below_a() {
        // Nyström approximations satisfy 0 ⪯ Â ⪯ A (+ν). Check eigenvalues.
        let mut rng = Rng::seed_from(3);
        let a = decaying_psd(&mut rng, 30, 0.2);
        let nys = build_dense(&a, 10, 1e-8, &mut rng).unwrap();
        let approx = nys.dense_approx();
        let e = eigh(&approx);
        assert!(e.eigenvalues.iter().all(|&w| w > -1e-8), "not PSD");
        // residual A − Â should be (near) PSD too.
        let mut resid = a.clone();
        resid.add_scaled(&approx, -1.0);
        let er = eigh(&resid);
        assert!(
            er.eigenvalues.iter().all(|&w| w > -1e-6),
            "Â exceeds A: min resid eig {:?}",
            er.eigenvalues.first()
        );
    }

    #[test]
    fn inv_apply_matches_dense_woodbury() {
        let mut rng = Rng::seed_from(4);
        let a = decaying_psd(&mut rng, 25, 0.4);
        let lam = 1e-3;
        let nys = build_dense(&a, 12, lam, &mut rng).unwrap();
        let dense = nys.dense_approx().add_diag(lam);
        let mut v = vec![0.0; 25];
        rng.fill_normal(&mut v);
        let want = Cholesky::factor(&dense).unwrap().solve(&v);
        let got = nys.inv_apply(&v);
        for (w, g) in want.iter().zip(&got) {
            assert!((w - g).abs() < 1e-7, "{w} vs {g}");
        }
    }

    #[test]
    fn rebuild_from_recycled_workspace_allocates_nothing_new() {
        let mut rng = Rng::seed_from(5);
        let a = decaying_psd(&mut rng, 32, 0.3);
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();

        let nys = GpuNystrom::build(&op, 12, 1e-6, &mut rng, &mut ws).unwrap();
        nys.recycle(&mut ws);
        let fresh_after_first = ws.stats().fresh_allocs;

        let nys = GpuNystrom::build(&op, 12, 1e-6, &mut rng, &mut ws).unwrap();
        nys.recycle(&mut ws);
        assert_eq!(
            ws.stats().fresh_allocs,
            fresh_after_first,
            "second build must reuse every pooled buffer"
        );
        assert!(ws.stats().reuses > 0);
    }
}
