//! GPU-efficient randomized Nyström approximation — paper Algorithm 2.
//!
//! Given PSD `A ∈ R^{n×n}`, target rank ℓ and regularizer λ:
//!
//! ```text
//! 1: Ω ← randn(n, ℓ)
//! 2: Y ← A Ω
//! 3: ν ← √n · ulp(‖Y‖_F)          (tiny shift; embeds A + νI)
//! 4: Y_ν ← Y + ν Ω
//! 5: C ← chol(Ωᵀ Y_ν)
//! 6: B ← Y_ν C⁻¹
//! 7: R ← Bᵀ B + λI
//! 8: L ← chol(R)
//! ```
//!
//! yielding `Â = B Bᵀ` (a Nyström approximation of `A + νI`) and the
//! Woodbury-form inverse
//! `(Â + λI)⁻¹ v = v/λ − B (L⁻ᵀ (L⁻¹ (Bᵀ v)))/λ`.
//!
//! Relative to the standard stable algorithm this skips the QR of Ω (Gaussian
//! matrices are well-conditioned w.h.p.) and the SVD of the sketch — the two
//! steps the paper found to dominate wall time on GPU. Everything here is two
//! ℓ×ℓ Cholesky factorizations plus matmuls.
//!
//! Note on line 3: the paper prints `ν ← exp(‖Y‖_F)`, which cannot be meant
//! literally (it would overwhelm A); following Frangella–Tropp–Udell (whose
//! stable algorithm the paper modifies) we read it as the machine-epsilon
//! shift `ν = √n · eps(‖Y‖_F)`, where `eps(x)` is the ulp spacing at x.

use anyhow::{Context, Result};

use super::NystromApprox;
use crate::linalg::{Cholesky, Matrix};
use crate::rng::Rng;

/// Factorized GPU-efficient Nyström approximation.
pub struct GpuNystrom {
    /// `B = Y_ν C⁻¹` (n × ℓ).
    b: Matrix,
    /// Cholesky of `R = BᵀB + λI` (ℓ × ℓ).
    l: Cholesky,
    lambda: f64,
    /// The embedded shift ν (diagnostics).
    pub nu: f64,
}

impl GpuNystrom {
    /// Build from an explicit PSD matrix.
    pub fn build(a: &Matrix, sketch: usize, lambda: f64, rng: &mut Rng) -> Result<Self> {
        let n = a.rows();
        assert_eq!(a.rows(), a.cols(), "Nyström needs a square PSD matrix");
        let sketch = sketch.clamp(1, n);

        // 1: Gaussian test matrix Ω (n × ℓ).
        let mut omega = Matrix::zeros(n, sketch);
        rng.fill_normal(omega.data_mut());

        // 2: Y = A Ω.
        let y = a.matmul(&omega);
        Self::from_sketch(omega, y, lambda)
    }

    /// Build from a precomputed sketch pair (Ω, Y = AΩ). This is the entry
    /// point used by the optimizers on the decomposed path, where `Y = J(JᵀΩ)`
    /// is formed without materializing the kernel (two O(NPℓ) products
    /// instead of the O(N²P) kernel build — the whole point of sketching).
    pub fn from_sketch(omega: Matrix, y: Matrix, lambda: f64) -> Result<Self> {
        let n = y.rows();
        let sketch = y.cols();

        // 3–4: tiny shift for numerical PD-ness, embedded as A + νI.
        //
        // When rank(A) < ℓ the core ΩᵀYν is numerically singular and the ulp
        // shift may not suffice for a strict Cholesky; escalate ν by 10³ per
        // retry (still ≪ any eigenvalue of interest) until the factorization
        // succeeds — low-rank inputs are legitimate (Appendix B's test matrix
        // is low-rank by construction).
        let base_nu = (n as f64).sqrt() * ulp(y.frobenius_norm());
        let mut attempt = 0;
        let (y_nu, c, nu) = loop {
            let nu = base_nu * 1000f64.powi(attempt);
            let mut y_nu = y.clone();
            y_nu.add_scaled(&omega, nu);
            // 5: C = chol(Ωᵀ Y_ν), symmetrized first: it equals Ωᵀ(A+νI)Ω in
            // exact arithmetic but floating point leaves skew parts.
            let mut core = omega.transpose().matmul(&y_nu);
            symmetrize(&mut core);
            match Cholesky::factor(&core) {
                Ok(c) => break (y_nu, c, nu),
                Err(e) if attempt < 5 => {
                    let _ = e;
                    attempt += 1;
                }
                Err(e) => {
                    return Err(e).context(
                        "Nyström core ΩᵀYν is not PD even after ν escalation",
                    )
                }
            }
        };

        // 6: B = Y_ν C⁻¹ with C = Lᵀ (upper). Solve B Lᵀ = Y_ν row-wise.
        let b = c.right_solve_transpose(&y_nu);

        // 7–8: R = BᵀB + λI, L = chol(R).
        let r = b.transpose().matmul(&b).add_diag(lambda);
        let l = Cholesky::factor(&r).context("Nyström R = BᵀB+λI is not PD")?;

        debug_assert_eq!(b.rows(), n);
        debug_assert_eq!(b.cols(), sketch);
        Ok(GpuNystrom { b, l, lambda, nu })
    }

    /// The low-rank factor B (n × ℓ).
    pub fn factor(&self) -> &Matrix {
        &self.b
    }
}

impl NystromApprox for GpuNystrom {
    /// `(BBᵀ + λI)⁻¹ v = v/λ − B ((BᵀB + λI)⁻¹ Bᵀ v)/λ` (Woodbury again).
    fn inv_apply(&self, v: &[f64]) -> Vec<f64> {
        let btv = self.b.tr_matvec(v);
        let z = self.l.solve(&btv);
        let bz = self.b.matvec(&z);
        v.iter()
            .zip(&bz)
            .map(|(vi, bzi)| (vi - bzi) / self.lambda)
            .collect()
    }

    fn sketch_size(&self) -> usize {
        self.b.cols()
    }

    fn dense_approx(&self) -> Matrix {
        self.b.matmul(&self.b.transpose())
    }
}

/// Unit in the last place at magnitude `x` (the `eps(x)` of line 3).
fn ulp(x: f64) -> f64 {
    if x == 0.0 {
        return f64::MIN_POSITIVE;
    }
    let bits = x.abs().to_bits();
    f64::from_bits(bits + 1) - x.abs()
}

fn symmetrize(m: &mut Matrix) {
    let n = m.rows();
    for i in 0..n {
        for j in i + 1..n {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh;

    /// PSD test matrix with controlled spectral decay: K = G diag(w) Gᵀ.
    fn decaying_psd(rng: &mut Rng, n: usize, decay: f64) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let q = crate::linalg::thin_qr(&g);
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let w = (-decay * j as f64).exp();
            for i in 0..n {
                k[(i, j)] = q[(i, j)] * w;
            }
        }
        k.matmul(&q.transpose())
    }

    #[test]
    fn full_rank_sketch_is_nearly_exact() {
        let mut rng = Rng::seed_from(1);
        let a = decaying_psd(&mut rng, 40, 0.3);
        let lam = 1e-6;
        let nys = GpuNystrom::build(&a, 40, lam, &mut rng).unwrap();
        // With ℓ = n the approximation is essentially exact: compare the
        // inverse application against a direct damped solve.
        let mut v = vec![0.0; 40];
        rng.fill_normal(&mut v);
        let direct = Cholesky::factor(&a.add_diag(lam)).unwrap().solve(&v);
        let approx = nys.inv_apply(&v);
        let rel: f64 = direct
            .iter()
            .zip(&approx)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f64::max)
            / direct.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert!(rel < 1e-6, "rel={rel}");
    }

    #[test]
    fn approximation_error_decreases_with_sketch() {
        let mut rng = Rng::seed_from(2);
        let a = decaying_psd(&mut rng, 60, 0.25);
        let mut errs = Vec::new();
        for sketch in [5, 15, 40] {
            let nys = GpuNystrom::build(&a, sketch, 1e-8, &mut rng).unwrap();
            errs.push(a.max_abs_diff(&nys.dense_approx()));
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "errs={errs:?}");
    }

    #[test]
    fn dense_approx_is_psd_and_below_a() {
        // Nyström approximations satisfy 0 ⪯ Â ⪯ A (+ν). Check eigenvalues.
        let mut rng = Rng::seed_from(3);
        let a = decaying_psd(&mut rng, 30, 0.2);
        let nys = GpuNystrom::build(&a, 10, 1e-8, &mut rng).unwrap();
        let approx = nys.dense_approx();
        let e = eigh(&approx);
        assert!(e.eigenvalues.iter().all(|&w| w > -1e-8), "not PSD");
        // residual A − Â should be (near) PSD too.
        let mut resid = a.clone();
        resid.add_scaled(&approx, -1.0);
        let er = eigh(&resid);
        assert!(
            er.eigenvalues.iter().all(|&w| w > -1e-6),
            "Â exceeds A: min resid eig {:?}",
            er.eigenvalues.first()
        );
    }

    #[test]
    fn inv_apply_matches_dense_woodbury() {
        let mut rng = Rng::seed_from(4);
        let a = decaying_psd(&mut rng, 25, 0.4);
        let lam = 1e-3;
        let nys = GpuNystrom::build(&a, 12, lam, &mut rng).unwrap();
        let dense = nys.dense_approx().add_diag(lam);
        let mut v = vec![0.0; 25];
        rng.fill_normal(&mut v);
        let want = Cholesky::factor(&dense).unwrap().solve(&v);
        let got = nys.inv_apply(&v);
        for (w, g) in want.iter().zip(&got) {
            assert!((w - g).abs() < 1e-7, "{w} vs {g}");
        }
    }

    #[test]
    fn ulp_is_tiny_but_positive() {
        assert!(ulp(1.0) > 0.0 && ulp(1.0) < 1e-15);
        assert!(ulp(1e10) < 1e-5);
        assert!(ulp(0.0) > 0.0);
    }
}
