//! Randomized Nyström approximations of the regularized kernel matrix
//! (paper §3.3–§3.4).
//!
//! * [`gpu_efficient`] — the paper's Algorithm 2: Cholesky-only
//!   sketch-and-solve, skipping the QR of Ω and the SVD of the sketch.
//! * [`stable`] — the standard stable Nyström of Frangella–Tropp–Udell
//!   (alg. 2.1), the baseline of the paper's Appendix-B benchmark. Its
//!   SVD-class factorization is our Jacobi `eigh` (DESIGN.md §Substitutions).
//! * [`effective_dim`] — d_eff(A) = Tr(A (A+λI)⁻¹) (paper §3.4), computed
//!   exactly via a Cholesky inverse-trace, plus the spectral variant.

mod adaptive;
mod effective_dim;
mod gpu_efficient;
mod pcg;
mod stable;

pub use adaptive::{adaptive_nystrom_from_jacobian, AdaptiveNystrom};
pub use effective_dim::{effective_dimension, effective_dimension_spectral};
pub use gpu_efficient::GpuNystrom;
pub use pcg::{nystrom_pcg, PcgOutcome};
pub use stable::StableNystrom;

/// Common interface: a factorized approximation of `A_nys + λI` that can
/// apply its inverse to vectors (the only operation the optimizers need).
pub trait NystromApprox {
    /// Apply `(Â + λI)⁻¹ v`.
    fn inv_apply(&self, v: &[f64]) -> Vec<f64>;

    /// The sketch size actually used.
    fn sketch_size(&self) -> usize;

    /// Reconstruct the dense approximation `Â` (tests / diagnostics only).
    fn dense_approx(&self) -> crate::linalg::Matrix;
}
