//! Randomized Nyström approximations of the regularized kernel matrix
//! (paper §3.3–§3.4).
//!
//! * [`gpu_efficient`] — the paper's Algorithm 2: Cholesky-only
//!   sketch-and-solve, skipping the QR of Ω and the SVD of the sketch.
//! * [`stable`] — the standard stable Nyström of Frangella–Tropp–Udell
//!   (alg. 2.1), the baseline of the paper's Appendix-B benchmark. Its
//!   SVD-class factorization is our Jacobi `eigh` (DESIGN.md §Substitutions).
//! * [`effective_dim`] — d_eff(A) = Tr(A (A+λI)⁻¹) (paper §3.4), computed
//!   exactly via a Cholesky inverse-trace, plus the spectral variant.
//!
//! Every builder consumes a [`crate::optim::kernel::KernelOp`] (the kernel
//! is sketched through the operator, never formed) plus a
//! [`crate::linalg::Workspace`] whose buffers it checks out and — via each
//! type's `recycle` — returns for reuse on the next training step.
//!
//! Application is pooled too: [`NystromApprox::inv_apply_into`] writes the
//! damped inverse into a caller buffer with interior scratch drawn from the
//! workspace, so the PCG hot loop ([`nystrom_pcg`]) and `kernel_solve`'s
//! sketch-and-solve branches run allocation-free at steady state. The
//! allocating [`NystromApprox::inv_apply`] remains for tests/benches.

mod adaptive;
mod effective_dim;
mod gpu_efficient;
mod pcg;
mod stable;

pub use adaptive::{adaptive_nystrom, AdaptiveNystrom};
pub use effective_dim::{effective_dimension, effective_dimension_spectral};
pub use gpu_efficient::GpuNystrom;
pub use pcg::{nystrom_pcg, PcgOutcome};
pub use stable::StableNystrom;

use anyhow::{Context, Result};

use crate::linalg::{Cholesky, Matrix, Workspace};

/// Shared ν-escalation core of both Nyström builders (Algorithm 2 lines
/// 3–6 / alg. 2.1 lines 3–5): embed `A + νI` via `Y_ν = Y + νΩ`, factor the
/// sketch core `ΩᵀY_ν`, and turn `Y_ν` into `B = Y_ν C⁻¹` by an in-place
/// triangular solve — escalating ν by 10³ per attempt when rank-deficient
/// sketches leave the core numerically non-PD.
///
/// Consumes (Ω, Y) and recycles both into `ws`; the returned B lives in
/// pooled storage (rejected attempts recycle theirs before retrying).
/// Returns `(B, ν)`.
pub(crate) fn sketch_to_factor(
    omega: Matrix,
    y: Matrix,
    tag: &str,
    ws: &mut Workspace,
) -> Result<(Matrix, f64)> {
    let n = y.rows();
    let sketch = y.cols();
    let base_nu = (n as f64).sqrt() * ulp(y.frobenius_norm());
    let mut attempt = 0;
    let (mut b, c, nu) = loop {
        let nu = base_nu * 1000f64.powi(attempt);
        let mut y_nu = ws.take_matrix_scratch(n, sketch);
        y_nu.data_mut().copy_from_slice(y.data());
        y_nu.add_scaled(&omega, nu);
        // Core C = chol(Ωᵀ Y_ν) — fused transpose product into a pooled
        // ℓ×ℓ buffer, symmetrized first: it equals Ωᵀ(A+νI)Ω in exact
        // arithmetic but floating point leaves skew parts.
        let mut core = ws.take_matrix_scratch(sketch, sketch);
        omega.matmul_tn_into(&y_nu, &mut core);
        symmetrize(&mut core);
        match Cholesky::factor_from_recoverable(core) {
            Ok(c) => break (y_nu, c, nu),
            Err((core, _)) if attempt < 5 => {
                // Keep the pooled buffers alive across the retry.
                ws.recycle_matrix(core);
                ws.recycle_matrix(y_nu);
                attempt += 1;
            }
            Err((core, e)) => {
                ws.recycle_matrix(core);
                ws.recycle_matrix(y_nu);
                return Err(e).with_context(|| {
                    format!("{tag} core ΩᵀYν is not PD even after ν escalation")
                });
            }
        }
    };
    ws.recycle_matrix(y);
    ws.recycle_matrix(omega);

    // B = Y_ν C⁻¹ with C = Lᵀ (upper): in-place row-wise solve, so the
    // pooled Y_ν buffer *becomes* B.
    c.right_solve_transpose_in_place(&mut b);
    ws.recycle_matrix(c.into_factor());
    Ok((b, nu))
}

/// Unit in the last place at magnitude `x` (the `eps(x)` of the ν shift).
pub(crate) fn ulp(x: f64) -> f64 {
    if x == 0.0 {
        return f64::MIN_POSITIVE;
    }
    let bits = x.abs().to_bits();
    f64::from_bits(bits + 1) - x.abs()
}

pub(crate) fn symmetrize(m: &mut Matrix) {
    let n = m.rows();
    for i in 0..n {
        for j in i + 1..n {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ulp_is_tiny_but_positive() {
        assert!(ulp(1.0) > 0.0 && ulp(1.0) < 1e-15);
        assert!(ulp(1e10) < 1e-5);
        assert!(ulp(0.0) > 0.0);
    }

    #[test]
    fn sketch_to_factor_handles_low_rank_sketches() {
        use crate::rng::Rng;
        let mut rng = Rng::seed_from(1);
        // Rank-3 kernel sketched at width 8: the core is singular at the
        // base ν, forcing the escalation path — it must still factor and
        // keep the workspace pool balanced.
        let mut j = Matrix::zeros(20, 3);
        rng.fill_normal(j.data_mut());
        let a = j.gram();
        let mut ws = Workspace::new();
        let mut omega = ws.take_matrix_scratch(20, 8);
        rng.fill_normal(omega.data_mut());
        let y = a.matmul(&omega);
        let (b, nu) = sketch_to_factor(omega, y, "test", &mut ws).unwrap();
        assert_eq!((b.rows(), b.cols()), (20, 8));
        assert!(nu > 0.0);
        assert!(b.data().iter().all(|x| x.is_finite()));
    }
}

/// Common interface: a factorized approximation of `A_nys + λI` that can
/// apply its inverse to vectors (the only operation the optimizers need).
pub trait NystromApprox {
    /// Apply `(Â + λI)⁻¹ v`.
    fn inv_apply(&self, v: &[f64]) -> Vec<f64>;

    /// Pooled `(Â + λI)⁻¹ v` into `out`, interior scratch drawn from `ws` —
    /// the preconditioner application of the PCG hot loop. The default
    /// falls back to the allocating form; the shipped factorizations
    /// override it with allocation-free paths that match bitwise.
    fn inv_apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let _ = ws;
        out.copy_from_slice(&self.inv_apply(v));
    }

    /// The sketch size actually used.
    fn sketch_size(&self) -> usize;

    /// Reconstruct the dense approximation `Â` (tests / diagnostics only).
    fn dense_approx(&self) -> crate::linalg::Matrix;
}
