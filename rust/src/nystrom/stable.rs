//! Standard *stable* randomized Nyström approximation
//! (Frangella–Tropp–Udell, SIAM J. Matrix Anal. 2023, algorithm 2.1) —
//! the baseline the paper's GPU-efficient Algorithm 2 is benchmarked against
//! (Appendix B).
//!
//! ```text
//! 1: Ω ← qr_econ(randn(n, ℓ)).Q        ← the QR Algorithm 2 skips
//! 2: Y ← A Ω
//! 3: ν ← √n · eps(‖Y‖₂);  Y_ν ← Y + νΩ
//! 4: C ← chol(Ωᵀ Y_ν)
//! 5: B ← Y_ν C⁻¹
//! 6: [U, Σ, ~] ← svd_econ(B)           ← the SVD Algorithm 2 skips
//! 7: Λ ← max(0, Σ² − νI)
//! ```
//!
//! yielding `Â = U Λ Uᵀ` and the exact damped inverse
//! `(Â + λI)⁻¹ = U ((Λ+λ)⁻¹ − λ⁻¹) Uᵀ + λ⁻¹ I`.
//!
//! The economy SVD of B (n × ℓ) is computed from the eigendecomposition of
//! the ℓ×ℓ Gram matrix BᵀB via our Jacobi `eigh` — the SVD-class
//! factorization whose cost Appendix B measures (DESIGN.md §Substitutions).
//!
//! Like the GPU-efficient builder, this one consumes a [`KernelOp`] + a
//! [`Workspace`]: all transpose products are fused (`matmul_tn`), `Y_ν`
//! becomes `B` by an in-place triangular solve, and intermediates —
//! including the QR and eigendecomposition interiors, via `thin_qr_into` /
//! `eigh_into` — return to the pool, so steady-state stable-Nyström solves
//! allocate nothing dense.

use anyhow::Result;

use super::NystromApprox;
use crate::linalg::{eigh_into, thin_qr_into, Matrix, Workspace};
use crate::optim::kernel::KernelOp;
use crate::rng::Rng;

/// Eigendecomposition-form stable Nyström approximation.
pub struct StableNystrom {
    /// U (n × ℓ), orthonormal columns.
    u: Matrix,
    /// Λ (ℓ), nonnegative.
    lam_diag: Vec<f64>,
    lambda: f64,
    pub nu: f64,
}

impl StableNystrom {
    /// Build from a kernel operator: orthonormal test matrix, operator
    /// sketch, eigendecomposition.
    // lint: hot-path — per-step Nyström rebuilds draw from the pool (R4).
    pub fn build(
        op: &dyn KernelOp,
        sketch: usize,
        lambda: f64,
        rng: &mut Rng,
        ws: &mut Workspace,
    ) -> Result<Self> {
        let n = op.size();
        let sketch = sketch.clamp(1, n);

        // 1: orthonormal test matrix (QR interiors pooled too).
        let mut g = ws.take_matrix_scratch(n, sketch);
        rng.fill_normal(g.data_mut());
        let mut omega = ws.take_matrix_scratch(n, sketch);
        thin_qr_into(&g, &mut omega, ws);
        ws.recycle_matrix(g);

        // 2: sketch through the operator.
        let y = op.sketch_y(&omega, ws);
        Self::from_sketch(omega, y, lambda, ws)
    }

    /// Build from a precomputed (orthonormal Ω, Y = AΩ) pair. Consumes both;
    /// their storage is recycled into `ws`.
    // lint: hot-path — per-step Nyström rebuilds draw from the pool (R4).
    pub fn from_sketch(
        omega: Matrix,
        y: Matrix,
        lambda: f64,
        ws: &mut Workspace,
    ) -> Result<Self> {
        let n = y.rows();
        let sketch = y.cols();

        // 3–5: the shared ν-escalation core (`super::sketch_to_factor`):
        // embed A+νI, factor the core, solve B = Y_ν C⁻¹ in place over the
        // pooled buffer.
        let (b, nu) = super::sketch_to_factor(omega, y, "stable Nyström", ws)?;

        // 6: economy SVD of B from eigh(BᵀB): BᵀB = V Σ² Vᵀ, U = B V Σ⁻¹
        // (eigh interiors pooled via eigh_into).
        let mut btb = ws.take_matrix_scratch(sketch, sketch);
        b.matmul_tn_into(&b, &mut btb);
        let mut evals = ws.take(sketch);
        let mut evecs = ws.take_matrix_scratch(sketch, sketch);
        eigh_into(&btb, &mut evals, &mut evecs, ws);
        ws.recycle_matrix(btb);
        let ell = sketch;
        // Descending order is conventional for SVD; eigh returns ascending.
        let mut u = ws.take_matrix(n, ell);
        let mut lam_diag = ws.take(ell);
        let mut bv = ws.take_matrix_scratch(n, ell);
        b.matmul_into(&evecs, &mut bv);
        ws.recycle_matrix(evecs);
        for (col, k) in (0..ell).rev().enumerate() {
            let sigma2 = evals[k].max(0.0);
            let sigma = sigma2.sqrt();
            // 7: Λ = max(0, Σ² − ν).
            lam_diag[col] = (sigma2 - nu).max(0.0);
            if sigma > 0.0 {
                for i in 0..n {
                    u[(i, col)] = bv[(i, k)] / sigma;
                }
            }
        }
        ws.recycle(evals);
        ws.recycle_matrix(bv);
        ws.recycle_matrix(b);
        Ok(StableNystrom {
            u,
            lam_diag,
            lambda,
            nu,
        })
    }

    /// The approximation's eigenvalues (descending).
    pub fn eigenvalues(&self) -> &[f64] {
        &self.lam_diag
    }

    /// Return the eigenvector and eigenvalue storage to the workspace pool.
    pub fn recycle(self, ws: &mut Workspace) {
        ws.recycle_matrix(self.u);
        ws.recycle(self.lam_diag);
    }
}

impl NystromApprox for StableNystrom {
    /// `(UΛUᵀ + λI)⁻¹ v = U ((Λ+λ)⁻¹ − λ⁻¹) Uᵀ v + v / λ`.
    fn inv_apply(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        let mut ws = Workspace::new();
        self.inv_apply_into(v, &mut out, &mut ws);
        out
    }

    /// Pooled application: `Uᵀv` is rescaled in place in its scratch buffer
    /// and `U (…)` lands directly in `out`, which the final combine then
    /// rewrites — the same per-element arithmetic as the allocating path
    /// with zero allocations at steady state.
    fn inv_apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let ell = self.lam_diag.len();
        let mut utv = ws.take_scratch(ell);
        self.u.tr_matvec_into(v, &mut utv);
        for (x, &w) in utv.iter_mut().zip(&self.lam_diag) {
            *x *= 1.0 / (w + self.lambda) - 1.0 / self.lambda;
        }
        self.u.matvec_into(&utv, out);
        for (o, vi) in out.iter_mut().zip(v) {
            *o = vi / self.lambda + *o;
        }
        ws.recycle(utv);
    }

    fn sketch_size(&self) -> usize {
        self.lam_diag.len()
    }

    fn dense_approx(&self) -> Matrix {
        let mut ul = self.u.clone();
        for j in 0..self.lam_diag.len() {
            let w = self.lam_diag[j];
            for i in 0..ul.rows() {
                ul[(i, j)] *= w;
            }
        }
        ul.matmul_nt(&self.u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{thin_qr, Cholesky};
    use crate::optim::kernel::DenseKernel;

    fn decaying_psd(rng: &mut Rng, n: usize, decay: f64) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let q = thin_qr(&g);
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let w = (-decay * j as f64).exp();
            for i in 0..n {
                k[(i, j)] = q[(i, j)] * w;
            }
        }
        k.matmul_nt(&q)
    }

    fn build_dense(
        a: &Matrix,
        sketch: usize,
        lambda: f64,
        rng: &mut Rng,
    ) -> Result<StableNystrom> {
        let mut ws = Workspace::new();
        StableNystrom::build(&DenseKernel::new(a), sketch, lambda, rng, &mut ws)
    }

    #[test]
    fn full_sketch_recovers_matrix() {
        let mut rng = Rng::seed_from(1);
        let a = decaying_psd(&mut rng, 30, 0.3);
        let nys = build_dense(&a, 30, 1e-8, &mut rng).unwrap();
        assert!(a.max_abs_diff(&nys.dense_approx()) < 1e-7);
    }

    #[test]
    fn inv_apply_matches_dense_solve() {
        let mut rng = Rng::seed_from(2);
        let a = decaying_psd(&mut rng, 25, 0.4);
        let lam = 1e-3;
        let nys = build_dense(&a, 12, lam, &mut rng).unwrap();
        let dense = nys.dense_approx().add_diag(lam);
        let mut v = vec![0.0; 25];
        rng.fill_normal(&mut v);
        let want = Cholesky::factor(&dense).unwrap().solve(&v);
        let got = nys.inv_apply(&v);
        for (w, g) in want.iter().zip(&got) {
            assert!((w - g).abs() < 1e-7, "{w} vs {g}");
        }
    }

    #[test]
    fn gpu_and_stable_agree_on_easy_spectra() {
        // With a strongly decaying spectrum and a generous sketch, the two
        // variants should produce nearly identical approximations — this is
        // the paper's claim that skipping QR/SVD costs little accuracy.
        let mut rng = Rng::seed_from(3);
        let a = decaying_psd(&mut rng, 40, 0.5);
        let mut ws = Workspace::new();
        let op = DenseKernel::new(&a);
        let stable = StableNystrom::build(&op, 25, 1e-6, &mut rng, &mut ws).unwrap();
        let gpu = super::super::GpuNystrom::build(&op, 25, 1e-6, &mut rng, &mut ws).unwrap();
        let d = stable.dense_approx().max_abs_diff(&gpu.dense_approx());
        let scale = a.frobenius_norm();
        assert!(d / scale < 1e-4, "relative divergence {}", d / scale);
    }

    #[test]
    fn eigenvalues_are_nonnegative_descending() {
        let mut rng = Rng::seed_from(4);
        let a = decaying_psd(&mut rng, 30, 0.2);
        let nys = build_dense(&a, 15, 1e-8, &mut rng).unwrap();
        let w = nys.eigenvalues();
        assert!(w.iter().all(|&x| x >= 0.0));
        for k in 1..w.len() {
            assert!(w[k - 1] >= w[k] - 1e-12);
        }
    }
}
