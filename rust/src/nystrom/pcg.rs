//! Sketch-and-precondition: Nyström-preconditioned conjugate gradients
//! (Frangella–Tropp–Udell's motivating application, discussed by the paper
//! in §3.3).
//!
//! The paper *rejects* this approach for PINNs: each CG iteration needs a
//! matvec with the kernel `K = J Jᵀ`, which on the fused path would require
//! extra differentiation passes through the PDE operator L, "nullifying any
//! performance benefit". On our decomposed path the matvec is the
//! [`KernelOp::apply`] pair `J(Jᵀv)` (O(NP) each) — still the dominant
//! cost, so the bench (`ablations`) reproduces the paper's conclusion
//! quantitatively: the preconditioner slashes the iteration count but each
//! iteration costs as much as the whole sketch, so sketch-and-solve wins at
//! equal budget.

use anyhow::Result;

use super::NystromApprox;
use crate::linalg::Workspace;
use crate::optim::kernel::KernelOp;

/// Outcome of a preconditioned CG solve.
#[derive(Debug, Clone)]
pub struct PcgOutcome {
    /// The solution; its storage is drawn from the caller's [`Workspace`],
    /// so recycle it when done.
    pub x: Vec<f64>,
    pub iterations: usize,
    pub rel_residual: f64,
    pub converged: bool,
}

/// Solve `(K + λI) x = b` with CG preconditioned by `(Â_nys + λI)⁻¹`,
/// where `K` is applied through the operator (`op.apply_into(v) = J(Jᵀv)`
/// on the training path — the kernel is never formed) and `precond` is any
/// [`NystromApprox`].
///
/// Every loop buffer (x, r, z, p, Kp) and all operator/preconditioner
/// scratch come from `ws`, so steady-state iterations allocate nothing; the
/// iterates are bitwise-identical to the historical allocating loop.
pub fn nystrom_pcg(
    op: &dyn KernelOp,
    lambda: f64,
    precond: &dyn NystromApprox,
    b: &[f64],
    max_iters: usize,
    tol: f64,
    ws: &mut Workspace,
) -> Result<PcgOutcome> {
    let n = b.len();
    let bnorm = crate::linalg::norm2(b);
    if bnorm == 0.0 {
        return Ok(PcgOutcome {
            x: ws.take(n),
            iterations: 0,
            rel_residual: 0.0,
            converged: true,
        });
    }
    let mut x = ws.take(n);
    let mut r = ws.take_scratch(n);
    r.copy_from_slice(b);
    let mut z = ws.take_scratch(n);
    precond.inv_apply_into(&r, &mut z, ws);
    let mut p = ws.take_scratch(n);
    p.copy_from_slice(&z);
    let mut ap = ws.take_scratch(n);
    let mut rz = crate::linalg::dot(&r, &z);

    let mut iterations = 0;
    let mut rnorm = bnorm;
    for _ in 0..max_iters {
        // ap = (K + λI) p, pooled.
        op.apply_into(&p, &mut ap, ws);
        for (kvi, vi) in ap.iter_mut().zip(&p) {
            *kvi += lambda * vi;
        }
        let pap = crate::linalg::dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            break;
        }
        let alpha = rz / pap;
        crate::linalg::axpy(alpha, &p, &mut x);
        crate::linalg::axpy(-alpha, &ap, &mut r);
        iterations += 1;
        rnorm = crate::linalg::norm2(&r);
        if rnorm <= tol * bnorm {
            break;
        }
        precond.inv_apply_into(&r, &mut z, ws);
        let rz_new = crate::linalg::dot(&r, &z);
        let beta = rz_new / rz;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
        rz = rz_new;
    }
    ws.recycle(ap);
    ws.recycle(p);
    ws.recycle(z);
    ws.recycle(r);
    let rel = rnorm / bnorm;
    Ok(PcgOutcome {
        x,
        iterations,
        rel_residual: rel,
        converged: rel <= tol,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cg_solve, Cholesky, Matrix, Workspace};
    use crate::nystrom::GpuNystrom;
    use crate::optim::kernel::DenseKernel;
    use crate::rng::Rng;

    fn decaying_psd(rng: &mut Rng, n: usize, decay: f64) -> Matrix {
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let q = crate::linalg::thin_qr(&g);
        let mut k = Matrix::zeros(n, n);
        for j in 0..n {
            let w = (-decay * j as f64).exp();
            for i in 0..n {
                k[(i, j)] = q[(i, j)] * w;
            }
        }
        k.matmul_nt(&q)
    }

    #[test]
    fn pcg_matches_direct_solve() {
        let mut rng = Rng::seed_from(1);
        let a = decaying_psd(&mut rng, 50, 0.15);
        let lam = 1e-6;
        let damped = a.add_diag(lam);
        let mut b = vec![0.0; 50];
        rng.fill_normal(&mut b);
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let pre = GpuNystrom::build(&op, 25, lam, &mut rng, &mut ws).unwrap();
        let out = nystrom_pcg(&op, lam, &pre, &b, 200, 1e-10, &mut ws).unwrap();
        assert!(out.converged, "rel = {}", out.rel_residual);
        let direct = Cholesky::factor(&damped).unwrap().solve(&b);
        for (x, d) in out.x.iter().zip(&direct) {
            assert!((x - d).abs() < 1e-6 * (1.0 + d.abs()), "{x} vs {d}");
        }
    }

    #[test]
    fn preconditioning_cuts_iteration_count() {
        // Ill-conditioned kernel: plain CG needs many iterations; the
        // Nyström-preconditioned solve should converge in far fewer — the
        // Frangella–Tropp–Udell effect the paper discusses.
        let mut rng = Rng::seed_from(2);
        let a = decaying_psd(&mut rng, 80, 0.2);
        let lam = 1e-8;
        let damped = a.add_diag(lam);
        let mut b = vec![0.0; 80];
        rng.fill_normal(&mut b);

        let plain = cg_solve(|v| damped.matvec(v), &b, 500, 1e-8);
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let pre = GpuNystrom::build(&op, 40, lam, &mut rng, &mut ws).unwrap();
        let pcg = nystrom_pcg(&op, lam, &pre, &b, 500, 1e-8, &mut ws).unwrap();
        assert!(pcg.converged);
        assert!(
            pcg.iterations * 2 < plain.iterations.max(2),
            "pcg {} vs plain {}",
            pcg.iterations,
            plain.iterations
        );
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let mut rng = Rng::seed_from(3);
        let a = decaying_psd(&mut rng, 10, 0.5);
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let pre = GpuNystrom::build(&op, 5, 1e-4, &mut rng, &mut ws).unwrap();
        let out = nystrom_pcg(&op, 1e-4, &pre, &[0.0; 10], 10, 1e-10, &mut ws).unwrap();
        assert!(out.converged);
        assert_eq!(out.iterations, 0);
    }

    #[test]
    fn repeated_solves_allocate_nothing_at_steady_state() {
        let mut rng = Rng::seed_from(4);
        let a = decaying_psd(&mut rng, 40, 0.2);
        let lam = 1e-6;
        let mut b = vec![0.0; 40];
        rng.fill_normal(&mut b);
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let pre = GpuNystrom::build(&op, 20, lam, &mut rng, &mut ws).unwrap();

        let out = nystrom_pcg(&op, lam, &pre, &b, 100, 1e-10, &mut ws).unwrap();
        ws.recycle(out.x);
        let frozen = (ws.stats().fresh_allocs, ws.stats().grown);

        let out2 = nystrom_pcg(&op, lam, &pre, &b, 100, 1e-10, &mut ws).unwrap();
        ws.recycle(out2.x);
        assert_eq!(
            (ws.stats().fresh_allocs, ws.stats().grown),
            frozen,
            "second PCG solve touched the allocator"
        );
        assert!(out2.iterations > 0);
    }
}
