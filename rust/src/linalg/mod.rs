//! Dense f64 linear algebra substrate (nalgebra/LAPACK are unavailable
//! offline; the paper's algorithms are all dense kernels over matrices that
//! fit comfortably in memory once the Woodbury identity moves the solve into
//! sample space).
//!
//! Contents:
//! * [`Matrix`] — row-major dense matrix with blocked, multi-threaded
//!   products (`matmul`, `gram`, `matvec`, ...), each with a pooled
//!   `*_into` twin (`matvec_into`, `tr_matvec_into`) that writes into
//!   caller-provided buffers with bitwise-identical arithmetic.
//! * [`ops`] — fused BLAS-style transpose products (`matmul_tn` = AᵀB,
//!   `matmul_nt` = ABᵀ, `gram_t` = AᵀA) plus `*_into` variants writing to
//!   caller-provided buffers; no transpose is ever materialized. The
//!   `*_fast` variants are the opt-in f32-compute/f64-accumulate tier of
//!   `--numerics fast`.
//! * [`Workspace`] — the step-buffer pool the trainer threads through
//!   `StepEnv` so per-step Gram/sketch/factor allocations are recycled
//!   (f64 and, for the fast tier's packed operands, f32 buffers).
//! * [`chol`] — blocked panel Cholesky factorization + triangular/multi-RHS
//!   solves (the exact kernel solve of ENGD-W, paper eq. 5): diagonal
//!   panels factor serially, trailing rows sweep whole panels per pool
//!   dispatch, and the result is bitwise-identical at every thread width.
//!   `solve_into` is the pooled solve of the hot paths.
//! * [`eigh`] — cyclic Jacobi symmetric eigendecomposition (the SVD-class
//!   factorization used by the *standard stable* Nyström baseline and the
//!   spectral diagnostics).
//! * [`qr`] — Householder QR (test-matrix orthonormalization in the stable
//!   Nyström baseline); reflector applications fan out per column over the
//!   worker pool with per-column arithmetic unchanged.
//! * [`cg`] — preconditioned conjugate gradients on a matrix-free operator
//!   (the Hessian-free baseline, Martens 2010); `cg_solve_warm_pooled` is
//!   the zero-allocation loop the optimizers run.

mod cg;
mod chol;
mod eigh;
mod matrix;
pub mod ops;
mod qr;
mod vec_ops;
mod workspace;

pub use cg::{cg_solve, cg_solve_warm, cg_solve_warm_pooled, CgOutcome};
pub use chol::Cholesky;
pub use eigh::{eigh, eigh_into, Eigh};
pub use matrix::Matrix;
pub use qr::{thin_qr, thin_qr_into};
pub use vec_ops::{axpy, dot, norm2, scale, sub};
pub use workspace::{Workspace, WorkspaceStats};
