//! Dense f64 linear algebra substrate (nalgebra/LAPACK are unavailable
//! offline; the paper's algorithms are all dense kernels over matrices that
//! fit comfortably in memory once the Woodbury identity moves the solve into
//! sample space).
//!
//! Contents:
//! * [`Matrix`] — row-major dense matrix with blocked, multi-threaded
//!   products (`matmul`, `gram`, `matvec`, ...).
//! * [`ops`] — fused BLAS-style transpose products (`matmul_tn` = AᵀB,
//!   `matmul_nt` = ABᵀ, `gram_t` = AᵀA) plus `*_into` variants writing to
//!   caller-provided buffers; no transpose is ever materialized.
//! * [`Workspace`] — the step-buffer pool the trainer threads through
//!   `StepEnv` so per-step Gram/sketch/factor allocations are recycled.
//! * [`chol`] — Cholesky factorization + triangular/multi-RHS solves (the
//!   exact kernel solve of ENGD-W, paper eq. 5), with in-place `factor_from`
//!   over pooled buffers.
//! * [`eigh`] — cyclic Jacobi symmetric eigendecomposition (the SVD-class
//!   factorization used by the *standard stable* Nyström baseline and the
//!   spectral diagnostics).
//! * [`qr`] — Householder QR (test-matrix orthonormalization in the stable
//!   Nyström baseline).
//! * [`cg`] — preconditioned conjugate gradients on a matrix-free operator
//!   (the Hessian-free baseline, Martens 2010).

mod cg;
mod chol;
mod eigh;
mod matrix;
pub mod ops;
mod qr;
mod vec_ops;
mod workspace;

pub use cg::{cg_solve, cg_solve_warm, CgOutcome};
pub use chol::Cholesky;
pub use eigh::{eigh, eigh_into, Eigh};
pub use matrix::Matrix;
pub use qr::{thin_qr, thin_qr_into};
pub use vec_ops::{axpy, dot, norm2, scale, sub};
pub use workspace::{Workspace, WorkspaceStats};
