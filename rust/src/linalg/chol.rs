//! Cholesky factorization and solves — the exact kernel solve of ENGD-W
//! (paper eq. 5) and both Cholesky steps of the GPU-efficient Nyström
//! (paper Algorithm 2, lines 5 and 8).

use anyhow::Result;

use super::matrix::Matrix;
use crate::parallel::{par_chunks, SendPtr};

/// Lower-triangular Cholesky factor `L` with `L Lᵀ = A`.
pub struct Cholesky {
    l: Matrix,
}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix (clones the input; use
    /// [`Cholesky::factor_from`] to factor a workspace buffer in place).
    pub fn factor(a: &Matrix) -> Result<Self> {
        Self::factor_from(a.clone())
    }

    /// Factor a symmetric positive-definite matrix, consuming its storage —
    /// the factorization happens in place, so workspace-pooled Gram/core
    /// buffers are factored with zero extra allocation (reclaim the buffer
    /// afterwards via [`Cholesky::into_factor`]).
    ///
    /// On failure the storage is dropped; retry loops that must keep their
    /// pooled buffer alive use [`Cholesky::factor_from_recoverable`].
    pub fn factor_from(a: Matrix) -> Result<Self> {
        Self::factor_from_recoverable(a).map_err(|(_, e)| e)
    }

    /// Like [`Cholesky::factor_from`], but a failure hands the (partially
    /// overwritten) storage back alongside the error, so ν-escalation retry
    /// loops can recycle the buffer into their [`super::Workspace`] instead
    /// of leaking it out of the pool.
    ///
    /// Blocked right-looking panel algorithm: an `NB`-column diagonal panel
    /// is factored serially, then every trailing row sweeps across the whole
    /// panel in a single worker-pool dispatch (one dispatch per panel instead
    /// of one per column). Each element keeps the exact per-element formulas
    /// of the unblocked column algorithm — the pivot's sequential Σx² and the
    /// `vec_ops::dot` prefix dot — so the factor is bitwise-identical at
    /// every pool width. Fails (rather than producing NaNs) if a pivot is not
    /// strictly positive — the caller decides how to re-damp.
    pub fn factor_from_recoverable(a: Matrix) -> Result<Self, (Matrix, anyhow::Error)> {
        if a.rows() != a.cols() {
            let e = anyhow::anyhow!(
                "cholesky: matrix is {}x{}, not square",
                a.rows(),
                a.cols()
            );
            return Err((a, e));
        }
        let n = a.rows();
        let mut l = a;
        /// Panel width of the blocked factorization (columns per dispatch).
        const NB: usize = 64;
        let cols = n;
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + NB).min(n);
            // (1) Diagonal panel: factor columns j0..j1 restricted to rows
            // j0..j1 (serial — the panel carries the sequential dependency).
            for j in j0..j1 {
                // Pivot: d = sqrt(A[j,j] - L[j,:j]·L[j,:j])
                let ljj = {
                    let row_j = l.row(j);
                    let s: f64 = row_j[..j].iter().map(|x| x * x).sum();
                    row_j[j] - s
                };
                if ljj <= 0.0 || !ljj.is_finite() {
                    let e = anyhow::anyhow!(
                        "cholesky: non-positive pivot {ljj:.3e} at column {j} \
                         (matrix is not PD at this damping)"
                    );
                    return Err((l, e));
                }
                let d = ljj.sqrt();
                l[(j, j)] = d;
                for i in j + 1..j1 {
                    let s = super::vec_ops::dot(&l.row(i)[..j], &l.row(j)[..j]);
                    l[(i, j)] = (l[(i, j)] - s) / d;
                }
            }
            // (2) Trailing-row panel sweep: rows j1..n fill columns j0..j1.
            // Each row is owned by one worker slot and walks the panel left
            // to right, so every prefix L[i,:j] it reads is already final:
            //   L[i,j] = (A[i,j] - L[i,:j]·L[j,:j]) / L[j,j]
            if n - j1 > 64 {
                let lp = SendPtr(l.data_mut().as_mut_ptr());
                par_chunks(n - j1, |s, e| {
                    for off in s..e {
                        let i = j1 + off;
                        // SAFETY: panel rows j0..j1 are read-only here; each
                        // trailing row i is written only by its own slot, and
                        // reads of row i stay left of the column it writes.
                        unsafe {
                            for j in j0..j1 {
                                let row_i =
                                    std::slice::from_raw_parts(lp.get().add(i * cols), j + 1);
                                let row_j =
                                    std::slice::from_raw_parts(lp.get().add(j * cols), j + 1);
                                let s = super::vec_ops::dot(&row_i[..j], &row_j[..j]);
                                *lp.get().add(i * cols + j) = (row_i[j] - s) / row_j[j];
                            }
                        }
                    }
                });
            } else {
                for i in j1..n {
                    for j in j0..j1 {
                        let s = super::vec_ops::dot(&l.row(i)[..j], &l.row(j)[..j]);
                        let d = l[(j, j)];
                        l[(i, j)] = (l[(i, j)] - s) / d;
                    }
                }
            }
            j0 = j1;
        }
        // Zero the strict upper triangle so `l` is a clean factor.
        for i in 0..n {
            for j in i + 1..n {
                l[(i, j)] = 0.0;
            }
        }
        Ok(Cholesky { l })
    }

    pub fn factor_matrix(&self) -> &Matrix {
        &self.l
    }

    /// Surrender the factor's storage (so a workspace pool can recycle it).
    pub fn into_factor(self) -> Matrix {
        self.l
    }

    /// Solve `A x = b` (forward + back substitution).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; self.l.rows()];
        self.solve_into(b, &mut x);
        x
    }

    /// Pooled solve `A x = b` into a caller-provided (workspace) buffer.
    ///
    /// The forward substitution writes `y` into `x` and the back
    /// substitution then runs in place, replaying the exact arithmetic of
    /// [`Cholesky::solve_lower`] + [`Cholesky::solve_upper`] — bitwise equal
    /// to the allocating [`Cholesky::solve`] with zero allocations.
    pub fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        assert_eq!(x.len(), n);
        // Forward: L y = b (y lives in x).
        for i in 0..n {
            let s = super::vec_ops::dot(&self.l.row(i)[..i], &x[..i]);
            x[i] = (b[i] - s) / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y, in place.
        for i in (0..n).rev() {
            x[i] /= self.l[(i, i)];
            let xi = x[i];
            // Eliminate column i from the remaining rows: x[:i] -= L[i,:i]·xi
            let row_i = self.l.row(i);
            for k in 0..i {
                x[k] -= row_i[k] * xi;
            }
        }
    }

    /// Solve `L y = b`.
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let s = super::vec_ops::dot(&self.l.row(i)[..i], &y[..i]);
            y[i] = (b[i] - s) / self.l[(i, i)];
        }
        y
    }

    /// Solve `Lᵀ x = b`.
    pub fn solve_upper(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for i in (0..n).rev() {
            x[i] /= self.l[(i, i)];
            let xi = x[i];
            // Eliminate column i from the remaining rows: x[:i] -= L[i,:i]·xi
            let row_i = self.l.row(i);
            for k in 0..i {
                x[k] -= row_i[k] * xi;
            }
        }
        x
    }

    /// Multi-RHS solve: `A X = B` where B's *columns* are the right-hand
    /// sides; returns X with the same layout.
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Matrix::zeros(n, b.cols());
        // Solve per column (parallelizable; columns are independent).
        let cols: Vec<Vec<f64>> = crate::parallel::par_map(b.cols(), |j| {
            let mut rhs = vec![0.0; n];
            b.copy_col_into(j, &mut rhs);
            self.solve(&rhs)
        });
        for (j, col) in cols.iter().enumerate() {
            for i in 0..n {
                out[(i, j)] = col[i];
            }
        }
        out
    }

    /// Right-solve `X Lᵀ⁻¹`, i.e. solve `X Lᵀ = B` row-wise — Algorithm 2
    /// line 6 (`B = Y_ν C⁻¹` with C upper-triangular from `chol(ΩᵀY_ν)`).
    ///
    /// Our `Cholesky` stores the *lower* factor L with A = L Lᵀ; `C = Lᵀ`.
    /// For each row b of B we solve `x Lᵀ = b  ⇔  L xᵀ = bᵀ`.
    pub fn right_solve_transpose(&self, b: &Matrix) -> Matrix {
        let mut out = b.clone();
        self.right_solve_transpose_in_place(&mut out);
        out
    }

    /// In-place variant of [`Cholesky::right_solve_transpose`]: overwrites
    /// each row of `b` with its solve, so the Nyström builders can turn a
    /// workspace-pooled `Y_ν` into `B` with zero extra allocation.
    ///
    /// Forward substitution runs left-to-right within a row, so the row can
    /// serve as both input and output; rows are independent and solved in
    /// parallel.
    pub fn right_solve_transpose_in_place(&self, b: &mut Matrix) {
        let n = self.l.rows();
        assert_eq!(b.cols(), n, "right_solve_transpose: width mismatch");
        let rows = b.rows();
        let width = b.cols();
        let b_ptr = SendPtr(b.data_mut().as_mut_ptr());
        par_chunks(rows, |istart, iend| {
            for i in istart..iend {
                // SAFETY: each thread owns disjoint rows of B.
                let row: &mut [f64] = unsafe {
                    std::slice::from_raw_parts_mut(b_ptr.get().add(i * width), width)
                };
                for k in 0..n {
                    let s = super::vec_ops::dot(&self.l.row(k)[..k], &row[..k]);
                    row[k] = (row[k] - s) / self.l[(k, k)];
                }
            }
        });
    }

    /// trace(A⁻¹) via the factor: Σ_j ‖L⁻¹ e_j‖² — used by the effective
    /// dimension d_eff = N − λ·tr((K+λI)⁻¹) (paper §3.4).
    pub fn inverse_trace(&self) -> f64 {
        let n = self.l.rows();
        let traces: Vec<f64> = crate::parallel::par_map(n, |j| {
            let mut e = vec![0.0; n];
            e[j] = 1.0;
            let y = self.solve_lower(&e);
            super::vec_ops::dot(&y, &y)
        });
        traces.iter().sum()
    }

    /// log det(A) = 2 Σ log L_ii (spectral diagnostics).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows())
            .map(|i| self.l[(i, i)].ln())
            .sum::<f64>()
            * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn spd(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        rng.fill_normal(a.data_mut());
        a.gram().add_diag(n as f64)
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::seed_from(1);
        for n in [1, 2, 5, 33, 100, 300] {
            let a = spd(&mut rng, n);
            let ch = Cholesky::factor(&a).unwrap();
            let l = ch.factor_matrix();
            let rec = l.matmul(&l.transpose());
            assert!(rec.max_abs_diff(&a) < 1e-8 * n as f64, "n={n}");
        }
    }

    #[test]
    fn solve_matches_direct_residual() {
        let mut rng = Rng::seed_from(2);
        for n in [1, 7, 64, 200] {
            let a = spd(&mut rng, n);
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut b);
            let x = Cholesky::factor(&a).unwrap().solve(&b);
            let r = a.matvec(&x);
            let err: f64 = r.iter().zip(&b).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
            assert!(err < 1e-8, "n={n} err={err}");
        }
    }

    #[test]
    fn multi_rhs_matches_single() {
        let mut rng = Rng::seed_from(3);
        let a = spd(&mut rng, 40);
        let mut b = Matrix::zeros(40, 5);
        rng.fill_normal(b.data_mut());
        let ch = Cholesky::factor(&a).unwrap();
        let x = ch.solve_matrix(&b);
        for j in 0..5 {
            let xj = ch.solve(&b.col_iter(j).collect::<Vec<_>>());
            for i in 0..40 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn right_solve_transpose_inverts() {
        let mut rng = Rng::seed_from(4);
        let a = spd(&mut rng, 20);
        let ch = Cholesky::factor(&a).unwrap();
        let mut b = Matrix::zeros(8, 20);
        rng.fill_normal(b.data_mut());
        let x = ch.right_solve_transpose(&b);
        // x @ Lᵀ should equal b.
        let rec = x.matmul(&ch.factor_matrix().transpose());
        assert!(rec.max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn inverse_trace_matches_explicit_inverse() {
        let mut rng = Rng::seed_from(5);
        let a = spd(&mut rng, 30);
        let ch = Cholesky::factor(&a).unwrap();
        let inv = ch.solve_matrix(&Matrix::identity(30));
        let want: f64 = (0..30).map(|i| inv[(i, i)]).sum();
        assert!((ch.inverse_trace() - want).abs() < 1e-8);
    }

    #[test]
    fn factor_from_matches_factor_and_returns_storage() {
        let mut rng = Rng::seed_from(6);
        let a = spd(&mut rng, 25);
        let by_ref = Cholesky::factor(&a).unwrap();
        let by_move = Cholesky::factor_from(a.clone()).unwrap();
        assert_eq!(
            by_ref.factor_matrix().max_abs_diff(by_move.factor_matrix()),
            0.0
        );
        let reclaimed = by_move.into_factor();
        assert_eq!((reclaimed.rows(), reclaimed.cols()), (25, 25));
    }

    #[test]
    fn in_place_right_solve_matches_allocating_variant() {
        let mut rng = Rng::seed_from(7);
        let a = spd(&mut rng, 20);
        let ch = Cholesky::factor(&a).unwrap();
        let mut b = Matrix::zeros(8, 20);
        rng.fill_normal(b.data_mut());
        let want = ch.right_solve_transpose(&b);
        ch.right_solve_transpose_in_place(&mut b);
        assert_eq!(b.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn non_pd_fails_cleanly() {
        let a = Matrix::from_fn(3, 3, |i, j| if i == j { -1.0 } else { 0.0 });
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn recoverable_factor_returns_storage_on_failure() {
        let a = Matrix::from_fn(3, 3, |i, j| if i == j { -1.0 } else { 0.0 });
        let (back, e) = Cholesky::factor_from_recoverable(a).err().unwrap();
        assert_eq!((back.rows(), back.cols()), (3, 3));
        assert!(e.to_string().contains("pivot"), "{e}");
    }

    #[test]
    fn log_det_matches_eigenvalues_diag() {
        let a = Matrix::from_fn(4, 4, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let ch = Cholesky::factor(&a).unwrap();
        let want = 1f64.ln() + 2f64.ln() + 3f64.ln() + 4f64.ln();
        assert!((ch.log_det() - want).abs() < 1e-12);
    }
}
