//! Conjugate gradients on a matrix-free SPD operator.
//!
//! This is the engine of the Hessian-free baseline (Martens 2010, paper §4):
//! truncated CG on the damped Gauss–Newton system
//! `(JᵀJ + λI) x = ∇L` using only operator applications `v ↦ Jᵀ(Jv) + λv`.
//! The paper's motivation for Woodbury is precisely that this iteration
//! suffers under the kernel's ill-conditioning — our Fig. 2 bench reproduces
//! that comparison.

/// Result of a CG solve.
#[derive(Debug, Clone)]
pub struct CgOutcome {
    pub x: Vec<f64>,
    pub iterations: usize,
    /// Final relative residual ‖Ax − b‖ / ‖b‖.
    pub rel_residual: f64,
    pub converged: bool,
}

/// Solve `A x = b` with (unpreconditioned) CG, truncated at `max_iters`.
///
/// `apply` computes `A v`. `tol` is the relative-residual stopping threshold.
pub fn cg_solve(
    apply: impl Fn(&[f64]) -> Vec<f64>,
    b: &[f64],
    max_iters: usize,
    tol: f64,
) -> CgOutcome {
    cg_solve_warm(apply, b, None, max_iters, tol)
}

/// [`cg_solve`] with an optional warm-start iterate `x0` (Martens 2010
/// §4.8: Hessian-free restarts CG from the previous step's solution, which
/// the optimizer checkpoints for bit-exact resume). `x0 = None` — or an
/// all-zero `x0` — reproduces the cold-start solve bitwise, with no extra
/// operator application.
pub fn cg_solve_warm(
    apply: impl Fn(&[f64]) -> Vec<f64>,
    b: &[f64],
    x0: Option<&[f64]>,
    max_iters: usize,
    tol: f64,
) -> CgOutcome {
    let n = b.len();
    let bnorm = super::vec_ops::norm2(b);
    if bnorm == 0.0 {
        return CgOutcome {
            x: vec![0.0; n],
            iterations: 0,
            rel_residual: 0.0,
            converged: true,
        };
    }
    if let Some(x0) = x0 {
        assert_eq!(x0.len(), n, "cg warm-start length mismatch");
    }
    let (mut x, mut r) = match x0 {
        Some(x0) if x0.iter().any(|&v| v != 0.0) => {
            let ax = apply(x0);
            let r: Vec<f64> = b.iter().zip(&ax).map(|(bi, ai)| bi - ai).collect();
            (x0.to_vec(), r)
        }
        _ => (vec![0.0; n], b.to_vec()),
    };
    let mut p = r.clone();
    let mut rs = super::vec_ops::dot(&r, &r);

    let mut iterations = 0;
    for _ in 0..max_iters {
        let ap = apply(&p);
        let pap = super::vec_ops::dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Operator is not PD at this damping (or numerics broke down):
            // return the best iterate so far, flagged unconverged.
            break;
        }
        let alpha = rs / pap;
        super::vec_ops::axpy(alpha, &p, &mut x);
        super::vec_ops::axpy(-alpha, &ap, &mut r);
        iterations += 1;
        let rs_new = super::vec_ops::dot(&r, &r);
        if rs_new.sqrt() <= tol * bnorm {
            rs = rs_new;
            break;
        }
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    let rel = rs.sqrt() / bnorm;
    CgOutcome {
        x,
        iterations,
        rel_residual: rel,
        converged: rel <= tol,
    }
}

/// Pooled warm-started CG: `apply` writes `A v` into a caller-provided
/// buffer (so the operator side can also run allocation-free) and every
/// loop vector (x, r, p, Ap) is drawn from `ws` — steady-state iterations
/// never touch the allocator. Given the same operator values the iterates
/// match [`cg_solve_warm`] bitwise. The returned `x` lives in pooled
/// storage; recycle it into `ws` when done.
pub fn cg_solve_warm_pooled(
    mut apply: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x0: Option<&[f64]>,
    max_iters: usize,
    tol: f64,
    ws: &mut super::workspace::Workspace,
) -> CgOutcome {
    let n = b.len();
    let bnorm = super::vec_ops::norm2(b);
    if bnorm == 0.0 {
        return CgOutcome {
            x: ws.take(n),
            iterations: 0,
            rel_residual: 0.0,
            converged: true,
        };
    }
    if let Some(x0) = x0 {
        assert_eq!(x0.len(), n, "cg warm-start length mismatch");
    }
    let mut x = ws.take_scratch(n);
    let mut r = ws.take_scratch(n);
    let mut ap = ws.take_scratch(n);
    match x0 {
        Some(x0) if x0.iter().any(|&v| v != 0.0) => {
            apply(x0, &mut ap);
            for ((ri, bi), ai) in r.iter_mut().zip(b).zip(&ap) {
                *ri = *bi - *ai;
            }
            x.copy_from_slice(x0);
        }
        _ => {
            x.fill(0.0);
            r.copy_from_slice(b);
        }
    }
    let mut p = ws.take_scratch(n);
    p.copy_from_slice(&r);
    let mut rs = super::vec_ops::dot(&r, &r);

    let mut iterations = 0;
    for _ in 0..max_iters {
        apply(&p, &mut ap);
        let pap = super::vec_ops::dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Operator is not PD at this damping (or numerics broke down):
            // return the best iterate so far, flagged unconverged.
            break;
        }
        let alpha = rs / pap;
        super::vec_ops::axpy(alpha, &p, &mut x);
        super::vec_ops::axpy(-alpha, &ap, &mut r);
        iterations += 1;
        let rs_new = super::vec_ops::dot(&r, &r);
        if rs_new.sqrt() <= tol * bnorm {
            rs = rs_new;
            break;
        }
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    ws.recycle(p);
    ws.recycle(ap);
    ws.recycle(r);
    let rel = rs.sqrt() / bnorm;
    CgOutcome {
        x,
        iterations,
        rel_residual: rel,
        converged: rel <= tol,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Matrix, Workspace};
    use crate::rng::Rng;

    #[test]
    fn solves_spd_system_exactly_in_n_steps() {
        let mut rng = Rng::seed_from(1);
        let n = 30;
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let a = g.gram().add_diag(n as f64);
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let out = cg_solve(|v| a.matvec(v), &b, 2 * n, 1e-12);
        assert!(out.converged, "rel={}", out.rel_residual);
        let r = a.matvec(&out.x);
        let err: f64 = r.iter().zip(&b).map(|(u, v)| (u - v).abs()).fold(0.0, f64::max);
        assert!(err < 1e-8);
    }

    #[test]
    fn truncation_is_respected() {
        let mut rng = Rng::seed_from(2);
        let n = 50;
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let a = g.gram().add_diag(1e-6); // ill-conditioned
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let out = cg_solve(|v| a.matvec(v), &b, 5, 1e-14);
        assert_eq!(out.iterations, 5);
        assert!(!out.converged);
    }

    #[test]
    fn warm_start_matches_cold_on_zero_guess_and_converges_faster() {
        let mut rng = Rng::seed_from(3);
        let n = 40;
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let a = g.gram().add_diag(1.0);
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        // All-zero x0 must reproduce the cold start bitwise.
        let cold = cg_solve(|v| a.matvec(v), &b, 2 * n, 1e-10);
        let zero = vec![0.0; n];
        let warm0 = cg_solve_warm(|v| a.matvec(v), &b, Some(&zero), 2 * n, 1e-10);
        assert_eq!(cold.iterations, warm0.iterations);
        for (x, y) in cold.x.iter().zip(&warm0.x) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Warm-starting from the solution itself converges immediately.
        let warm = cg_solve_warm(|v| a.matvec(v), &b, Some(&cold.x), 2 * n, 1e-8);
        assert!(warm.converged);
        assert!(
            warm.iterations <= 1,
            "restart from the solution took {} iterations",
            warm.iterations
        );
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let out = cg_solve(|v| v.to_vec(), &[0.0; 4], 10, 1e-10);
        assert!(out.converged);
        assert_eq!(out.x, vec![0.0; 4]);
    }

    #[test]
    fn pooled_variant_matches_allocating_bitwise_and_freezes_the_pool() {
        let mut rng = Rng::seed_from(4);
        let n = 35;
        let mut g = Matrix::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let a = g.gram().add_diag(1.0);
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let mut x0 = vec![0.0; n];
        rng.fill_normal(&mut x0);

        for warm in [None, Some(x0.as_slice())] {
            let reference = cg_solve_warm(|v| a.matvec(v), &b, warm, 2 * n, 1e-10);
            let mut ws = Workspace::new();
            let pooled = cg_solve_warm_pooled(
                |v, out| a.matvec_into(v, out),
                &b,
                warm,
                2 * n,
                1e-10,
                &mut ws,
            );
            assert_eq!(reference.iterations, pooled.iterations);
            for (x, y) in reference.x.iter().zip(&pooled.x) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            ws.recycle(pooled.x);
            // Steady state: a rerun draws everything from the pool.
            let frozen = (ws.stats().fresh_allocs, ws.stats().grown);
            let again = cg_solve_warm_pooled(
                |v, out| a.matvec_into(v, out),
                &b,
                warm,
                2 * n,
                1e-10,
                &mut ws,
            );
            ws.recycle(again.x);
            assert_eq!((ws.stats().fresh_allocs, ws.stats().grown), frozen);
        }
    }
}
