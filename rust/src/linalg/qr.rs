//! Thin (economy) QR via Householder reflections.
//!
//! Used by the standard stable Nyström baseline to orthonormalize the
//! Gaussian test matrix Ω (Frangella–Tropp–Udell alg. 2.1, the step the
//! paper's GPU-efficient Algorithm 2 deliberately *skips*).
//!
//! [`thin_qr_into`] is the workspace variant: the in-place R copy and the
//! packed reflector storage come from — and return to — the caller's
//! [`Workspace`], so the stable-Nyström solve path allocates nothing here
//! at steady state. [`thin_qr`] wraps it with owned buffers; both produce
//! bitwise-identical Q (same operations in the same order).

use super::matrix::Matrix;
use super::workspace::Workspace;

/// Economy QR: returns Q (m×n, orthonormal columns) for m ≥ n input.
pub fn thin_qr(a: &Matrix) -> Matrix {
    let mut q = Matrix::zeros(a.rows(), a.cols());
    let mut ws = Workspace::new();
    thin_qr_into(a, &mut q, &mut ws);
    q
}

/// Economy QR into a caller-provided `q` (m×n, overwritten), with all
/// interior scratch drawn from `ws`.
pub fn thin_qr_into(a: &Matrix, q: &mut Matrix, ws: &mut Workspace) {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "thin_qr expects a tall matrix, got {m}x{n}");
    assert_eq!(
        (q.rows(), q.cols()),
        (m, n),
        "thin_qr_into output must be {m}x{n}, got {}x{}",
        q.rows(),
        q.cols()
    );

    // Householder factorization over a pooled working copy; reflector k
    // (length m − k) is packed at offset k·m of the pooled `vs` buffer.
    let mut r = ws.take_matrix_scratch(m, n);
    r.data_mut().copy_from_slice(a.data());
    let mut betas = ws.take_scratch(n);
    let mut vs = ws.take_scratch(n * m);
    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let beta = {
            let v = &mut vs[k * m..k * m + (m - k)];
            for i in k..m {
                v[i - k] = r[(i, k)];
            }
            let alpha = -v[0].signum() * super::vec_ops::norm2(v);
            if alpha == 0.0 {
                // Degenerate (zero) column: identity reflector.
                betas[k] = 0.0;
                continue;
            }
            v[0] -= alpha;
            let vnorm2 = super::vec_ops::dot(v, v);
            if vnorm2 > 0.0 {
                2.0 / vnorm2
            } else {
                0.0
            }
        };
        // Apply to the trailing columns of R. Columns are independent and
        // each is updated with the same ascending-`i` arithmetic whether the
        // sweep runs serial or panel-parallel, so the factorization stays
        // bitwise-identical at every pool width.
        let v = &vs[k * m..k * m + (m - k)];
        apply_reflector(v, beta, k, k, r.data_mut(), m, n);
        betas[k] = beta;
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I.
    q.data_mut().fill(0.0);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k * m..k * m + (m - k)];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        apply_reflector(v, beta, k, 0, q.data_mut(), m, n);
    }
    ws.recycle(vs);
    ws.recycle(betas);
    ws.recycle_matrix(r);
}

/// Apply the Householder update `X[:, j0..n] -= β v (vᵀ X[:, j0..n])` to the
/// rows `k..m` of a row-major `m × n` buffer.
///
/// Each column `j` is owned by exactly one worker slot and is reduced in
/// ascending `i`, so the panel-parallel dispatch is bitwise-identical to the
/// serial sweep. Small trailing blocks stay serial to skip dispatch overhead.
fn apply_reflector(v: &[f64], beta: f64, k: usize, j0: usize, x: &mut [f64], m: usize, n: usize) {
    let ncols = n - j0;
    if ncols * (m - k) > 16_384 {
        let xp = crate::parallel::SendPtr(x.as_mut_ptr());
        crate::parallel::par_chunks(ncols, |cs, ce| {
            for off in cs..ce {
                let j = j0 + off;
                // SAFETY: each slot reads and writes only its own columns.
                unsafe {
                    let mut s = 0.0;
                    for i in k..m {
                        s += v[i - k] * *xp.get().add(i * n + j);
                    }
                    s *= beta;
                    for i in k..m {
                        *xp.get().add(i * n + j) -= s * v[i - k];
                    }
                }
            }
        });
    } else {
        for j in j0..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * x[i * n + j];
            }
            s *= beta;
            for i in k..m {
                x[i * n + j] -= s * v[i - k];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::seed_from(1);
        for (m, n) in [(5, 5), (30, 10), (100, 17), (64, 1)] {
            let mut a = Matrix::zeros(m, n);
            rng.fill_normal(a.data_mut());
            let q = thin_qr(&a);
            let qtq = q.transpose().matmul(&q);
            assert!(
                qtq.max_abs_diff(&Matrix::identity(n)) < 1e-10,
                "({m},{n})"
            );
        }
    }

    #[test]
    fn q_spans_the_input() {
        // range(Q) == range(A): projecting A onto Q's span reproduces A.
        let mut rng = Rng::seed_from(2);
        let mut a = Matrix::zeros(40, 8);
        rng.fill_normal(a.data_mut());
        let q = thin_qr(&a);
        let proj = q.matmul(&q.transpose().matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-9);
    }

    #[test]
    fn into_variant_matches_allocating_bitwise_and_reuses_pool() {
        let mut rng = Rng::seed_from(3);
        let mut a = Matrix::zeros(33, 9);
        rng.fill_normal(a.data_mut());
        let reference = thin_qr(&a);

        let mut ws = Workspace::new();
        let mut q = ws.take_matrix_scratch(33, 9);
        thin_qr_into(&a, &mut q, &mut ws);
        assert_eq!(q.max_abs_diff(&reference), 0.0, "into variant diverged");

        // Steady state: a second factorization of the same shape draws its
        // scratch entirely from the pool.
        let fresh = ws.stats().fresh_allocs;
        thin_qr_into(&a, &mut q, &mut ws);
        assert_eq!(ws.stats().fresh_allocs, fresh, "second QR allocated");
        assert_eq!(q.max_abs_diff(&reference), 0.0);
        ws.recycle_matrix(q);
    }

    #[test]
    fn degenerate_zero_columns_are_handled() {
        // A zero column exercises the identity-reflector path in both the
        // factorization and the accumulation sweeps.
        let mut a = Matrix::zeros(6, 3);
        for i in 0..6 {
            a[(i, 0)] = (i + 1) as f64;
            a[(i, 2)] = ((i * i) % 5) as f64 - 2.0;
        }
        let q = thin_qr(&a);
        assert!(q.data().iter().all(|x| x.is_finite()));
        let proj = q.matmul(&q.transpose().matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-9);
    }
}
