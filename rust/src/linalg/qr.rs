//! Thin (economy) QR via Householder reflections.
//!
//! Used by the standard stable Nyström baseline to orthonormalize the
//! Gaussian test matrix Ω (Frangella–Tropp–Udell alg. 2.1, the step the
//! paper's GPU-efficient Algorithm 2 deliberately *skips*).

use super::matrix::Matrix;

/// Economy QR: returns Q (m×n, orthonormal columns) for m ≥ n input.
pub fn thin_qr(a: &Matrix) -> Matrix {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "thin_qr expects a tall matrix, got {m}x{n}");

    // Householder factorization, storing reflectors in-place.
    let mut r = a.clone();
    let mut betas = vec![0.0; n];
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let mut v: Vec<f64> = (k..m).map(|i| r[(i, k)]).collect();
        let alpha = -v[0].signum() * super::vec_ops::norm2(&v);
        if alpha == 0.0 {
            // Degenerate (zero) column: identity reflector.
            vs.push(v);
            betas[k] = 0.0;
            continue;
        }
        v[0] -= alpha;
        let vnorm2 = super::vec_ops::dot(&v, &v);
        let beta = if vnorm2 > 0.0 { 2.0 / vnorm2 } else { 0.0 };
        // Apply to the trailing columns of R.
        for j in k..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * r[(i, j)];
            }
            s *= beta;
            for i in k..m {
                r[(i, j)] -= s * v[i - k];
            }
        }
        vs.push(v);
        betas[k] = beta;
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        q[(j, j)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut s = 0.0;
            for i in k..m {
                s += v[i - k] * q[(i, j)];
            }
            s *= beta;
            for i in k..m {
                q[(i, j)] -= s * v[i - k];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::seed_from(1);
        for (m, n) in [(5, 5), (30, 10), (100, 17), (64, 1)] {
            let mut a = Matrix::zeros(m, n);
            rng.fill_normal(a.data_mut());
            let q = thin_qr(&a);
            let qtq = q.transpose().matmul(&q);
            assert!(
                qtq.max_abs_diff(&Matrix::identity(n)) < 1e-10,
                "({m},{n})"
            );
        }
    }

    #[test]
    fn q_spans_the_input() {
        // range(Q) == range(A): projecting A onto Q's span reproduces A.
        let mut rng = Rng::seed_from(2);
        let mut a = Matrix::zeros(40, 8);
        rng.fill_normal(a.data_mut());
        let q = thin_qr(&a);
        let proj = q.matmul(&q.transpose().matmul(&a));
        assert!(proj.max_abs_diff(&a) < 1e-9);
    }
}
