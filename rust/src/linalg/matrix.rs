//! Row-major dense matrix with blocked, thread-parallel products.
//!
//! The blocking constants are tuned in the §Perf pass (EXPERIMENTS.md): the
//! kernel loops are written j-innermost over row-major data so the compiler
//! auto-vectorizes the inner axpy, and the L2-resident `MC × KC` panel of A
//! is reused across the full width of B.

use crate::parallel::par_chunks;

/// Row-major dense f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

/// Panel height of A processed per thread-block (rows). Shared with the
/// fused product kernels in `linalg::ops`.
pub(crate) const MC: usize = 64;
/// Reduction-panel width kept hot in L2 (columns of A / rows of B).
pub(crate) const KC: usize = 256;

impl Matrix {
    // ----- constructors -------------------------------------------------

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "buffer has {} elements, {}x{} needs {}",
            data.len(),
            rows,
            cols,
            rows * cols
        );
        Matrix { rows, cols, data }
    }

    /// Build from a closure over (i, j).
    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    // ----- accessors ----------------------------------------------------

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Strided, allocation-free walk down column `j` of the row-major buffer
    /// (replaces the old `col()` which built a `Vec` element-by-element).
    ///
    /// Hard-asserts the column bound: a release-mode out-of-range `j` would
    /// otherwise yield a silently short, garbage iterator.
    #[inline]
    pub fn col_iter(&self, j: usize) -> impl ExactSizeIterator<Item = f64> + '_ {
        assert!(j < self.cols, "col_iter: column {j} of a {}x{} matrix", self.rows, self.cols);
        self.data[j..].iter().step_by(self.cols).copied()
    }

    /// Gather column `j` into a caller-provided buffer (for consumers that
    /// need a contiguous slice, e.g. triangular solves).
    pub fn copy_col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows, "copy_col_into length mismatch");
        for (dst, src) in out.iter_mut().zip(self.col_iter(j)) {
            *dst = src;
        }
    }

    // ----- simple transforms ---------------------------------------------

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// self + alpha * I (the damping shift (K + λI) of eq. 5).
    pub fn add_diag(&self, alpha: f64) -> Matrix {
        let mut out = self.clone();
        out.add_diag_in_place(alpha);
        out
    }

    /// In-place damping shift: `self += alpha * I`. The allocation-free
    /// variant used on workspace-pooled Gram/sketch buffers.
    pub fn add_diag_in_place(&mut self, alpha: f64) {
        assert_eq!(self.rows, self.cols, "add_diag needs a square matrix");
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    pub fn scale_in_place(&mut self, alpha: f64) {
        for x in &mut self.data {
            *x *= alpha;
        }
    }

    /// self += alpha * other (elementwise).
    pub fn add_scaled(&mut self, other: &Matrix, alpha: f64) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += alpha * y;
        }
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Largest |a_ij| distance to another matrix (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    // ----- products -------------------------------------------------------
    //
    // The blocked, thread-parallel kernels (including the fused transpose
    // products `matmul_tn` / `matmul_nt` and the `*_into` variants that
    // write to workspace-pooled buffers) live in `linalg::ops`; the
    // allocating entry points here are thin wrappers.

    /// Blocked, multi-threaded `C = A @ B`.
    ///
    /// Parallelizes over MC-row panels of A; within a panel, the j-innermost
    /// kernel does `C[i, :] += a_ik * B[k, :]`, which vectorizes cleanly on
    /// row-major data and streams B once per KC panel.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.rows, b.cols());
        self.matmul_into(b, &mut c);
        c
    }

    /// Symmetric Gram product `K = A @ Aᵀ` exploiting symmetry (the Rust-side
    /// analogue of the L1 Pallas gram kernel, used on the decomposed path).
    pub fn gram(&self) -> Matrix {
        let mut k = Matrix::zeros(self.rows, self.rows);
        self.gram_into(&mut k);
        k
    }

    /// `y = A @ x` (thread-parallel over rows).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// Pooled `y = A @ x` writing into a caller-provided (workspace) buffer.
    ///
    /// Each output element is a single fixed-order row dot, so this matches
    /// [`Matrix::matvec`] bitwise at every pool width.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        assert_eq!(self.rows, y.len(), "matvec output length mismatch");
        let y_ptr = crate::parallel::SendPtr(y.as_mut_ptr());
        par_chunks(self.rows, |start, end| {
            // SAFETY: disjoint row ranges per thread.
            let y_chunk: &mut [f64] =
                unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(start), end - start) };
            for (yi, i) in y_chunk.iter_mut().zip(start..end) {
                *yi = super::vec_ops::dot(self.row(i), x);
            }
        });
    }

    /// `y = Aᵀ @ x` without forming the transpose (accumulates rows).
    pub fn tr_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.tr_matvec_into(x, &mut y);
        y
    }

    /// Pooled `y = Aᵀ @ x` writing into a caller-provided (workspace) buffer.
    ///
    /// Accumulates rows in ascending `i` within disjoint 512-column chunks —
    /// the same per-element order as the allocating variant at any width.
    pub fn tr_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(self.rows, x.len(), "tr_matvec shape mismatch");
        assert_eq!(self.cols, y.len(), "tr_matvec output length mismatch");
        // Parallel over column chunks to keep writes disjoint.
        y.fill(0.0);
        let y_ptr = crate::parallel::SendPtr(y.as_mut_ptr());
        let cols = self.cols;
        par_chunks(self.cols.div_ceil(512), |cstart, cend| {
            let j0 = cstart * 512;
            let j1 = (cend * 512).min(cols);
            if j0 >= j1 {
                return;
            }
            // SAFETY: disjoint column ranges per thread.
            let y_chunk: &mut [f64] =
                unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(j0), j1 - j0) };
            for i in 0..self.rows {
                let xi = x[i];
                if xi == 0.0 {
                    continue;
                }
                let a_row = &self.row(i)[j0..j1];
                for (yj, aij) in y_chunk.iter_mut().zip(a_row) {
                    *yj += xi * aij;
                }
            }
        });
    }

    /// Effective FLOP count of `matmul` with `other` (perf reporting).
    pub fn matmul_flops(&self, b: &Matrix) -> f64 {
        2.0 * self.rows as f64 * self.cols as f64 * b.cols as f64
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (70, 129, 65), (128, 256, 64)] {
            let a = random_matrix(&mut rng, m, k);
            let b = random_matrix(&mut rng, k, n);
            let c = a.matmul(&b);
            let c0 = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn gram_matches_matmul_transpose() {
        let mut rng = Rng::seed_from(2);
        for (n, p) in [(1, 4), (7, 3), (33, 65), (64, 128), (100, 50)] {
            let a = random_matrix(&mut rng, n, p);
            let k = a.gram();
            let k0 = a.matmul(&a.transpose());
            assert!(k.max_abs_diff(&k0) < 1e-10, "({n},{p})");
            // Exact symmetry by construction.
            for i in 0..n {
                for j in 0..n {
                    assert_eq!(k[(i, j)], k[(j, i)]);
                }
            }
        }
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let mut rng = Rng::seed_from(3);
        let a = random_matrix(&mut rng, 37, 53);
        let x: Vec<f64> = (0..53).map(|i| (i as f64).sin()).collect();
        let y = a.matvec(&x);
        for i in 0..37 {
            let want: f64 = (0..53).map(|j| a[(i, j)] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-10);
        }
        let z: Vec<f64> = (0..37).map(|i| (i as f64).cos()).collect();
        let w = a.tr_matvec(&z);
        for j in 0..53 {
            let want: f64 = (0..37).map(|i| a[(i, j)] * z[i]).sum();
            assert!((w[j] - want).abs() < 1e-10);
        }
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::seed_from(4);
        let a = random_matrix(&mut rng, 45, 71);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn add_diag_shifts_diagonal_only() {
        let mut rng = Rng::seed_from(5);
        let a = random_matrix(&mut rng, 12, 12);
        let b = a.add_diag(2.5);
        for i in 0..12 {
            for j in 0..12 {
                let want = a[(i, j)] + if i == j { 2.5 } else { 0.0 };
                assert_eq!(b[(i, j)], want);
            }
        }
    }

    #[test]
    fn col_iter_walks_columns_without_copying() {
        let mut rng = Rng::seed_from(6);
        let a = random_matrix(&mut rng, 9, 5);
        for j in 0..5 {
            let it = a.col_iter(j);
            assert_eq!(it.len(), 9);
            for (i, v) in it.enumerate() {
                assert_eq!(v, a[(i, j)]);
            }
            let mut buf = vec![0.0; 9];
            a.copy_col_into(j, &mut buf);
            for i in 0..9 {
                assert_eq!(buf[i], a[(i, j)]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
