//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! This is the SVD-class dense factorization in our stack: the *standard
//! stable* Nyström baseline (Frangella–Tropp–Udell alg. 2.1) needs an
//! economy SVD of the sketch `B ∈ R^{N×S}`, which we obtain from the
//! eigendecomposition of the small `S×S` Gram matrix `BᵀB` (see
//! `nystrom::stable`). Jacobi is slower than LAPACK's tridiagonalization
//! pipelines but unconditionally robust and embarrassingly simple to verify —
//! and its cost *is the point* of the paper's Appendix-B benchmark: the
//! GPU-efficient variant exists precisely to avoid paying for it.
//!
//! [`eigh_into`] is the workspace variant: the Jacobi working copy and the
//! rotation accumulator come from — and return to — the caller's
//! [`Workspace`], so the stable-Nyström solve path allocates no dense
//! factorization temporaries at steady state. [`eigh`] wraps it with owned
//! buffers; both produce bitwise-identical results.

use super::matrix::Matrix;
use super::workspace::Workspace;

/// Eigendecomposition `A = V diag(w) Vᵀ` with eigenvalues ascending.
pub struct Eigh {
    /// Eigenvalues, ascending.
    pub eigenvalues: Vec<f64>,
    /// Columns are the corresponding eigenvectors.
    pub eigenvectors: Matrix,
}

/// Cyclic Jacobi with threshold sweeps. Converges quadratically once
/// off-diagonal mass is small; we cap at 30 sweeps (typ. ≤ 12 for our sizes).
pub fn eigh(a: &Matrix) -> Eigh {
    let n = a.rows();
    let mut eigenvalues = vec![0.0; n];
    let mut eigenvectors = Matrix::zeros(n, n);
    let mut ws = Workspace::new();
    eigh_into(a, &mut eigenvalues, &mut eigenvectors, &mut ws);
    Eigh {
        eigenvalues,
        eigenvectors,
    }
}

/// [`eigh`] into caller-provided outputs (`eigenvalues` of length n,
/// `eigenvectors` n×n, both overwritten), with the Jacobi scratch drawn
/// from `ws`.
pub fn eigh_into(
    a: &Matrix,
    eigenvalues: &mut [f64],
    eigenvectors: &mut Matrix,
    ws: &mut Workspace,
) {
    assert_eq!(a.rows(), a.cols(), "eigh needs a square matrix");
    let n = a.rows();
    assert_eq!(eigenvalues.len(), n, "eigh_into needs {n} eigenvalue slots");
    assert_eq!(
        (eigenvectors.rows(), eigenvectors.cols()),
        (n, n),
        "eigh_into eigenvector output must be {n}x{n}"
    );
    if n == 0 {
        return;
    }

    let mut m = ws.take_matrix_scratch(n, n);
    m.data_mut().copy_from_slice(a.data());
    let mut v = ws.take_matrix_scratch(n, n);
    v.data_mut().fill(0.0);
    for i in 0..n {
        v[(i, i)] = 1.0;
    }

    for _sweep in 0..30 {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frobenius_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.5).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    1.0 / (tau - (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // A ← JᵀAJ, applied to rows/cols p and q.
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // Accumulate V ← VJ.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Sort ascending, permuting V's columns into the output. Total-order
    // key with NaN last: a non-finite diagonal entry (overflowed input,
    // poisoned sweep) used to panic the pivot sort via
    // `partial_cmp(..).unwrap()`; now +∞ orders after every finite value
    // as usual and NaN orders after everything, deterministically (the
    // sort is stable, so tied/NaN columns keep their sweep order).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| {
        let key = |d: f64| (d.is_nan(), d);
        key(m[(i, i)])
            .partial_cmp(&key(m[(j, j)]))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for (new_j, &old_j) in order.iter().enumerate() {
        eigenvalues[new_j] = m[(old_j, old_j)];
        for i in 0..n {
            eigenvectors[(i, new_j)] = v[(i, old_j)];
        }
    }
    ws.recycle_matrix(v);
    ws.recycle_matrix(m);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_symmetric(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        rng.fill_normal(a.data_mut());
        let at = a.transpose();
        let mut s = a;
        s.add_scaled(&at, 1.0);
        s.scale_in_place(0.5);
        s
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::seed_from(1);
        for n in [1, 2, 3, 10, 40] {
            let a = random_symmetric(&mut rng, n);
            let e = eigh(&a);
            // A V = V diag(w)
            let av = a.matmul(&e.eigenvectors);
            for j in 0..n {
                for i in 0..n {
                    let want = e.eigenvectors[(i, j)] * e.eigenvalues[j];
                    assert!((av[(i, j)] - want).abs() < 1e-9, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let mut rng = Rng::seed_from(2);
        let a = random_symmetric(&mut rng, 25);
        let e = eigh(&a);
        let vtv = e.eigenvectors.transpose().matmul(&e.eigenvectors);
        assert!(vtv.max_abs_diff(&Matrix::identity(25)) < 1e-10);
    }

    #[test]
    fn known_eigenvalues() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.eigenvalues[0] - 1.0).abs() < 1e-12);
        assert!((e.eigenvalues[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Rng::seed_from(3);
        let a = random_symmetric(&mut rng, 15);
        let e = eigh(&a);
        let trace: f64 = (0..15).map(|i| a[(i, i)]).sum();
        let sum: f64 = e.eigenvalues.iter().sum();
        assert!((trace - sum).abs() < 1e-9);
    }

    #[test]
    fn non_finite_diagonal_orders_last_instead_of_panicking() {
        // Regression: the final pivot sort used `partial_cmp(..).unwrap()`,
        // so a non-finite diagonal entry (overflowed Gram input, poisoned
        // sweep) panicked instead of producing a deterministic ordering.
        // A diagonal input never rotates (every off-diagonal is zero), so
        // the sort sees the diagonal verbatim: finite values ascend, +∞
        // after them, NaN last.
        let a = Matrix::from_vec(
            4,
            4,
            vec![
                f64::NAN,
                0.0,
                0.0,
                0.0,
                0.0,
                2.0,
                0.0,
                0.0,
                0.0,
                0.0,
                f64::INFINITY,
                0.0,
                0.0,
                0.0,
                0.0,
                1.0,
            ],
        );
        let e = eigh(&a);
        assert_eq!(e.eigenvalues[0], 1.0);
        assert_eq!(e.eigenvalues[1], 2.0);
        assert_eq!(e.eigenvalues[2], f64::INFINITY);
        assert!(e.eigenvalues[3].is_nan());
        // Eigenvector columns follow the permutation: column 0 must be the
        // eigenvector of the entry 1.0 (original column 3).
        assert_eq!(e.eigenvectors[(3, 0)], 1.0);
        assert_eq!(e.eigenvectors[(1, 1)], 1.0);
        assert_eq!(e.eigenvectors[(2, 2)], 1.0);
        assert_eq!(e.eigenvectors[(0, 3)], 1.0);
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        let mut rng = Rng::seed_from(4);
        let mut b = Matrix::zeros(10, 30);
        rng.fill_normal(b.data_mut());
        let e = eigh(&b.gram());
        assert!(e.eigenvalues.iter().all(|&w| w > -1e-9));
    }

    #[test]
    fn into_variant_matches_allocating_bitwise_and_reuses_pool() {
        let mut rng = Rng::seed_from(5);
        let a = random_symmetric(&mut rng, 18);
        let reference = eigh(&a);

        let mut ws = Workspace::new();
        let mut evals = vec![0.0; 18];
        let mut evecs = ws.take_matrix_scratch(18, 18);
        eigh_into(&a, &mut evals, &mut evecs, &mut ws);
        for (x, y) in evals.iter().zip(&reference.eigenvalues) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(evecs.max_abs_diff(&reference.eigenvectors), 0.0);

        // Steady state: a second decomposition of the same shape draws its
        // scratch entirely from the pool.
        let fresh = ws.stats().fresh_allocs;
        eigh_into(&a, &mut evals, &mut evecs, &mut ws);
        assert_eq!(ws.stats().fresh_allocs, fresh, "second eigh allocated");
        ws.recycle_matrix(evecs);
    }

    #[test]
    fn empty_matrix_is_fine() {
        let a = Matrix::zeros(0, 0);
        let e = eigh(&a);
        assert!(e.eigenvalues.is_empty());
        assert_eq!((e.eigenvectors.rows(), e.eigenvectors.cols()), (0, 0));
    }
}
