//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! This is the SVD-class dense factorization in our stack: the *standard
//! stable* Nyström baseline (Frangella–Tropp–Udell alg. 2.1) needs an
//! economy SVD of the sketch `B ∈ R^{N×S}`, which we obtain from the
//! eigendecomposition of the small `S×S` Gram matrix `BᵀB` (see
//! `nystrom::stable`). Jacobi is slower than LAPACK's tridiagonalization
//! pipelines but unconditionally robust and embarrassingly simple to verify —
//! and its cost *is the point* of the paper's Appendix-B benchmark: the
//! GPU-efficient variant exists precisely to avoid paying for it.

use super::matrix::Matrix;

/// Eigendecomposition `A = V diag(w) Vᵀ` with eigenvalues ascending.
pub struct Eigh {
    /// Eigenvalues, ascending.
    pub eigenvalues: Vec<f64>,
    /// Columns are the corresponding eigenvectors.
    pub eigenvectors: Matrix,
}

/// Cyclic Jacobi with threshold sweeps. Converges quadratically once
/// off-diagonal mass is small; we cap at 30 sweeps (typ. ≤ 12 for our sizes).
pub fn eigh(a: &Matrix) -> Eigh {
    assert_eq!(a.rows(), a.cols(), "eigh needs a square matrix");
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Matrix::identity(n);

    if n == 0 {
        return Eigh {
            eigenvalues: vec![],
            eigenvectors: v,
        };
    }

    for _sweep in 0..30 {
        // Off-diagonal Frobenius mass.
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (1.0 + m.frobenius_norm()) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Stable rotation computation (Golub & Van Loan §8.5).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    1.0 / (tau - (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // A ← JᵀAJ, applied to rows/cols p and q.
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // Accumulate V ← VJ.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Sort ascending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| m[(i, i)].partial_cmp(&m[(j, j)]).unwrap());
    let eigenvalues: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let mut eigenvectors = Matrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            eigenvectors[(i, new_j)] = v[(i, old_j)];
        }
    }
    Eigh {
        eigenvalues,
        eigenvectors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_symmetric(rng: &mut Rng, n: usize) -> Matrix {
        let mut a = Matrix::zeros(n, n);
        rng.fill_normal(a.data_mut());
        let at = a.transpose();
        let mut s = a;
        s.add_scaled(&at, 1.0);
        s.scale_in_place(0.5);
        s
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::seed_from(1);
        for n in [1, 2, 3, 10, 40] {
            let a = random_symmetric(&mut rng, n);
            let e = eigh(&a);
            // A V = V diag(w)
            let av = a.matmul(&e.eigenvectors);
            for j in 0..n {
                for i in 0..n {
                    let want = e.eigenvectors[(i, j)] * e.eigenvalues[j];
                    assert!((av[(i, j)] - want).abs() < 1e-9, "n={n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let mut rng = Rng::seed_from(2);
        let a = random_symmetric(&mut rng, 25);
        let e = eigh(&a);
        let vtv = e.eigenvectors.transpose().matmul(&e.eigenvectors);
        assert!(vtv.max_abs_diff(&Matrix::identity(25)) < 1e-10);
    }

    #[test]
    fn known_eigenvalues() {
        // [[2, 1], [1, 2]] has eigenvalues 1 and 3.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = eigh(&a);
        assert!((e.eigenvalues[0] - 1.0).abs() < 1e-12);
        assert!((e.eigenvalues[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn trace_and_det_invariants() {
        let mut rng = Rng::seed_from(3);
        let a = random_symmetric(&mut rng, 15);
        let e = eigh(&a);
        let trace: f64 = (0..15).map(|i| a[(i, i)]).sum();
        let sum: f64 = e.eigenvalues.iter().sum();
        assert!((trace - sum).abs() < 1e-9);
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        let mut rng = Rng::seed_from(4);
        let mut b = Matrix::zeros(10, 30);
        rng.fill_normal(b.data_mut());
        let e = eigh(&b.gram());
        assert!(e.eigenvalues.iter().all(|&w| w > -1e-9));
    }
}
