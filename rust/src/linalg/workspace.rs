//! Step-workspace buffer pool.
//!
//! The decomposed optimizer paths allocate several large temporaries per
//! step — the N×N Gram matrix, Gaussian sketches Ω, sketch products Y, the
//! Nyström factors B/U, and ℓ×ℓ cores. At a few hundred steps per run those
//! allocations (and the page faults behind them) are pure overhead: the
//! shapes repeat every step. [`Workspace`] is a trivially simple checkout /
//! check-in pool owned by the trainer and threaded through
//! [`crate::optim::StepEnv`]: `take` hands out a recycled buffer when one
//! with enough capacity exists, `recycle` returns it for the next step.
//!
//! The pool tracks [`WorkspaceStats`] so tests (and the perf harness) can
//! assert steady-state behavior: after the first step of a fixed-shape
//! training loop, `fresh_allocs` must stop growing — everything later is a
//! reuse. See `rust/tests/properties.rs::prop_kernel_solve_reuses_workspace`.
//!
//! Scope: the invariant covers *pool-tracked* buffers — everything the
//! solve paths check out via `take*`. Since the `thin_qr_into`/`eigh_into`
//! refactor the stable-Nyström path draws its QR and eigendecomposition
//! interiors from the pool as well, so no dense temporary on any
//! `SolveMode` branch escapes the accounting.

use super::matrix::Matrix;

/// Allocation counters for pool-behavior assertions and perf reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// `take` calls that had to allocate a brand-new buffer.
    pub fresh_allocs: usize,
    /// `take` calls served from the pool without growing capacity.
    pub reuses: usize,
    /// `take` calls served from the pool but forced to grow capacity.
    pub grown: usize,
}

impl WorkspaceStats {
    /// Total checkouts.
    pub fn takes(&self) -> usize {
        self.fresh_allocs + self.reuses + self.grown
    }
}

/// A checkout/check-in pool of `Vec<f64>` buffers (and `Matrix` wrappers),
/// plus a sibling `Vec<f32>` pool for the relaxed-numerics sketch tier.
#[derive(Debug, Default)]
pub struct Workspace {
    /// Returned buffers, unordered; `take` picks the best (tightest) fit.
    free: Vec<Vec<f64>>,
    /// Returned f32 buffers (the `--numerics fast` Gram/sketch pack tier).
    free32: Vec<Vec<f32>>,
    stats: WorkspaceStats,
}

/// Pool-size cap: a single solve keeps at most a handful of buffers in
/// flight, so anything beyond this is drift (e.g. a fresh QR output checked
/// in every step). Past the cap, `recycle` keeps the largest buffers and
/// drops the rest, bounding pool memory for arbitrarily long runs.
const MAX_POOLED_BUFFERS: usize = 32;

impl Workspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pull the best-fitting buffer out of the pool (stats-tracked), with
    /// unspecified length/contents.
    ///
    /// Fit policy: the free buffer with the smallest sufficient capacity is
    /// reused; if none is large enough but the pool is non-empty, the
    /// largest free buffer is grown (counted in [`WorkspaceStats::grown`]);
    /// only an empty pool allocates from scratch.
    fn checkout(&mut self, len: usize) -> Vec<f64> {
        checkout_from(&mut self.free, &mut self.stats, len)
    }

    /// Check out a zero-filled buffer of exactly `len` elements.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        let mut buf = self.checkout(len);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Check out a buffer of exactly `len` elements *without* zeroing —
    /// contents are unspecified stale values. For consumers that overwrite
    /// every element anyway (the `*_into` kernels, `copy_from_slice`,
    /// `fill_normal`), this skips a redundant O(len) memset per checkout on
    /// the hot path.
    pub fn take_scratch(&mut self, len: usize) -> Vec<f64> {
        let mut buf = self.checkout(len);
        if buf.len() > len {
            buf.truncate(len);
        } else {
            buf.resize(len, 0.0);
        }
        buf
    }

    /// Check out a zero-filled `rows × cols` matrix.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Check out a `rows × cols` matrix with unspecified contents (see
    /// [`Workspace::take_scratch`]); the caller must overwrite every
    /// element before reading.
    pub fn take_matrix_scratch(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.take_scratch(rows * cols))
    }

    /// Return a buffer to the pool for reuse.
    pub fn recycle(&mut self, buf: Vec<f64>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.free.len() < MAX_POOLED_BUFFERS {
            self.free.push(buf);
            return;
        }
        // At capacity: keep the larger of (incoming, smallest pooled).
        let smallest = self
            .free
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        if let Some(i) = smallest {
            if self.free[i].capacity() < buf.capacity() {
                self.free[i] = buf;
            }
        }
    }

    /// Return a matrix's backing buffer to the pool.
    pub fn recycle_matrix(&mut self, m: Matrix) {
        self.recycle(m.into_vec());
    }

    /// Check out an f32 buffer of exactly `len` elements without zeroing —
    /// the pack buffers of the `--numerics fast` Gram/sketch tier overwrite
    /// every element. Tracked by the same [`WorkspaceStats`] counters as the
    /// f64 pool, so the steady-state freeze assertions cover this tier too.
    pub fn take_scratch_f32(&mut self, len: usize) -> Vec<f32> {
        let mut buf = checkout_from(&mut self.free32, &mut self.stats, len);
        if buf.len() > len {
            buf.truncate(len);
        } else {
            buf.resize(len, 0.0);
        }
        buf
    }

    /// Return an f32 buffer to the pool for reuse.
    pub fn recycle_f32(&mut self, buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        if self.free32.len() < MAX_POOLED_BUFFERS {
            self.free32.push(buf);
            return;
        }
        let smallest = self
            .free32
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| b.capacity())
            .map(|(i, _)| i);
        if let Some(i) = smallest {
            if self.free32[i].capacity() < buf.capacity() {
                self.free32[i] = buf;
            }
        }
    }

    /// Allocation counters since creation.
    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }

    /// Number of buffers currently checked in.
    pub fn pooled_buffers(&self) -> usize {
        self.free.len()
    }

    /// Total pooled capacity in elements (f64s).
    pub fn pooled_capacity(&self) -> usize {
        self.free.iter().map(|b| b.capacity()).sum()
    }
}

/// Best-fit checkout shared by the f64 and f32 pools: tightest sufficient
/// capacity wins; an undersized non-empty pool grows its largest buffer; an
/// empty pool allocates fresh.
fn checkout_from<T>(free: &mut Vec<Vec<T>>, stats: &mut WorkspaceStats, len: usize) -> Vec<T> {
    let best = free
        .iter()
        .enumerate()
        .filter(|(_, b)| b.capacity() >= len)
        .min_by_key(|(_, b)| b.capacity())
        .map(|(i, _)| i);
    match best {
        Some(i) => {
            stats.reuses += 1;
            free.swap_remove(i)
        }
        None => {
            let largest = free
                .iter()
                .enumerate()
                .max_by_key(|(_, b)| b.capacity())
                .map(|(i, _)| i);
            match largest {
                Some(i) => {
                    stats.grown += 1;
                    free.swap_remove(i)
                }
                None => {
                    stats.fresh_allocs += 1;
                    Vec::new()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_take_of_same_shape_reuses() {
        let mut ws = Workspace::new();
        let a = ws.take(128);
        ws.recycle(a);
        let b = ws.take(128);
        assert_eq!(
            ws.stats(),
            WorkspaceStats {
                fresh_allocs: 1,
                reuses: 1,
                grown: 0
            }
        );
        assert_eq!(b.len(), 128);
        assert!(b.iter().all(|&x| x == 0.0));
        ws.recycle(b);
    }

    #[test]
    fn best_fit_prefers_tightest_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        ws.recycle(big);
        ws.recycle(small);
        let c = ws.take(8); // must come from the 10-capacity buffer
        assert!(c.capacity() < 1000);
        assert_eq!(ws.pooled_buffers(), 1);
        assert_eq!(ws.pooled_capacity(), 1000);
        ws.recycle(c);
    }

    #[test]
    fn growth_is_counted_not_hidden() {
        let mut ws = Workspace::new();
        let a = ws.take(16);
        ws.recycle(a);
        let b = ws.take(64); // pool non-empty but too small: grow
        assert_eq!(b.len(), 64);
        let s = ws.stats();
        assert_eq!((s.fresh_allocs, s.grown), (1, 1));
        ws.recycle(b);
    }

    #[test]
    fn take_matrix_round_trips_through_pool() {
        let mut ws = Workspace::new();
        let m = ws.take_matrix(6, 7);
        assert_eq!((m.rows(), m.cols()), (6, 7));
        ws.recycle_matrix(m);
        let m2 = ws.take_matrix(7, 6);
        assert_eq!(ws.stats().reuses, 1);
        assert!(m2.data().iter().all(|&x| x == 0.0));
        ws.recycle_matrix(m2);
    }

    #[test]
    fn pool_is_bounded_and_prefers_large_buffers() {
        let mut ws = Workspace::new();
        for _ in 0..MAX_POOLED_BUFFERS {
            ws.recycle(vec![0.0; 4]);
        }
        assert_eq!(ws.pooled_buffers(), MAX_POOLED_BUFFERS);
        // Past the cap a big buffer displaces a small one...
        ws.recycle(vec![0.0; 512]);
        assert_eq!(ws.pooled_buffers(), MAX_POOLED_BUFFERS);
        assert!(ws.pooled_capacity() >= 512 + 4 * (MAX_POOLED_BUFFERS - 1));
        // ...and a small one is simply dropped.
        let before = ws.pooled_capacity();
        ws.recycle(vec![0.0; 1]);
        assert_eq!(ws.pooled_capacity(), before);
    }

    #[test]
    fn stale_contents_are_zeroed_on_reuse() {
        let mut ws = Workspace::new();
        let mut a = ws.take(32);
        a.iter_mut().for_each(|x| *x = f64::NAN);
        ws.recycle(a);
        let b = ws.take(32);
        assert!(b.iter().all(|&x| x == 0.0));
        ws.recycle(b);
    }

    #[test]
    fn scratch_checkout_skips_the_memset() {
        let mut ws = Workspace::new();
        let mut a = ws.take(16);
        a.iter_mut().for_each(|x| *x = 7.0);
        ws.recycle(a);
        // Same-size scratch reuse keeps the stale contents (no zero pass).
        let b = ws.take_scratch(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&x| x == 7.0));
        ws.recycle(b);
        // Shrinking truncates; growing within capacity zero-extends the
        // tail only.
        let c = ws.take_scratch(8);
        assert_eq!(c.len(), 8);
        assert!(c.iter().all(|&x| x == 7.0));
        ws.recycle(c);
    }
}
