//! Flat-vector helpers used throughout the optimizer suite.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// `a - b` as a new vector.
#[inline]
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_norm() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0, 0.0, 1.0, -1.0, 0.5];
        assert_eq!(dot(&a, &b), 2.0 + 3.0 - 4.0 + 2.5);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_scale_sub() {
        let x = [1.0, -1.0, 2.0];
        let mut y = [0.5, 0.5, 0.5];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [2.5, -1.5, 4.5]);
        scale(2.0, &mut y);
        assert_eq!(y, [5.0, -3.0, 9.0]);
        assert_eq!(sub(&y, &[1.0, 1.0, 1.0]), vec![4.0, -4.0, 8.0]);
    }
}
