//! Fused BLAS-style product kernels: transpose products without transposes.
//!
//! The paper's cost model (eq. 5, Alg. 1, eq. 9) assumes `JᵀΩ`, `JᵀJ`, and
//! `BᵀB` are *single* passes over row-major data — but the seed code spelled
//! them `j.transpose().matmul(..)`, materializing an O(N·P) copy on every
//! optimizer step. This module adds the fused forms:
//!
//! * [`Matrix::matmul_tn`] — `C = AᵀB` (the sketch product `JᵀΩ`, the
//!   Nyström cores `ΩᵀY` and `BᵀB`),
//! * [`Matrix::matmul_nt`] — `C = ABᵀ` (dense reconstructions `BBᵀ`),
//! * [`Matrix::gram_t`] — `G = AᵀA` (dense ENGD's P×P Gramian),
//! * [`Matrix::gram_into`] and the other `*_into` variants, which write
//!   into caller-provided buffers so the trainer's [`super::Workspace`]
//!   can recycle them across steps.
//!
//! All kernels are blocked over [`MC`]×[`KC`] panels and thread-parallel via
//! [`par_chunks`]/[`par_dynamic`], exactly like the original `matmul`; the
//! accumulation order per output element matches the j-innermost axpy
//! schedule, so fused and materialized paths agree to rounding.

//!
//! The `*_fast` variants are the relaxed-numerics tier (`--numerics fast`):
//! operands are packed once into workspace-pooled f32 buffers and products
//! are formed in f32 but accumulated in f64, halving operand bandwidth on
//! the Gram/sketch hot spots. They are tolerance-verified against the f64
//! kernels and never run in the default bitwise mode.

use super::matrix::{Matrix, KC, MC};
use super::workspace::Workspace;
use crate::parallel::{par_chunks, par_dynamic, SendPtr};

impl Matrix {
    /// Blocked, multi-threaded `C = A @ B` into a caller-provided buffer.
    ///
    /// `out` must be `self.rows() × b.cols()`; its previous contents are
    /// overwritten.
    pub fn matmul_into(&self, b: &Matrix, out: &mut Matrix) {
        let (m, k_dim) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(
            k_dim,
            b.rows(),
            "matmul shape mismatch: {}x{} @ {}x{}",
            m,
            k_dim,
            b.rows(),
            n
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for i in i0..i1 {
                        // SAFETY: each thread owns disjoint row panels of C.
                        let c_row: &mut [f64] = unsafe {
                            std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                        };
                        let a_row = self.row(i);
                        for k in k0..k1 {
                            let aik = a_row[k];
                            if aik == 0.0 {
                                continue;
                            }
                            let b_row = b.row(k);
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += aik * bv;
                            }
                        }
                    }
                }
            }
        });
    }

    /// Fused transpose product `C = Aᵀ @ B` (no transpose is materialized).
    ///
    /// `self` is K×M, `b` is K×N, the result M×N. This is the sketch map
    /// `JᵀΩ` of eq. 9 and the Nyström cores `ΩᵀY`, `BᵀB` of Algorithm 2.
    pub fn matmul_tn(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.cols(), b.cols());
        self.matmul_tn_into(b, &mut c);
        c
    }

    /// `C = Aᵀ @ B` into a caller-provided M×N buffer (overwritten).
    ///
    /// Row k of A and row k of B contribute the rank-1 update
    /// `C[i, :] += A[k, i] · B[k, :]`; both operands stream row-major, and
    /// threads own disjoint row panels of C (disjoint column ranges of A).
    pub fn matmul_tn_into(&self, b: &Matrix, out: &mut Matrix) {
        let (k_dim, m) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(
            k_dim,
            b.rows(),
            "matmul_tn shape mismatch: ({}x{})ᵀ @ {}x{}",
            k_dim,
            m,
            b.rows(),
            n
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_tn_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for k in k0..k1 {
                        let a_row = self.row(k);
                        let b_row = b.row(k);
                        for i in i0..i1 {
                            let aki = a_row[i];
                            if aki == 0.0 {
                                continue;
                            }
                            // SAFETY: disjoint C row panels per thread.
                            let c_row: &mut [f64] = unsafe {
                                std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                            };
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += aki * bv;
                            }
                        }
                    }
                }
            }
        });
    }

    /// Fused transpose product `C = A @ Bᵀ` (no transpose is materialized).
    ///
    /// `self` is M×K, `b` is N×K, the result M×N: pure row-dot form, the
    /// friendliest access pattern row-major data allows.
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.rows(), b.rows());
        self.matmul_nt_into(b, &mut c);
        c
    }

    /// `C = A @ Bᵀ` into a caller-provided M×N buffer (overwritten).
    pub fn matmul_nt_into(&self, b: &Matrix, out: &mut Matrix) {
        let (m, k_dim) = (self.rows(), self.cols());
        let n = b.rows();
        assert_eq!(
            k_dim,
            b.cols(),
            "matmul_nt shape mismatch: {}x{} @ ({}x{})ᵀ",
            m,
            k_dim,
            n,
            b.cols()
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_nt_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m, |istart, iend| {
            for i in istart..iend {
                let a_row = self.row(i);
                // SAFETY: thread writes only rows in [istart, iend).
                let c_row: &mut [f64] =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n) };
                for (j, c) in c_row.iter_mut().enumerate() {
                    *c = super::vec_ops::dot(a_row, b.row(j));
                }
            }
        });
    }

    /// Symmetric Gram product `K = A @ Aᵀ` into a caller-provided buffer
    /// (the kernel build of eq. 5 on a workspace-pooled N×N matrix).
    ///
    /// Computes the lower triangle in parallel over row blocks and mirrors.
    pub fn gram_into(&self, out: &mut Matrix) {
        let n = self.rows();
        assert_eq!(
            (out.rows(), out.cols()),
            (n, n),
            "gram_into output must be {n}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        let k_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(n, |istart, iend| {
            for i in istart..iend {
                let ai = self.row(i);
                // SAFETY: thread writes only rows in [istart, iend).
                let k_row: &mut [f64] =
                    unsafe { std::slice::from_raw_parts_mut(k_ptr.get().add(i * n), n) };
                for j in 0..=i {
                    k_row[j] = super::vec_ops::dot(ai, self.row(j));
                }
            }
        });
        // Mirror the strict lower triangle.
        for i in 0..n {
            for j in (i + 1)..n {
                out[(i, j)] = out[(j, i)];
            }
        }
    }

    /// Fused column Gramian `G = Aᵀ @ A` (dense ENGD's P×P matrix, eq. 1)
    /// without materializing `Aᵀ`.
    pub fn gram_t(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols(), self.cols());
        self.gram_t_into(&mut g);
        g
    }

    /// `G = Aᵀ @ A` into a caller-provided P×P buffer (overwritten).
    ///
    /// Each row `a_k` of A contributes the rank-1 update `G += a_k a_kᵀ`;
    /// only the upper triangle is accumulated (then mirrored). Work is
    /// stolen in MC-row panels of G because triangular panels are uneven.
    pub fn gram_t_into(&self, out: &mut Matrix) {
        let p = self.cols();
        let n_rows = self.rows();
        assert_eq!(
            (out.rows(), out.cols()),
            (p, p),
            "gram_t_into output must be {p}x{p}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let g_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_dynamic(p.div_ceil(MC), |panel| {
            let i0 = panel * MC;
            let i1 = (i0 + MC).min(p);
            for k in 0..n_rows {
                let a_row = self.row(k);
                for i in i0..i1 {
                    let aki = a_row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    // SAFETY: disjoint G row panels per work item; only the
                    // suffix [i, p) of row i (the upper triangle) is written.
                    let g_row: &mut [f64] = unsafe {
                        std::slice::from_raw_parts_mut(g_ptr.get().add(i * p + i), p - i)
                    };
                    for (g, &av) in g_row.iter_mut().zip(&a_row[i..]) {
                        *g += aki * av;
                    }
                }
            }
        });
        // Mirror the strict upper triangle down.
        for i in 0..p {
            for j in (i + 1)..p {
                out[(j, i)] = out[(i, j)];
            }
        }
    }

    // ----- relaxed-numerics (f32-compute / f64-accumulate) tier ----------

    /// Pack the row-major buffer into a pooled f32 copy (fast tier only).
    fn pack_f32(&self, ws: &mut Workspace) -> Vec<f32> {
        let mut buf = ws.take_scratch_f32(self.rows() * self.cols());
        for (dst, &src) in buf.iter_mut().zip(self.data()) {
            *dst = src as f32;
        }
        buf
    }

    /// Fast-tier `C = A @ B`: f32 operand panels, f64 accumulators.
    pub fn matmul_into_fast(&self, b: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        let (m, k_dim) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(k_dim, b.rows(), "matmul shape mismatch: {m}x{k_dim} @ {}x{n}", b.rows());
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_into_fast output must be {m}x{n}"
        );
        let a32 = self.pack_f32(ws);
        let b32 = b.pack_f32(ws);
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for i in i0..i1 {
                        // SAFETY: each thread owns disjoint row panels of C.
                        let c_row: &mut [f64] = unsafe {
                            std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                        };
                        let a_row = &a32[i * k_dim..(i + 1) * k_dim];
                        for k in k0..k1 {
                            let aik = a_row[k];
                            if aik == 0.0 {
                                continue;
                            }
                            let b_row = &b32[k * n..(k + 1) * n];
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += (aik * bv) as f64;
                            }
                        }
                    }
                }
            }
        });
        ws.recycle_f32(b32);
        ws.recycle_f32(a32);
    }

    /// Fast-tier `C = Aᵀ @ B` (the sketch map `JᵀΩ` under `--numerics fast`).
    pub fn matmul_tn_into_fast(&self, b: &Matrix, out: &mut Matrix, ws: &mut Workspace) {
        let (k_dim, m) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(
            k_dim,
            b.rows(),
            "matmul_tn shape mismatch: ({k_dim}x{m})ᵀ @ {}x{n}",
            b.rows()
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_tn_into_fast output must be {m}x{n}"
        );
        let a32 = self.pack_f32(ws);
        let b32 = b.pack_f32(ws);
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for k in k0..k1 {
                        let a_row = &a32[k * m..(k + 1) * m];
                        let b_row = &b32[k * n..(k + 1) * n];
                        for i in i0..i1 {
                            let aki = a_row[i];
                            if aki == 0.0 {
                                continue;
                            }
                            // SAFETY: disjoint C row panels per thread.
                            let c_row: &mut [f64] = unsafe {
                                std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                            };
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += (aki * bv) as f64;
                            }
                        }
                    }
                }
            }
        });
        ws.recycle_f32(b32);
        ws.recycle_f32(a32);
    }

    /// Fast-tier Gram product `K = A @ Aᵀ` (eq. 5's kernel build).
    pub fn gram_into_fast(&self, out: &mut Matrix, ws: &mut Workspace) {
        let n = self.rows();
        let p = self.cols();
        assert_eq!(
            (out.rows(), out.cols()),
            (n, n),
            "gram_into_fast output must be {n}x{n}"
        );
        let a32 = self.pack_f32(ws);
        let k_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(n, |istart, iend| {
            for i in istart..iend {
                let ai = &a32[i * p..(i + 1) * p];
                // SAFETY: thread writes only rows in [istart, iend).
                let k_row: &mut [f64] =
                    unsafe { std::slice::from_raw_parts_mut(k_ptr.get().add(i * n), n) };
                for j in 0..=i {
                    k_row[j] = dot_f32(ai, &a32[j * p..(j + 1) * p]);
                }
            }
        });
        ws.recycle_f32(a32);
        // Mirror the strict lower triangle.
        for i in 0..n {
            for j in (i + 1)..n {
                out[(i, j)] = out[(j, i)];
            }
        }
    }

    /// Fast-tier column Gramian `G = Aᵀ @ A` (dense ENGD's P×P matrix).
    pub fn gram_t_into_fast(&self, out: &mut Matrix, ws: &mut Workspace) {
        let p = self.cols();
        let n_rows = self.rows();
        assert_eq!(
            (out.rows(), out.cols()),
            (p, p),
            "gram_t_into_fast output must be {p}x{p}"
        );
        let a32 = self.pack_f32(ws);
        out.data_mut().fill(0.0);
        let g_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_dynamic(p.div_ceil(MC), |panel| {
            let i0 = panel * MC;
            let i1 = (i0 + MC).min(p);
            for k in 0..n_rows {
                let a_row = &a32[k * p..(k + 1) * p];
                for i in i0..i1 {
                    let aki = a_row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    // SAFETY: disjoint G row panels per work item; only the
                    // suffix [i, p) of row i (the upper triangle) is written.
                    let g_row: &mut [f64] = unsafe {
                        std::slice::from_raw_parts_mut(g_ptr.get().add(i * p + i), p - i)
                    };
                    for (g, &av) in g_row.iter_mut().zip(&a_row[i..]) {
                        *g += (aki * av) as f64;
                    }
                }
            }
        });
        ws.recycle_f32(a32);
        // Mirror the strict upper triangle down.
        for i in 0..p {
            for j in (i + 1)..p {
                out[(j, i)] = out[(i, j)];
            }
        }
    }
}

/// 4-way unrolled dot with f32 products and f64 partial sums (fast tier).
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += (a[i] * b[i]) as f64;
        s1 += (a[i + 1] * b[i + 1]) as f64;
        s2 += (a[i + 2] * b[i + 2]) as f64;
        s3 += (a[i + 3] * b[i + 3]) as f64;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += (a[i] * b[i]) as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    /// Shapes spanning square, tall (N≫P), and wide (N≪P) regimes.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (17, 33, 9),
        (2, 70, 40),
        (70, 2, 40),
        (128, 64, 96),
    ];

    #[test]
    fn matmul_tn_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(1);
        for &(k, m, n) in SHAPES {
            let a = random_matrix(&mut rng, k, m);
            let b = random_matrix(&mut rng, k, n);
            let fused = a.matmul_tn(&b);
            let reference = a.transpose().matmul(&b);
            assert!(
                fused.max_abs_diff(&reference) < 1e-10,
                "tn ({k},{m},{n})"
            );
        }
    }

    #[test]
    fn matmul_nt_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(2);
        for &(m, k, n) in SHAPES {
            let a = random_matrix(&mut rng, m, k);
            let b = random_matrix(&mut rng, n, k);
            let fused = a.matmul_nt(&b);
            let reference = a.matmul(&b.transpose());
            assert!(
                fused.max_abs_diff(&reference) < 1e-10,
                "nt ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn gram_t_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(3);
        for &(n, p) in &[(1usize, 4usize), (7, 3), (33, 65), (64, 128), (100, 50)] {
            let a = random_matrix(&mut rng, n, p);
            let fused = a.gram_t();
            let reference = a.transpose().gram();
            assert!(fused.max_abs_diff(&reference) < 1e-10, "({n},{p})");
            for i in 0..p {
                for j in 0..p {
                    assert_eq!(fused[(i, j)], fused[(j, i)], "asymmetry at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn into_variants_overwrite_stale_contents() {
        let mut rng = Rng::seed_from(4);
        let a = random_matrix(&mut rng, 20, 12);
        let b = random_matrix(&mut rng, 20, 7);
        let mut out = Matrix::from_fn(12, 7, |_, _| f64::NAN);
        a.matmul_tn_into(&b, &mut out);
        assert!(out.data().iter().all(|x| x.is_finite()));
        assert!(out.max_abs_diff(&a.transpose().matmul(&b)) < 1e-10);

        let mut k = Matrix::from_fn(20, 20, |_, _| f64::NAN);
        a.gram_into(&mut k);
        assert!(k.max_abs_diff(&a.matmul(&a.transpose())) < 1e-10);
    }

    #[test]
    fn fast_tier_matches_f64_within_tolerance() {
        let mut rng = Rng::seed_from(9);
        let mut ws = Workspace::new();
        let a = random_matrix(&mut rng, 48, 24);
        let b = random_matrix(&mut rng, 48, 7);
        let tol = 1e-3;

        let mut tn = Matrix::zeros(24, 7);
        a.matmul_tn_into_fast(&b, &mut tn, &mut ws);
        assert!(tn.max_abs_diff(&a.matmul_tn(&b)) < tol);

        let c = random_matrix(&mut rng, 24, 9);
        let mut mm = Matrix::zeros(48, 9);
        a.matmul_into_fast(&c, &mut mm, &mut ws);
        assert!(mm.max_abs_diff(&a.matmul(&c)) < tol);

        let mut k = Matrix::zeros(48, 48);
        a.gram_into_fast(&mut k, &mut ws);
        assert!(k.max_abs_diff(&a.gram()) < tol);

        let mut g = Matrix::zeros(24, 24);
        a.gram_t_into_fast(&mut g, &mut ws);
        assert!(g.max_abs_diff(&a.gram_t()) < tol);

        // Steady state: a second pass re-packs into the pooled f32 buffers.
        let fresh = ws.stats().fresh_allocs;
        a.gram_into_fast(&mut k, &mut ws);
        a.matmul_tn_into_fast(&b, &mut tn, &mut ws);
        assert_eq!(ws.stats().fresh_allocs, fresh, "fast tier allocated at steady state");
    }

    #[test]
    #[should_panic(expected = "matmul_tn shape mismatch")]
    fn tn_shape_mismatch_panics() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul_tn(&b);
    }

    #[test]
    #[should_panic(expected = "matmul_tn_into output must be")]
    fn tn_into_output_shape_panics() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(3, 5);
        let mut out = Matrix::zeros(2, 4);
        a.matmul_tn_into(&b, &mut out);
    }
}
