//! Fused BLAS-style product kernels: transpose products without transposes.
//!
//! The paper's cost model (eq. 5, Alg. 1, eq. 9) assumes `JᵀΩ`, `JᵀJ`, and
//! `BᵀB` are *single* passes over row-major data — but the seed code spelled
//! them `j.transpose().matmul(..)`, materializing an O(N·P) copy on every
//! optimizer step. This module adds the fused forms:
//!
//! * [`Matrix::matmul_tn`] — `C = AᵀB` (the sketch product `JᵀΩ`, the
//!   Nyström cores `ΩᵀY` and `BᵀB`),
//! * [`Matrix::matmul_nt`] — `C = ABᵀ` (dense reconstructions `BBᵀ`),
//! * [`Matrix::gram_t`] — `G = AᵀA` (dense ENGD's P×P Gramian),
//! * [`Matrix::gram_into`] and the other `*_into` variants, which write
//!   into caller-provided buffers so the trainer's [`super::Workspace`]
//!   can recycle them across steps.
//!
//! All kernels are blocked over [`MC`]×[`KC`] panels and thread-parallel via
//! [`par_chunks`]/[`par_dynamic`], exactly like the original `matmul`; the
//! accumulation order per output element matches the j-innermost axpy
//! schedule, so fused and materialized paths agree to rounding.

use super::matrix::{Matrix, KC, MC};
use crate::parallel::{par_chunks, par_dynamic, SendPtr};

impl Matrix {
    /// Blocked, multi-threaded `C = A @ B` into a caller-provided buffer.
    ///
    /// `out` must be `self.rows() × b.cols()`; its previous contents are
    /// overwritten.
    pub fn matmul_into(&self, b: &Matrix, out: &mut Matrix) {
        let (m, k_dim) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(
            k_dim,
            b.rows(),
            "matmul shape mismatch: {}x{} @ {}x{}",
            m,
            k_dim,
            b.rows(),
            n
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for i in i0..i1 {
                        // SAFETY: each thread owns disjoint row panels of C.
                        let c_row: &mut [f64] = unsafe {
                            std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                        };
                        let a_row = self.row(i);
                        for k in k0..k1 {
                            let aik = a_row[k];
                            if aik == 0.0 {
                                continue;
                            }
                            let b_row = b.row(k);
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += aik * bv;
                            }
                        }
                    }
                }
            }
        });
    }

    /// Fused transpose product `C = Aᵀ @ B` (no transpose is materialized).
    ///
    /// `self` is K×M, `b` is K×N, the result M×N. This is the sketch map
    /// `JᵀΩ` of eq. 9 and the Nyström cores `ΩᵀY`, `BᵀB` of Algorithm 2.
    pub fn matmul_tn(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.cols(), b.cols());
        self.matmul_tn_into(b, &mut c);
        c
    }

    /// `C = Aᵀ @ B` into a caller-provided M×N buffer (overwritten).
    ///
    /// Row k of A and row k of B contribute the rank-1 update
    /// `C[i, :] += A[k, i] · B[k, :]`; both operands stream row-major, and
    /// threads own disjoint row panels of C (disjoint column ranges of A).
    pub fn matmul_tn_into(&self, b: &Matrix, out: &mut Matrix) {
        let (k_dim, m) = (self.rows(), self.cols());
        let n = b.cols();
        assert_eq!(
            k_dim,
            b.rows(),
            "matmul_tn shape mismatch: ({}x{})ᵀ @ {}x{}",
            k_dim,
            m,
            b.rows(),
            n
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_tn_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m.div_ceil(MC), |pstart, pend| {
            for panel in pstart..pend {
                let i0 = panel * MC;
                let i1 = (i0 + MC).min(m);
                for k0 in (0..k_dim).step_by(KC) {
                    let k1 = (k0 + KC).min(k_dim);
                    for k in k0..k1 {
                        let a_row = self.row(k);
                        let b_row = b.row(k);
                        for i in i0..i1 {
                            let aki = a_row[i];
                            if aki == 0.0 {
                                continue;
                            }
                            // SAFETY: disjoint C row panels per thread.
                            let c_row: &mut [f64] = unsafe {
                                std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n)
                            };
                            for (c, &bv) in c_row.iter_mut().zip(b_row) {
                                *c += aki * bv;
                            }
                        }
                    }
                }
            }
        });
    }

    /// Fused transpose product `C = A @ Bᵀ` (no transpose is materialized).
    ///
    /// `self` is M×K, `b` is N×K, the result M×N: pure row-dot form, the
    /// friendliest access pattern row-major data allows.
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.rows(), b.rows());
        self.matmul_nt_into(b, &mut c);
        c
    }

    /// `C = A @ Bᵀ` into a caller-provided M×N buffer (overwritten).
    pub fn matmul_nt_into(&self, b: &Matrix, out: &mut Matrix) {
        let (m, k_dim) = (self.rows(), self.cols());
        let n = b.rows();
        assert_eq!(
            k_dim,
            b.cols(),
            "matmul_nt shape mismatch: {}x{} @ ({}x{})ᵀ",
            m,
            k_dim,
            n,
            b.cols()
        );
        assert_eq!(
            (out.rows(), out.cols()),
            (m, n),
            "matmul_nt_into output must be {m}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        let c_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(m, |istart, iend| {
            for i in istart..iend {
                let a_row = self.row(i);
                // SAFETY: thread writes only rows in [istart, iend).
                let c_row: &mut [f64] =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(i * n), n) };
                for (j, c) in c_row.iter_mut().enumerate() {
                    *c = super::vec_ops::dot(a_row, b.row(j));
                }
            }
        });
    }

    /// Symmetric Gram product `K = A @ Aᵀ` into a caller-provided buffer
    /// (the kernel build of eq. 5 on a workspace-pooled N×N matrix).
    ///
    /// Computes the lower triangle in parallel over row blocks and mirrors.
    pub fn gram_into(&self, out: &mut Matrix) {
        let n = self.rows();
        assert_eq!(
            (out.rows(), out.cols()),
            (n, n),
            "gram_into output must be {n}x{n}, got {}x{}",
            out.rows(),
            out.cols()
        );
        let k_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_chunks(n, |istart, iend| {
            for i in istart..iend {
                let ai = self.row(i);
                // SAFETY: thread writes only rows in [istart, iend).
                let k_row: &mut [f64] =
                    unsafe { std::slice::from_raw_parts_mut(k_ptr.get().add(i * n), n) };
                for j in 0..=i {
                    k_row[j] = super::vec_ops::dot(ai, self.row(j));
                }
            }
        });
        // Mirror the strict lower triangle.
        for i in 0..n {
            for j in (i + 1)..n {
                out[(i, j)] = out[(j, i)];
            }
        }
    }

    /// Fused column Gramian `G = Aᵀ @ A` (dense ENGD's P×P matrix, eq. 1)
    /// without materializing `Aᵀ`.
    pub fn gram_t(&self) -> Matrix {
        let mut g = Matrix::zeros(self.cols(), self.cols());
        self.gram_t_into(&mut g);
        g
    }

    /// `G = Aᵀ @ A` into a caller-provided P×P buffer (overwritten).
    ///
    /// Each row `a_k` of A contributes the rank-1 update `G += a_k a_kᵀ`;
    /// only the upper triangle is accumulated (then mirrored). Work is
    /// stolen in MC-row panels of G because triangular panels are uneven.
    pub fn gram_t_into(&self, out: &mut Matrix) {
        let p = self.cols();
        let n_rows = self.rows();
        assert_eq!(
            (out.rows(), out.cols()),
            (p, p),
            "gram_t_into output must be {p}x{p}, got {}x{}",
            out.rows(),
            out.cols()
        );
        out.data_mut().fill(0.0);
        let g_ptr = SendPtr(out.data_mut().as_mut_ptr());
        par_dynamic(p.div_ceil(MC), |panel| {
            let i0 = panel * MC;
            let i1 = (i0 + MC).min(p);
            for k in 0..n_rows {
                let a_row = self.row(k);
                for i in i0..i1 {
                    let aki = a_row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    // SAFETY: disjoint G row panels per work item; only the
                    // suffix [i, p) of row i (the upper triangle) is written.
                    let g_row: &mut [f64] = unsafe {
                        std::slice::from_raw_parts_mut(g_ptr.get().add(i * p + i), p - i)
                    };
                    for (g, &av) in g_row.iter_mut().zip(&a_row[i..]) {
                        *g += aki * av;
                    }
                }
            }
        });
        // Mirror the strict upper triangle down.
        for i in 0..p {
            for j in (i + 1)..p {
                out[(j, i)] = out[(i, j)];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    /// Shapes spanning square, tall (N≫P), and wide (N≪P) regimes.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (3, 5, 2),
        (17, 33, 9),
        (2, 70, 40),
        (70, 2, 40),
        (128, 64, 96),
    ];

    #[test]
    fn matmul_tn_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(1);
        for &(k, m, n) in SHAPES {
            let a = random_matrix(&mut rng, k, m);
            let b = random_matrix(&mut rng, k, n);
            let fused = a.matmul_tn(&b);
            let reference = a.transpose().matmul(&b);
            assert!(
                fused.max_abs_diff(&reference) < 1e-10,
                "tn ({k},{m},{n})"
            );
        }
    }

    #[test]
    fn matmul_nt_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(2);
        for &(m, k, n) in SHAPES {
            let a = random_matrix(&mut rng, m, k);
            let b = random_matrix(&mut rng, n, k);
            let fused = a.matmul_nt(&b);
            let reference = a.matmul(&b.transpose());
            assert!(
                fused.max_abs_diff(&reference) < 1e-10,
                "nt ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn gram_t_matches_materialized_transpose() {
        let mut rng = Rng::seed_from(3);
        for &(n, p) in &[(1usize, 4usize), (7, 3), (33, 65), (64, 128), (100, 50)] {
            let a = random_matrix(&mut rng, n, p);
            let fused = a.gram_t();
            let reference = a.transpose().gram();
            assert!(fused.max_abs_diff(&reference) < 1e-10, "({n},{p})");
            for i in 0..p {
                for j in 0..p {
                    assert_eq!(fused[(i, j)], fused[(j, i)], "asymmetry at ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn into_variants_overwrite_stale_contents() {
        let mut rng = Rng::seed_from(4);
        let a = random_matrix(&mut rng, 20, 12);
        let b = random_matrix(&mut rng, 20, 7);
        let mut out = Matrix::from_fn(12, 7, |_, _| f64::NAN);
        a.matmul_tn_into(&b, &mut out);
        assert!(out.data().iter().all(|x| x.is_finite()));
        assert!(out.max_abs_diff(&a.transpose().matmul(&b)) < 1e-10);

        let mut k = Matrix::from_fn(20, 20, |_, _| f64::NAN);
        a.gram_into(&mut k);
        assert!(k.max_abs_diff(&a.matmul(&a.transpose())) < 1e-10);
    }

    #[test]
    #[should_panic(expected = "matmul_tn shape mismatch")]
    fn tn_shape_mismatch_panics() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(4, 2);
        let _ = a.matmul_tn(&b);
    }

    #[test]
    #[should_panic(expected = "matmul_tn_into output must be")]
    fn tn_into_output_shape_panics() {
        let a = Matrix::zeros(3, 2);
        let b = Matrix::zeros(3, 5);
        let mut out = Matrix::zeros(2, 4);
        a.matmul_tn_into(&b, &mut out);
    }
}
