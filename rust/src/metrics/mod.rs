//! Metrics substrate: run directories, JSONL/CSV sinks, timers, and summary
//! statistics (the role W&B plays in the paper's experimental protocol).

pub mod report;

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::json::JsonValue;

/// One training-step record; serialized as a JSONL line and a CSV row.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub wall_s: f64,
    pub loss: f64,
    /// Relative L2 error against the exact solution (NaN when not evaluated
    /// this step).
    pub l2_error: f64,
    /// Step length actually taken (after line search, if any).
    pub lr: f64,
    /// Optimizer-specific extras (e.g. d_eff, cg_iters, sketch size).
    pub extra: Vec<(String, f64)>,
}

/// Writes per-step records to `<dir>/<name>.jsonl` + `.csv` as they arrive.
///
/// ## CSV schema stability
///
/// Extras vary per step (`d_eff`, `ls_evals`, sketch stats appear only on
/// diagnostic/eval steps), so the column set cannot be frozen from the
/// first record. The logger keeps the **union** of extra keys seen so far
/// (first-seen order): every row carries one cell per known extra column
/// (blank when the step didn't report that key), and a record that
/// introduces a *new* key triggers a rewrite of the whole CSV from the
/// in-memory records under the widened header. New keys appear at most a
/// handful of times per run (the first diagnostic step), so appends stay
/// the steady-state path and live `tail -f` keeps working.
pub struct RunLogger {
    jsonl: BufWriter<File>,
    csv: BufWriter<File>,
    csv_path: PathBuf,
    /// Union of extra keys seen so far, in first-seen order — the extra
    /// columns of the CSV header.
    extra_cols: Vec<String>,
    csv_header_written: bool,
    start: Instant,
    /// Wall-clock seconds accumulated before this logger existed (a
    /// resumed run's pre-checkpoint time; see [`RunLogger::advance_clock`]).
    clock_offset: f64,
    pub dir: PathBuf,
    pub name: String,
    records: Vec<StepRecord>,
    echo: bool,
}

impl RunLogger {
    pub fn create(dir: impl AsRef<Path>, name: &str, echo: bool) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let jsonl = BufWriter::new(File::create(dir.join(format!("{name}.jsonl")))?);
        let csv_path = dir.join(format!("{name}.csv"));
        let csv = BufWriter::new(File::create(&csv_path)?);
        Ok(RunLogger {
            jsonl,
            csv,
            csv_path,
            extra_cols: Vec::new(),
            csv_header_written: false,
            start: Instant::now(),
            clock_offset: 0.0,
            dir,
            name: name.to_string(),
            records: Vec::new(),
            echo,
        })
    }

    /// Seconds since logger creation, plus any offset carried over from
    /// before a checkpoint resume.
    pub fn elapsed(&self) -> f64 {
        self.clock_offset + self.start.elapsed().as_secs_f64()
    }

    /// Pre-load the wall clock with `seconds` already spent (a resumed
    /// run's pre-checkpoint time), so `wall_s`, `time_to_l2`, and any
    /// elapsed-based budget continue monotonically across the resume
    /// boundary instead of restarting at zero.
    pub fn advance_clock(&mut self, seconds: f64) {
        self.clock_offset += seconds.max(0.0);
    }

    /// One CSV data row under the current `extra_cols` schema: fixed
    /// columns, then one cell per known extra key (blank when missing).
    fn csv_row(rec: &StepRecord, extra_cols: &[String]) -> String {
        use std::fmt::Write as _;
        let mut row = format!(
            "{},{:.4},{:.6e},{:.6e},{:.3e}",
            rec.step, rec.wall_s, rec.loss, rec.l2_error, rec.lr,
        );
        for col in extra_cols {
            row.push(',');
            if let Some((_, v)) = rec.extra.iter().find(|(k, _)| k == col) {
                let _ = write!(row, "{v:.6e}");
            }
        }
        row
    }

    fn csv_header(extra_cols: &[String]) -> String {
        let mut header = "step,wall_s,loss,l2_error,lr".to_string();
        for col in extra_cols {
            header.push(',');
            header.push_str(col);
        }
        header
    }

    pub fn log(&mut self, rec: StepRecord) -> Result<()> {
        // JSONL
        let mut obj = vec![
            ("step".to_string(), JsonValue::Number(rec.step as f64)),
            ("wall_s".to_string(), JsonValue::Number(rec.wall_s)),
            ("loss".to_string(), JsonValue::Number(rec.loss)),
            ("l2_error".to_string(), JsonValue::Number(rec.l2_error)),
            ("lr".to_string(), JsonValue::Number(rec.lr)),
        ];
        for (k, v) in &rec.extra {
            obj.push((k.clone(), JsonValue::Number(*v)));
        }
        writeln!(
            self.jsonl,
            "{}",
            crate::config::json::to_string(&JsonValue::Object(obj))
        )?;

        // CSV: grow the schema by any unseen extra keys; a widened header
        // means every earlier row is short, so rewrite the file from the
        // in-memory records (rare — steady-state records append).
        let mut widened = false;
        for (k, _) in &rec.extra {
            if !self.extra_cols.iter().any(|c| c == k) {
                self.extra_cols.push(k.clone());
                widened = true;
            }
        }
        if widened && self.csv_header_written {
            // The old writer's buffer is empty (every log flushes), but
            // flush defensively: a buffered tail draining into the
            // replaced file through the stale handle would corrupt it.
            self.csv.flush()?;
            // Rewrite via temp-file + rename so a crash mid-rewrite can
            // never lose the history already on disk.
            let tmp = self.csv_path.with_extension("csv.tmp");
            let mut csv = BufWriter::new(
                File::create(&tmp)
                    .with_context(|| format!("rewriting {}", self.csv_path.display()))?,
            );
            writeln!(csv, "{}", Self::csv_header(&self.extra_cols))?;
            for old in &self.records {
                writeln!(csv, "{}", Self::csv_row(old, &self.extra_cols))?;
            }
            csv.flush()?;
            fs::rename(&tmp, &self.csv_path)
                .with_context(|| format!("replacing {}", self.csv_path.display()))?;
            self.csv = csv;
        }
        if !self.csv_header_written {
            writeln!(self.csv, "{}", Self::csv_header(&self.extra_cols))?;
            self.csv_header_written = true;
        }
        writeln!(self.csv, "{}", Self::csv_row(&rec, &self.extra_cols))?;
        if self.echo {
            let l2 = if rec.l2_error.is_nan() {
                "      -  ".to_string()
            } else {
                format!("{:.3e}", rec.l2_error)
            };
            println!(
                "[{}] step {:>5}  t={:7.2}s  loss={:.6e}  L2={}  lr={:.2e}",
                self.name, rec.step, rec.wall_s, rec.loss, l2, rec.lr
            );
        }
        self.records.push(rec);
        // Flush per record: steps cost orders of magnitude more than the
        // write, and live `tail -f` on the CSVs is part of the workflow.
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }

    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    /// Best (minimum) L2 error observed so far.
    pub fn best_l2(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.l2_error)
            .filter(|x| x.is_finite())
            .fold(f64::INFINITY, f64::min)
    }

    /// First wall-clock time at which L2 dropped below `threshold`
    /// (the paper's headline "same error, 75× faster" metric).
    pub fn time_to_l2(&self, threshold: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.l2_error.is_finite() && r.l2_error <= threshold)
            .map(|r| r.wall_s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }
}

/// Simple wall-clock stopwatch for perf sections.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Median / inter-quartile summary for bench reporting (the role criterion
/// plays in a crates.io build).
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub median: f64,
    pub q1: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut s = samples.to_vec();
        // Total-order key with NaN last (the `rank_trials` pattern): a
        // timing sample that divided by zero used to panic the quantile
        // sort outright. NaNs sinking to the top keeps the low quantiles
        // meaningful and surfaces the corruption in `max`.
        s.sort_by(|a, b| {
            let key = |x: &f64| (x.is_nan(), *x);
            key(a).partial_cmp(&key(b)).unwrap_or(std::cmp::Ordering::Equal)
        });
        let q = |f: f64| -> f64 {
            let idx = f * (s.len() - 1) as f64;
            let lo = idx.floor() as usize;
            let hi = idx.ceil() as usize;
            let frac = idx - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        };
        Summary {
            median: q(0.5),
            q1: q(0.25),
            q3: q(0.75),
            min: s[0],
            max: *s.last().unwrap(),
            n: s.len(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.4e}s  IQR [{:.4e}, {:.4e}]  range [{:.4e}, {:.4e}]  n={}",
            self.median, self.q1, self.q3, self.min, self.max, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_quantiles_survive_nan_samples() {
        // Regression: the quantile sort used `partial_cmp(..).unwrap()`,
        // which panics the moment a sample is NaN (a zero-iteration timing
        // arm divides 0/0). NaNs must instead order last deterministically:
        // low quantiles stay meaningful, and `max` reports the corruption.
        let s = Summary::of(&[3.0, f64::NAN, 1.0, 2.0, f64::NAN]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
        assert!(s.max.is_nan(), "NaN samples must sink to the top, got {}", s.max);
        assert_eq!(s.n, 5);

        // NaN-free summaries are untouched by the total-order key.
        let clean = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!((clean.min, clean.median, clean.max), (1.0, 2.0, 3.0));
    }

    #[test]
    fn logger_writes_jsonl_and_csv() {
        let dir = std::env::temp_dir().join(format!("engd-test-{}", std::process::id()));
        let mut lg = RunLogger::create(&dir, "t", false).unwrap();
        for step in 0..3 {
            lg.log(StepRecord {
                step,
                wall_s: step as f64 * 0.1,
                loss: 1.0 / (step + 1) as f64,
                l2_error: if step == 2 { 0.01 } else { f64::NAN },
                lr: 0.1,
                extra: vec![("d_eff".into(), 42.0)],
            })
            .unwrap();
        }
        lg.flush().unwrap();
        let jsonl = std::fs::read_to_string(dir.join("t.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 3);
        let parsed = crate::config::json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("d_eff").unwrap().as_f64(), Some(42.0));
        let csv = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(csv.starts_with("step,wall_s,loss,l2_error,lr,d_eff"));
        assert_eq!(lg.best_l2(), 0.01);
        assert!(lg.time_to_l2(0.05).is_some());
        assert!(lg.time_to_l2(0.001).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_schema_is_stable_under_heterogeneous_extras() {
        // Extras vary per step (diagnostics appear late, sketch stats only
        // on eval steps): the CSV must converge on one header covering the
        // union of keys, with every row aligned to it.
        let dir = std::env::temp_dir().join(format!("engd-csv-{}", std::process::id()));
        let mut lg = RunLogger::create(&dir, "het", false).unwrap();
        let mk = |step: usize, extra: Vec<(String, f64)>| StepRecord {
            step,
            wall_s: step as f64,
            loss: 1.0,
            l2_error: f64::NAN,
            lr: 0.1,
            extra,
        };
        lg.log(mk(0, vec![])).unwrap();
        lg.log(mk(1, vec![("d_eff".into(), 42.0)])).unwrap();
        lg.log(mk(2, vec![("ls_evals".into(), 8.0)])).unwrap();
        lg.log(mk(3, vec![("ls_evals".into(), 6.0), ("d_eff".into(), 40.0)]))
            .unwrap();
        lg.flush().unwrap();

        let csv = std::fs::read_to_string(dir.join("het.csv")).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 5, "header + 4 rows: {csv}");
        assert_eq!(lines[0], "step,wall_s,loss,l2_error,lr,d_eff,ls_evals");
        let ncols = lines[0].split(',').count();
        for (i, line) in lines.iter().enumerate() {
            assert_eq!(
                line.split(',').count(),
                ncols,
                "row {i} misaligned with header: {line}"
            );
        }
        fn cell<'a>(lines: &[&'a str], row: usize, col: &str) -> &'a str {
            let idx = lines[0].split(',').position(|c| c == col).unwrap();
            lines[row].split(',').nth(idx).unwrap()
        }
        // Missing extras are blank cells; present ones align to their key.
        assert_eq!(cell(&lines, 1, "d_eff"), "");
        assert_eq!(cell(&lines, 1, "ls_evals"), "");
        assert_eq!(cell(&lines, 2, "d_eff"), "4.200000e1");
        assert_eq!(cell(&lines, 3, "d_eff"), "");
        assert_eq!(cell(&lines, 3, "ls_evals"), "8.000000e0");
        assert_eq!(cell(&lines, 4, "d_eff"), "4.000000e1");
        assert_eq!(cell(&lines, 4, "ls_evals"), "6.000000e0");
        // The report parser must digest the heterogeneous file.
        let summary = super::report::parse_run_csv(dir.join("het.csv")).unwrap();
        assert_eq!(summary.steps, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resumed_clock_offsets_elapsed_and_time_to() {
        let dir = std::env::temp_dir().join(format!("engd-clk-{}", std::process::id()));
        let mut lg = RunLogger::create(&dir, "clk", false).unwrap();
        lg.advance_clock(100.0);
        assert!(lg.elapsed() >= 100.0, "offset ignored: {}", lg.elapsed());
        let wall = lg.elapsed();
        lg.log(StepRecord {
            step: 1,
            wall_s: wall,
            loss: 1.0,
            l2_error: 0.01,
            lr: 0.1,
            extra: vec![],
        })
        .unwrap();
        // time_to_l2 reports the offset clock, not time-since-create.
        assert!(lg.time_to_l2(0.05).unwrap() >= 100.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_quartiles() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }
}
