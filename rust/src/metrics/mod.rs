//! Metrics substrate: run directories, JSONL/CSV sinks, timers, and summary
//! statistics (the role W&B plays in the paper's experimental protocol).

pub mod report;

use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::json::JsonValue;

/// One training-step record; serialized as a JSONL line and a CSV row.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub wall_s: f64,
    pub loss: f64,
    /// Relative L2 error against the exact solution (NaN when not evaluated
    /// this step).
    pub l2_error: f64,
    /// Step length actually taken (after line search, if any).
    pub lr: f64,
    /// Optimizer-specific extras (e.g. d_eff, cg_iters, sketch size).
    pub extra: Vec<(String, f64)>,
}

/// Writes per-step records to `<dir>/<name>.jsonl` + `.csv` as they arrive.
pub struct RunLogger {
    jsonl: BufWriter<File>,
    csv: BufWriter<File>,
    csv_header_written: bool,
    start: Instant,
    pub dir: PathBuf,
    pub name: String,
    records: Vec<StepRecord>,
    echo: bool,
}

impl RunLogger {
    pub fn create(dir: impl AsRef<Path>, name: &str, echo: bool) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let jsonl = BufWriter::new(File::create(dir.join(format!("{name}.jsonl")))?);
        let csv = BufWriter::new(File::create(dir.join(format!("{name}.csv")))?);
        Ok(RunLogger {
            jsonl,
            csv,
            csv_header_written: false,
            start: Instant::now(),
            dir,
            name: name.to_string(),
            records: Vec::new(),
            echo,
        })
    }

    /// Seconds since logger creation.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn log(&mut self, rec: StepRecord) -> Result<()> {
        // JSONL
        let mut obj = vec![
            ("step".to_string(), JsonValue::Number(rec.step as f64)),
            ("wall_s".to_string(), JsonValue::Number(rec.wall_s)),
            ("loss".to_string(), JsonValue::Number(rec.loss)),
            ("l2_error".to_string(), JsonValue::Number(rec.l2_error)),
            ("lr".to_string(), JsonValue::Number(rec.lr)),
        ];
        for (k, v) in &rec.extra {
            obj.push((k.clone(), JsonValue::Number(*v)));
        }
        writeln!(
            self.jsonl,
            "{}",
            crate::config::json::to_string(&JsonValue::Object(obj))
        )?;

        // CSV (header from the first record's extras)
        if !self.csv_header_written {
            let extras: Vec<&str> = rec.extra.iter().map(|(k, _)| k.as_str()).collect();
            writeln!(
                self.csv,
                "step,wall_s,loss,l2_error,lr{}{}",
                if extras.is_empty() { "" } else { "," },
                extras.join(",")
            )?;
            self.csv_header_written = true;
        }
        let extras: Vec<String> = rec.extra.iter().map(|(_, v)| format!("{v:.6e}")).collect();
        writeln!(
            self.csv,
            "{},{:.4},{:.6e},{:.6e},{:.3e}{}{}",
            rec.step,
            rec.wall_s,
            rec.loss,
            rec.l2_error,
            rec.lr,
            if extras.is_empty() { "" } else { "," },
            extras.join(",")
        )?;
        if self.echo {
            let l2 = if rec.l2_error.is_nan() {
                "      -  ".to_string()
            } else {
                format!("{:.3e}", rec.l2_error)
            };
            println!(
                "[{}] step {:>5}  t={:7.2}s  loss={:.6e}  L2={}  lr={:.2e}",
                self.name, rec.step, rec.wall_s, rec.loss, l2, rec.lr
            );
        }
        self.records.push(rec);
        // Flush per record: steps cost orders of magnitude more than the
        // write, and live `tail -f` on the CSVs is part of the workflow.
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }

    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    /// Best (minimum) L2 error observed so far.
    pub fn best_l2(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.l2_error)
            .filter(|x| x.is_finite())
            .fold(f64::INFINITY, f64::min)
    }

    /// First wall-clock time at which L2 dropped below `threshold`
    /// (the paper's headline "same error, 75× faster" metric).
    pub fn time_to_l2(&self, threshold: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.l2_error.is_finite() && r.l2_error <= threshold)
            .map(|r| r.wall_s)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.jsonl.flush()?;
        self.csv.flush()?;
        Ok(())
    }
}

/// Simple wall-clock stopwatch for perf sections.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(Instant::now())
    }
    pub fn seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Median / inter-quartile summary for bench reporting (the role criterion
/// plays in a crates.io build).
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub median: f64,
    pub q1: f64,
    pub q3: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |f: f64| -> f64 {
            let idx = f * (s.len() - 1) as f64;
            let lo = idx.floor() as usize;
            let hi = idx.ceil() as usize;
            let frac = idx - lo as f64;
            s[lo] * (1.0 - frac) + s[hi] * frac
        };
        Summary {
            median: q(0.5),
            q1: q(0.25),
            q3: q(0.75),
            min: s[0],
            max: *s.last().unwrap(),
            n: s.len(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "median {:.4e}s  IQR [{:.4e}, {:.4e}]  range [{:.4e}, {:.4e}]  n={}",
            self.median, self.q1, self.q3, self.min, self.max, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logger_writes_jsonl_and_csv() {
        let dir = std::env::temp_dir().join(format!("engd-test-{}", std::process::id()));
        let mut lg = RunLogger::create(&dir, "t", false).unwrap();
        for step in 0..3 {
            lg.log(StepRecord {
                step,
                wall_s: step as f64 * 0.1,
                loss: 1.0 / (step + 1) as f64,
                l2_error: if step == 2 { 0.01 } else { f64::NAN },
                lr: 0.1,
                extra: vec![("d_eff".into(), 42.0)],
            })
            .unwrap();
        }
        lg.flush().unwrap();
        let jsonl = std::fs::read_to_string(dir.join("t.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 3);
        let parsed = crate::config::json::parse(jsonl.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.get("d_eff").unwrap().as_f64(), Some(42.0));
        let csv = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert!(csv.starts_with("step,wall_s,loss,l2_error,lr,d_eff"));
        assert_eq!(lg.best_l2(), 0.01);
        assert!(lg.time_to_l2(0.05).is_some());
        assert!(lg.time_to_l2(0.001).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_quartiles() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.q1, 2.0);
        assert_eq!(s.q3, 4.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }
}
