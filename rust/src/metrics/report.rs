//! Results reporting: scan run CSVs and summarize them as a markdown table —
//! the tool that fills EXPERIMENTS.md from `results/bench/`.

use std::path::Path;

use anyhow::{Context, Result};

/// Summary of one run CSV.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub name: String,
    pub steps: usize,
    pub wall_s: f64,
    pub final_loss: f64,
    pub best_l2: f64,
    /// (threshold, first wall-clock seconds at/below it)
    pub time_to: Vec<(f64, f64)>,
}

/// Parse a training CSV written by [`crate::metrics::RunLogger`].
pub fn parse_run_csv(path: impl AsRef<Path>) -> Result<RunSummary> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut lines = text.lines();
    let header: Vec<&str> = lines
        .next()
        .context("empty CSV")?
        .split(',')
        .collect();
    let col = |name: &str| header.iter().position(|c| *c == name);
    let (step_i, wall_i, loss_i, l2_i) = (
        col("step").context("no step column")?,
        col("wall_s").context("no wall_s column")?,
        col("loss").context("no loss column")?,
        col("l2_error").context("no l2_error column")?,
    );

    let thresholds = [1e-1, 1e-2, 1e-3, 1e-4];
    let mut time_to: Vec<(f64, f64)> = Vec::new();
    let mut steps = 0usize;
    let mut wall_s = 0.0;
    let mut final_loss = f64::NAN;
    let mut best_l2 = f64::INFINITY;
    for line in lines {
        let cols: Vec<&str> = line.split(',').collect();
        let get = |i: usize| cols.get(i).and_then(|s| s.parse::<f64>().ok());
        if let Some(s) = get(step_i) {
            steps = s as usize;
        }
        if let Some(w) = get(wall_i) {
            wall_s = w;
        }
        if let Some(l) = get(loss_i) {
            final_loss = l;
        }
        if let Some(e) = get(l2_i) {
            if e.is_finite() {
                best_l2 = best_l2.min(e);
                for &t in &thresholds {
                    if e <= t && !time_to.iter().any(|(tt, _)| *tt == t) {
                        time_to.push((t, wall_s));
                    }
                }
            }
        }
    }
    Ok(RunSummary {
        name: path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("?")
            .to_string(),
        steps,
        wall_s,
        final_loss,
        best_l2,
        time_to,
    })
}

/// Summarize every CSV under `dir` (recursively), sorted by path.
pub fn summarize_dir(dir: impl AsRef<Path>) -> Result<Vec<(String, RunSummary)>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.as_ref().to_path_buf()];
    while let Some(d) = stack.pop() {
        let entries = match std::fs::read_dir(&d) {
            Ok(e) => e,
            Err(_) => continue,
        };
        for entry in entries.flatten() {
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "csv") {
                if let Ok(s) = parse_run_csv(&p) {
                    let rel = p.display().to_string();
                    out.push((rel, s));
                }
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Render summaries as a GitHub-markdown table.
pub fn markdown_table(rows: &[(String, RunSummary)]) -> String {
    let mut s = String::from(
        "| run | steps | wall [s] | final loss | best L2 | t(≤1e-1) | t(≤1e-2) | t(≤1e-3) |\n\
         |---|---|---|---|---|---|---|---|\n",
    );
    for (path, r) in rows {
        let t = |thr: f64| -> String {
            r.time_to
                .iter()
                .find(|(tt, _)| *tt == thr)
                .map(|(_, s)| format!("{s:.1}s"))
                .unwrap_or_else(|| "—".into())
        };
        s.push_str(&format!(
            "| {} | {} | {:.1} | {:.3e} | {:.3e} | {} | {} | {} |\n",
            path,
            r.steps,
            r.wall_s,
            r.final_loss,
            r.best_l2,
            t(1e-1),
            t(1e-2),
            t(1e-3),
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_logger_output() {
        let dir = std::env::temp_dir().join(format!("engd-report-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("runA.csv");
        std::fs::write(
            &path,
            "step,wall_s,loss,l2_error,lr\n\
             1,0.5,1.0e0,NaN,1e-1\n\
             2,1.0,5.0e-1,9.0e-2,1e-1\n\
             3,1.5,1.0e-2,5.0e-3,1e-1\n",
        )
        .unwrap();
        let s = parse_run_csv(&path).unwrap();
        assert_eq!(s.steps, 3);
        assert_eq!(s.best_l2, 5.0e-3);
        assert_eq!(s.time_to, vec![(1e-1, 1.0), (1e-2, 1.5)]);

        let rows = summarize_dir(&dir).unwrap();
        assert_eq!(rows.len(), 1);
        let md = markdown_table(&rows);
        assert!(md.contains("runA"));
        assert!(md.contains("5.000e-3"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
