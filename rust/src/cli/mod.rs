//! Command-line argument parsing (substrate — clap is unavailable offline).
//!
//! Grammar: `engd <command> [--flag value]... [--switch]... [positional]...`
//! Flags may also be written `--flag=value`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    /// Switch names the command recognizes (everything else with no value
    /// is an error — catches typos like `--step 100`).
    known_switches: Vec<&'static str>,
}

impl Args {
    /// Parse `std::env::args()` (skipping argv[0]).
    pub fn parse(known_switches: &[&'static str]) -> Result<Self> {
        Self::parse_from(std::env::args().skip(1).collect(), known_switches)
    }

    pub fn parse_from(argv: Vec<String>, known_switches: &[&'static str]) -> Result<Self> {
        let mut args = Args {
            known_switches: known_switches.to_vec(),
            ..Default::default()
        };
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if args.known_switches.contains(&name) {
                    args.switches.push(name.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        bail!("flag --{name} is missing a value");
                    }
                    args.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    bail!("flag --{name} is missing a value");
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn require(&self, flag: &str) -> Result<&str> {
        self.get(flag)
            .ok_or_else(|| anyhow!("missing required flag --{flag}"))
    }

    pub fn get_f64(&self, flag: &str) -> Result<Option<f64>> {
        self.get(flag)
            .map(|s| {
                s.parse::<f64>()
                    .map_err(|_| anyhow!("--{flag} expects a number, got '{s}'"))
            })
            .transpose()
    }

    pub fn get_usize(&self, flag: &str) -> Result<Option<usize>> {
        self.get(flag)
            .map(|s| {
                s.parse::<usize>()
                    .map_err(|_| anyhow!("--{flag} expects an integer, got '{s}'"))
            })
            .transpose()
    }

    /// All flags, for forwarding/validation.
    pub fn flags(&self) -> impl Iterator<Item = (&str, &str)> {
        self.flags.iter().map(|(k, v)| (k.as_str(), v.as_str()))
    }

    /// A leading bare number, wherever the grammar put it: a numeric first
    /// token parses as the `command`, later ones as positionals. Used by
    /// the examples' `[steps]` argument.
    pub fn leading_usize(&self) -> Option<usize> {
        self.command
            .parse()
            .ok()
            .or_else(|| self.positional.first().and_then(|s| s.parse().ok()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse_from(
            s.split_whitespace().map(String::from).collect(),
            &["echo", "full"],
        )
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("train --problem poisson5d --steps=100 --echo extra").unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get("problem"), Some("poisson5d"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(100));
        assert!(a.has("echo"));
        assert!(!a.has("full"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(parse("train --steps").is_err());
        assert!(parse("train --steps --echo").is_err());
    }

    #[test]
    fn numeric_validation() {
        let a = parse("x --lr 1e-3").unwrap();
        assert_eq!(a.get_f64("lr").unwrap(), Some(1e-3));
        let a = parse("x --lr abc").unwrap();
        assert!(a.get_f64("lr").is_err());
    }

    #[test]
    fn require_reports_flag_name() {
        let a = parse("train").unwrap();
        let err = a.require("config").unwrap_err().to_string();
        assert!(err.contains("--config"));
    }
}
