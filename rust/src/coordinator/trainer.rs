//! The training loop.

use anyhow::{Context, Result};

use super::checkpoint::Checkpoint;
use crate::config::RunConfig;
use crate::linalg::{Workspace, WorkspaceStats};
use crate::metrics::{RunLogger, StepRecord};
use crate::optim::{build_optimizer, Optimizer, StepEnv};
use crate::pde::{exact_solution, init_params, l2_relative_error, Sampler};
use crate::rng::Rng;
use crate::runtime::{ProblemSpec, Runtime};

/// Summary of a finished run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub name: String,
    pub steps_done: usize,
    pub wall_s: f64,
    pub final_loss: f64,
    pub best_l2: f64,
    /// (threshold, seconds) pairs for time-to-accuracy reporting.
    pub time_to: Vec<(f64, f64)>,
    /// Wall-clock seconds spent inside PJRT compilation (excluded from the
    /// per-step budget, like jit warm-up in the paper's PyTorch runs).
    pub compile_s: f64,
}

/// A reusable training driver bound to one runtime + problem.
pub struct Trainer<'a> {
    /// First step index to run (resumes advance this past 1).
    start_step: usize,
    pub cfg: RunConfig,
    pub rt: &'a Runtime,
    problem: ProblemSpec,
    optimizer: Box<dyn Optimizer>,
    sampler: Sampler,
    rng: Rng,
    /// Step-buffer pool shared across the whole run: Gram matrices,
    /// sketches, and Nyström factors are checked out per step and recycled,
    /// so steady-state steps allocate nothing for their pool-tracked dense
    /// temporaries.
    workspace: Workspace,
    /// Fixed evaluation set (points + exact values).
    eval_points: Vec<f64>,
    eval_exact: Vec<f64>,
    pub theta: Vec<f64>,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: RunConfig, rt: &'a Runtime) -> Result<Self> {
        let problem = rt.manifest().problem(&cfg.problem)?.clone();
        let optimizer = build_optimizer(&cfg)?;
        let mut rng = Rng::seed_from(cfg.seed);
        let mut sampler = Sampler::new(problem.dim, cfg.seed ^ 0xA5A5_A5A5);
        let eval_points = sampler.eval_set(problem.n_eval);
        let exact = exact_solution(&problem.pde)?;
        let eval_exact = exact.eval_batch(&eval_points, problem.dim);
        let arch = problem.arch.clone();
        let mut theta = init_params(&arch, &mut rng);
        anyhow::ensure!(
            theta.len() == problem.n_params,
            "architecture/param-count mismatch: {} vs manifest {}",
            theta.len(),
            problem.n_params
        );
        let mut optimizer = optimizer;
        let mut start_step = 1usize;
        if let Some(path) = &cfg.resume_from {
            let ck = Checkpoint::load(path)
                .with_context(|| format!("resuming from {path}"))?;
            anyhow::ensure!(
                ck.problem == cfg.problem,
                "checkpoint is for problem '{}', run wants '{}'",
                ck.problem,
                cfg.problem
            );
            anyhow::ensure!(
                ck.theta.len() == problem.n_params,
                "checkpoint θ has {} params, manifest says {}",
                ck.theta.len(),
                problem.n_params
            );
            theta = ck.theta;
            if !ck.phi.is_empty() {
                optimizer.restore_state(ck.phi);
            }
            start_step = ck.step + 1;
        }
        Ok(Trainer {
            start_step,
            cfg,
            rt,
            problem,
            optimizer,
            sampler,
            rng,
            workspace: Workspace::new(),
            eval_points,
            eval_exact,
            theta,
        })
    }

    /// Allocation counters of the step-buffer pool (steady-state training
    /// must show `fresh_allocs` frozen after the first step — asserted by
    /// the integration suite).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.workspace.stats()
    }

    /// Save a checkpoint of the current state to
    /// `<out_dir>/<name>.ckpt`.
    pub fn save_checkpoint(&self, step: usize) -> Result<()> {
        let ck = Checkpoint {
            problem: self.cfg.problem.clone(),
            step,
            seed: self.cfg.seed,
            theta: self.theta.clone(),
            phi: self.optimizer.state(),
        };
        let path = std::path::Path::new(&self.cfg.out_dir)
            .join(format!("{}.ckpt", self.cfg.name));
        ck.save(path)
    }

    /// Relative L2 error of the current iterate on the fixed validation set.
    pub fn evaluate_l2(&self) -> Result<f64> {
        let art = self.rt.artifact(&self.problem.name, "u_pred")?;
        let out = art.call(&[&self.theta, &self.eval_points])?;
        Ok(l2_relative_error(&out[0], &self.eval_exact))
    }

    /// Run the configured number of steps (or until the time budget runs
    /// out), logging to `<out_dir>/<name>.{jsonl,csv}`.
    pub fn run(&mut self, echo: bool) -> Result<TrainReport> {
        let mut logger = RunLogger::create(&self.cfg.out_dir, &self.cfg.name, echo)
            .context("creating run logger")?;

        // Warm the artifact cache before the clock matters: compile time is
        // a startup cost, not a per-step cost (DESIGN.md §Perf).
        let _ = self.evaluate_l2()?;

        let mut final_loss = f64::NAN;
        let mut steps_done = 0;
        let end = self.start_step + self.cfg.steps - 1;
        for k in self.start_step..=end {
            if self.cfg.time_budget_s > 0.0 && logger.elapsed() > self.cfg.time_budget_s {
                break;
            }
            let x_int = self.sampler.interior(self.problem.n_interior);
            let x_bnd = self.sampler.boundary(self.problem.n_boundary);
            let evaluate = k % self.cfg.eval_every.max(1) == 0 || k == self.cfg.steps;
            let mut env = StepEnv {
                rt: self.rt,
                problem: &self.problem,
                x_int: &x_int,
                x_bnd: &x_bnd,
                k,
                rng: &mut self.rng,
                ws: &mut self.workspace,
                diagnostics: evaluate,
            };
            let info = self
                .optimizer
                .step(&mut self.theta, &mut env)
                .with_context(|| format!("step {k}"))?;
            final_loss = info.loss;
            steps_done = k;

            let l2 = if evaluate {
                self.evaluate_l2()?
            } else {
                f64::NAN
            };
            logger.log(StepRecord {
                step: k,
                wall_s: logger.elapsed(),
                loss: info.loss,
                l2_error: l2,
                lr: info.lr_used,
                extra: info.extra,
            })?;
            if self.cfg.checkpoint_every > 0 && k % self.cfg.checkpoint_every == 0 {
                self.save_checkpoint(k)?;
            }
        }
        logger.flush()?;

        let thresholds = [1e-1, 1e-2, 1e-3, 1e-4];
        let time_to = thresholds
            .iter()
            .filter_map(|&t| logger.time_to_l2(t).map(|s| (t, s)))
            .collect();
        Ok(TrainReport {
            name: self.cfg.name.clone(),
            steps_done,
            wall_s: logger.elapsed(),
            final_loss,
            best_l2: logger.best_l2(),
            time_to,
            compile_s: *self.rt.compile_seconds.borrow(),
        })
    }
}

/// One-call convenience: build a trainer and run it.
pub fn train(cfg: RunConfig, rt: &Runtime, echo: bool) -> Result<TrainReport> {
    Trainer::new(cfg, rt)?.run(echo)
}
