//! The training loop.
//!
//! Backend-agnostic since the native-backend refactor: the trainer drives
//! any [`Evaluator`] (PJRT artifacts or pure-Rust native AD) and never
//! touches an artifact directly — evaluation cost is attributed to the
//! backend in [`TrainReport::eval_s`].
//!
//! Determinism contract: the collocation batch and the optimizer RNG
//! stream of step `k` are derived from `(cfg.seed, k)` alone, not from a
//! sequential stream. A run resumed from a step-`m` checkpoint therefore
//! replays steps `m+1..` with exactly the batches and sketches of the
//! uninterrupted run, reproducing its loss trajectory bit-for-bit (the
//! integration suite asserts this).

use std::time::Instant;

use anyhow::{Context, Result};

use super::checkpoint::Checkpoint;
use crate::backend::{Evaluator, NumericsMode, SchedSnapshot, SimdTier};
use crate::config::RunConfig;
use crate::linalg::{Workspace, WorkspaceStats};
use crate::metrics::{RunLogger, StepRecord};
use crate::optim::{build_optimizer, Optimizer, StepEnv};
use crate::pde::{exact_solution, init_params, l2_relative_error, ProblemSpec, Sampler};
use crate::rng::{Rng, SplitMix64};

/// Summary of a finished run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub name: String,
    /// Which backend evaluated the model ("pjrt", "native").
    pub backend: String,
    pub steps_done: usize,
    pub wall_s: f64,
    pub final_loss: f64,
    /// Per-step training losses, in step order (bit-exact resume checks).
    pub losses: Vec<f64>,
    pub best_l2: f64,
    /// (threshold, seconds) pairs for time-to-accuracy reporting.
    pub time_to: Vec<(f64, f64)>,
    /// Wall-clock seconds spent inside PJRT compilation (excluded from the
    /// per-step budget, like jit warm-up in the paper's PyTorch runs).
    pub compile_s: f64,
    /// Wall-clock seconds spent in L2 evaluation (`u_pred`), per backend.
    pub eval_s: f64,
}

/// Derive the seed of an independent per-step RNG stream from the run seed,
/// the 1-based step index, and a purpose salt.
fn step_stream_seed(seed: u64, step: usize, salt: u64) -> u64 {
    let mixed = seed
        ^ salt.rotate_left(31)
        ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    SplitMix64::new(mixed).next_u64()
}

/// Purpose salts for the per-step streams.
const SALT_SAMPLER: u64 = 0x5350_4C31; // "SPL1"
const SALT_OPT_RNG: u64 = 0x534B_4348; // "SKCH"

/// A reusable training driver bound to one backend + problem.
pub struct Trainer<'a> {
    /// First step index to run (resumes advance this past 1).
    start_step: usize,
    /// Wall-clock seconds already spent before the resumed checkpoint
    /// (0.0 for fresh runs): pre-loaded into the run logger so `wall_s`
    /// continues monotonically and `time_budget_s` spans the whole run.
    resume_wall_s: f64,
    pub cfg: RunConfig,
    pub eval: &'a dyn Evaluator,
    problem: ProblemSpec,
    optimizer: Box<dyn Optimizer>,
    /// Step-buffer pool shared across the whole run: Gram matrices,
    /// sketches, Nyström factors, and native-backend Jacobians are checked
    /// out per step and recycled, so steady-state steps allocate nothing
    /// for their pool-tracked dense temporaries.
    workspace: Workspace,
    /// Fixed evaluation set (points + exact values).
    eval_points: Vec<f64>,
    eval_exact: Vec<f64>,
    /// Cumulative seconds spent in `u_pred` evaluation.
    eval_seconds: f64,
    /// Scheduler counters at the end of the previous logged step (shard
    /// executors only): `sched_stats` is cumulative, the CSV wants
    /// per-step deltas.
    sched_prev: Option<SchedSnapshot>,
    pub theta: Vec<f64>,
}

impl<'a> Trainer<'a> {
    pub fn new(cfg: RunConfig, eval: &'a dyn Evaluator) -> Result<Self> {
        let problem = eval.problem(&cfg.problem)?;
        let optimizer = build_optimizer(&cfg)?;
        let mut rng = Rng::seed_from(cfg.seed);
        let mut sampler = Sampler::new(problem.dim, cfg.seed ^ 0xA5A5_A5A5);
        let eval_points = sampler.eval_set(problem.n_eval);
        let exact = exact_solution(&problem.pde)?;
        let eval_exact = exact.eval_batch(&eval_points, problem.dim);
        let arch = problem.arch.clone();
        let mut theta = init_params(&arch, &mut rng);
        anyhow::ensure!(
            theta.len() == problem.n_params,
            "architecture/param-count mismatch: {} vs problem spec {}",
            theta.len(),
            problem.n_params
        );
        let mut optimizer = optimizer;
        let mut start_step = 1usize;
        let mut resume_wall_s = 0.0;
        if let Some(path) = &cfg.resume_from {
            let ck = Checkpoint::load(path)
                .with_context(|| format!("resuming from {path}"))?;
            anyhow::ensure!(
                ck.problem == cfg.problem,
                "checkpoint is for problem '{}', run wants '{}'",
                ck.problem,
                cfg.problem
            );
            // The state vector's layout is optimizer-specific; feeding
            // SPRING's φ into Adam (etc.) would silently corrupt the run.
            // Legacy checkpoints record no kind and load unvalidated.
            anyhow::ensure!(
                ck.optimizer.is_empty() || ck.optimizer == cfg.optimizer.kind.name(),
                "checkpoint was written by optimizer '{}', run uses '{}'",
                ck.optimizer,
                cfg.optimizer.kind.name()
            );
            // A fast-tier trajectory is not bitwise-continuable under
            // bitwise numerics (and vice versa): refuse a silent switch.
            // Legacy checkpoints record no mode and load unvalidated.
            anyhow::ensure!(
                ck.numerics.is_empty() || ck.numerics == cfg.numerics.name(),
                "checkpoint was written under --numerics {}, run uses {} \
                 (pass --numerics {} to continue this trajectory)",
                ck.numerics,
                cfg.numerics.name(),
                ck.numerics
            );
            anyhow::ensure!(
                ck.theta.len() == problem.n_params,
                "checkpoint θ has {} params, problem spec says {}",
                ck.theta.len(),
                problem.n_params
            );
            theta = ck.theta;
            if !ck.phi.is_empty() {
                optimizer.restore_state(ck.phi);
            }
            start_step = ck.step + 1;
            resume_wall_s = ck.wall_s;
        }
        Ok(Trainer {
            start_step,
            resume_wall_s,
            cfg,
            eval,
            problem,
            optimizer,
            workspace: Workspace::new(),
            eval_points,
            eval_exact,
            eval_seconds: 0.0,
            sched_prev: None,
            theta,
        })
    }

    /// Allocation counters of the step-buffer pool (steady-state training
    /// must show `fresh_allocs` frozen after the first step — asserted by
    /// the integration suite).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        self.workspace.stats()
    }

    /// Save a checkpoint of the current state to
    /// `<out_dir>/<name>.ckpt`. `wall_s` is the cumulative training
    /// wall-clock at `step` (the run logger's `elapsed()`, which already
    /// includes any pre-resume time).
    pub fn save_checkpoint(&self, step: usize, wall_s: f64) -> Result<()> {
        let ck = Checkpoint {
            problem: self.cfg.problem.clone(),
            optimizer: self.cfg.optimizer.kind.name().to_string(),
            step,
            seed: self.cfg.seed,
            wall_s,
            numerics: self.cfg.numerics.name().to_string(),
            // The dispatched tier is provenance, not a contract: only the
            // fast tier's results depend on it (up to rounding).
            simd_tier: match self.cfg.numerics {
                NumericsMode::Bitwise => String::new(),
                NumericsMode::Fast => SimdTier::detect().name().to_string(),
            },
            theta: self.theta.clone(),
            phi: self.optimizer.state(),
        };
        let path = std::path::Path::new(&self.cfg.out_dir)
            .join(format!("{}.ckpt", self.cfg.name));
        ck.save(path)
    }

    /// Relative L2 error of the current iterate on the fixed validation
    /// set, via the backend's `u_pred`. Time spent is accumulated into
    /// [`TrainReport::eval_s`].
    pub fn evaluate_l2(&mut self) -> Result<f64> {
        let t0 = Instant::now();
        let u = self
            .eval
            .u_pred(&self.problem, &self.theta, &self.eval_points)?;
        self.eval_seconds += t0.elapsed().as_secs_f64();
        Ok(l2_relative_error(&u, &self.eval_exact))
    }

    /// Run the configured number of steps (or until the time budget runs
    /// out), logging to `<out_dir>/<name>.{jsonl,csv}`.
    pub fn run(&mut self, echo: bool) -> Result<TrainReport> {
        let mut logger = RunLogger::create(&self.cfg.out_dir, &self.cfg.name, echo)
            .context("creating run logger")?;
        // A resumed run continues the checkpoint's clock: wall_s columns
        // stay monotone and time_budget_s covers pre-resume time too.
        logger.advance_clock(self.resume_wall_s);

        // Warm the backend before the clock matters: PJRT compile time is a
        // startup cost, not a per-step cost (DESIGN.md §Perf); the native
        // backend just pays one cheap evaluation.
        let _ = self.evaluate_l2()?;

        let mut final_loss = f64::NAN;
        let mut losses = Vec::with_capacity(self.cfg.steps);
        let mut steps_done = 0;
        let end = self.start_step + self.cfg.steps - 1;
        for k in self.start_step..=end {
            if self.cfg.time_budget_s > 0.0 && logger.elapsed() > self.cfg.time_budget_s {
                break;
            }
            // Step-keyed streams: batch and sketches depend on (seed, k)
            // only, so checkpoint resume replays the exact trajectory.
            let mut sampler = Sampler::new(
                self.problem.dim,
                step_stream_seed(self.cfg.seed, k, SALT_SAMPLER),
            );
            let x_int = sampler.interior(self.problem.n_interior);
            let x_bnd = sampler.boundary(self.problem.n_boundary);
            let mut step_rng =
                Rng::seed_from(step_stream_seed(self.cfg.seed, k, SALT_OPT_RNG));
            let evaluate = k % self.cfg.eval_every.max(1) == 0 || k == end;
            let mut env = StepEnv {
                eval: self.eval,
                problem: &self.problem,
                x_int: &x_int,
                x_bnd: &x_bnd,
                k,
                rng: &mut step_rng,
                ws: &mut self.workspace,
                diagnostics: evaluate,
                numerics: self.cfg.numerics,
            };
            let info = self
                .optimizer
                .step(&mut self.theta, &mut env)
                .with_context(|| format!("step {k}"))?;
            final_loss = info.loss;
            losses.push(info.loss);
            steps_done = k;

            let l2 = if evaluate {
                self.evaluate_l2()?
            } else {
                f64::NAN
            };
            // Numerics provenance rides along in the extras schema: the
            // mode always (0 = bitwise, 1 = fast), the dispatched kernel
            // tier only when it can affect results (fast mode).
            let mut extra = info.extra;
            extra.push(("numerics".into(), self.cfg.numerics.code()));
            if self.cfg.numerics == NumericsMode::Fast {
                extra.push(("simd_tier".into(), SimdTier::detect().code()));
            }
            // Shard executors expose scheduler counters; record the
            // per-step increments (ranges/steals plus, for the process
            // tier, requeues/respawns) and per-shard busy seconds.
            if let Some(now) = self.eval.sched_stats() {
                let prev = self.sched_prev.take().unwrap_or_default();
                let d = now.delta_since(&prev);
                extra.push(("sched_ranges".into(), d.ranges as f64));
                extra.push(("sched_steals".into(), d.steals as f64));
                extra.push(("sched_requeues".into(), d.requeues as f64));
                extra.push(("sched_respawns".into(), d.respawns as f64));
                for (i, s) in d.shard_busy_s.iter().enumerate() {
                    extra.push((format!("shard{i}_s"), *s));
                }
                self.sched_prev = Some(now);
            }
            logger.log(StepRecord {
                step: k,
                wall_s: logger.elapsed(),
                loss: info.loss,
                l2_error: l2,
                lr: info.lr_used,
                extra,
            })?;
            if self.cfg.checkpoint_every > 0 && k % self.cfg.checkpoint_every == 0 {
                self.save_checkpoint(k, logger.elapsed())?;
            }
        }
        logger.flush()?;

        let thresholds = [1e-1, 1e-2, 1e-3, 1e-4];
        let time_to = thresholds
            .iter()
            .filter_map(|&t| logger.time_to_l2(t).map(|s| (t, s)))
            .collect();
        Ok(TrainReport {
            name: self.cfg.name.clone(),
            backend: self.eval.backend_name().to_string(),
            steps_done,
            wall_s: logger.elapsed(),
            final_loss,
            losses,
            best_l2: logger.best_l2(),
            time_to,
            compile_s: self.eval.compile_seconds(),
            eval_s: self.eval_seconds,
        })
    }
}

/// One-call convenience: build a trainer and run it.
pub fn train(cfg: RunConfig, eval: &dyn Evaluator, echo: bool) -> Result<TrainReport> {
    Trainer::new(cfg, eval)?.run(echo)
}
