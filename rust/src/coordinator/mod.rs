//! Training coordinator — the L3 driver that owns the run loop.
//!
//! Per the paper's protocol (§4): each iteration draws a fresh collocation
//! batch, the optimizer produces an update (through the fused artifacts or
//! the Rust linalg path), and the relative L2 error against the known exact
//! solution is evaluated on a fixed validation set; runs are bounded by a
//! step count and/or a wall-clock budget.

mod checkpoint;
mod trainer;

pub use checkpoint::Checkpoint;
pub use trainer::{train, TrainReport, Trainer};
