//! Checkpointing: save/restore the training state (θ, SPRING's φ, step
//! counter) so long runs survive restarts — standard framework plumbing the
//! paper's 7000–10000 s runs imply.
//!
//! Format: a small JSON header (magic, problem, shapes, step, seed) followed
//! by raw little-endian f64 buffers, in one file. No external serialization
//! deps (offline build), so the layout is hand-rolled and versioned.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::json::{self, JsonValue};

const MAGIC: &[u8; 8] = b"ENGDCKP1";

/// A training checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub problem: String,
    /// Optimizer kind (`OptimizerKind::name`) that produced `phi`. The
    /// state layout is optimizer-specific, so resume refuses a mismatch
    /// rather than misinterpreting the vector. Empty in pre-PR-3
    /// checkpoints (accepted, unvalidated).
    pub optimizer: String,
    /// 1-based index of the last completed step.
    pub step: usize,
    pub seed: u64,
    /// Cumulative wall-clock seconds spent training when the checkpoint
    /// was written. A resumed run pre-loads its logger clock with this,
    /// so `wall_s` / `time_to_l2` columns continue monotonically and
    /// `time_budget_s` counts time across the resume boundary. 0.0 in
    /// pre-PR-5 checkpoints (accepted: the clock restarts, as before).
    pub wall_s: f64,
    /// Numerics mode (`NumericsMode::name`) the run was training under.
    /// A `fast`-tier trajectory is not bitwise-continuable under `bitwise`
    /// (and vice versa), so resume refuses a silent switch. Empty in
    /// pre-PR-6 checkpoints (accepted, unvalidated).
    pub numerics: String,
    /// SIMD kernel tier (`SimdTier::name`) that was dispatched when the
    /// checkpoint was written — provenance only, never validated (fast-tier
    /// results are reproducible across tiers only up to rounding). Empty
    /// under bitwise mode and in pre-PR-6 checkpoints.
    pub simd_tier: String,
    pub theta: Vec<f64>,
    /// Optimizer auxiliary state (SPRING's φ, Adam's [t, m, v], SGD's
    /// velocity, Hessian-free's [λ, warm start], dense ENGD's [P, EMA
    /// Gramian]; empty when stateless).
    pub phi: Vec<f64>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let header = JsonValue::Object(vec![
            ("problem".into(), JsonValue::String(self.problem.clone())),
            ("optimizer".into(), JsonValue::String(self.optimizer.clone())),
            ("step".into(), JsonValue::Number(self.step as f64)),
            ("seed".into(), JsonValue::Number(self.seed as f64)),
            ("wall_s".into(), JsonValue::Number(self.wall_s)),
            ("numerics".into(), JsonValue::String(self.numerics.clone())),
            ("simd_tier".into(), JsonValue::String(self.simd_tier.clone())),
            ("theta_len".into(), JsonValue::Number(self.theta.len() as f64)),
            ("phi_len".into(), JsonValue::Number(self.phi.len() as f64)),
        ]);
        let header = json::to_string(&header);
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for x in self.theta.iter().chain(&self.phi) {
            f.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an engd checkpoint (bad magic)");
        }
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        if hlen > 1 << 20 {
            bail!("checkpoint header implausibly large ({hlen} bytes)");
        }
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)?;
        let get = |k: &str| -> Result<f64> {
            header
                .get(k)
                .and_then(JsonValue::as_f64)
                .ok_or_else(|| anyhow::anyhow!("checkpoint header missing '{k}'"))
        };
        let theta_len = get("theta_len")? as usize;
        let phi_len = get("phi_len")? as usize;
        let mut read_f64s = |n: usize| -> Result<Vec<f64>> {
            let mut buf = vec![0u8; n * 8];
            f.read_exact(&mut buf)?;
            Ok(buf
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect())
        };
        let theta = read_f64s(theta_len)?;
        let phi = read_f64s(phi_len)?;
        Ok(Checkpoint {
            problem: header
                .get("problem")
                .and_then(JsonValue::as_str)
                .unwrap_or_default()
                .to_string(),
            // Absent in pre-PR-3 checkpoints: loads as "" (unvalidated).
            optimizer: header
                .get("optimizer")
                .and_then(JsonValue::as_str)
                .unwrap_or_default()
                .to_string(),
            step: get("step")? as usize,
            seed: get("seed")? as u64,
            // Absent in pre-PR-5 checkpoints: the resumed clock restarts.
            wall_s: header
                .get("wall_s")
                .and_then(JsonValue::as_f64)
                .unwrap_or(0.0),
            // Absent in pre-PR-6 checkpoints: loads as "" (unvalidated).
            numerics: header
                .get("numerics")
                .and_then(JsonValue::as_str)
                .unwrap_or_default()
                .to_string(),
            simd_tier: header
                .get("simd_tier")
                .and_then(JsonValue::as_str)
                .unwrap_or_default()
                .to_string(),
            theta,
            phi,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_exactly() {
        let ck = Checkpoint {
            problem: "poisson5d".into(),
            optimizer: "spring".into(),
            step: 123,
            seed: 42,
            wall_s: 321.75,
            numerics: "fast".into(),
            simd_tier: "avx2".into(),
            theta: (0..257).map(|i| (i as f64).sin() * 1e-3).collect(),
            phi: (0..257).map(|i| (i as f64).cos()).collect(),
        };
        let path = std::env::temp_dir().join(format!("engd-ckp-{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(ck, back); // bitwise f64 equality through LE bytes
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_phi_is_fine() {
        let ck = Checkpoint {
            problem: "p".into(),
            optimizer: String::new(),
            step: 1,
            seed: 7,
            wall_s: 0.0,
            numerics: "bitwise".into(),
            simd_tier: String::new(),
            theta: vec![1.0, 2.0],
            phi: vec![],
        };
        let path = std::env::temp_dir().join(format!("engd-ckp2-{}.bin", std::process::id()));
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_header_without_wall_s_defaults_to_zero() {
        // Pre-PR-5 checkpoints carry no wall_s: they must load with a
        // restarted clock, not fail.
        let path = std::env::temp_dir().join(format!("engd-ckp4-{}.bin", std::process::id()));
        let header = r#"{"problem":"p","step":2,"seed":3,"theta_len":1,"phi_len":0}"#;
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(MAGIC).unwrap();
        f.write_all(&(header.len() as u64).to_le_bytes()).unwrap();
        f.write_all(header.as_bytes()).unwrap();
        f.write_all(&1.5f64.to_le_bytes()).unwrap();
        drop(f);
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.wall_s, 0.0);
        assert_eq!(ck.numerics, "");
        assert_eq!(ck.simd_tier, "");
        assert_eq!(ck.step, 2);
        assert_eq!(ck.theta, vec![1.5]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("engd-ckp3-{}.bin", std::process::id()));
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
