//! Property-testing mini-framework (substrate — the `proptest` crate is
//! unavailable offline).
//!
//! Provides seeded generators over a [`Gen`] source and a [`run_prop`] driver
//! that runs a property across many random cases, then greedily *shrinks*
//! numeric scalars toward simpler values on failure. Used by the coordinator
//! and linalg test suites for invariant-style tests
//! ("for all shapes/seeds/dampings: ...").
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the workspace rpath to the
//! // xla_extension-bundled libstdc++ in this offline image)
//! use engd::proptest::{run_prop, Gen};
//! run_prop("dot is symmetric", 64, |g| {
//!     let n = g.usize_in(1, 32);
//!     let a = g.vec_f64(n, -10.0, 10.0);
//!     let b = g.vec_f64(n, -10.0, 10.0);
//!     let ab = engd::linalg::dot(&a, &b);
//!     let ba = engd::linalg::dot(&b, &a);
//!     ((ab - ba).abs() < 1e-12).then_some(()).ok_or("asymmetry".into())
//! });
//! ```

use crate::rng::Rng;

/// A seeded generation context handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Log of scalar draws (for the failure report).
    pub trace: Vec<(String, f64)>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::seed_from(seed),
            trace: Vec::new(),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = lo + self.rng.below(hi - lo + 1);
        self.trace.push(("usize".into(), v as f64));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(("f64".into(), v));
        v
    }

    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.log_uniform(lo, hi);
        self.trace.push(("log_uniform".into(), v));
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f64(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.rng.fill_normal(&mut v);
        v
    }

    /// Access the raw RNG (e.g. to seed a sub-system deterministically).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` seeded cases. A property returns `Ok(())` to pass
/// or `Err(reason)` to fail. Panics (like `#[test]` expects) on the first
/// failing seed with a reproduction hint.
pub fn run_prop<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    // Base seed is stable so failures are reproducible; override with
    // ENGD_PROP_SEED to explore a different region.
    let base: u64 = crate::config::envvars::read("ENGD_PROP_SEED")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(reason) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  \
                 {reason}\n  draws: {:?}\n  reproduce with ENGD_PROP_SEED={base}",
                g.trace
            );
        }
    }
}

/// Assert two slices match to an absolute tolerance, reporting the worst
/// offender (shared helper for numeric properties).
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    let mut worst = 0.0;
    let mut worst_i = 0;
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let d = (x - y).abs();
        if d > worst {
            worst = d;
            worst_i = i;
        }
    }
    if worst > tol {
        Err(format!(
            "max |diff| = {worst:.3e} at index {worst_i} (tol {tol:.1e}): \
             {:.6e} vs {:.6e}",
            a[worst_i], b[worst_i]
        ))
    } else {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u64);
        run_prop("trivial", 10, |_| {
            count.set(count.get() + 1);
            Ok(())
        });
        assert_eq!(count.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_context() {
        run_prop("fails", 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            if x < 2.0 {
                Err("x is always < 2".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_reports_worst_index() {
        let err = assert_close(&[1.0, 2.0, 3.0], &[1.0, 2.5, 3.0], 1e-9).unwrap_err();
        assert!(err.contains("index 1"), "{err}");
        assert!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9).is_ok());
    }

    #[test]
    fn generators_are_in_range() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let x = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let l = g.log_uniform(1e-8, 1e-2);
            assert!(l >= 1e-8 * 0.999 && l <= 1e-2 * 1.001);
        }
    }
}
