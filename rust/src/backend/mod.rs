//! The backend seam: every way the trainer can evaluate the PINN.
//!
//! [`Evaluator`] names exactly the computations [`crate::optim::StepEnv`]
//! and the [`crate::coordinator::Trainer`] consume — the loss, the
//! per-sample residual Jacobian `(r, J)`, the plain gradient, and the
//! evaluation-set prediction. Two implementations ship:
//!
//! * **PJRT** ([`crate::runtime::Runtime`]) — executes the AOT-lowered XLA
//!   artifacts (the paper-faithful path; also the only one offering the
//!   fused single-artifact steps);
//! * **native** ([`NativeBackend`]) — evaluates the tanh-MLP and its PDE
//!   operators in pure Rust: second-order forward-mode duals for the
//!   Laplacian, hand-rolled reverse mode for the per-sample Jacobian rows.
//!   No artifacts, no PJRT client, runs anywhere `cargo test` does.
//!
//! plus the sharded execution tiers, both built on the native backend's
//! range-granular `shard_*` protocol and the work-stealing range scheduler
//! in [`sharded`]:
//!
//! * **sharded threads** ([`ShardedEvaluator`], `--backend sharded:<n>`) —
//!   the collocation batch served as sub-ranges by inner native evaluators
//!   on the persistent in-process worker pool;
//! * **sharded processes** ([`process::ProcessEvaluator`],
//!   `--backend process:<n>`) — the same dispatch shipped to `n` worker
//!   *processes* (spawned from this binary via the hidden `--shard-worker`
//!   entry point) over a length-prefixed frame protocol on stdio pipes; a
//!   crashed or hung worker is respawned and its in-flight ranges
//!   requeued.
//!
//! Every tier writes each range's results into the same deterministic
//! output slot and reduces in the unsharded backend's fixed chunk order,
//! so **all three are bitwise identical** for any worker count, schedule,
//! and completion order (`rust/tests/pool.rs`, `rust/tests/process.rs`).
//!
//! The optimizers' *fused* execution path is artifact-specific by nature;
//! on a backend with no PJRT runtime they transparently fall back to the
//! decomposed path (same update up to floating point — paper eq. 5).

pub mod native;
mod pjrt;
pub mod process;
pub mod sharded;

use anyhow::{anyhow, bail, ensure, Result};

use crate::linalg::{Matrix, Workspace};
use crate::pde::ProblemSpec;
use crate::runtime::Runtime;

pub use native::{NativeBackend, NumericsMode, SimdTier};
pub use process::{ProcessEvaluator, ProcessOptions};
pub use sharded::{SchedSnapshot, Schedule, ShardedEvaluator};

/// A backend able to evaluate the PINN model and its PDE residuals.
///
/// All batched point sets are row-major (`n × dim`). Implementations must
/// agree with each other up to floating point; the integration suite
/// cross-checks PJRT against native whenever artifacts are present.
pub trait Evaluator {
    /// Short identity for logs/reports ("pjrt", "native").
    fn backend_name(&self) -> &'static str;

    /// Resolve a problem by name (manifest-backed or built-in).
    fn problem(&self, name: &str) -> Result<ProblemSpec>;

    /// Names of every problem this backend can serve.
    fn problem_names(&self) -> Vec<String>;

    /// Cumulative range-scheduler counters, when this backend dispatches
    /// work through one (the sharded thread/process tiers). The trainer
    /// logs per-step deltas to the metrics CSV.
    fn sched_stats(&self) -> Option<SchedSnapshot> {
        None
    }

    /// `L(θ) = ½‖r(θ)‖²` on the given batch (line-search probes).
    fn loss(&self, p: &ProblemSpec, theta: &[f64], x_int: &[f64], x_bnd: &[f64])
        -> Result<f64>;

    /// `(L, ∇L)` without materializing J — the SGD/Adam path.
    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)>;

    /// `(r, J)` with `J = ∂r/∂θ ∈ R^{N×P}` — the object Woodbury lives on.
    /// Dense J storage is drawn from the caller's [`Workspace`] where the
    /// backend materializes it host-side; recycle it when done.
    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)>;

    /// Network prediction `u_θ` on an evaluation set.
    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>>;

    /// Cumulative wall seconds spent compiling (PJRT warm-up; 0 natively).
    fn compile_seconds(&self) -> f64 {
        0.0
    }

    /// Downcast to the PJRT runtime, when this backend is one — the hook
    /// the fused optimizer paths use to reach their step artifacts.
    fn as_pjrt(&self) -> Option<&Runtime> {
        None
    }
}

/// Parsed backend selector — the `--backend` / TOML `backend` grammar.
///
/// Parsing is shared by [`select_with_numerics`] and the config layer
/// ([`validate_backend`]), so malformed selectors and zero shard counts
/// (`sharded:0`, `process:0`) are rejected at config-parse time with a
/// clear error instead of deep inside evaluator construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Auto,
    Pjrt,
    Native,
    /// In-process sharded tier with an explicit shard count (≥ 1).
    Sharded(usize),
    /// Out-of-process sharded tier with an explicit worker count (≥ 1).
    Process(usize),
}

impl BackendKind {
    /// Parse `pjrt | native | sharded[:n] | process[:n] | auto` (an empty
    /// string reads as `auto`). Bare `sharded`/`process` default to one
    /// shard per worker thread.
    pub fn parse(kind: &str) -> Result<Self> {
        fn count(k: &str, tier: &str, digits: &str) -> Result<usize> {
            let n: usize = digits
                .parse()
                .map_err(|_| anyhow!("bad shard count in '{k}' (expected {tier}:<n>)"))?;
            ensure!(
                n > 0,
                "shard count must be at least 1 (got '{k}'; {tier}:0 would run nothing)"
            );
            Ok(n)
        }
        Ok(match kind {
            "auto" | "" => Self::Auto,
            "pjrt" => Self::Pjrt,
            "native" => Self::Native,
            "sharded" => Self::Sharded(crate::parallel::num_threads()),
            "process" => Self::Process(crate::parallel::num_threads()),
            k if k.starts_with("sharded:") => {
                Self::Sharded(count(k, "sharded", &k["sharded:".len()..])?)
            }
            k if k.starts_with("process:") => {
                Self::Process(count(k, "process", &k["process:".len()..])?)
            }
            other => {
                bail!("unknown backend '{other}' (expected pjrt|native|sharded[:n]|process[:n]|auto)")
            }
        })
    }
}

/// Config-parse-time validation of a backend selector string: errors
/// exactly when [`select`] would refuse it, without building anything.
pub fn validate_backend(kind: &str) -> Result<()> {
    BackendKind::parse(kind).map(|_| ())
}

/// Build the backend named by `kind`:
///
/// * `"pjrt"`    — PJRT runtime over `artifacts_dir` (errors when missing);
/// * `"native"`  — pure-Rust evaluation, no artifacts required;
/// * `"sharded"` / `"sharded:<n>"` — the batch-sharded composite over `n`
///   inner native evaluators (default: one per worker thread); results are
///   bitwise-identical to `"native"`;
/// * `"process"` / `"process:<n>"` — the same sharded dispatch over `n`
///   worker *processes* respawned from this binary (`--shard-worker`);
///   also bitwise-identical to `"native"`, and fault-tolerant: a killed
///   worker is respawned and its ranges requeued;
/// * `"auto"`    — PJRT when `artifacts_dir/manifest.json` exists *and* a
///   PJRT client can be created, otherwise native. The default everywhere.
///
/// Defaults the numerics mode from `ENGD_NUMERICS`; the config/CLI path
/// passes an explicit mode through [`select_with_numerics`].
pub fn select(kind: &str, artifacts_dir: &str) -> Result<Box<dyn Evaluator>> {
    select_with_numerics(kind, artifacts_dir, NumericsMode::from_env())
}

/// [`select`] with an explicit numerics mode for the native kernel tiers
/// (`--numerics bitwise|fast`). PJRT executes fixed XLA artifacts, so
/// requesting `fast` with `--backend pjrt` is refused rather than silently
/// ignored; `auto` + `fast` selects the native backend directly.
pub fn select_with_numerics(
    kind: &str,
    artifacts_dir: &str,
    numerics: NumericsMode,
) -> Result<Box<dyn Evaluator>> {
    match BackendKind::parse(kind)? {
        BackendKind::Pjrt => {
            if numerics != NumericsMode::Bitwise {
                bail!(
                    "--numerics {} applies to the native kernel tiers; the pjrt backend \
                     executes fixed XLA artifacts (use --backend native or sharded)",
                    numerics.name()
                );
            }
            Ok(Box::new(Runtime::new(artifacts_dir)?))
        }
        BackendKind::Native => Ok(Box::new(NativeBackend::with_numerics(numerics))),
        BackendKind::Sharded(n) => Ok(Box::new(ShardedEvaluator::with_numerics(n, numerics))),
        BackendKind::Process(n) => {
            Ok(Box::new(ProcessEvaluator::with_numerics(n, numerics)))
        }
        BackendKind::Auto => {
            // Fast mode is a native-tier request: skip the PJRT probe
            // rather than select a backend that cannot honor it.
            if numerics == NumericsMode::Bitwise {
                let manifest = std::path::Path::new(artifacts_dir).join("manifest.json");
                if manifest.exists() {
                    match Runtime::new(artifacts_dir) {
                        Ok(rt) => return Ok(Box::new(rt)),
                        Err(e) => eprintln!(
                            "note: PJRT runtime unavailable ({e:#}); falling back to the \
                             native backend"
                        ),
                    }
                }
            }
            Ok(Box::new(NativeBackend::with_numerics(numerics)))
        }
    }
}

/// [`select`] driven by the standard CLI flags: `--backend` (default
/// "auto") and `--artifacts` (default "artifacts"). Shared by the `engd`
/// binary and every example.
pub fn select_from_args(args: &crate::cli::Args) -> Result<Box<dyn Evaluator>> {
    select(
        args.get_or("backend", "auto"),
        args.get_or("artifacts", "artifacts"),
    )
}
