//! The backend seam: every way the trainer can evaluate the PINN.
//!
//! [`Evaluator`] names exactly the computations [`crate::optim::StepEnv`]
//! and the [`crate::coordinator::Trainer`] consume — the loss, the
//! per-sample residual Jacobian `(r, J)`, the plain gradient, and the
//! evaluation-set prediction. Two implementations ship:
//!
//! * **PJRT** ([`crate::runtime::Runtime`]) — executes the AOT-lowered XLA
//!   artifacts (the paper-faithful path; also the only one offering the
//!   fused single-artifact steps);
//! * **native** ([`NativeBackend`]) — evaluates the tanh-MLP and its PDE
//!   operators in pure Rust: second-order forward-mode duals for the
//!   Laplacian, hand-rolled reverse mode for the per-sample Jacobian rows.
//!   No artifacts, no PJRT client, runs anywhere `cargo test` does.
//!
//! plus one composite:
//!
//! * **sharded** ([`ShardedEvaluator`]) — the collocation batch split into
//!   contiguous shards across inner native evaluators, each writing its
//!   Jacobian row-block / residual range straight into the shared
//!   workspace output; reductions follow a fixed shard order so results
//!   are bitwise-identical to the unsharded native backend for any shard
//!   count (`--backend sharded:<n>`).
//!
//! The optimizers' *fused* execution path is artifact-specific by nature;
//! on a backend with no PJRT runtime they transparently fall back to the
//! decomposed path (same update up to floating point — paper eq. 5).

pub mod native;
mod pjrt;
pub mod sharded;

use anyhow::{bail, Result};

use crate::linalg::{Matrix, Workspace};
use crate::pde::ProblemSpec;
use crate::runtime::Runtime;

pub use native::{NativeBackend, NumericsMode, SimdTier};
pub use sharded::ShardedEvaluator;

/// A backend able to evaluate the PINN model and its PDE residuals.
///
/// All batched point sets are row-major (`n × dim`). Implementations must
/// agree with each other up to floating point; the integration suite
/// cross-checks PJRT against native whenever artifacts are present.
pub trait Evaluator {
    /// Short identity for logs/reports ("pjrt", "native").
    fn backend_name(&self) -> &'static str;

    /// Resolve a problem by name (manifest-backed or built-in).
    fn problem(&self, name: &str) -> Result<ProblemSpec>;

    /// Names of every problem this backend can serve.
    fn problem_names(&self) -> Vec<String>;

    /// `L(θ) = ½‖r(θ)‖²` on the given batch (line-search probes).
    fn loss(&self, p: &ProblemSpec, theta: &[f64], x_int: &[f64], x_bnd: &[f64])
        -> Result<f64>;

    /// `(L, ∇L)` without materializing J — the SGD/Adam path.
    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)>;

    /// `(r, J)` with `J = ∂r/∂θ ∈ R^{N×P}` — the object Woodbury lives on.
    /// Dense J storage is drawn from the caller's [`Workspace`] where the
    /// backend materializes it host-side; recycle it when done.
    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)>;

    /// Network prediction `u_θ` on an evaluation set.
    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>>;

    /// Cumulative wall seconds spent compiling (PJRT warm-up; 0 natively).
    fn compile_seconds(&self) -> f64 {
        0.0
    }

    /// Downcast to the PJRT runtime, when this backend is one — the hook
    /// the fused optimizer paths use to reach their step artifacts.
    fn as_pjrt(&self) -> Option<&Runtime> {
        None
    }
}

/// Build the backend named by `kind`:
///
/// * `"pjrt"`    — PJRT runtime over `artifacts_dir` (errors when missing);
/// * `"native"`  — pure-Rust evaluation, no artifacts required;
/// * `"sharded"` / `"sharded:<n>"` — the batch-sharded composite over `n`
///   inner native evaluators (default: one per worker thread); results are
///   bitwise-identical to `"native"`;
/// * `"auto"`    — PJRT when `artifacts_dir/manifest.json` exists *and* a
///   PJRT client can be created, otherwise native. The default everywhere.
///
/// Defaults the numerics mode from `ENGD_NUMERICS`; the config/CLI path
/// passes an explicit mode through [`select_with_numerics`].
pub fn select(kind: &str, artifacts_dir: &str) -> Result<Box<dyn Evaluator>> {
    select_with_numerics(kind, artifacts_dir, NumericsMode::from_env())
}

/// [`select`] with an explicit numerics mode for the native kernel tiers
/// (`--numerics bitwise|fast`). PJRT executes fixed XLA artifacts, so
/// requesting `fast` with `--backend pjrt` is refused rather than silently
/// ignored; `auto` + `fast` selects the native backend directly.
pub fn select_with_numerics(
    kind: &str,
    artifacts_dir: &str,
    numerics: NumericsMode,
) -> Result<Box<dyn Evaluator>> {
    match kind {
        "pjrt" => {
            if numerics != NumericsMode::Bitwise {
                bail!(
                    "--numerics {} applies to the native kernel tiers; the pjrt backend \
                     executes fixed XLA artifacts (use --backend native or sharded)",
                    numerics.name()
                );
            }
            Ok(Box::new(Runtime::new(artifacts_dir)?))
        }
        "native" => Ok(Box::new(NativeBackend::with_numerics(numerics))),
        "sharded" => Ok(Box::new(ShardedEvaluator::with_numerics(
            crate::parallel::num_threads(),
            numerics,
        ))),
        k if k.starts_with("sharded:") => {
            let n: usize = k["sharded:".len()..].parse().map_err(|_| {
                anyhow::anyhow!("bad shard count in '{k}' (expected sharded:<n>)")
            })?;
            if n == 0 {
                bail!("shard count must be at least 1 (got '{k}')");
            }
            Ok(Box::new(ShardedEvaluator::with_numerics(n, numerics)))
        }
        "auto" | "" => {
            // Fast mode is a native-tier request: skip the PJRT probe
            // rather than select a backend that cannot honor it.
            if numerics == NumericsMode::Bitwise {
                let manifest = std::path::Path::new(artifacts_dir).join("manifest.json");
                if manifest.exists() {
                    match Runtime::new(artifacts_dir) {
                        Ok(rt) => return Ok(Box::new(rt)),
                        Err(e) => eprintln!(
                            "note: PJRT runtime unavailable ({e:#}); falling back to the \
                             native backend"
                        ),
                    }
                }
            }
            Ok(Box::new(NativeBackend::with_numerics(numerics)))
        }
        other => bail!("unknown backend '{other}' (expected pjrt|native|sharded[:n]|auto)"),
    }
}

/// [`select`] driven by the standard CLI flags: `--backend` (default
/// "auto") and `--artifacts` (default "artifacts"). Shared by the `engd`
/// binary and every example.
pub fn select_from_args(args: &crate::cli::Args) -> Result<Box<dyn Evaluator>> {
    select(
        args.get_or("backend", "auto"),
        args.get_or("artifacts", "artifacts"),
    )
}
