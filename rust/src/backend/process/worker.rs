//! The worker side of the process tier: a frame-serving loop around the
//! native backend's `shard_*` entry points.
//!
//! A worker is the *same binary* as its supervisor, re-entered through the
//! hidden `--shard-worker` argv flag (the `engd` binary, the
//! `rust/tests/process.rs` harness, and `benches/shard_scale.rs` all route
//! that flag here before their normal entry). It writes the [`MAGIC`]
//! prologue, then answers frames on stdin/stdout until `Exit` or EOF.
//! Nothing else in the process may touch stdout — diagnostics go to
//! stderr, which the supervisor leaves connected to its own.
//!
//! Determinism: the supervisor pins `ENGD_THREADS` and `ENGD_NUMERICS` in
//! the worker's environment, so [`NativeBackend::new`] reconstructs the
//! exact reduction chunk grid and kernel tier of an in-process shard, and
//! every served range is bitwise what `NativeBackend` would have produced.

use std::io::{BufReader, BufWriter, Read, Write};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::frames::{read_frame, write_frame, EvalCtx, EvalKind, Frame, MAGIC, PROTOCOL};
use crate::backend::native::NativeBackend;

/// Exit code of a fault-injected abrupt death (tests assert on it).
pub(crate) const FAULT_EXIT_CODE: i32 = 86;

/// Deterministic fault injection for the supervisor test-suite:
/// `ENGD_SHARD_FAULT=after=<n>` makes the worker exit with
/// [`FAULT_EXIT_CODE`] — no reply, no shutdown handshake — the moment
/// range request `n` (0-based) arrives. The supervisor arms this only on
/// one worker's first incarnation, so the respawn serves normally.
fn fault_after() -> Option<u64> {
    let v = crate::config::envvars::read("ENGD_SHARD_FAULT")?;
    v.strip_prefix("after=")?.parse().ok()
}

/// Entry point of `--shard-worker` mode. Serves the frame protocol on this
/// process's stdin/stdout until `Exit` or supervisor hang-up, then returns
/// for a clean exit.
pub fn worker_main() -> Result<()> {
    let stdout = std::io::stdout();
    let mut out = BufWriter::new(stdout.lock());
    out.write_all(&MAGIC).context("writing stream prologue")?;
    out.flush()?;
    let stdin = std::io::stdin();
    let mut inp = BufReader::new(stdin.lock());
    serve(&mut inp, &mut out)
}

fn serve(inp: &mut impl Read, out: &mut impl Write) -> Result<()> {
    // Numerics mode and thread-chunk grid both come from the environment
    // the supervisor pinned at spawn time.
    let backend = NativeBackend::new();
    let fault = fault_after();
    let mut served = 0u64;
    let mut ctx: Option<Box<EvalCtx>> = None;
    let mut scratch: Vec<f64> = Vec::new();
    loop {
        let frame = match read_frame(inp) {
            Ok(f) => f,
            // EOF between frames: the supervisor dropped our stdin
            // (shutdown without an explicit Exit). Leave quietly.
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(()),
            Err(e) => return Err(e).context("reading frame from supervisor"),
        };
        match frame {
            Frame::Hello { protocol } => {
                ensure!(
                    protocol == PROTOCOL,
                    "supervisor speaks protocol {protocol}, worker speaks {PROTOCOL}"
                );
                write_frame(out, &Frame::HelloAck { pid: std::process::id() as u64 })?;
            }
            Frame::Eval(new_ctx) => ctx = Some(new_ctx),
            Frame::Range { lo, hi } => {
                if fault.is_some_and(|n| served >= n) {
                    // Injected crash: die abruptly with the range in
                    // flight, exactly like a killed or wedged worker.
                    std::process::exit(FAULT_EXIT_CODE);
                }
                served += 1;
                let reply = match serve_range(&backend, &ctx, lo as usize, hi as usize, scratch)
                {
                    Ok(values) => Frame::Data { values },
                    Err(e) => Frame::Error { message: format!("{e:#}") },
                };
                write_frame(out, &reply)?;
                // Reclaim the reply buffer: steady-state serving reuses one
                // allocation per worker.
                scratch = match reply {
                    Frame::Data { mut values } => {
                        values.clear();
                        values
                    }
                    _ => Vec::new(),
                };
            }
            Frame::Exit => return Ok(()),
            other => bail!("unexpected frame in worker: {other:?}"),
        }
    }
}

/// Compute one range via the shard protocol, returning the reply payload
/// in the [`EvalKind`]'s documented layout (`out` is recycled storage).
fn serve_range(
    backend: &NativeBackend,
    ctx: &Option<Box<EvalCtx>>,
    lo: usize,
    hi: usize,
    mut out: Vec<f64>,
) -> Result<Vec<f64>> {
    let ctx = ctx.as_ref().ok_or_else(|| anyhow!("range request before any Eval context"))?;
    ensure!(lo <= hi, "inverted range [{lo}, {hi})");
    let units = hi - lo;
    let spec = &ctx.spec;
    // clear + resize zero-fills everything, as `shard_rows_into` requires
    // of its Jacobian block.
    out.clear();
    out.resize(units * ctx.kind.values_per_unit(spec.n_params), 0.0);
    match ctx.kind {
        EvalKind::Loss => {
            backend.shard_loss_partials(spec, &ctx.theta, &ctx.x_a, &ctx.x_b, lo, hi, &mut out)?;
        }
        EvalKind::LossGrad => {
            let (loss_out, grad_out) = out.split_at_mut(units);
            backend.shard_loss_grad_partials(
                spec, &ctx.theta, &ctx.x_a, &ctx.x_b, lo, hi, loss_out, grad_out,
            )?;
        }
        EvalKind::Rows => {
            let (r_out, j_out) = out.split_at_mut(units);
            backend
                .shard_rows_into(spec, &ctx.theta, &ctx.x_a, &ctx.x_b, lo, hi, r_out, j_out)?;
        }
        EvalKind::UPred => {
            backend.shard_u_pred_into(spec, &ctx.theta, &ctx.x_a, lo, hi, &mut out)?;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::super::frames::frame_bytes;
    use super::*;
    use crate::backend::native::thread_chunks;
    use crate::backend::Evaluator;
    use crate::pde::init_params;
    use crate::rng::Rng;

    /// Drive `serve` through an in-memory session: the full handshake, an
    /// Eval context, every chunk range, and Exit — then check the replies
    /// are bitwise the native backend's partials.
    #[test]
    fn worker_loop_serves_bitwise_native_partials() {
        let native = NativeBackend::new();
        let p = native.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(29);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let (chunks, _) = thread_chunks(p.n_total());
        let mut want = vec![0.0; chunks];
        native.shard_loss_partials(&p, &theta, &xi, &xb, 0, chunks, &mut want).unwrap();

        let mut request = Vec::new();
        for f in [
            Frame::Hello { protocol: PROTOCOL },
            Frame::Eval(Box::new(EvalCtx {
                kind: EvalKind::Loss,
                spec: p.clone(),
                theta: theta.clone(),
                x_a: xi.clone(),
                x_b: xb.clone(),
            })),
        ] {
            request.extend_from_slice(&frame_bytes(&f));
        }
        for c in 0..chunks {
            let f = Frame::Range { lo: c as u64, hi: c as u64 + 1 };
            request.extend_from_slice(&frame_bytes(&f));
        }
        request.extend_from_slice(&frame_bytes(&Frame::Exit));

        let mut replies = Vec::new();
        serve(&mut std::io::Cursor::new(request), &mut replies).unwrap();

        let mut r = std::io::Cursor::new(replies);
        match read_frame(&mut r).unwrap() {
            Frame::HelloAck { .. } => {}
            other => panic!("{other:?}"),
        }
        for (c, want_c) in want.iter().enumerate() {
            match read_frame(&mut r).unwrap() {
                Frame::Data { values } => {
                    assert_eq!(values.len(), 1);
                    assert_eq!(values[0].to_bits(), want_c.to_bits(), "chunk {c}");
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(r.position() as usize, r.get_ref().len());
    }

    #[test]
    fn range_before_eval_is_an_error_reply_not_a_crash() {
        let mut request = Vec::new();
        request.extend_from_slice(&frame_bytes(&Frame::Range { lo: 0, hi: 1 }));
        request.extend_from_slice(&frame_bytes(&Frame::Exit));
        let mut replies = Vec::new();
        serve(&mut std::io::Cursor::new(request), &mut replies).unwrap();
        match read_frame(&mut std::io::Cursor::new(replies)).unwrap() {
            Frame::Error { message } => assert!(message.contains("before any Eval")),
            other => panic!("{other:?}"),
        }
    }
}
