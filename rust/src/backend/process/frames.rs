//! Wire format of the process tier: length-prefixed frames on stdio pipes.
//!
//! Every frame is `u32 tag | u64 payload_len | payload`, all little-endian
//! (the supervisor and worker are always the same binary on the same
//! machine, so no cross-endian concern — the explicit layout is for
//! debuggability and a future socket transport). Payload scalars are
//! `u64`/`f64` little-endian; strings and vectors are length-prefixed with
//! a `u64` count. `f64` values travel as raw IEEE-754 bits, so θ, batches,
//! and results survive the round trip bit-for-bit — the process tier's
//! bitwise contract starts here.
//!
//! The conversation is strictly request/reply after a one-shot handshake:
//!
//! ```text
//! worker → supervisor   MAGIC (8 raw bytes, no frame header)
//! supervisor → worker   Hello { protocol }
//! worker → supervisor   HelloAck { pid }
//! supervisor → worker   Eval { kind, spec, θ, x_a, x_b }     (per batch)
//! supervisor → worker   Range { lo, hi }                     (per range)
//! worker → supervisor   Data { values } | Error { message }
//! supervisor → worker   Exit                                 (shutdown)
//! ```
//!
//! `MAGIC` lets the supervisor skip any noise an embedding binary prints
//! before entering worker mode, and confirms it spawned something that
//! actually speaks this protocol.

use std::io::{self, Read, Write};

use anyhow::{bail, ensure, Result};

use crate::pde::{PdeOperator, ProblemSpec};

/// Raw 8-byte stream prologue written by the worker before its first frame.
pub(crate) const MAGIC: [u8; 8] = *b"ENGDSHW1";

/// Protocol revision carried in `Hello`; bumped on any wire change.
pub(crate) const PROTOCOL: u64 = 1;

/// Sanity cap on a payload length (a desynced stream otherwise reads a
/// garbage length and tries to allocate it).
const MAX_PAYLOAD: u64 = 1 << 33;

/// Which `shard_*` entry point an `Eval` context drives, and therefore
/// what a work unit and a reply element mean.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum EvalKind {
    /// Units are reduction chunks; reply is `hi−lo` loss partials.
    Loss,
    /// Units are reduction chunks; reply is `hi−lo` loss partials followed
    /// by `(hi−lo)·n_params` flat gradient partials.
    LossGrad,
    /// Units are batch rows; reply is `hi−lo` residuals followed by the
    /// `(hi−lo)·n_params` Jacobian row-block.
    Rows,
    /// Units are evaluation points; reply is `hi−lo` predictions.
    UPred,
}

impl EvalKind {
    fn code(self) -> u64 {
        match self {
            EvalKind::Loss => 0,
            EvalKind::LossGrad => 1,
            EvalKind::Rows => 2,
            EvalKind::UPred => 3,
        }
    }

    fn from_code(c: u64) -> Result<Self> {
        Ok(match c {
            0 => EvalKind::Loss,
            1 => EvalKind::LossGrad,
            2 => EvalKind::Rows,
            3 => EvalKind::UPred,
            _ => bail!("unknown eval kind code {c}"),
        })
    }

    /// Reply f64s per work unit for a problem with `n_params` parameters.
    pub(crate) fn values_per_unit(self, n_params: usize) -> usize {
        match self {
            EvalKind::Loss | EvalKind::UPred => 1,
            EvalKind::LossGrad | EvalKind::Rows => 1 + n_params,
        }
    }
}

/// Everything a worker needs to serve ranges of one evaluation call.
#[derive(Debug)]
pub(crate) struct EvalCtx {
    pub(crate) kind: EvalKind,
    pub(crate) spec: ProblemSpec,
    pub(crate) theta: Vec<f64>,
    /// Interior batch (`Rows`/`Loss`/`LossGrad`) or the evaluation set
    /// (`UPred`).
    pub(crate) x_a: Vec<f64>,
    /// Boundary batch; empty for `UPred`.
    pub(crate) x_b: Vec<f64>,
}

#[derive(Debug)]
pub(crate) enum Frame {
    Hello { protocol: u64 },
    HelloAck { pid: u64 },
    Eval(Box<EvalCtx>),
    Range { lo: u64, hi: u64 },
    Data { values: Vec<f64> },
    Error { message: String },
    Exit,
}

const TAG_HELLO: u32 = 1;
const TAG_HELLO_ACK: u32 = 2;
const TAG_EVAL: u32 = 3;
const TAG_RANGE: u32 = 4;
const TAG_DATA: u32 = 5;
const TAG_ERROR: u32 = 6;
const TAG_EXIT: u32 = 7;

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u64(b, s.len() as u64);
    b.extend_from_slice(s.as_bytes());
}

fn put_f64s(b: &mut Vec<u8>, v: &[f64]) {
    put_u64(b, v.len() as u64);
    b.reserve(v.len() * 8);
    for x in v {
        put_f64(b, *x);
    }
}

fn put_usizes(b: &mut Vec<u8>, v: &[usize]) {
    put_u64(b, v.len() as u64);
    for x in v {
        put_u64(b, *x as u64);
    }
}

fn put_spec(b: &mut Vec<u8>, p: &ProblemSpec) {
    put_str(b, &p.name);
    put_u64(b, p.dim as u64);
    put_usizes(b, &p.arch);
    put_u64(b, p.n_params as u64);
    put_u64(b, p.n_interior as u64);
    put_u64(b, p.n_boundary as u64);
    put_u64(b, p.n_eval as u64);
    put_f64(b, p.interior_weight);
    put_f64(b, p.boundary_weight);
    put_str(b, &p.pde);
    put_str(b, p.operator.name());
}

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.buf.len() - self.pos >= n,
            "payload truncated: wanted {n} bytes at offset {}, have {}",
            self.pos,
            self.buf.len() - self.pos
        );
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        Ok(String::from_utf8(self.bytes(n)?.to_vec())?)
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        ensure!(
            n <= (self.buf.len() - self.pos) / 8,
            "vector length {n} exceeds the remaining payload"
        );
        let raw = self.bytes(n * 8)?;
        let mut out = Vec::with_capacity(n);
        for k in 0..n {
            out.push(f64::from_le_bytes(raw[k * 8..k * 8 + 8].try_into().unwrap()));
        }
        Ok(out)
    }

    fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.u64()? as usize;
        ensure!(
            n <= (self.buf.len() - self.pos) / 8,
            "vector length {n} exceeds the remaining payload"
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()? as usize);
        }
        Ok(out)
    }

    fn spec(&mut self) -> Result<ProblemSpec> {
        Ok(ProblemSpec {
            name: self.str()?,
            dim: self.u64()? as usize,
            arch: self.usizes()?,
            n_params: self.u64()? as usize,
            n_interior: self.u64()? as usize,
            n_boundary: self.u64()? as usize,
            n_eval: self.u64()? as usize,
            interior_weight: self.f64()?,
            boundary_weight: self.f64()?,
            pde: self.str()?,
            operator: PdeOperator::parse(&self.str()?)?,
        })
    }

    fn done(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "trailing garbage: {} of {} payload bytes unread",
            self.buf.len() - self.pos,
            self.buf.len()
        );
        Ok(())
    }
}

fn assemble(tag: u32, payload: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(12 + payload.len());
    out.extend_from_slice(&tag.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Serialize a frame (header + payload) into one contiguous byte buffer.
pub(crate) fn frame_bytes(f: &Frame) -> Vec<u8> {
    let mut p = Vec::new();
    let tag = match f {
        Frame::Hello { protocol } => {
            put_u64(&mut p, *protocol);
            TAG_HELLO
        }
        Frame::HelloAck { pid } => {
            put_u64(&mut p, *pid);
            TAG_HELLO_ACK
        }
        Frame::Eval(ctx) => {
            return eval_frame_bytes(ctx.kind, &ctx.spec, &ctx.theta, &ctx.x_a, &ctx.x_b);
        }
        Frame::Range { lo, hi } => {
            put_u64(&mut p, *lo);
            put_u64(&mut p, *hi);
            TAG_RANGE
        }
        Frame::Data { values } => {
            put_f64s(&mut p, values);
            TAG_DATA
        }
        Frame::Error { message } => {
            put_str(&mut p, message);
            TAG_ERROR
        }
        Frame::Exit => TAG_EXIT,
    };
    assemble(tag, p)
}

/// Serialize an `Eval` frame straight from borrowed slices — the
/// supervisor encodes one context per evaluation call and reuses the bytes
/// across workers and respawns without cloning θ or the batches.
pub(crate) fn eval_frame_bytes(
    kind: EvalKind,
    spec: &ProblemSpec,
    theta: &[f64],
    x_a: &[f64],
    x_b: &[f64],
) -> Vec<u8> {
    let mut p = Vec::with_capacity(64 + 8 * (theta.len() + x_a.len() + x_b.len()));
    put_u64(&mut p, kind.code());
    put_spec(&mut p, spec);
    put_f64s(&mut p, theta);
    put_f64s(&mut p, x_a);
    put_f64s(&mut p, x_b);
    assemble(TAG_EVAL, p)
}

fn decode(tag: u32, payload: &[u8]) -> Result<Frame> {
    let mut d = Dec::new(payload);
    let frame = match tag {
        TAG_HELLO => Frame::Hello { protocol: d.u64()? },
        TAG_HELLO_ACK => Frame::HelloAck { pid: d.u64()? },
        TAG_EVAL => Frame::Eval(Box::new(EvalCtx {
            kind: EvalKind::from_code(d.u64()?)?,
            spec: d.spec()?,
            theta: d.f64s()?,
            x_a: d.f64s()?,
            x_b: d.f64s()?,
        })),
        TAG_RANGE => Frame::Range {
            lo: d.u64()?,
            hi: d.u64()?,
        },
        TAG_DATA => Frame::Data { values: d.f64s()? },
        TAG_ERROR => Frame::Error { message: d.str()? },
        TAG_EXIT => Frame::Exit,
        other => bail!("unknown frame tag {other}"),
    };
    d.done()?;
    Ok(frame)
}

/// Write one frame and flush (request/reply pacing needs the flush —
/// `BufWriter`-wrapped pipes would otherwise deadlock both sides waiting).
pub(crate) fn write_frame(w: &mut impl Write, f: &Frame) -> io::Result<()> {
    w.write_all(&frame_bytes(f))?;
    w.flush()
}

/// Read one frame; frames after the stream prologue only (the caller
/// consumes [`MAGIC`] first). `UnexpectedEof` before a header means the
/// peer hung up cleanly between frames.
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<Frame> {
    let mut head = [0u8; 12];
    r.read_exact(&mut head)?;
    let tag = u32::from_le_bytes(head[..4].try_into().unwrap());
    let len = u64::from_le_bytes(head[4..].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame payload length {len} exceeds the sanity cap (desynced stream?)"),
        ));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    decode(tag, &payload)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{e:#}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::builtin_problem;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = frame_bytes(f);
        let mut cursor = std::io::Cursor::new(bytes);
        let back = read_frame(&mut cursor).unwrap();
        assert_eq!(cursor.position() as usize, cursor.get_ref().len(), "bytes left over");
        back
    }

    #[test]
    fn scalar_frames_roundtrip() {
        assert!(matches!(
            roundtrip(&Frame::Hello { protocol: PROTOCOL }),
            Frame::Hello { protocol: PROTOCOL }
        ));
        assert!(matches!(roundtrip(&Frame::HelloAck { pid: 4242 }), Frame::HelloAck { pid: 4242 }));
        assert!(
            matches!(roundtrip(&Frame::Range { lo: 3, hi: 17 }), Frame::Range { lo: 3, hi: 17 })
        );
        assert!(matches!(roundtrip(&Frame::Exit), Frame::Exit));
        match roundtrip(&Frame::Error { message: "boom × 3".into() }) {
            Frame::Error { message } => assert_eq!(message, "boom × 3"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn data_frames_preserve_f64_bits() {
        let values = vec![0.0, -0.0, 1.5, f64::MIN_POSITIVE, f64::NAN, f64::NEG_INFINITY, 1e300];
        match roundtrip(&Frame::Data { values: values.clone() }) {
            Frame::Data { values: back } => {
                assert_eq!(back.len(), values.len());
                for (a, b) in values.iter().zip(&back) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn eval_frames_roundtrip_the_full_context() {
        for name in ["poisson2d", "heat2d"] {
            let spec = builtin_problem(name).unwrap();
            let theta = vec![1.25, -2.5, 3.75];
            let x_a = vec![0.1, 0.2, 0.3, 0.4];
            let x_b = vec![0.9];
            let f = Frame::Eval(Box::new(EvalCtx {
                kind: EvalKind::Rows,
                spec: spec.clone(),
                theta: theta.clone(),
                x_a: x_a.clone(),
                x_b: x_b.clone(),
            }));
            match roundtrip(&f) {
                Frame::Eval(ctx) => {
                    assert_eq!(ctx.kind, EvalKind::Rows);
                    assert_eq!(ctx.spec.name, spec.name);
                    assert_eq!(ctx.spec.arch, spec.arch);
                    assert_eq!(ctx.spec.n_params, spec.n_params);
                    assert_eq!(ctx.spec.operator, spec.operator);
                    assert_eq!(ctx.spec.pde, spec.pde);
                    assert_eq!(ctx.theta, theta);
                    assert_eq!(ctx.x_a, x_a);
                    assert_eq!(ctx.x_b, x_b);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn desynced_streams_are_rejected() {
        // Absurd payload length: refused before allocating.
        let mut head = Vec::new();
        head.extend_from_slice(&TAG_DATA.to_le_bytes());
        head.extend_from_slice(&u64::MAX.to_le_bytes());
        let err = read_frame(&mut std::io::Cursor::new(head)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // Unknown tag.
        let bytes = assemble(99, Vec::new());
        let err = read_frame(&mut std::io::Cursor::new(bytes)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        // Truncated payload inside a declared-complete frame.
        let mut short = Vec::new();
        put_u64(&mut short, 10); // claims 10 f64s, carries none
        let err = read_frame(&mut std::io::Cursor::new(assemble(TAG_DATA, short))).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn values_per_unit_matches_reply_layout() {
        assert_eq!(EvalKind::Loss.values_per_unit(7), 1);
        assert_eq!(EvalKind::UPred.values_per_unit(7), 1);
        assert_eq!(EvalKind::LossGrad.values_per_unit(7), 8);
        assert_eq!(EvalKind::Rows.values_per_unit(7), 8);
    }
}
