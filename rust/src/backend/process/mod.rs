//! Out-of-process shard executors: the third execution tier.
//!
//! [`ProcessEvaluator`] runs the same batch-sharded dispatch as
//! [`crate::backend::sharded::ShardedEvaluator`], but each shard is a
//! worker *process* instead of a pool thread: the supervisor spawns `n`
//! copies of the current binary with the hidden `--shard-worker` argv flag
//! (see [`worker_main`]), ships θ and the batches once per evaluation over
//! a length-prefixed frame protocol on stdio pipes ([`frames`]), then
//! streams range requests from the shared work-stealing [`RangeQueue`] and
//! writes each reply into its deterministic slot of the pooled output.
//!
//! ## Bitwise contract
//!
//! `--backend process:<n>` is bitwise-identical to `--backend native` for
//! any worker count, schedule, and interleaving, by the same argument as
//! the thread tier: workers compute ranges through the identical
//! `shard_*` kernels (the supervisor pins `ENGD_THREADS` and
//! `ENGD_NUMERICS` in each worker's environment so the reduction chunk
//! grid and kernel tier match), every range lands in a fixed output slot,
//! f64 payloads travel as raw IEEE-754 bits, and reductions run in the
//! unsharded chunk order. `rust/tests/process.rs` asserts the identity for
//! the whole evaluation surface and for full training trajectories —
//! including runs where a worker is killed mid-step.
//!
//! ## Fault tolerance
//!
//! Each worker's I/O thread treats a vanished pipe, a protocol desync, or
//! a missed reply deadline (`ENGD_SHARD_TIMEOUT_S`, default 30 s) as a
//! dead worker: the in-flight range goes back on the queue for any live
//! shard, the process is killed and respawned (up to
//! [`ProcessOptions::max_respawns`] per evaluation), and the evaluation
//! only fails if the batch cannot be completed at all. A worker replying
//! with an explicit `Error` frame is a *deterministic* failure — every
//! respawn would hit it too — so it fails the evaluation immediately.

mod frames;
mod worker;

pub use worker::worker_main;

use std::io::{BufReader, Read, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use super::native::{thread_chunks, NativeBackend, NumericsMode};
use super::sharded::{RangeQueue, SchedState, Schedule};
use super::{Evaluator, SchedSnapshot};
use crate::linalg::{Matrix, Workspace, WorkspaceStats};
use crate::parallel::{self, SendPtr};
use crate::pde::ProblemSpec;
use self::frames::{EvalKind, Frame};

/// Supervisor knobs; [`Default`] reads the environment.
#[derive(Debug, Clone)]
pub struct ProcessOptions {
    /// Worker processes to run (≥ 1).
    pub workers: usize,
    /// Argv (after the executable path) that re-enters the spawned binary
    /// in worker mode. The `engd` binary and the process-tier test/bench
    /// harnesses all answer `--shard-worker`.
    pub spawn_args: Vec<String>,
    /// Per-range reply deadline; a worker that blows it is declared hung,
    /// killed, and respawned. Default: `ENGD_SHARD_TIMEOUT_S` seconds,
    /// else 30 s.
    pub deadline: Duration,
    /// Respawn budget per worker per evaluation call; a worker that dies
    /// more often retires for the rest of the call (its ranges are
    /// requeued for the others).
    pub max_respawns: usize,
    /// Work-assignment policy. Default: `ENGD_SHARD_SCHEDULE`
    /// (work stealing unless `static`).
    pub schedule: Schedule,
    /// Deterministic fault injection (tests): worker `.0` exits abruptly
    /// when range request `.1` (0-based) arrives — armed only on that
    /// worker's first incarnation, so its respawn serves normally.
    pub fault_once: Option<(usize, u64)>,
}

impl Default for ProcessOptions {
    fn default() -> Self {
        let deadline = crate::config::envvars::read("ENGD_SHARD_TIMEOUT_S")
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|s| *s > 0.0)
            .unwrap_or(30.0);
        ProcessOptions {
            workers: parallel::num_threads(),
            spawn_args: vec!["--shard-worker".to_string()],
            deadline: Duration::from_secs_f64(deadline),
            max_respawns: 2,
            schedule: Schedule::from_env(),
            fault_once: None,
        }
    }
}

/// A live worker process plus its I/O endpoints. Replies arrive through a
/// dedicated reader thread (so the dispatch loop can wait with a timeout);
/// requests go straight down the child's stdin.
struct WorkerProc {
    child: Child,
    stdin: ChildStdin,
    rx: Receiver<std::io::Result<Frame>>,
    /// Evaluation generation whose `Eval` context this worker holds —
    /// the context is re-sent only after a respawn or a new evaluation.
    ctx_gen: u64,
}

/// One supervisor-side worker slot. The slot mutex is held by that
/// worker's I/O thread for a whole dispatch, so slots never contend.
#[derive(Default)]
struct Slot {
    proc: Option<WorkerProc>,
    /// A previous incarnation died — the next spawn counts as a respawn.
    died: bool,
}

/// How a range request failed, which decides the recovery.
enum WorkerFailure {
    /// The worker vanished, desynced, or missed the deadline: kill,
    /// requeue the range, respawn.
    Dead(anyhow::Error),
    /// The worker reported a deterministic evaluation error: fail the
    /// dispatch (a respawn would hit it again).
    Fatal(anyhow::Error),
}

/// The process-tier [`Evaluator`]: batch shards served by worker
/// processes. Construction is lazy — workers spawn on the first
/// evaluation call and persist (with their warmed tape scratch) across
/// steps until the evaluator drops.
pub struct ProcessEvaluator {
    /// Problem catalogue + numerics-mode holder. Serving never touches it
    /// (the full `ProblemSpec` travels in the `Eval` frame), so custom
    /// problem sets work even though workers boot the built-in catalogue.
    catalog: NativeBackend,
    opts: ProcessOptions,
    slots: Vec<Mutex<Slot>>,
    sched: SchedState,
    /// Monotone evaluation-context generation (see `WorkerProc::ctx_gen`).
    ctx_gen: AtomicU64,
    /// The one-shot fault of `ProcessOptions::fault_once` has been armed.
    fault_armed: AtomicBool,
    /// Pooled storage for reduction partials, as in the thread tier.
    scratch: Mutex<Workspace>,
}

impl ProcessEvaluator {
    /// `workers` worker processes over the built-in problem catalogue, in
    /// the `ENGD_NUMERICS`-requested numerics mode.
    ///
    /// Panics if `workers == 0` — the config layer
    /// (`crate::backend::validate_backend`) rejects `process:0` before it
    /// can reach here.
    pub fn new(workers: usize) -> Self {
        Self::with_options(ProcessOptions { workers, ..ProcessOptions::default() })
    }

    /// Built-in catalogue in an explicit numerics mode (the config/CLI
    /// path); the mode is pinned into every worker's environment.
    pub fn with_numerics(workers: usize, numerics: NumericsMode) -> Self {
        Self::build(
            NativeBackend::with_numerics(numerics),
            ProcessOptions { workers, ..ProcessOptions::default() },
        )
    }

    /// Fully explicit supervisor options (tests, benches).
    pub fn with_options(opts: ProcessOptions) -> Self {
        Self::build(NativeBackend::new(), opts)
    }

    /// Custom problem set with explicit options (tests). The specs travel
    /// to the workers inside every `Eval` frame, so no worker-side
    /// catalogue is needed.
    pub fn with_problems_options(problems: Vec<ProblemSpec>, opts: ProcessOptions) -> Self {
        Self::build(NativeBackend::with_problems(problems), opts)
    }

    fn build(catalog: NativeBackend, opts: ProcessOptions) -> Self {
        assert!(opts.workers > 0, "ProcessEvaluator needs at least one worker (got 0)");
        let workers = opts.workers;
        ProcessEvaluator {
            catalog,
            opts,
            slots: (0..workers).map(|_| Mutex::new(Slot::default())).collect(),
            sched: SchedState::new(workers),
            ctx_gen: AtomicU64::new(0),
            fault_armed: AtomicBool::new(false),
            scratch: Mutex::new(Workspace::new()),
        }
    }

    /// Number of worker processes.
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// PIDs of the currently live workers (`None` for never-spawned or
    /// currently-dead slots) — observability and external kill tests.
    pub fn worker_pids(&self) -> Vec<Option<u32>> {
        self.slots
            .iter()
            .map(|s| {
                let slot = s.lock().unwrap_or_else(|p| p.into_inner());
                slot.proc.as_ref().map(|p| p.child.id())
            })
            .collect()
    }

    /// Kill worker `idx`'s process outright (tests: simulate an external
    /// crash). The next evaluation respawns it and re-ships the context.
    /// Blocks while a dispatch holds the slot.
    pub fn kill_worker(&self, idx: usize) {
        let mut slot = self.slots[idx].lock().unwrap_or_else(|p| p.into_inner());
        Self::kill_slot(&mut slot);
    }

    /// Allocation counters of the partial-buffer pool.
    pub fn scratch_stats(&self) -> WorkspaceStats {
        self.lock_scratch().stats()
    }

    fn lock_scratch(&self) -> MutexGuard<'_, Workspace> {
        self.scratch.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    fn kill_slot(slot: &mut Slot) {
        if let Some(mut proc) = slot.proc.take() {
            let _ = proc.child.kill();
            let _ = proc.child.wait();
        }
        slot.died = true;
    }

    /// Spawn one worker process and complete the `MAGIC`/`Hello` handshake.
    fn spawn_worker(&self, idx: usize) -> Result<WorkerProc> {
        let exe = match crate::config::envvars::read_os("ENGD_WORKER_EXE") {
            Some(p) => PathBuf::from(p),
            None => std::env::current_exe().context("resolving the worker executable")?,
        };
        let mut cmd = Command::new(exe);
        cmd.args(&self.opts.spawn_args)
            // Pin the determinism-critical knobs: the worker must rebuild
            // the supervisor's reduction chunk grid and kernel tier.
            .env("ENGD_THREADS", parallel::num_threads().to_string())
            .env("ENGD_NUMERICS", self.catalog.numerics().name())
            .env_remove("ENGD_BACKEND")
            .env_remove("ENGD_SHARD_FAULT")
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit());
        if let Some((w, after)) = self.opts.fault_once {
            // One worker, one incarnation: swap only evaluates when the
            // index matches, so the flag arms exactly once.
            if w == idx && !self.fault_armed.swap(true, Ordering::SeqCst) {
                cmd.env("ENGD_SHARD_FAULT", format!("after={after}"));
            }
        }
        let mut child = cmd.spawn().with_context(|| format!("spawning shard worker {idx}"))?;
        let stdin = child.stdin.take().expect("piped stdin");
        let stdout = child.stdout.take().expect("piped stdout");
        let mut proc = WorkerProc { child, stdin, rx: start_reader(stdout), ctx_gen: 0 };
        let hello = Frame::Hello { protocol: frames::PROTOCOL };
        let failure = match frames::write_frame(&mut proc.stdin, &hello) {
            Err(e) => anyhow!("greeting shard worker {idx}: {e}"),
            Ok(()) => match proc.rx.recv_timeout(self.opts.deadline) {
                Ok(Ok(Frame::HelloAck { .. })) => return Ok(proc),
                Ok(Ok(other)) => anyhow!("worker {idx} handshake desync: {other:?}"),
                Ok(Err(e)) => anyhow!("worker {idx} handshake failed: {e}"),
                Err(_) => anyhow!("worker {idx} handshake timed out"),
            },
        };
        let _ = proc.child.kill();
        let _ = proc.child.wait();
        Err(failure)
    }

    /// Run all of `units` through the workers: plan ranges, pump each
    /// worker's request/reply stream from its own I/O thread, recover from
    /// crashes, and land every reply via `write(lo, hi, values)`.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &self,
        kind: EvalKind,
        spec: &ProblemSpec,
        theta: &[f64],
        x_a: &[f64],
        x_b: &[f64],
        units: usize,
        write: &(dyn Fn(usize, usize, &[f64]) -> Result<()> + Sync),
    ) -> Result<()> {
        let workers = self.slots.len();
        let queue = RangeQueue::new(units, workers, self.opts.schedule);
        // One encode per evaluation; the bytes are shared by every worker
        // and re-shipped as-is after a respawn.
        let eval_bytes = frames::eval_frame_bytes(kind, spec, theta, x_a, x_b);
        let per_unit = kind.values_per_unit(spec.n_params);
        let gen = self.ctx_gen.fetch_add(1, Ordering::Relaxed) + 1;
        let in_flight = AtomicUsize::new(0);
        let done_units = AtomicUsize::new(0);
        let error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|scope| {
            for idx in 0..workers {
                let queue = &queue;
                let eval_bytes = &eval_bytes[..];
                let in_flight = &in_flight;
                let done_units = &done_units;
                let error = &error;
                scope.spawn(move || {
                    let outcome = self.worker_io_loop(
                        idx, gen, eval_bytes, queue, in_flight, done_units, per_unit, write,
                    );
                    if let Err(e) = outcome {
                        queue.poison();
                        let mut first = error.lock().unwrap_or_else(|p| p.into_inner());
                        if first.is_none() {
                            *first = Some(e);
                        }
                    }
                });
            }
        });
        if let Some(e) = error.lock().unwrap_or_else(|p| p.into_inner()).take() {
            return Err(e);
        }
        let done = done_units.load(Ordering::SeqCst);
        ensure!(
            done == units,
            "shard workers completed only {done} of {units} work units \
             (all respawn budgets exhausted?)"
        );
        Ok(())
    }

    /// One worker's dispatch loop: claim ranges, serve them through the
    /// worker process, recover dead workers. Returns `Err` only for
    /// dispatch-fatal conditions; a worker that exhausts its respawn
    /// budget retires with `Ok` after requeueing its range.
    #[allow(clippy::too_many_arguments)]
    fn worker_io_loop(
        &self,
        idx: usize,
        gen: u64,
        eval_bytes: &[u8],
        queue: &RangeQueue,
        in_flight: &AtomicUsize,
        done_units: &AtomicUsize,
        per_unit: usize,
        write: &(dyn Fn(usize, usize, &[f64]) -> Result<()> + Sync),
    ) -> Result<()> {
        let mut slot = self.slots[idx].lock().unwrap_or_else(|p| p.into_inner());
        let mut respawns_left = self.opts.max_respawns;
        // Only work stealing can hand this shard a peer's requeued range,
        // so only then is waiting on peers' in-flight work useful.
        let can_wait = self.opts.schedule == Schedule::WorkSteal;
        let t0 = Instant::now();
        let result = loop {
            let claimed = loop {
                if queue.is_poisoned() {
                    break None;
                }
                // Count ourselves in-flight *before* popping: peers then
                // never observe (empty queue, nothing in flight) while a
                // range could still be requeued.
                in_flight.fetch_add(1, Ordering::SeqCst);
                if let Some(r) = queue.pop_for(idx) {
                    break Some(r);
                }
                let others = in_flight.fetch_sub(1, Ordering::SeqCst) - 1;
                if others == 0 || !can_wait {
                    break None;
                }
                std::thread::sleep(Duration::from_micros(200));
            };
            let Some((lo, hi, stolen)) = claimed else {
                break Ok(());
            };
            self.sched.note_range(stolen);
            match self.run_range(&mut slot, idx, gen, eval_bytes, lo, hi) {
                Ok(values) => {
                    let expect = (hi - lo) * per_unit;
                    if values.len() != expect {
                        in_flight.fetch_sub(1, Ordering::SeqCst);
                        break Err(anyhow!(
                            "worker {idx} returned {} values for range [{lo}, {hi}) \
                             (expected {expect})",
                            values.len()
                        ));
                    }
                    let landed = write(lo, hi, &values);
                    if landed.is_ok() {
                        done_units.fetch_add(hi - lo, Ordering::SeqCst);
                    }
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    if let Err(e) = landed {
                        break Err(e);
                    }
                }
                Err(WorkerFailure::Fatal(e)) => {
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    break Err(e);
                }
                Err(WorkerFailure::Dead(e)) => {
                    // Crash, desync, or deadline: requeue for any live
                    // shard (before the in-flight decrement, so waiters
                    // can't miss it), then respawn lazily or retire.
                    Self::kill_slot(&mut slot);
                    queue.requeue(idx, lo, hi);
                    self.sched.note_requeue();
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    if respawns_left == 0 {
                        eprintln!(
                            "note: shard worker {idx} retired for this evaluation after \
                             exhausting its respawn budget ({e:#})"
                        );
                        break Ok(());
                    }
                    respawns_left -= 1;
                }
            }
        };
        self.sched.add_busy(idx, t0.elapsed());
        result
    }

    /// Serve one range through worker `idx`, (re)spawning it and
    /// (re)shipping the evaluation context as needed.
    fn run_range(
        &self,
        slot: &mut Slot,
        idx: usize,
        gen: u64,
        eval_bytes: &[u8],
        lo: usize,
        hi: usize,
    ) -> std::result::Result<Vec<f64>, WorkerFailure> {
        if slot.proc.is_none() {
            let was_respawn = slot.died;
            let proc = self.spawn_worker(idx).map_err(WorkerFailure::Dead)?;
            slot.proc = Some(proc);
            if was_respawn {
                self.sched.note_respawn();
            }
        }
        let proc = slot.proc.as_mut().expect("just spawned");
        if proc.ctx_gen != gen {
            proc.stdin
                .write_all(eval_bytes)
                .and_then(|()| proc.stdin.flush())
                .map_err(|e| WorkerFailure::Dead(anyhow!("sending eval context: {e}")))?;
            proc.ctx_gen = gen;
        }
        let range = Frame::Range { lo: lo as u64, hi: hi as u64 };
        proc.stdin
            .write_all(&frames::frame_bytes(&range))
            .and_then(|()| proc.stdin.flush())
            .map_err(|e| WorkerFailure::Dead(anyhow!("sending range request: {e}")))?;
        match proc.rx.recv_timeout(self.opts.deadline) {
            Ok(Ok(Frame::Data { values })) => Ok(values),
            Ok(Ok(Frame::Error { message })) => {
                Err(WorkerFailure::Fatal(anyhow!("worker {idx}: {message}")))
            }
            Ok(Ok(other)) => {
                Err(WorkerFailure::Dead(anyhow!("worker {idx} protocol desync: {other:?}")))
            }
            Ok(Err(e)) => Err(WorkerFailure::Dead(anyhow!("worker {idx} stream died: {e}"))),
            Err(RecvTimeoutError::Timeout) => Err(WorkerFailure::Dead(anyhow!(
                "worker {idx} missed the {:.1?} reply deadline",
                self.opts.deadline
            ))),
            Err(RecvTimeoutError::Disconnected) => {
                Err(WorkerFailure::Dead(anyhow!("worker {idx} reader thread disconnected")))
            }
        }
    }
}

/// Move the child's stdout into a reader thread that scans for the
/// [`frames::MAGIC`] prologue and then forwards decoded frames (or the
/// terminating I/O error) through a channel the dispatch loop can wait on
/// with a timeout.
fn start_reader(stdout: ChildStdout) -> Receiver<std::io::Result<Frame>> {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::Builder::new()
        .name("engd-shard-reader".to_string())
        .spawn(move || {
            let mut r = BufReader::new(stdout);
            if let Err(e) = sync_to_magic(&mut r) {
                let _ = tx.send(Err(e));
                return;
            }
            loop {
                match frames::read_frame(&mut r) {
                    Ok(f) => {
                        if tx.send(Ok(f)).is_err() {
                            return; // supervisor dropped this worker
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
        })
        .expect("spawning shard reader thread");
    rx
}

/// Consume the stream up to and including the 8-byte magic prologue,
/// tolerating a bounded amount of pre-protocol noise (a harness binary
/// may print a line before entering worker mode).
fn sync_to_magic(r: &mut impl Read) -> std::io::Result<()> {
    let mut window = [0u8; 8];
    let mut have = 0usize;
    let mut scanned = 0usize;
    loop {
        if have == window.len() && window == frames::MAGIC {
            return Ok(());
        }
        if scanned > 65536 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "worker never sent the protocol magic (is --shard-worker handled?)",
            ));
        }
        let mut b = [0u8; 1];
        r.read_exact(&mut b)?;
        scanned += 1;
        if have < window.len() {
            window[have] = b[0];
            have += 1;
        } else {
            window.rotate_left(1);
            window[7] = b[0];
        }
    }
}

impl Drop for ProcessEvaluator {
    fn drop(&mut self) {
        for slot in &mut self.slots {
            let slot = slot.get_mut().unwrap_or_else(|p| p.into_inner());
            let Some(proc) = slot.proc.take() else { continue };
            let WorkerProc { mut child, mut stdin, .. } = proc;
            // Polite shutdown: Exit frame, then EOF. Fall back to SIGKILL
            // if the worker doesn't leave within the grace window.
            let _ = frames::write_frame(&mut stdin, &Frame::Exit);
            drop(stdin);
            let grace = Instant::now() + Duration::from_millis(500);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < grace => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

impl Evaluator for ProcessEvaluator {
    fn backend_name(&self) -> &'static str {
        "process"
    }

    fn problem(&self, name: &str) -> Result<ProblemSpec> {
        self.catalog.problem(name)
    }

    fn problem_names(&self) -> Vec<String> {
        self.catalog.problem_names()
    }

    fn sched_stats(&self) -> Option<SchedSnapshot> {
        Some(self.sched.snapshot())
    }

    fn loss(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<f64> {
        let (chunks, _) = thread_chunks(p.n_total());
        // As in the thread tier: scratch may hold stale pool contents, but
        // the ranges tile `0..chunks` and `dispatch` fails unless every
        // unit landed, so the reduction only ever reads fresh values.
        let mut partials = self.lock_scratch().take_scratch(chunks);
        let dispatched = {
            let pptr = SendPtr(partials.as_mut_ptr());
            self.dispatch(EvalKind::Loss, p, theta, x_int, x_bnd, chunks, &|lo, hi, vals| {
                // SAFETY: queued chunk ranges are disjoint and `partials`
                // outlives the dispatch.
                let out = unsafe {
                    std::slice::from_raw_parts_mut(pptr.get().add(lo), hi - lo)
                };
                out.copy_from_slice(vals);
                Ok(())
            })
        };
        let loss = if dispatched.is_ok() {
            0.5 * partials.iter().sum::<f64>()
        } else {
            f64::NAN
        };
        self.lock_scratch().recycle(partials);
        dispatched?;
        Ok(loss)
    }

    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)> {
        let np = p.n_params;
        let (chunks, _) = thread_chunks(p.n_total());
        let (mut loss_parts, mut grad_parts) = {
            let mut ws = self.lock_scratch();
            (ws.take_scratch(chunks), ws.take_scratch(chunks * np))
        };
        let dispatched = {
            let lptr = SendPtr(loss_parts.as_mut_ptr());
            let gptr = SendPtr(grad_parts.as_mut_ptr());
            self.dispatch(
                EvalKind::LossGrad,
                p,
                theta,
                x_int,
                x_bnd,
                chunks,
                &|c0, c1, vals| {
                    let k = c1 - c0;
                    // Reply layout: k loss partials, then k·P gradients.
                    let (lv, gv) = vals.split_at(k);
                    // SAFETY: disjoint chunk ranges of both flat buffers,
                    // which outlive the dispatch.
                    unsafe {
                        std::slice::from_raw_parts_mut(lptr.get().add(c0), k)
                            .copy_from_slice(lv);
                        std::slice::from_raw_parts_mut(gptr.get().add(c0 * np), k * np)
                            .copy_from_slice(gv);
                    }
                    Ok(())
                },
            )
        };
        // Fixed chunk order — byte-for-byte the unsharded reduction.
        let mut grad = vec![0.0; np]; // lint: allow(alloc) — returned gradient, owned by caller
        let mut loss = 0.0;
        if dispatched.is_ok() {
            for k in 0..chunks {
                loss += loss_parts[k];
                for (total, gi) in grad.iter_mut().zip(&grad_parts[k * np..(k + 1) * np]) {
                    *total += gi;
                }
            }
        }
        {
            let mut ws = self.lock_scratch();
            ws.recycle(loss_parts);
            ws.recycle(grad_parts);
        }
        dispatched?;
        Ok((0.5 * loss, grad))
    }

    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)> {
        let n = p.n_total();
        let np = p.n_params;
        let mut j = ws.take_matrix(n, np);
        let mut r = vec![0.0; n]; // lint: allow(alloc) — returned residual, owned by caller
        let dispatched = {
            let jptr = SendPtr(j.data_mut().as_mut_ptr());
            let rptr = SendPtr(r.as_mut_ptr());
            self.dispatch(EvalKind::Rows, p, theta, x_int, x_bnd, n, &|row0, row1, vals| {
                let k = row1 - row0;
                // Reply layout: k residuals, then the k·P row-block.
                let (rv, jv) = vals.split_at(k);
                // SAFETY: disjoint row ranges of J and r, which outlive
                // the dispatch.
                unsafe {
                    std::slice::from_raw_parts_mut(rptr.get().add(row0), k)
                        .copy_from_slice(rv);
                    std::slice::from_raw_parts_mut(jptr.get().add(row0 * np), k * np)
                        .copy_from_slice(jv);
                }
                Ok(())
            })
        };
        if let Err(e) = dispatched {
            // A failed dispatch must not strand the pooled Jacobian: the
            // evaluator (and its caller's Workspace) outlive this error
            // (engd-lint R6).
            ws.recycle_matrix(j);
            return Err(e);
        }
        Ok((r, j))
    }

    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>> {
        let m = x_eval.len() / p.dim.max(1);
        let mut out = vec![0.0; m];
        {
            let optr = SendPtr(out.as_mut_ptr());
            self.dispatch(EvalKind::UPred, p, theta, x_eval, &[], m, &|i0, i1, vals| {
                // SAFETY: disjoint prediction ranges.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(optr.get().add(i0), i1 - i0)
                };
                slice.copy_from_slice(vals);
                Ok(())
            })?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Anything that actually spawns workers lives in the harness-free
    // `rust/tests/process.rs` suite (the libtest binary can't serve the
    // frame protocol on stdout). In-crate tests cover the supervisor's
    // pure pieces.

    #[test]
    fn default_options_read_the_environment_shape() {
        let opts = ProcessOptions::default();
        assert_eq!(opts.workers, parallel::num_threads());
        assert_eq!(opts.spawn_args, vec!["--shard-worker".to_string()]);
        assert!(opts.deadline > Duration::ZERO);
        assert!(opts.max_respawns >= 1);
        assert!(opts.fault_once.is_none());
    }

    #[test]
    fn magic_sync_tolerates_bounded_noise() {
        let mut clean = Vec::from(frames::MAGIC);
        clean.extend_from_slice(&[1, 2, 3]);
        let mut cur = std::io::Cursor::new(clean);
        sync_to_magic(&mut cur).unwrap();
        assert_eq!(cur.position(), 8);

        let mut noisy = b"harness header line\n".to_vec();
        noisy.extend_from_slice(&frames::MAGIC);
        sync_to_magic(&mut std::io::Cursor::new(noisy)).unwrap();

        let garbage = vec![0u8; 70_000];
        let err = sync_to_magic(&mut std::io::Cursor::new(garbage)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);

        let truncated = vec![b'E'; 4];
        let err = sync_to_magic(&mut std::io::Cursor::new(truncated)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    #[should_panic(expected = "at least one worker")]
    fn zero_workers_panics() {
        let _ = ProcessEvaluator::new(0);
    }

    #[test]
    fn catalogue_is_served_without_spawning_workers() {
        let ev = ProcessEvaluator::new(2);
        assert_eq!(ev.backend_name(), "process");
        assert!(ev.problem("poisson2d").is_ok());
        assert!(ev.problem_names().contains(&"heat2d".to_string()));
        assert_eq!(ev.worker_pids(), vec![None, None]);
        let snap = ev.sched_stats().unwrap();
        assert_eq!((snap.ranges, snap.steals, snap.requeues, snap.respawns), (0, 0, 0, 0));
    }
}
