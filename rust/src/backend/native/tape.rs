//! Hand-rolled AD for the tanh-MLP PDE residuals.
//!
//! One [`Tape`] is a per-thread scratch structure that evaluates, at a
//! single collocation point `x`:
//!
//! * the forward pass `u_θ(x)` together with **second-order forward duals**
//!   per coordinate — for each `i < ncoords` it carries `(∂/∂x_i,
//!   ∂²/∂x_i²)` through every layer, so the Laplacian is
//!   `Δu = Σ_i d2(i)` at cost O(d) network passes, the Taylor-mode-style
//!   strategy the paper cites for its JAX implementation;
//! * the **reverse pass** `∇_θ (α·u + Σ_i β_i·∂_i u + Σ_i γ_i·∂²_i u)`,
//!   i.e. the exact adjoint of the dual-carrying forward computation,
//!   accumulated straight into a caller-provided flat-θ buffer. Seeding
//!   `γ ≡ −s` yields an interior-residual Jacobian row; `α = s` a boundary
//!   row; scaling the seeds by `r_i` accumulates `∇L = Jᵀr` with no J.
//!
//! Derivative bookkeeping (per hidden layer, `h = tanh(z)`):
//!
//! ```text
//! forward:  ζ_i = W t_{i,prev}         t_i = σ'(z)·ζ_i
//!           ξ_i = W s_{i,prev}         s_i = σ''(z)·ζ_i² + σ'(z)·ξ_i
//! reverse:  z̄  += σ'·h̄ + Σ_i [σ''·ζ_i·t̄_i + (σ'''·ζ_i² + σ''·ξ_i)·s̄_i]
//!           ζ̄_i = σ'·t̄_i + 2σ''·ζ_i·s̄_i,      ξ̄_i = σ'·s̄_i
//! ```
//!
//! with `σ' = 1−h²`, `σ'' = −2hσ'`, `σ''' = σ'(6h²−2)`.
//!
//! Everything is verified against [`crate::pde::mlp_forward`] and against
//! central finite differences by unit + property tests (this module and
//! `rust/tests/native.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::pde::param_count;

/// Process-wide count of [`Tape`] constructions. The worker-pool contract
/// says a warmed-up training step rebuilds zero tapes; `rust/tests/pool.rs`
/// asserts this counter freezes after the first step.
static TAPE_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// How many tapes have ever been built in this process.
pub fn tape_builds() -> usize {
    TAPE_BUILDS.load(Ordering::Relaxed)
}

/// Per-point forward/reverse AD scratch for one architecture. Owned by one
/// worker thread and reused across points, evaluations, and training steps
/// (it lives in the thread's `parallel::with_scratch` slot); all buffers
/// are allocated once at construction.
pub struct Tape {
    arch: Vec<usize>,
    /// Flat-θ offset of each layer's weight block (biases follow it).
    offsets: Vec<usize>,
    /// Per layer: activated outputs h (tanh values; last layer: z itself).
    h: Vec<Vec<f64>>,
    /// Per layer: pre-activation first duals ζ_i, flattened `i*width + o`.
    tz: Vec<Vec<f64>>,
    /// Per layer: pre-activation second duals ξ_i.
    sz: Vec<Vec<f64>>,
    /// Per layer: activated first duals t_i.
    th: Vec<Vec<f64>>,
    /// Per layer: activated second duals s_i.
    sh: Vec<Vec<f64>>,
    /// Copy of the input point (needed by the reverse pass at layer 0).
    x_in: Vec<f64>,
    /// Number of dual coordinates carried by the last `forward`.
    ncoords: usize,
    // Reverse-pass scratch, sized to the widest layer.
    zbar: Vec<f64>,
    tbar: Vec<f64>,
    sbar: Vec<f64>,
    zbar_next: Vec<f64>,
    tbar_next: Vec<f64>,
    sbar_next: Vec<f64>,
}

impl Tape {
    pub fn new(arch: &[usize]) -> Self {
        TAPE_BUILDS.fetch_add(1, Ordering::Relaxed);
        assert!(arch.len() >= 2, "MLP needs at least one layer");
        assert_eq!(*arch.last().unwrap(), 1, "scalar-output MLP expected");
        let d = arch[0];
        let nl = arch.len() - 1;
        let mut offsets = Vec::with_capacity(nl);
        let mut off = 0usize;
        for l in 0..nl {
            offsets.push(off);
            off += arch[l] * arch[l + 1] + arch[l + 1];
        }
        let widest = *arch.iter().max().unwrap();
        let mut h = Vec::with_capacity(nl);
        let mut tz = Vec::with_capacity(nl);
        let mut sz = Vec::with_capacity(nl);
        let mut th = Vec::with_capacity(nl);
        let mut sh = Vec::with_capacity(nl);
        for l in 0..nl {
            let w = arch[l + 1];
            h.push(vec![0.0; w]);
            tz.push(vec![0.0; d * w]);
            sz.push(vec![0.0; d * w]);
            th.push(vec![0.0; d * w]);
            sh.push(vec![0.0; d * w]);
        }
        Tape {
            arch: arch.to_vec(),
            offsets,
            h,
            tz,
            sz,
            th,
            sh,
            x_in: vec![0.0; d],
            ncoords: 0,
            zbar: vec![0.0; widest],
            tbar: vec![0.0; d * widest],
            sbar: vec![0.0; d * widest],
            zbar_next: vec![0.0; widest],
            tbar_next: vec![0.0; d * widest],
            sbar_next: vec![0.0; d * widest],
        }
    }

    /// Forward pass at `x`, carrying `(∂_i, ∂²_i)` duals for the first
    /// `ncoords` coordinates (0 = plain forward).
    pub fn forward(&mut self, theta: &[f64], x: &[f64], ncoords: usize) {
        let arch = &self.arch;
        let d = arch[0];
        let nl = arch.len() - 1;
        debug_assert_eq!(x.len(), d, "input dim mismatch");
        debug_assert_eq!(theta.len(), param_count(arch), "param count mismatch");
        debug_assert!(ncoords <= d);
        self.ncoords = ncoords;
        self.x_in.copy_from_slice(x);
        for l in 0..nl {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let b = &theta[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            let last = l + 1 == nl;
            // Split so layer l-1 (read) and layer l (write) coexist.
            let (h_done, h_rest) = self.h.split_at_mut(l);
            let (th_done, th_rest) = self.th.split_at_mut(l);
            let (sh_done, sh_rest) = self.sh.split_at_mut(l);
            let h_cur = &mut h_rest[0];
            let th_cur = &mut th_rest[0];
            let sh_cur = &mut sh_rest[0];
            let tz_cur = &mut self.tz[l];
            let sz_cur = &mut self.sz[l];
            let h_prev: &[f64] = if l == 0 { x } else { &h_done[l - 1] };
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                let mut z = b[o];
                for (wi, hi) in row.iter().zip(h_prev.iter()) {
                    z += wi * hi;
                }
                for i in 0..ncoords {
                    let (zeta, xi) = if l == 0 {
                        // t_prev = e_i, s_prev = 0.
                        (row[i], 0.0)
                    } else {
                        let tp = &th_done[l - 1][i * fan_in..(i + 1) * fan_in];
                        let sp = &sh_done[l - 1][i * fan_in..(i + 1) * fan_in];
                        let mut zeta = 0.0;
                        let mut xi = 0.0;
                        for k in 0..fan_in {
                            zeta += row[k] * tp[k];
                            xi += row[k] * sp[k];
                        }
                        (zeta, xi)
                    };
                    tz_cur[i * fan_out + o] = zeta;
                    sz_cur[i * fan_out + o] = xi;
                }
                if last {
                    // Linear head: activated values = pre-activation values.
                    h_cur[o] = z;
                    for i in 0..ncoords {
                        th_cur[i * fan_out + o] = tz_cur[i * fan_out + o];
                        sh_cur[i * fan_out + o] = sz_cur[i * fan_out + o];
                    }
                } else {
                    let y = z.tanh();
                    let d1 = 1.0 - y * y;
                    let d2 = -2.0 * y * d1;
                    h_cur[o] = y;
                    for i in 0..ncoords {
                        let zeta = tz_cur[i * fan_out + o];
                        let xi = sz_cur[i * fan_out + o];
                        th_cur[i * fan_out + o] = d1 * zeta;
                        sh_cur[i * fan_out + o] = d2 * zeta * zeta + d1 * xi;
                    }
                }
            }
        }
    }

    /// `u_θ(x)` from the last forward.
    pub fn value(&self) -> f64 {
        self.h[self.arch.len() - 2][0]
    }

    /// `∂u/∂x_i` from the last forward (requires `i < ncoords`).
    pub fn d1(&self, i: usize) -> f64 {
        debug_assert!(i < self.ncoords);
        self.th[self.arch.len() - 2][i]
    }

    /// `∂²u/∂x_i²` from the last forward (requires `i < ncoords`).
    pub fn d2(&self, i: usize) -> f64 {
        debug_assert!(i < self.ncoords);
        self.sh[self.arch.len() - 2][i]
    }

    /// Accumulate `out += ∇_θ (α·u + Σ_i β_i·∂_i u + Σ_i γ_i·∂²_i u)` using
    /// the duals stored by the last [`Tape::forward`]. `beta`/`gamma` may be
    /// shorter than `ncoords` (missing entries are zero) but not longer.
    pub fn backward(
        &mut self,
        theta: &[f64],
        alpha: f64,
        beta: &[f64],
        gamma: &[f64],
        out: &mut [f64],
    ) {
        let arch = &self.arch;
        let nl = arch.len() - 1;
        let nc = self.ncoords;
        debug_assert!(beta.len() <= nc && gamma.len() <= nc);
        debug_assert_eq!(out.len(), param_count(arch));
        // Seed at the (width-1, linear) output layer.
        self.zbar[0] = alpha;
        for i in 0..nc {
            self.tbar[i] = beta.get(i).copied().unwrap_or(0.0);
            self.sbar[i] = gamma.get(i).copied().unwrap_or(0.0);
        }
        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let h_prev: &[f64] = if l == 0 { &self.x_in } else { &self.h[l - 1] };
            // 1. Parameter gradients of this layer.
            let (out_w, out_rest) = out[off..].split_at_mut(fan_in * fan_out);
            let out_b = &mut out_rest[..fan_out];
            for o in 0..fan_out {
                let zb = self.zbar[o];
                let wrow = &mut out_w[o * fan_in..(o + 1) * fan_in];
                if zb != 0.0 {
                    for k in 0..fan_in {
                        wrow[k] += zb * h_prev[k];
                    }
                }
                out_b[o] += zb;
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    let sb = self.sbar[i * fan_out + o];
                    if l == 0 {
                        // t_prev = e_i (s_prev = 0): only column i gets ∂ζ/∂W.
                        wrow[i] += tb;
                    } else if tb != 0.0 || sb != 0.0 {
                        let tp = &self.th[l - 1][i * fan_in..(i + 1) * fan_in];
                        let sp = &self.sh[l - 1][i * fan_in..(i + 1) * fan_in];
                        for k in 0..fan_in {
                            wrow[k] += tb * tp[k] + sb * sp[k];
                        }
                    }
                }
            }
            if l == 0 {
                break;
            }
            // 2. Propagate through Wᵀ to the previous layer's activated
            //    outputs (h̄, t̄, s̄), into the *_next scratch.
            for k in 0..fan_in {
                self.zbar_next[k] = 0.0;
            }
            for i in 0..nc {
                for k in 0..fan_in {
                    self.tbar_next[i * fan_in + k] = 0.0;
                    self.sbar_next[i * fan_in + k] = 0.0;
                }
            }
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                let zb = self.zbar[o];
                if zb != 0.0 {
                    for k in 0..fan_in {
                        self.zbar_next[k] += row[k] * zb;
                    }
                }
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    let sb = self.sbar[i * fan_out + o];
                    if tb != 0.0 {
                        for k in 0..fan_in {
                            self.tbar_next[i * fan_in + k] += row[k] * tb;
                        }
                    }
                    if sb != 0.0 {
                        for k in 0..fan_in {
                            self.sbar_next[i * fan_in + k] += row[k] * sb;
                        }
                    }
                }
            }
            // 3. Convert activation-level adjoints of layer l-1 to
            //    pre-activation adjoints (the tanh chain rules above).
            let hm = &self.h[l - 1];
            let tzm = &self.tz[l - 1];
            let szm = &self.sz[l - 1];
            for o in 0..fan_in {
                let y = hm[o];
                let d1 = 1.0 - y * y;
                let d2 = -2.0 * y * d1;
                let d3 = d1 * (6.0 * y * y - 2.0);
                let mut zb = d1 * self.zbar_next[o];
                for i in 0..nc {
                    let zeta = tzm[i * fan_in + o];
                    let xi = szm[i * fan_in + o];
                    let tb = self.tbar_next[i * fan_in + o];
                    let sb = self.sbar_next[i * fan_in + o];
                    zb += d2 * zeta * tb + (d3 * zeta * zeta + d2 * xi) * sb;
                    self.tbar[i * fan_in + o] = d1 * tb + 2.0 * d2 * zeta * sb;
                    self.sbar[i * fan_in + o] = d1 * sb;
                }
                self.zbar[o] = zb;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{init_params, mlp_forward};
    use crate::rng::Rng;

    fn fd_value(theta: &[f64], arch: &[usize], x: &[f64], i: usize, h: f64) -> (f64, f64) {
        // (first, second) central differences of u along coordinate i.
        let mut xp = x.to_vec();
        let mut xm = x.to_vec();
        xp[i] += h;
        xm[i] -= h;
        let up = mlp_forward(theta, arch, &xp);
        let um = mlp_forward(theta, arch, &xm);
        let u0 = mlp_forward(theta, arch, x);
        ((up - um) / (2.0 * h), (up - 2.0 * u0 + um) / (h * h))
    }

    #[test]
    fn forward_matches_mlp_oracle() {
        let arch = [3usize, 8, 6, 1];
        let mut rng = Rng::seed_from(11);
        let theta = init_params(&arch, &mut rng);
        let mut tape = Tape::new(&arch);
        for case in 0..20 {
            let mut x = [0.0; 3];
            rng.fill_uniform(&mut x, 0.0, 1.0);
            tape.forward(&theta, &x, if case % 2 == 0 { 3 } else { 0 });
            let want = mlp_forward(&theta, &arch, &x);
            assert!(
                (tape.value() - want).abs() < 1e-13,
                "case {case}: {} vs {}",
                tape.value(),
                want
            );
        }
    }

    #[test]
    fn duals_match_finite_differences() {
        let arch = [2usize, 10, 10, 1];
        let mut rng = Rng::seed_from(7);
        let theta = init_params(&arch, &mut rng);
        let mut tape = Tape::new(&arch);
        for _ in 0..10 {
            let mut x = [0.0; 2];
            rng.fill_uniform(&mut x, 0.1, 0.9);
            tape.forward(&theta, &x, 2);
            for i in 0..2 {
                let (fd1, fd2) = fd_value(&theta, &arch, &x, i, 1e-5);
                assert!(
                    (tape.d1(i) - fd1).abs() < 1e-8 * (1.0 + fd1.abs()),
                    "d1[{i}]: {} vs fd {fd1}",
                    tape.d1(i)
                );
                assert!(
                    (tape.d2(i) - fd2).abs() < 1e-4 * (1.0 + fd2.abs()),
                    "d2[{i}]: {} vs fd {fd2}",
                    tape.d2(i)
                );
            }
        }
    }

    #[test]
    fn backward_value_grad_matches_fd() {
        // α-seeded backward = plain ∇_θ u, checked by central differences.
        let arch = [2usize, 6, 5, 1];
        let mut rng = Rng::seed_from(3);
        let theta = init_params(&arch, &mut rng);
        let x = [0.4, 0.7];
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, 0);
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 1.0, &[], &[], &mut grad);
        let eps = 1e-6;
        for jj in 0..theta.len() {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (mlp_forward(&tp, &arch, &x) - mlp_forward(&tm, &arch, &x)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-7 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }

    #[test]
    fn backward_laplacian_grad_matches_fd() {
        // γ-seeded backward = ∇_θ Δu, checked by FD of the tape's own
        // Laplacian (whose duals are independently FD-verified above).
        let arch = [2usize, 6, 6, 1];
        let mut rng = Rng::seed_from(5);
        let theta = init_params(&arch, &mut rng);
        let x = [0.3, 0.6];
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, 2);
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 0.0, &[], &[1.0, 1.0], &mut grad);
        let lap_at = |t: &[f64], tape: &mut Tape| {
            tape.forward(t, &x, 2);
            tape.d2(0) + tape.d2(1)
        };
        let eps = 1e-6;
        for jj in (0..theta.len()).step_by(7) {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (lap_at(&tp, &mut tape) - lap_at(&tm, &mut tape)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }

    #[test]
    fn backward_time_derivative_grad_matches_fd() {
        // β-seeded backward = ∇_θ ∂_t u (the heat-operator path).
        let arch = [3usize, 5, 1];
        let mut rng = Rng::seed_from(9);
        let theta = init_params(&arch, &mut rng);
        let x = [0.2, 0.8, 0.5];
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, 3);
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 0.0, &[0.0, 0.0, 1.0], &[], &mut grad);
        let dt_at = |t: &[f64], tape: &mut Tape| {
            tape.forward(t, &x, 3);
            tape.d1(2)
        };
        let eps = 1e-6;
        for jj in 0..theta.len() {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (dt_at(&tp, &mut tape) - dt_at(&tm, &mut tape)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }
}
