//! Hand-rolled AD for the tanh-MLP PDE residuals — the coordinate-blocked,
//! point-batched kernel layer of the native backend.
//!
//! One [`Tape`] is a per-thread scratch structure that evaluates, for a
//! *block* of up to [`Tape::block_points`] collocation points at once:
//!
//! * the forward pass `u_θ(x)` together with **forward duals** per input
//!   coordinate, to the per-coordinate order requested by a
//!   [`DualOrder`] mask — for each order-2 coordinate `i` it carries
//!   `(∂/∂x_i, ∂²/∂x_i²)` through every layer (the Laplacian is
//!   `Δu = Σ_i d2(i)` at cost O(d) network passes, the Taylor-mode-style
//!   strategy the paper cites for its JAX implementation); order-1
//!   coordinates (the heat operator's time axis) carry only `∂_i`, which
//!   drops two matrix-panel products per layer;
//! * the **reverse pass** `∇_θ (α·u + Σ_i β_i·∂_i u + Σ_i γ_i·∂²_i u)`,
//!   i.e. the exact adjoint of the dual-carrying forward computation,
//!   accumulated straight into caller-provided flat-θ buffers — one row
//!   per point ([`Tape::backward_batch`]) or a shared gradient
//!   accumulator seeded per point ([`Tape::backward`]). Seeding `γ ≡ −s`
//!   yields an interior-residual Jacobian row; `α = s` a boundary row;
//!   scaling the seeds by `r_i` accumulates `∇L = Jᵀr` with no J.
//!
//! ## Numerics tiers
//!
//! The tape ships two kernel tiers behind [`NumericsMode`]
//! (`--numerics bitwise|fast`, `ENGD_NUMERICS`, the `numerics` TOML key):
//!
//! * **`bitwise`** ([`Tape::new`], the default) — everything documented
//!   below: each lane preserves the scalar per-point FP sequence exactly
//!   (no FMA contraction, no reassociation, per-lane zero-skip guards),
//!   so blocking, sharding, and threading change no trajectory bit and
//!   `python/tools/tape_oracle.py` mirrors the kernels bitwise.
//! * **`fast`** ([`Tape::with_numerics`]) — the same math through the
//!   [`super::simd`] kernel tier: FMA-contracted multi-row panel passes
//!   dispatched by runtime CPU feature detection ([`SimdTier::detect`],
//!   `ENGD_SIMD` override), wider point blocks, and quad-level zero-skip
//!   guards in the fused reverse sweep. Results agree with the bitwise
//!   tier to rounding-level tolerance only (property-tested at 1e-10
//!   relative against [`ScalarTape`]); per-point results remain
//!   independent of block/shard/thread shape for a fixed binary and CPU
//!   tier, but `fast` trajectories are **not** bitwise-comparable to
//!   `bitwise` ones (checkpoints record the mode; resume refuses a
//!   silent switch). The single-point [`Tape::backward`] kernel is
//!   shared by both tiers.
//!
//! Everything below describes the bitwise tier unless stated otherwise.
//!
//! ## Adjoint panels (the fused batched reverse pass)
//!
//! [`Tape::backward_batch`] is a **layer-outer / point-inner** nest: the
//! whole block's adjoints stay resident as per-(point, coordinate)
//! **adjoint panels** (`z̄`/`t̄`/`s̄`, one `widest`-strided panel per dual
//! lane, same panel discipline as the forward duals), and each layer is
//! retired for *all* points before the sweep descends:
//!
//! 1. per-point parameter gradients of the layer, each into its own
//!    contiguous row of the caller's J sub-block;
//! 2. one fused `Wᵀ` propagation: weight row `o` is loaded **once per
//!    layer per block** and pushed through every point's live adjoint
//!    lanes as stride-1 axpys (`dst[k] += row[k]·λ̄`), instead of the
//!    per-point nest re-streaming W from L2/L3 for every row of the
//!    block;
//! 3. per-point tanh chain rules converting activation-level adjoints to
//!    pre-activation adjoints, as stride-1 lane sweeps over precomputed
//!    `σ'/σ''/σ'''` vectors.
//!
//! Per destination element the accumulation order over `o` is ascending
//! and every zero-adjoint skip is taken per lane, exactly as in the
//! per-point [`Tape::backward`] — so each row of the fused pass is
//! **bitwise** the standalone per-point reverse pass, which the property
//! tests assert against both [`Tape::backward`] and [`ScalarTape`].
//!
//! ## Blocked layout
//!
//! Duals are stored as **contiguous per-coordinate panels**: layer `l`
//! keeps, for every (point `b`, coordinate `i`) pair, one `fan_out`-long
//! panel at offset `(b·nc + i)·fan_out`. The forward propagation
//! (`ζ_i = W·t_prev_i`, `ξ_i = W·s_prev_i`) transposes `W` once per layer
//! per block and then runs broadcast–accumulate kernels whose inner loops
//! are stride-1 over the `fan_out` lanes:
//!
//! ```text
//! for k in 0..fan_in:            // sequential — preserves FP sum order
//!     ζ[o] += Wᵀ[k][o] · t_prev[k]   // o: contiguous lanes, auto-SIMD
//! ```
//!
//! Every lane (one output neuron of one point/coordinate pair) performs
//! exactly the scalar dot-product sequence `Σ_k w·t` in ascending `k`, so
//! the blocked kernels are **bitwise identical** to the scalar
//! per-(point, coordinate) loops they replace — vectorization happens
//! across independent lanes, never across a floating-point reduction.
//! [`ScalarTape`] keeps the naive loop nest as an in-tree reference; the
//! property tests in this module assert bitwise agreement of
//! `value`/`d1`/`d2`/`backward` across random architectures, dual masks,
//! and batched-vs-single-point entry points.
//!
//! Batching a point block through one call amortizes the `Wᵀ` transpose
//! and keeps each weight panel hot across `B·(1 + nc)` propagation passes
//! (ζ and ξ are fused per coordinate, so a panel load feeds both dual
//! orders) instead of re-walking θ per point; the block size adapts to
//! the coordinate count ([`Tape::block_points`]) so panel storage stays
//! bounded (~[`MAX_BLOCK_POINTS`] value lanes / `DUAL_LANE_BUDGET` dual
//! lanes) from `poisson1d` to `poisson100d`.
//!
//! Derivative bookkeeping (per hidden layer, `h = tanh(z)`):
//!
//! ```text
//! forward:  ζ_i = W t_{i,prev}         t_i = σ'(z)·ζ_i
//!           ξ_i = W s_{i,prev}         s_i = σ''(z)·ζ_i² + σ'(z)·ξ_i
//! reverse:  z̄  += σ'·h̄ + Σ_i [σ''·ζ_i·t̄_i + (σ'''·ζ_i² + σ''·ξ_i)·s̄_i]
//!           ζ̄_i = σ'·t̄_i + 2σ''·ζ_i·s̄_i,      ξ̄_i = σ'·s̄_i
//! ```
//!
//! with `σ' = 1−h²`, `σ'' = −2hσ'`, `σ''' = σ'(6h²−2)`.
//!
//! Everything is verified against [`crate::pde::mlp_forward`], against
//! central finite differences, and against [`ScalarTape`] by unit +
//! property tests (this module and `rust/tests/native.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::simd::{self, NumericsMode, SimdTier};
use crate::pde::{param_count, DualOrder};

/// Process-wide count of [`Tape`] constructions. The worker-pool contract
/// says a warmed-up training step rebuilds zero tapes; `rust/tests/pool.rs`
/// asserts this counter freezes after the first step.
static TAPE_BUILDS: AtomicUsize = AtomicUsize::new(0);

/// How many tapes have ever been built in this process.
pub fn tape_builds() -> usize {
    TAPE_BUILDS.load(Ordering::Relaxed)
}

/// Most points one bitwise-tier [`Tape::forward_batch`] call carries (the
/// block size for value-only passes; dual-carrying passes shrink with the
/// coordinate count — see [`Tape::block_points`]). The fast tier doubles
/// both caps (`simd::FAST_MAX_BLOCK_POINTS` / `FAST_DUAL_LANE_BUDGET`).
pub const MAX_BLOCK_POINTS: usize = 32;

/// Soft cap on dual lanes (point × coordinate pairs) per bitwise-tier
/// block: per-layer panel storage is ~`max(DUAL_LANE_BUDGET, d)` panels of
/// the layer width, so high-dimensional problems fall back to small point
/// blocks while low-dimensional ones batch aggressively.
const DUAL_LANE_BUDGET: usize = 64;

/// Value-lane / dual-lane block caps per numerics mode.
fn limits_for(mode: NumericsMode) -> (usize, usize) {
    match mode {
        NumericsMode::Bitwise => (MAX_BLOCK_POINTS, DUAL_LANE_BUDGET),
        NumericsMode::Fast => (simd::FAST_MAX_BLOCK_POINTS, simd::FAST_DUAL_LANE_BUDGET),
    }
}

/// Points per block for a `nc`-coordinate dual pass under the given caps.
fn block_points_with(nc: usize, max_block: usize, lane_budget: usize) -> usize {
    if nc == 0 {
        max_block
    } else {
        (lane_budget / nc).clamp(1, max_block)
    }
}

/// Whether any coefficient of a reverse-sweep row quad is live (the fast
/// tier's quad-level analogue of the per-row zero-skip guard).
#[inline(always)]
fn any_nz(c: &[f64; 4]) -> bool {
    c.iter().any(|&v| v != 0.0)
}

/// Per-block forward/reverse AD scratch for one architecture. Owned by one
/// worker thread and reused across blocks, evaluations, and training steps
/// (it lives in the thread's `parallel::with_scratch` slot); all buffers
/// are allocated once at construction.
pub struct Tape {
    arch: Vec<usize>,
    /// Numerics tier of this tape (bitwise kernels vs the fast SIMD tier).
    mode: NumericsMode,
    /// Instruction-set tier the fast kernels dispatch to (pinned at
    /// construction; irrelevant in bitwise mode).
    tier: SimdTier,
    /// Value-lane block cap for this tape's mode.
    max_block: usize,
    /// Dual-lane budget for this tape's mode.
    lane_budget: usize,
    /// Flat-θ offset of each layer's weight block (biases follow it).
    offsets: Vec<usize>,
    /// Per layer: activated outputs h (tanh values; last layer: z itself),
    /// `b * width + o`.
    h: Vec<Vec<f64>>,
    /// Per layer: pre-activation first duals ζ, per-coordinate panels
    /// `(b * nc + i) * width + o`.
    tz: Vec<Vec<f64>>,
    /// Per layer: pre-activation second duals ξ, `(b * nc2 + i) * width + o`.
    sz: Vec<Vec<f64>>,
    /// Per layer: activated first duals t (same panel layout as `tz`).
    th: Vec<Vec<f64>>,
    /// Per layer: activated second duals s (same panel layout as `sz`).
    sh: Vec<Vec<f64>>,
    /// Copy of the input block (needed by the reverse pass at layer 0).
    x_in: Vec<f64>,
    /// Wᵀ of the layer currently propagating (transposed per layer per
    /// block so forward kernels read contiguous `fan_out`-lanes).
    wt: Vec<f64>,
    /// σ'(z) per output neuron of the point being activated.
    d1v: Vec<f64>,
    /// σ''(z) per output neuron of the point being activated.
    d2v: Vec<f64>,
    /// Points carried by the last `forward_batch`.
    n_pts: usize,
    /// Coordinates carrying first-order duals in the last `forward_batch`.
    nc: usize,
    /// Coordinates (prefix of `nc`) also carrying second-order duals.
    nc2: usize,
    /// Widest layer (panel stride of the adjoint panels below).
    widest: usize,
    // Single-point reverse-pass scratch ([`Tape::backward`]), sized to the
    // widest layer.
    zbar: Vec<f64>,
    tbar: Vec<f64>,
    sbar: Vec<f64>,
    zbar_next: Vec<f64>,
    tbar_next: Vec<f64>,
    sbar_next: Vec<f64>,
    // Fused batched reverse-pass state ([`Tape::backward_batch`]): the
    // whole block's adjoints, one `widest`-strided panel per live lane —
    // z̄ per point (`pz`), t̄ per (point, coordinate) (`pt`), s̄ per
    // (point, order-2 coordinate) (`ps`) — plus the layer-below images
    // the fused Wᵀ sweep accumulates into (`*_next`).
    pz: Vec<f64>,
    pt: Vec<f64>,
    ps: Vec<f64>,
    pz_next: Vec<f64>,
    pt_next: Vec<f64>,
    ps_next: Vec<f64>,
    /// σ'''(z) per output neuron of the point being activated (the fused
    /// reverse chain rule precomputes σ-derivative vectors per point).
    d3v: Vec<f64>,
}

impl Tape {
    /// A bitwise-tier tape (the default numerics mode).
    pub fn new(arch: &[usize]) -> Self {
        Self::build(arch, NumericsMode::Bitwise, SimdTier::Scalar)
    }

    /// A tape in the given numerics mode; fast mode dispatches to the
    /// process-wide [`SimdTier::detect`].
    pub fn with_numerics(arch: &[usize], mode: NumericsMode) -> Self {
        let tier = match mode {
            NumericsMode::Bitwise => SimdTier::Scalar,
            NumericsMode::Fast => SimdTier::detect(),
        };
        Self::build(arch, mode, tier)
    }

    /// A fast-mode tape pinned to `tier` (clamped to `scalar` if this CPU
    /// cannot run it) — the forced-tier seam the cross-check tests use.
    pub fn with_tier(arch: &[usize], tier: SimdTier) -> Self {
        let tier = if tier.supported() { tier } else { SimdTier::Scalar };
        Self::build(arch, NumericsMode::Fast, tier)
    }

    fn build(arch: &[usize], mode: NumericsMode, tier: SimdTier) -> Self {
        TAPE_BUILDS.fetch_add(1, Ordering::Relaxed);
        assert!(arch.len() >= 2, "MLP needs at least one layer");
        assert_eq!(*arch.last().unwrap(), 1, "scalar-output MLP expected");
        let (max_block, lane_budget) = limits_for(mode);
        let d = arch[0];
        let nl = arch.len() - 1;
        let mut offsets = Vec::with_capacity(nl);
        let mut off = 0usize;
        for l in 0..nl {
            offsets.push(off);
            off += arch[l] * arch[l + 1] + arch[l + 1];
        }
        let widest = *arch.iter().max().unwrap();
        // Worst-case dual lanes over every mask this input dimension can
        // request: `block_points_with` shrinks the block as `nc` grows, so
        // this stays ~max(lane_budget, d) lanes.
        let lane_cap = (1..=d)
            .map(|nc| block_points_with(nc, max_block, lane_budget) * nc)
            .max()
            .unwrap_or(0);
        let widest_w = (0..nl).map(|l| arch[l] * arch[l + 1]).max().unwrap();
        let mut h = Vec::with_capacity(nl);
        let mut tz = Vec::with_capacity(nl);
        let mut sz = Vec::with_capacity(nl);
        let mut th = Vec::with_capacity(nl);
        let mut sh = Vec::with_capacity(nl);
        for l in 0..nl {
            let w = arch[l + 1];
            h.push(vec![0.0; max_block * w]);
            tz.push(vec![0.0; lane_cap * w]);
            sz.push(vec![0.0; lane_cap * w]);
            th.push(vec![0.0; lane_cap * w]);
            sh.push(vec![0.0; lane_cap * w]);
        }
        Tape {
            arch: arch.to_vec(),
            mode,
            tier,
            max_block,
            lane_budget,
            offsets,
            h,
            tz,
            sz,
            th,
            sh,
            x_in: vec![0.0; max_block * d],
            wt: vec![0.0; widest_w],
            d1v: vec![0.0; widest],
            d2v: vec![0.0; widest],
            n_pts: 0,
            nc: 0,
            nc2: 0,
            widest,
            zbar: vec![0.0; widest],
            tbar: vec![0.0; d * widest],
            sbar: vec![0.0; d * widest],
            zbar_next: vec![0.0; widest],
            tbar_next: vec![0.0; d * widest],
            sbar_next: vec![0.0; d * widest],
            pz: vec![0.0; max_block * widest],
            pt: vec![0.0; lane_cap * widest],
            ps: vec![0.0; lane_cap * widest],
            pz_next: vec![0.0; max_block * widest],
            pt_next: vec![0.0; lane_cap * widest],
            ps_next: vec![0.0; lane_cap * widest],
            d3v: vec![0.0; widest],
        }
    }

    /// Largest point block a `forward_batch` with this dual mask may carry:
    /// the mode's value-lane cap ([`MAX_BLOCK_POINTS`] in bitwise mode,
    /// double that in fast mode) for value-only passes, shrinking as the
    /// coordinate count grows so panel storage stays bounded.
    pub fn block_points(&self, orders: DualOrder) -> usize {
        debug_assert!(orders.first <= self.arch[0]);
        block_points_with(orders.first, self.max_block, self.lane_budget)
    }

    /// This tape's numerics mode.
    pub fn numerics(&self) -> NumericsMode {
        self.mode
    }

    /// Instruction-set tier the fast kernels dispatch to (pinned at
    /// construction; `scalar` for bitwise-mode tapes, where it is unused).
    pub fn tier(&self) -> SimdTier {
        self.tier
    }

    /// Forward pass over a block of `n_pts` points (`xs` row-major,
    /// `n_pts × d`), carrying duals per the `orders` mask: coordinates
    /// `0..orders.first` get `∂_i`, the prefix `0..orders.second` also
    /// `∂²_i`. `n_pts` must not exceed [`Tape::block_points`]`(orders)`.
    pub fn forward_batch(&mut self, theta: &[f64], xs: &[f64], n_pts: usize, orders: DualOrder) {
        if self.mode == NumericsMode::Fast {
            return self.forward_batch_fast(theta, xs, n_pts, orders);
        }
        let d = self.arch[0];
        let nl = self.arch.len() - 1;
        let (nc, nc2) = (orders.first, orders.second);
        // Hard asserts: a mask violating the prefix invariant or an
        // oversized block would index panels the pass never writes
        // (silently stale lanes), which release builds must refuse too.
        assert!(nc2 <= nc && nc <= d, "dual-order mask out of range");
        assert!(n_pts <= self.block_points(orders), "block exceeds capacity");
        debug_assert_eq!(xs.len(), n_pts * d, "point block shape mismatch");
        debug_assert_eq!(theta.len(), param_count(&self.arch), "param count mismatch");
        self.n_pts = n_pts;
        self.nc = nc;
        self.nc2 = nc2;
        self.x_in[..n_pts * d].copy_from_slice(xs);
        let Tape { arch, offsets, h, tz, sz, th, sh, x_in, wt, d1v, d2v, .. } = self;
        for l in 0..nl {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let bias = &theta[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            let last = l + 1 == nl;
            // Wᵀ once per layer per block: every kernel below walks a
            // contiguous fan_out-panel per previous-layer neuron. The
            // transpose is O(fan_in·fan_out), amortized over the
            // n_pts·(1 + nc) propagation passes of the block.
            let wt = &mut wt[..fan_in * fan_out];
            for k in 0..fan_in {
                let dst = &mut wt[k * fan_out..(k + 1) * fan_out];
                for (o, v) in dst.iter_mut().enumerate() {
                    *v = w[o * fan_in + k];
                }
            }
            // Split so layer l-1 (read) and layer l (write) coexist.
            let (h_done, h_rest) = h.split_at_mut(l);
            let (th_done, th_rest) = th.split_at_mut(l);
            let (sh_done, sh_rest) = sh.split_at_mut(l);
            let h_cur = &mut h_rest[0];
            let th_cur = &mut th_rest[0];
            let sh_cur = &mut sh_rest[0];
            let tz_cur = &mut tz[l];
            let sz_cur = &mut sz[l];
            for b in 0..n_pts {
                let h_prev: &[f64] = if l == 0 {
                    &x_in[b * d..(b + 1) * d]
                } else {
                    &h_done[l - 1][b * fan_in..(b + 1) * fan_in]
                };
                // z = W h_prev + b: per-lane sums accumulate k ascending
                // from the bias, exactly the scalar order.
                let zc = &mut h_cur[b * fan_out..(b + 1) * fan_out];
                zc.copy_from_slice(bias);
                for (k, &hk) in h_prev.iter().enumerate() {
                    let wrow = &wt[k * fan_out..(k + 1) * fan_out];
                    for (acc, &wv) in zc.iter_mut().zip(wrow) {
                        *acc += wv * hk;
                    }
                }
                // ζ_i = W t_prev_i and (order-2 lanes) ξ_i = W s_prev_i,
                // fused per coordinate so each Wᵀ panel is loaded once for
                // both dual orders. Accumulators are independent per lane
                // and k ascends, so every lane's FP sum order is the
                // scalar one.
                for i in 0..nc {
                    let tbase = (b * nc + i) * fan_out;
                    if l == 0 {
                        // t_prev = e_i: ζ = column i of W = row i of Wᵀ;
                        // s_prev = 0.
                        tz_cur[tbase..tbase + fan_out]
                            .copy_from_slice(&wt[i * fan_out..(i + 1) * fan_out]);
                        if i < nc2 {
                            let sbase = (b * nc2 + i) * fan_out;
                            sz_cur[sbase..sbase + fan_out].fill(0.0);
                        }
                    } else if i < nc2 {
                        let sbase = (b * nc2 + i) * fan_out;
                        let tp0 = (b * nc + i) * fan_in;
                        let sp0 = (b * nc2 + i) * fan_in;
                        let tp = &th_done[l - 1][tp0..tp0 + fan_in];
                        let sp = &sh_done[l - 1][sp0..sp0 + fan_in];
                        let tdst = &mut tz_cur[tbase..tbase + fan_out];
                        let sdst = &mut sz_cur[sbase..sbase + fan_out];
                        tdst.fill(0.0);
                        sdst.fill(0.0);
                        for (k, (&tpk, &spk)) in tp.iter().zip(sp.iter()).enumerate() {
                            let wrow = &wt[k * fan_out..(k + 1) * fan_out];
                            for ((tacc, sacc), &wv) in
                                tdst.iter_mut().zip(sdst.iter_mut()).zip(wrow)
                            {
                                *tacc += wv * tpk;
                                *sacc += wv * spk;
                            }
                        }
                    } else {
                        // First-order-only lanes (the heat time coordinate).
                        let tp0 = (b * nc + i) * fan_in;
                        let tp = &th_done[l - 1][tp0..tp0 + fan_in];
                        let tdst = &mut tz_cur[tbase..tbase + fan_out];
                        tdst.fill(0.0);
                        for (k, &tpk) in tp.iter().enumerate() {
                            let wrow = &wt[k * fan_out..(k + 1) * fan_out];
                            for (tacc, &wv) in tdst.iter_mut().zip(wrow) {
                                *tacc += wv * tpk;
                            }
                        }
                    }
                }
                if last {
                    // Linear head: activated values = pre-activation values
                    // (h_cur already holds z).
                    for i in 0..nc {
                        let base = (b * nc + i) * fan_out;
                        th_cur[base..base + fan_out].copy_from_slice(&tz_cur[base..base + fan_out]);
                    }
                    for i in 0..nc2 {
                        let base = (b * nc2 + i) * fan_out;
                        sh_cur[base..base + fan_out].copy_from_slice(&sz_cur[base..base + fan_out]);
                    }
                } else {
                    // tanh + chain rules, lane-wise per point.
                    let hb = &mut h_cur[b * fan_out..(b + 1) * fan_out];
                    let d1b = &mut d1v[..fan_out];
                    let d2b = &mut d2v[..fan_out];
                    for ((hv, dv1), dv2) in hb.iter_mut().zip(d1b.iter_mut()).zip(d2b.iter_mut()) {
                        let y = hv.tanh();
                        let dd1 = 1.0 - y * y;
                        *hv = y;
                        *dv1 = dd1;
                        *dv2 = -2.0 * y * dd1;
                    }
                    for i in 0..nc {
                        let base = (b * nc + i) * fan_out;
                        let tdst = &mut th_cur[base..base + fan_out];
                        let zsrc = &tz_cur[base..base + fan_out];
                        for ((t, &zeta), &dv1) in tdst.iter_mut().zip(zsrc).zip(d1b.iter()) {
                            *t = dv1 * zeta;
                        }
                    }
                    for i in 0..nc2 {
                        let sbase = (b * nc2 + i) * fan_out;
                        let tbase = (b * nc + i) * fan_out;
                        let sdst = &mut sh_cur[sbase..sbase + fan_out];
                        let xsrc = &sz_cur[sbase..sbase + fan_out];
                        let zsrc = &tz_cur[tbase..tbase + fan_out];
                        for (((s, &xi), &zeta), (&dv1, &dv2)) in
                            sdst.iter_mut().zip(xsrc).zip(zsrc).zip(d1b.iter().zip(d2b.iter()))
                        {
                            *s = dv2 * zeta * zeta + dv1 * xi;
                        }
                    }
                }
            }
        }
    }

    /// Single-point forward: a one-point block (bitwise identical to the
    /// same point anywhere inside a larger block).
    pub fn forward(&mut self, theta: &[f64], x: &[f64], orders: DualOrder) {
        self.forward_batch(theta, x, 1, orders);
    }

    /// `u_θ` of block point `b` from the last forward.
    pub fn value(&self, b: usize) -> f64 {
        debug_assert!(b < self.n_pts);
        self.h[self.arch.len() - 2][b]
    }

    /// `∂u/∂x_i` of block point `b` (requires `i < orders.first`).
    pub fn d1(&self, b: usize, i: usize) -> f64 {
        debug_assert!(b < self.n_pts && i < self.nc);
        self.th[self.arch.len() - 2][b * self.nc + i]
    }

    /// `∂²u/∂x_i²` of block point `b` (requires `i < orders.second`).
    pub fn d2(&self, b: usize, i: usize) -> f64 {
        debug_assert!(b < self.n_pts && i < self.nc2);
        self.sh[self.arch.len() - 2][b * self.nc2 + i]
    }

    /// Accumulate `out += ∇_θ (α·u + Σ_i β_i·∂_i u + Σ_i γ_i·∂²_i u)` for
    /// block point `b`, using the duals stored by the last
    /// [`Tape::forward_batch`]. `beta` may be shorter than `orders.first`
    /// and `gamma` shorter than `orders.second` (missing entries are zero)
    /// but not longer.
    pub fn backward(
        &mut self,
        theta: &[f64],
        b: usize,
        alpha: f64,
        beta: &[f64],
        gamma: &[f64],
        out: &mut [f64],
    ) {
        let arch = &self.arch;
        let d = arch[0];
        let nl = arch.len() - 1;
        let nc = self.nc;
        let nc2 = self.nc2;
        debug_assert!(b < self.n_pts);
        debug_assert!(beta.len() <= nc && gamma.len() <= nc2);
        debug_assert_eq!(out.len(), param_count(arch));
        // Seed at the (width-1, linear) output layer.
        self.zbar[0] = alpha;
        for i in 0..nc {
            self.tbar[i] = beta.get(i).copied().unwrap_or(0.0);
        }
        for i in 0..nc2 {
            self.sbar[i] = gamma.get(i).copied().unwrap_or(0.0);
        }
        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let h_prev: &[f64] = if l == 0 {
                &self.x_in[b * d..(b + 1) * d]
            } else {
                &self.h[l - 1][b * fan_in..(b + 1) * fan_in]
            };
            // 1. Parameter gradients of this layer (k-contiguous panels).
            let (out_w, out_rest) = out[off..].split_at_mut(fan_in * fan_out);
            let out_b = &mut out_rest[..fan_out];
            for o in 0..fan_out {
                let zb = self.zbar[o];
                let wrow = &mut out_w[o * fan_in..(o + 1) * fan_in];
                if zb != 0.0 {
                    for (wk, &hk) in wrow.iter_mut().zip(h_prev) {
                        *wk += zb * hk;
                    }
                }
                out_b[o] += zb;
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    let sb = if i < nc2 {
                        self.sbar[i * fan_out + o]
                    } else {
                        0.0
                    };
                    if l == 0 {
                        // t_prev = e_i (s_prev = 0): only column i gets ∂ζ/∂W.
                        wrow[i] += tb;
                    } else if tb != 0.0 || sb != 0.0 {
                        let tp0 = (b * nc + i) * fan_in;
                        let tp = &self.th[l - 1][tp0..tp0 + fan_in];
                        if i < nc2 {
                            let sp0 = (b * nc2 + i) * fan_in;
                            let sp = &self.sh[l - 1][sp0..sp0 + fan_in];
                            for ((wk, &tpk), &spk) in wrow.iter_mut().zip(tp).zip(sp) {
                                *wk += tb * tpk + sb * spk;
                            }
                        } else {
                            for (wk, &tpk) in wrow.iter_mut().zip(tp) {
                                *wk += tb * tpk;
                            }
                        }
                    }
                }
            }
            if l == 0 {
                break;
            }
            // 2. Propagate through Wᵀ to the previous layer's activated
            //    outputs (h̄, t̄, s̄), into the *_next scratch. Accumulation
            //    order over o is ascending per destination element, and
            //    t̄/s̄ live in disjoint buffers, so splitting the t and s
            //    loops leaves every per-element FP sum order unchanged.
            for v in self.zbar_next[..fan_in].iter_mut() {
                *v = 0.0;
            }
            for v in self.tbar_next[..nc * fan_in].iter_mut() {
                *v = 0.0;
            }
            for v in self.sbar_next[..nc2 * fan_in].iter_mut() {
                *v = 0.0;
            }
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                let zb = self.zbar[o];
                if zb != 0.0 {
                    for (dv, &wv) in self.zbar_next[..fan_in].iter_mut().zip(row) {
                        *dv += wv * zb;
                    }
                }
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    if tb != 0.0 {
                        let dst = &mut self.tbar_next[i * fan_in..(i + 1) * fan_in];
                        for (dv, &wv) in dst.iter_mut().zip(row) {
                            *dv += wv * tb;
                        }
                    }
                }
                for i in 0..nc2 {
                    let sb = self.sbar[i * fan_out + o];
                    if sb != 0.0 {
                        let dst = &mut self.sbar_next[i * fan_in..(i + 1) * fan_in];
                        for (dv, &wv) in dst.iter_mut().zip(row) {
                            *dv += wv * sb;
                        }
                    }
                }
            }
            // 3. Convert activation-level adjoints of layer l-1 to
            //    pre-activation adjoints (the tanh chain rules above).
            let hm = &self.h[l - 1][b * fan_in..(b + 1) * fan_in];
            let tz_prev = &self.tz[l - 1];
            let sz_prev = &self.sz[l - 1];
            for o in 0..fan_in {
                let y = hm[o];
                let dd1 = 1.0 - y * y;
                let dd2 = -2.0 * y * dd1;
                let dd3 = dd1 * (6.0 * y * y - 2.0);
                let mut zb = dd1 * self.zbar_next[o];
                for i in 0..nc2 {
                    let zeta = tz_prev[(b * nc + i) * fan_in + o];
                    let xi = sz_prev[(b * nc2 + i) * fan_in + o];
                    let tb = self.tbar_next[i * fan_in + o];
                    let sb = self.sbar_next[i * fan_in + o];
                    zb += dd2 * zeta * tb + (dd3 * zeta * zeta + dd2 * xi) * sb;
                    self.tbar[i * fan_in + o] = dd1 * tb + 2.0 * dd2 * zeta * sb;
                    self.sbar[i * fan_in + o] = dd1 * sb;
                }
                for i in nc2..nc {
                    // First-order-only lanes (the heat time coordinate).
                    let zeta = tz_prev[(b * nc + i) * fan_in + o];
                    let tb = self.tbar_next[i * fan_in + o];
                    zb += dd2 * zeta * tb;
                    self.tbar[i * fan_in + o] = dd1 * tb;
                }
                self.zbar[o] = zb;
            }
        }
    }

    /// Fused reverse passes for block points `0..n_pts` of the last
    /// [`Tape::forward_batch`], each writing its seeded θ-gradient into its
    /// own row of `out` (row-major `n_pts × n_params` — e.g. a contiguous
    /// Jacobian row-block / adjoint panel of J). Per-point seeds:
    /// `alpha[b]`, `beta[b·nc..(b+1)·nc]`, `gamma[b·nc2..(b+1)·nc2]`.
    ///
    /// The nest is layer-outer / point-inner: all points' adjoint panels
    /// stay resident per layer and propagate through each `Wᵀ` in one
    /// sweep, so a weight row is loaded once per layer per block instead
    /// of once per point. Per destination element the floating-point
    /// accumulation sequence is exactly the per-point one (o ascending,
    /// identical zero-skip guards), so every row is **bitwise** what a
    /// standalone [`Tape::backward`] call would produce — asserted by
    /// `prop_blocked_tape_matches_scalar_reference_bitwise`.
    pub fn backward_batch(
        &mut self,
        theta: &[f64],
        n_pts: usize,
        alpha: &[f64],
        beta: &[f64],
        gamma: &[f64],
        out: &mut [f64],
    ) {
        if self.mode == NumericsMode::Fast {
            return self.backward_batch_fast(theta, n_pts, alpha, beta, gamma, out);
        }
        let np = param_count(&self.arch);
        let (nc, nc2) = (self.nc, self.nc2);
        let ww = self.widest;
        let d = self.arch[0];
        let nl = self.arch.len() - 1;
        debug_assert!(n_pts <= self.n_pts);
        debug_assert_eq!(alpha.len(), n_pts);
        debug_assert_eq!(beta.len(), n_pts * nc);
        debug_assert_eq!(gamma.len(), n_pts * nc2);
        debug_assert_eq!(out.len(), n_pts * np);
        let Tape {
            arch,
            offsets,
            h,
            tz,
            sz,
            th,
            sh,
            x_in,
            d1v,
            d2v,
            d3v,
            pz,
            pt,
            ps,
            pz_next,
            pt_next,
            ps_next,
            ..
        } = self;
        // Seed the output-layer panels (width-1 linear head): only lane
        // element 0 of each panel is live at the top layer, exactly the
        // elements [`Tape::backward`] seeds.
        for b in 0..n_pts {
            pz[b * ww] = alpha[b];
            for i in 0..nc {
                pt[(b * nc + i) * ww] = beta[b * nc + i];
            }
            for i in 0..nc2 {
                ps[(b * nc2 + i) * ww] = gamma[b * nc2 + i];
            }
        }
        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            // 1. Per-point parameter gradients of this layer, each into
            //    its own contiguous row of the J sub-block — the same
            //    loop body as [`Tape::backward`], reading the point's
            //    resident adjoint panels.
            for b in 0..n_pts {
                let h_prev: &[f64] = if l == 0 {
                    &x_in[b * d..(b + 1) * d]
                } else {
                    &h[l - 1][b * fan_in..(b + 1) * fan_in]
                };
                let (out_w, out_rest) =
                    out[b * np + off..].split_at_mut(fan_in * fan_out);
                let out_b = &mut out_rest[..fan_out];
                for o in 0..fan_out {
                    let zb = pz[b * ww + o];
                    let wrow = &mut out_w[o * fan_in..(o + 1) * fan_in];
                    if zb != 0.0 {
                        for (wk, &hk) in wrow.iter_mut().zip(h_prev) {
                            *wk += zb * hk;
                        }
                    }
                    out_b[o] += zb;
                    for i in 0..nc {
                        let tb = pt[(b * nc + i) * ww + o];
                        let sb = if i < nc2 { ps[(b * nc2 + i) * ww + o] } else { 0.0 };
                        if l == 0 {
                            // t_prev = e_i (s_prev = 0): only column i
                            // gets ∂ζ/∂W.
                            wrow[i] += tb;
                        } else if tb != 0.0 || sb != 0.0 {
                            let tp0 = (b * nc + i) * fan_in;
                            let tp = &th[l - 1][tp0..tp0 + fan_in];
                            if i < nc2 {
                                let sp0 = (b * nc2 + i) * fan_in;
                                let sp = &sh[l - 1][sp0..sp0 + fan_in];
                                for ((wk, &tpk), &spk) in wrow.iter_mut().zip(tp).zip(sp) {
                                    *wk += tb * tpk + sb * spk;
                                }
                            } else {
                                for (wk, &tpk) in wrow.iter_mut().zip(tp) {
                                    *wk += tb * tpk;
                                }
                            }
                        }
                    }
                }
            }
            if l == 0 {
                break;
            }
            // 2. The fused Wᵀ sweep: weight row `o` is loaded once per
            //    layer per block and pushed through every point's live
            //    adjoint lanes as stride-1 axpys. Per destination element
            //    the accumulation order over `o` is ascending and the
            //    zero-skips are per lane — the per-point FP sequence.
            for b in 0..n_pts {
                pz_next[b * ww..b * ww + fan_in].fill(0.0);
            }
            for lane in 0..n_pts * nc {
                pt_next[lane * ww..lane * ww + fan_in].fill(0.0);
            }
            for lane in 0..n_pts * nc2 {
                ps_next[lane * ww..lane * ww + fan_in].fill(0.0);
            }
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                for b in 0..n_pts {
                    let zb = pz[b * ww + o];
                    if zb != 0.0 {
                        let dst = &mut pz_next[b * ww..b * ww + fan_in];
                        for (dv, &wv) in dst.iter_mut().zip(row) {
                            *dv += wv * zb;
                        }
                    }
                    // Order-2 coordinates: the (t̄, s̄) pair shares one row
                    // pass when both lanes are live (disjoint destination
                    // panels — each element still receives exactly its
                    // per-point o-ascending add), with the per-lane guards
                    // of the per-point kernel otherwise.
                    for i in 0..nc2 {
                        let tlane = b * nc + i;
                        let slane = b * nc2 + i;
                        let tb = pt[tlane * ww + o];
                        let sb = ps[slane * ww + o];
                        if tb != 0.0 && sb != 0.0 {
                            let tdst = &mut pt_next[tlane * ww..tlane * ww + fan_in];
                            let sdst = &mut ps_next[slane * ww..slane * ww + fan_in];
                            for ((td, sd), &wv) in
                                tdst.iter_mut().zip(sdst.iter_mut()).zip(row)
                            {
                                *td += wv * tb;
                                *sd += wv * sb;
                            }
                        } else {
                            if tb != 0.0 {
                                let tdst = &mut pt_next[tlane * ww..tlane * ww + fan_in];
                                for (td, &wv) in tdst.iter_mut().zip(row) {
                                    *td += wv * tb;
                                }
                            }
                            if sb != 0.0 {
                                let sdst = &mut ps_next[slane * ww..slane * ww + fan_in];
                                for (sd, &wv) in sdst.iter_mut().zip(row) {
                                    *sd += wv * sb;
                                }
                            }
                        }
                    }
                    // First-order-only lanes (the heat time coordinate).
                    for i in nc2..nc {
                        let lane = b * nc + i;
                        let tb = pt[lane * ww + o];
                        if tb != 0.0 {
                            let dst = &mut pt_next[lane * ww..lane * ww + fan_in];
                            for (dv, &wv) in dst.iter_mut().zip(row) {
                                *dv += wv * tb;
                            }
                        }
                    }
                }
            }
            // 3. Per-point tanh chain rules: activation-level adjoints of
            //    layer l-1 become pre-activation adjoints, as stride-1
            //    lane sweeps over precomputed σ'/σ''/σ''' vectors. Per
            //    lane element the term sequence (z̄ init, then i
            //    ascending) is exactly the per-point one.
            for b in 0..n_pts {
                let hm = &h[l - 1][b * fan_in..(b + 1) * fan_in];
                let d1b = &mut d1v[..fan_in];
                let d2b = &mut d2v[..fan_in];
                let d3b = &mut d3v[..fan_in];
                for (((&y, dv1), dv2), dv3) in hm
                    .iter()
                    .zip(d1b.iter_mut())
                    .zip(d2b.iter_mut())
                    .zip(d3b.iter_mut())
                {
                    let dd1 = 1.0 - y * y;
                    *dv1 = dd1;
                    *dv2 = -2.0 * y * dd1;
                    *dv3 = dd1 * (6.0 * y * y - 2.0);
                }
                {
                    let src = &pz_next[b * ww..b * ww + fan_in];
                    let dst = &mut pz[b * ww..b * ww + fan_in];
                    for ((zv, &zn), &dv1) in dst.iter_mut().zip(src).zip(d1b.iter()) {
                        *zv = dv1 * zn;
                    }
                }
                let tz_prev = &tz[l - 1];
                let sz_prev = &sz[l - 1];
                for i in 0..nc2 {
                    let tlane = b * nc + i;
                    let slane = b * nc2 + i;
                    let zsrc = &tz_prev[tlane * fan_in..(tlane + 1) * fan_in];
                    let xsrc = &sz_prev[slane * fan_in..(slane + 1) * fan_in];
                    let tnx = &pt_next[tlane * ww..tlane * ww + fan_in];
                    let snx = &ps_next[slane * ww..slane * ww + fan_in];
                    let zdst = &mut pz[b * ww..b * ww + fan_in];
                    let tdst = &mut pt[tlane * ww..tlane * ww + fan_in];
                    let sdst = &mut ps[slane * ww..slane * ww + fan_in];
                    for o in 0..fan_in {
                        let zeta = zsrc[o];
                        let xi = xsrc[o];
                        let tb = tnx[o];
                        let sb = snx[o];
                        zdst[o] += d2b[o] * zeta * tb + (d3b[o] * zeta * zeta + d2b[o] * xi) * sb;
                        tdst[o] = d1b[o] * tb + 2.0 * d2b[o] * zeta * sb;
                        sdst[o] = d1b[o] * sb;
                    }
                }
                for i in nc2..nc {
                    let tlane = b * nc + i;
                    let zsrc = &tz_prev[tlane * fan_in..(tlane + 1) * fan_in];
                    let tnx = &pt_next[tlane * ww..tlane * ww + fan_in];
                    let zdst = &mut pz[b * ww..b * ww + fan_in];
                    let tdst = &mut pt[tlane * ww..tlane * ww + fan_in];
                    // First-order-only lanes (the heat time coordinate).
                    for o in 0..fan_in {
                        let zeta = zsrc[o];
                        let tb = tnx[o];
                        zdst[o] += d2b[o] * zeta * tb;
                        tdst[o] = d1b[o] * tb;
                    }
                }
            }
        }
    }

    /// Fast-tier forward pass: the same per-point math and panel layout as
    /// the bitwise [`Tape::forward_batch`] body, with the matrix-panel
    /// propagation routed through the dispatched [`super::simd`] kernels
    /// (FMA contraction, four-row blocked passes). Entered automatically
    /// by `forward_batch` when the tape is in fast mode.
    // lint: fast-tier — contraction/reassociation allowed here (engd-lint R5).
    fn forward_batch_fast(&mut self, theta: &[f64], xs: &[f64], n_pts: usize, orders: DualOrder) {
        let d = self.arch[0];
        let nl = self.arch.len() - 1;
        let (nc, nc2) = (orders.first, orders.second);
        assert!(nc2 <= nc && nc <= d, "dual-order mask out of range");
        assert!(n_pts <= self.block_points(orders), "block exceeds capacity");
        debug_assert_eq!(xs.len(), n_pts * d, "point block shape mismatch");
        debug_assert_eq!(theta.len(), param_count(&self.arch), "param count mismatch");
        self.n_pts = n_pts;
        self.nc = nc;
        self.nc2 = nc2;
        self.x_in[..n_pts * d].copy_from_slice(xs);
        let tier = self.tier;
        let Tape { arch, offsets, h, tz, sz, th, sh, x_in, wt, d1v, d2v, .. } = self;
        for l in 0..nl {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let bias = &theta[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            let last = l + 1 == nl;
            let wt = &mut wt[..fan_in * fan_out];
            for k in 0..fan_in {
                let dst = &mut wt[k * fan_out..(k + 1) * fan_out];
                for (o, v) in dst.iter_mut().enumerate() {
                    *v = w[o * fan_in + k];
                }
            }
            let (h_done, h_rest) = h.split_at_mut(l);
            let (th_done, th_rest) = th.split_at_mut(l);
            let (sh_done, sh_rest) = sh.split_at_mut(l);
            let h_cur = &mut h_rest[0];
            let th_cur = &mut th_rest[0];
            let sh_cur = &mut sh_rest[0];
            let tz_cur = &mut tz[l];
            let sz_cur = &mut sz[l];
            for b in 0..n_pts {
                let h_prev: &[f64] = if l == 0 {
                    &x_in[b * d..(b + 1) * d]
                } else {
                    &h_done[l - 1][b * fan_in..(b + 1) * fan_in]
                };
                // z = W h_prev + b through the dispatched panel kernel.
                let zc = &mut h_cur[b * fan_out..(b + 1) * fan_out];
                zc.copy_from_slice(bias);
                simd::panel_axpy(tier, &wt[..], h_prev, zc);
                for i in 0..nc {
                    let tbase = (b * nc + i) * fan_out;
                    if l == 0 {
                        // t_prev = e_i: ζ = column i of W = row i of Wᵀ;
                        // s_prev = 0.
                        tz_cur[tbase..tbase + fan_out]
                            .copy_from_slice(&wt[i * fan_out..(i + 1) * fan_out]);
                        if i < nc2 {
                            let sbase = (b * nc2 + i) * fan_out;
                            sz_cur[sbase..sbase + fan_out].fill(0.0);
                        }
                    } else if i < nc2 {
                        let sbase = (b * nc2 + i) * fan_out;
                        let tp0 = (b * nc + i) * fan_in;
                        let sp0 = (b * nc2 + i) * fan_in;
                        let tp = &th_done[l - 1][tp0..tp0 + fan_in];
                        let sp = &sh_done[l - 1][sp0..sp0 + fan_in];
                        let tdst = &mut tz_cur[tbase..tbase + fan_out];
                        let sdst = &mut sz_cur[sbase..sbase + fan_out];
                        tdst.fill(0.0);
                        sdst.fill(0.0);
                        simd::panel_axpy2(tier, &wt[..], tp, sp, tdst, sdst);
                    } else {
                        // First-order-only lanes (the heat time coordinate).
                        let tp0 = (b * nc + i) * fan_in;
                        let tp = &th_done[l - 1][tp0..tp0 + fan_in];
                        let tdst = &mut tz_cur[tbase..tbase + fan_out];
                        tdst.fill(0.0);
                        simd::panel_axpy(tier, &wt[..], tp, tdst);
                    }
                }
                if last {
                    // Linear head: activated values = pre-activation values
                    // (h_cur already holds z).
                    for i in 0..nc {
                        let base = (b * nc + i) * fan_out;
                        th_cur[base..base + fan_out].copy_from_slice(&tz_cur[base..base + fan_out]);
                    }
                    for i in 0..nc2 {
                        let base = (b * nc2 + i) * fan_out;
                        sh_cur[base..base + fan_out].copy_from_slice(&sz_cur[base..base + fan_out]);
                    }
                } else {
                    // tanh + chain rules, lane-wise per point (tanh
                    // dominates here; kept identical to the bitwise tier).
                    let hb = &mut h_cur[b * fan_out..(b + 1) * fan_out];
                    let d1b = &mut d1v[..fan_out];
                    let d2b = &mut d2v[..fan_out];
                    for ((hv, dv1), dv2) in hb.iter_mut().zip(d1b.iter_mut()).zip(d2b.iter_mut()) {
                        let y = hv.tanh();
                        let dd1 = 1.0 - y * y;
                        *hv = y;
                        *dv1 = dd1;
                        *dv2 = -2.0 * y * dd1;
                    }
                    for i in 0..nc {
                        let base = (b * nc + i) * fan_out;
                        let tdst = &mut th_cur[base..base + fan_out];
                        let zsrc = &tz_cur[base..base + fan_out];
                        for ((t, &zeta), &dv1) in tdst.iter_mut().zip(zsrc).zip(d1b.iter()) {
                            *t = dv1 * zeta;
                        }
                    }
                    for i in 0..nc2 {
                        let sbase = (b * nc2 + i) * fan_out;
                        let tbase = (b * nc + i) * fan_out;
                        let sdst = &mut sh_cur[sbase..sbase + fan_out];
                        let xsrc = &sz_cur[sbase..sbase + fan_out];
                        let zsrc = &tz_cur[tbase..tbase + fan_out];
                        for (((s, &xi), &zeta), (&dv1, &dv2)) in
                            sdst.iter_mut().zip(xsrc).zip(zsrc).zip(d1b.iter().zip(d2b.iter()))
                        {
                            *s = dv2 * zeta * zeta + dv1 * xi;
                        }
                    }
                }
            }
        }
    }

    /// Fast-tier fused reverse sweep: the same layer-outer / point-inner
    /// nest, seeding, and panel layout as the bitwise
    /// [`Tape::backward_batch`] body, with the parameter-gradient and `Wᵀ`
    /// inner loops routed through the dispatched [`super::simd`] kernels —
    /// FMA contraction, weight rows streamed four at a time per
    /// destination pass, and quad-level zero-skip guards instead of
    /// per-row ones. Entered automatically by `backward_batch` in fast
    /// mode.
    // lint: fast-tier — contraction/reassociation allowed here (engd-lint R5).
    fn backward_batch_fast(
        &mut self,
        theta: &[f64],
        n_pts: usize,
        alpha: &[f64],
        beta: &[f64],
        gamma: &[f64],
        out: &mut [f64],
    ) {
        let np = param_count(&self.arch);
        let (nc, nc2) = (self.nc, self.nc2);
        let ww = self.widest;
        let d = self.arch[0];
        let nl = self.arch.len() - 1;
        debug_assert!(n_pts <= self.n_pts);
        debug_assert_eq!(alpha.len(), n_pts);
        debug_assert_eq!(beta.len(), n_pts * nc);
        debug_assert_eq!(gamma.len(), n_pts * nc2);
        debug_assert_eq!(out.len(), n_pts * np);
        let tier = self.tier;
        let Tape {
            arch,
            offsets,
            h,
            tz,
            sz,
            th,
            sh,
            x_in,
            d1v,
            d2v,
            d3v,
            pz,
            pt,
            ps,
            pz_next,
            pt_next,
            ps_next,
            ..
        } = self;
        // Seed the output-layer panels (width-1 linear head): only lane
        // element 0 of each panel is live at the top layer.
        for b in 0..n_pts {
            pz[b * ww] = alpha[b];
            for i in 0..nc {
                pt[(b * nc + i) * ww] = beta[b * nc + i];
            }
            for i in 0..nc2 {
                ps[(b * nc2 + i) * ww] = gamma[b * nc2 + i];
            }
        }
        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            // 1. Per-point parameter gradients of this layer through the
            //    FMA axpy kernels (one fused pass per live adjoint source).
            for b in 0..n_pts {
                let h_prev: &[f64] = if l == 0 {
                    &x_in[b * d..(b + 1) * d]
                } else {
                    &h[l - 1][b * fan_in..(b + 1) * fan_in]
                };
                let (out_w, out_rest) =
                    out[b * np + off..].split_at_mut(fan_in * fan_out);
                let out_b = &mut out_rest[..fan_out];
                for o in 0..fan_out {
                    let zb = pz[b * ww + o];
                    let wrow = &mut out_w[o * fan_in..(o + 1) * fan_in];
                    if zb != 0.0 {
                        simd::axpy(tier, &mut wrow[..], h_prev, zb);
                    }
                    out_b[o] += zb;
                    for i in 0..nc {
                        let tb = pt[(b * nc + i) * ww + o];
                        let sb = if i < nc2 { ps[(b * nc2 + i) * ww + o] } else { 0.0 };
                        if l == 0 {
                            // t_prev = e_i (s_prev = 0): only column i
                            // gets ∂ζ/∂W.
                            wrow[i] += tb;
                        } else if tb != 0.0 || sb != 0.0 {
                            let tp0 = (b * nc + i) * fan_in;
                            let tp = &th[l - 1][tp0..tp0 + fan_in];
                            if i < nc2 {
                                let sp0 = (b * nc2 + i) * fan_in;
                                let sp = &sh[l - 1][sp0..sp0 + fan_in];
                                simd::axpy2(tier, &mut wrow[..], tp, tb, sp, sb);
                            } else {
                                simd::axpy(tier, &mut wrow[..], tp, tb);
                            }
                        }
                    }
                }
            }
            if l == 0 {
                break;
            }
            // 2. The fused Wᵀ sweep, four weight rows per destination
            //    pass: each adjoint lane element is loaded and stored once
            //    per row quad instead of once per row, with a quad-level
            //    liveness guard replacing the bitwise per-row skip.
            for b in 0..n_pts {
                pz_next[b * ww..b * ww + fan_in].fill(0.0);
            }
            for lane in 0..n_pts * nc {
                pt_next[lane * ww..lane * ww + fan_in].fill(0.0);
            }
            for lane in 0..n_pts * nc2 {
                ps_next[lane * ww..lane * ww + fan_in].fill(0.0);
            }
            let mut o = 0usize;
            while o + 4 <= fan_out {
                let rows = &w[o * fan_in..(o + 4) * fan_in];
                for b in 0..n_pts {
                    let zq = [
                        pz[b * ww + o],
                        pz[b * ww + o + 1],
                        pz[b * ww + o + 2],
                        pz[b * ww + o + 3],
                    ];
                    if any_nz(&zq) {
                        simd::sweep4(tier, &mut pz_next[b * ww..b * ww + fan_in], rows, zq);
                    }
                    for i in 0..nc2 {
                        let tlane = b * nc + i;
                        let slane = b * nc2 + i;
                        let tq = [
                            pt[tlane * ww + o],
                            pt[tlane * ww + o + 1],
                            pt[tlane * ww + o + 2],
                            pt[tlane * ww + o + 3],
                        ];
                        let sq = [
                            ps[slane * ww + o],
                            ps[slane * ww + o + 1],
                            ps[slane * ww + o + 2],
                            ps[slane * ww + o + 3],
                        ];
                        let tlive = any_nz(&tq);
                        let slive = any_nz(&sq);
                        if tlive && slive {
                            simd::sweep4_pair(
                                tier,
                                &mut pt_next[tlane * ww..tlane * ww + fan_in],
                                &mut ps_next[slane * ww..slane * ww + fan_in],
                                rows,
                                tq,
                                sq,
                            );
                        } else if tlive {
                            simd::sweep4(
                                tier,
                                &mut pt_next[tlane * ww..tlane * ww + fan_in],
                                rows,
                                tq,
                            );
                        } else if slive {
                            simd::sweep4(
                                tier,
                                &mut ps_next[slane * ww..slane * ww + fan_in],
                                rows,
                                sq,
                            );
                        }
                    }
                    // First-order-only lanes (the heat time coordinate).
                    for i in nc2..nc {
                        let lane = b * nc + i;
                        let tq = [
                            pt[lane * ww + o],
                            pt[lane * ww + o + 1],
                            pt[lane * ww + o + 2],
                            pt[lane * ww + o + 3],
                        ];
                        if any_nz(&tq) {
                            simd::sweep4(
                                tier,
                                &mut pt_next[lane * ww..lane * ww + fan_in],
                                rows,
                                tq,
                            );
                        }
                    }
                }
                o += 4;
            }
            // Remainder rows (fan_out % 4), one at a time.
            while o < fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                for b in 0..n_pts {
                    let zb = pz[b * ww + o];
                    if zb != 0.0 {
                        simd::axpy(tier, &mut pz_next[b * ww..b * ww + fan_in], row, zb);
                    }
                    for i in 0..nc {
                        let lane = b * nc + i;
                        let tb = pt[lane * ww + o];
                        if tb != 0.0 {
                            simd::axpy(tier, &mut pt_next[lane * ww..lane * ww + fan_in], row, tb);
                        }
                    }
                    for i in 0..nc2 {
                        let lane = b * nc2 + i;
                        let sb = ps[lane * ww + o];
                        if sb != 0.0 {
                            simd::axpy(tier, &mut ps_next[lane * ww..lane * ww + fan_in], row, sb);
                        }
                    }
                }
                o += 1;
            }
            // 3. Per-point tanh chain rules — identical to the bitwise
            //    tier (elementwise, dominated by the σ-derivative setup).
            for b in 0..n_pts {
                let hm = &h[l - 1][b * fan_in..(b + 1) * fan_in];
                let d1b = &mut d1v[..fan_in];
                let d2b = &mut d2v[..fan_in];
                let d3b = &mut d3v[..fan_in];
                for (((&y, dv1), dv2), dv3) in hm
                    .iter()
                    .zip(d1b.iter_mut())
                    .zip(d2b.iter_mut())
                    .zip(d3b.iter_mut())
                {
                    let dd1 = 1.0 - y * y;
                    *dv1 = dd1;
                    *dv2 = -2.0 * y * dd1;
                    *dv3 = dd1 * (6.0 * y * y - 2.0);
                }
                {
                    let src = &pz_next[b * ww..b * ww + fan_in];
                    let dst = &mut pz[b * ww..b * ww + fan_in];
                    for ((zv, &zn), &dv1) in dst.iter_mut().zip(src).zip(d1b.iter()) {
                        *zv = dv1 * zn;
                    }
                }
                let tz_prev = &tz[l - 1];
                let sz_prev = &sz[l - 1];
                for i in 0..nc2 {
                    let tlane = b * nc + i;
                    let slane = b * nc2 + i;
                    let zsrc = &tz_prev[tlane * fan_in..(tlane + 1) * fan_in];
                    let xsrc = &sz_prev[slane * fan_in..(slane + 1) * fan_in];
                    let tnx = &pt_next[tlane * ww..tlane * ww + fan_in];
                    let snx = &ps_next[slane * ww..slane * ww + fan_in];
                    let zdst = &mut pz[b * ww..b * ww + fan_in];
                    let tdst = &mut pt[tlane * ww..tlane * ww + fan_in];
                    let sdst = &mut ps[slane * ww..slane * ww + fan_in];
                    for o in 0..fan_in {
                        let zeta = zsrc[o];
                        let xi = xsrc[o];
                        let tb = tnx[o];
                        let sb = snx[o];
                        zdst[o] += d2b[o] * zeta * tb + (d3b[o] * zeta * zeta + d2b[o] * xi) * sb;
                        tdst[o] = d1b[o] * tb + 2.0 * d2b[o] * zeta * sb;
                        sdst[o] = d1b[o] * sb;
                    }
                }
                for i in nc2..nc {
                    let tlane = b * nc + i;
                    let zsrc = &tz_prev[tlane * fan_in..(tlane + 1) * fan_in];
                    let tnx = &pt_next[tlane * ww..tlane * ww + fan_in];
                    let zdst = &mut pz[b * ww..b * ww + fan_in];
                    let tdst = &mut pt[tlane * ww..tlane * ww + fan_in];
                    // First-order-only lanes (the heat time coordinate).
                    for o in 0..fan_in {
                        let zeta = zsrc[o];
                        let tb = tnx[o];
                        zdst[o] += d2b[o] * zeta * tb;
                        tdst[o] = d1b[o] * tb;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar reference implementation
// ---------------------------------------------------------------------------

/// The pre-blocking scalar tape: coordinate-strided buffers and naive
/// per-(point, coordinate) dot-product loops, kept verbatim as the
/// independent reference the blocked kernels are property-tested against
/// (bitwise) and benchmarked against (`benches/parallel_micro.rs`). Not
/// part of the public API.
#[doc(hidden)]
pub struct ScalarTape {
    arch: Vec<usize>,
    offsets: Vec<usize>,
    h: Vec<Vec<f64>>,
    tz: Vec<Vec<f64>>,
    sz: Vec<Vec<f64>>,
    th: Vec<Vec<f64>>,
    sh: Vec<Vec<f64>>,
    x_in: Vec<f64>,
    ncoords: usize,
    zbar: Vec<f64>,
    tbar: Vec<f64>,
    sbar: Vec<f64>,
    zbar_next: Vec<f64>,
    tbar_next: Vec<f64>,
    sbar_next: Vec<f64>,
}

#[doc(hidden)]
impl ScalarTape {
    pub fn new(arch: &[usize]) -> Self {
        assert!(arch.len() >= 2, "MLP needs at least one layer");
        assert_eq!(*arch.last().unwrap(), 1, "scalar-output MLP expected");
        let d = arch[0];
        let nl = arch.len() - 1;
        let mut offsets = Vec::with_capacity(nl);
        let mut off = 0usize;
        for l in 0..nl {
            offsets.push(off);
            off += arch[l] * arch[l + 1] + arch[l + 1];
        }
        let widest = *arch.iter().max().unwrap();
        let mut h = Vec::with_capacity(nl);
        let mut tz = Vec::with_capacity(nl);
        let mut sz = Vec::with_capacity(nl);
        let mut th = Vec::with_capacity(nl);
        let mut sh = Vec::with_capacity(nl);
        for l in 0..nl {
            let w = arch[l + 1];
            h.push(vec![0.0; w]);
            tz.push(vec![0.0; d * w]);
            sz.push(vec![0.0; d * w]);
            th.push(vec![0.0; d * w]);
            sh.push(vec![0.0; d * w]);
        }
        ScalarTape {
            arch: arch.to_vec(),
            offsets,
            h,
            tz,
            sz,
            th,
            sh,
            x_in: vec![0.0; d],
            ncoords: 0,
            zbar: vec![0.0; widest],
            tbar: vec![0.0; d * widest],
            sbar: vec![0.0; d * widest],
            zbar_next: vec![0.0; widest],
            tbar_next: vec![0.0; d * widest],
            sbar_next: vec![0.0; d * widest],
        }
    }

    /// Forward pass at one point `x`, carrying `(∂_i, ∂²_i)` duals for the
    /// first `ncoords` coordinates (0 = plain forward).
    pub fn forward(&mut self, theta: &[f64], x: &[f64], ncoords: usize) {
        let arch = &self.arch;
        let d = arch[0];
        let nl = arch.len() - 1;
        debug_assert_eq!(x.len(), d, "input dim mismatch");
        debug_assert_eq!(theta.len(), param_count(arch), "param count mismatch");
        debug_assert!(ncoords <= d);
        self.ncoords = ncoords;
        self.x_in.copy_from_slice(x);
        for l in 0..nl {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let b = &theta[off + fan_in * fan_out..off + fan_in * fan_out + fan_out];
            let last = l + 1 == nl;
            let (h_done, h_rest) = self.h.split_at_mut(l);
            let (th_done, th_rest) = self.th.split_at_mut(l);
            let (sh_done, sh_rest) = self.sh.split_at_mut(l);
            let h_cur = &mut h_rest[0];
            let th_cur = &mut th_rest[0];
            let sh_cur = &mut sh_rest[0];
            let tz_cur = &mut self.tz[l];
            let sz_cur = &mut self.sz[l];
            let h_prev: &[f64] = if l == 0 { x } else { &h_done[l - 1] };
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                let mut z = b[o];
                for (wi, hi) in row.iter().zip(h_prev.iter()) {
                    z += wi * hi;
                }
                for i in 0..ncoords {
                    let (zeta, xi) = if l == 0 {
                        (row[i], 0.0)
                    } else {
                        let tp = &th_done[l - 1][i * fan_in..(i + 1) * fan_in];
                        let sp = &sh_done[l - 1][i * fan_in..(i + 1) * fan_in];
                        let mut zeta = 0.0;
                        let mut xi = 0.0;
                        for k in 0..fan_in {
                            zeta += row[k] * tp[k];
                            xi += row[k] * sp[k];
                        }
                        (zeta, xi)
                    };
                    tz_cur[i * fan_out + o] = zeta;
                    sz_cur[i * fan_out + o] = xi;
                }
                if last {
                    h_cur[o] = z;
                    for i in 0..ncoords {
                        th_cur[i * fan_out + o] = tz_cur[i * fan_out + o];
                        sh_cur[i * fan_out + o] = sz_cur[i * fan_out + o];
                    }
                } else {
                    let y = z.tanh();
                    let d1 = 1.0 - y * y;
                    let d2 = -2.0 * y * d1;
                    h_cur[o] = y;
                    for i in 0..ncoords {
                        let zeta = tz_cur[i * fan_out + o];
                        let xi = sz_cur[i * fan_out + o];
                        th_cur[i * fan_out + o] = d1 * zeta;
                        sh_cur[i * fan_out + o] = d2 * zeta * zeta + d1 * xi;
                    }
                }
            }
        }
    }

    pub fn value(&self) -> f64 {
        self.h[self.arch.len() - 2][0]
    }

    pub fn d1(&self, i: usize) -> f64 {
        debug_assert!(i < self.ncoords);
        self.th[self.arch.len() - 2][i]
    }

    pub fn d2(&self, i: usize) -> f64 {
        debug_assert!(i < self.ncoords);
        self.sh[self.arch.len() - 2][i]
    }

    /// Accumulate `out += ∇_θ (α·u + Σ_i β_i·∂_i u + Σ_i γ_i·∂²_i u)` using
    /// the duals stored by the last [`ScalarTape::forward`].
    pub fn backward(
        &mut self,
        theta: &[f64],
        alpha: f64,
        beta: &[f64],
        gamma: &[f64],
        out: &mut [f64],
    ) {
        let arch = &self.arch;
        let nl = arch.len() - 1;
        let nc = self.ncoords;
        debug_assert!(beta.len() <= nc && gamma.len() <= nc);
        debug_assert_eq!(out.len(), param_count(arch));
        self.zbar[0] = alpha;
        for i in 0..nc {
            self.tbar[i] = beta.get(i).copied().unwrap_or(0.0);
            self.sbar[i] = gamma.get(i).copied().unwrap_or(0.0);
        }
        for l in (0..nl).rev() {
            let (fan_in, fan_out) = (arch[l], arch[l + 1]);
            let off = self.offsets[l];
            let w = &theta[off..off + fan_in * fan_out];
            let h_prev: &[f64] = if l == 0 { &self.x_in } else { &self.h[l - 1] };
            let (out_w, out_rest) = out[off..].split_at_mut(fan_in * fan_out);
            let out_b = &mut out_rest[..fan_out];
            for o in 0..fan_out {
                let zb = self.zbar[o];
                let wrow = &mut out_w[o * fan_in..(o + 1) * fan_in];
                if zb != 0.0 {
                    for k in 0..fan_in {
                        wrow[k] += zb * h_prev[k];
                    }
                }
                out_b[o] += zb;
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    let sb = self.sbar[i * fan_out + o];
                    if l == 0 {
                        wrow[i] += tb;
                    } else if tb != 0.0 || sb != 0.0 {
                        let tp = &self.th[l - 1][i * fan_in..(i + 1) * fan_in];
                        let sp = &self.sh[l - 1][i * fan_in..(i + 1) * fan_in];
                        for k in 0..fan_in {
                            wrow[k] += tb * tp[k] + sb * sp[k];
                        }
                    }
                }
            }
            if l == 0 {
                break;
            }
            for k in 0..fan_in {
                self.zbar_next[k] = 0.0;
            }
            for i in 0..nc {
                for k in 0..fan_in {
                    self.tbar_next[i * fan_in + k] = 0.0;
                    self.sbar_next[i * fan_in + k] = 0.0;
                }
            }
            for o in 0..fan_out {
                let row = &w[o * fan_in..(o + 1) * fan_in];
                let zb = self.zbar[o];
                if zb != 0.0 {
                    for k in 0..fan_in {
                        self.zbar_next[k] += row[k] * zb;
                    }
                }
                for i in 0..nc {
                    let tb = self.tbar[i * fan_out + o];
                    let sb = self.sbar[i * fan_out + o];
                    if tb != 0.0 {
                        for k in 0..fan_in {
                            self.tbar_next[i * fan_in + k] += row[k] * tb;
                        }
                    }
                    if sb != 0.0 {
                        for k in 0..fan_in {
                            self.sbar_next[i * fan_in + k] += row[k] * sb;
                        }
                    }
                }
            }
            let hm = &self.h[l - 1];
            let tzm = &self.tz[l - 1];
            let szm = &self.sz[l - 1];
            for o in 0..fan_in {
                let y = hm[o];
                let d1 = 1.0 - y * y;
                let d2 = -2.0 * y * d1;
                let d3 = d1 * (6.0 * y * y - 2.0);
                let mut zb = d1 * self.zbar_next[o];
                for i in 0..nc {
                    let zeta = tzm[i * fan_in + o];
                    let xi = szm[i * fan_in + o];
                    let tb = self.tbar_next[i * fan_in + o];
                    let sb = self.sbar_next[i * fan_in + o];
                    zb += d2 * zeta * tb + (d3 * zeta * zeta + d2 * xi) * sb;
                    self.tbar[i * fan_in + o] = d1 * tb + 2.0 * d2 * zeta * sb;
                    self.sbar[i * fan_in + o] = d1 * sb;
                }
                self.zbar[o] = zb;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{init_params, mlp_forward};
    use crate::proptest::run_prop;
    use crate::rng::Rng;

    fn fd_value(theta: &[f64], arch: &[usize], x: &[f64], i: usize, h: f64) -> (f64, f64) {
        // (first, second) central differences of u along coordinate i.
        let mut xp = x.to_vec();
        let mut xm = x.to_vec();
        xp[i] += h;
        xm[i] -= h;
        let up = mlp_forward(theta, arch, &xp);
        let um = mlp_forward(theta, arch, &xm);
        let u0 = mlp_forward(theta, arch, x);
        ((up - um) / (2.0 * h), (up - 2.0 * u0 + um) / (h * h))
    }

    #[test]
    fn forward_matches_mlp_oracle() {
        let arch = [3usize, 8, 6, 1];
        let mut rng = Rng::seed_from(11);
        let theta = init_params(&arch, &mut rng);
        let mut tape = Tape::new(&arch);
        for case in 0..20 {
            let mut x = [0.0; 3];
            rng.fill_uniform(&mut x, 0.0, 1.0);
            let orders = if case % 2 == 0 {
                DualOrder::full(3)
            } else {
                DualOrder::NONE
            };
            tape.forward(&theta, &x, orders);
            let want = mlp_forward(&theta, &arch, &x);
            assert!(
                (tape.value(0) - want).abs() < 1e-13,
                "case {case}: {} vs {}",
                tape.value(0),
                want
            );
        }
    }

    #[test]
    fn duals_match_finite_differences() {
        let arch = [2usize, 10, 10, 1];
        let mut rng = Rng::seed_from(7);
        let theta = init_params(&arch, &mut rng);
        let mut tape = Tape::new(&arch);
        for _ in 0..10 {
            let mut x = [0.0; 2];
            rng.fill_uniform(&mut x, 0.1, 0.9);
            tape.forward(&theta, &x, DualOrder::full(2));
            for i in 0..2 {
                let (fd1, fd2) = fd_value(&theta, &arch, &x, i, 1e-5);
                assert!(
                    (tape.d1(0, i) - fd1).abs() < 1e-8 * (1.0 + fd1.abs()),
                    "d1[{i}]: {} vs fd {fd1}",
                    tape.d1(0, i)
                );
                assert!(
                    (tape.d2(0, i) - fd2).abs() < 1e-4 * (1.0 + fd2.abs()),
                    "d2[{i}]: {} vs fd {fd2}",
                    tape.d2(0, i)
                );
            }
        }
    }

    #[test]
    fn backward_value_grad_matches_fd() {
        // α-seeded backward = plain ∇_θ u, checked by central differences.
        let arch = [2usize, 6, 5, 1];
        let mut rng = Rng::seed_from(3);
        let theta = init_params(&arch, &mut rng);
        let x = [0.4, 0.7];
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, DualOrder::NONE);
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 0, 1.0, &[], &[], &mut grad);
        let eps = 1e-6;
        for jj in 0..theta.len() {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (mlp_forward(&tp, &arch, &x) - mlp_forward(&tm, &arch, &x)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-7 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }

    #[test]
    fn backward_laplacian_grad_matches_fd() {
        // γ-seeded backward = ∇_θ Δu, checked by FD of the tape's own
        // Laplacian (whose duals are independently FD-verified above).
        let arch = [2usize, 6, 6, 1];
        let mut rng = Rng::seed_from(5);
        let theta = init_params(&arch, &mut rng);
        let x = [0.3, 0.6];
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, DualOrder::full(2));
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 0, 0.0, &[], &[1.0, 1.0], &mut grad);
        let lap_at = |t: &[f64], tape: &mut Tape| {
            tape.forward(t, &x, DualOrder::full(2));
            tape.d2(0, 0) + tape.d2(0, 1)
        };
        let eps = 1e-6;
        for jj in (0..theta.len()).step_by(7) {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (lap_at(&tp, &mut tape) - lap_at(&tm, &mut tape)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }

    #[test]
    fn backward_time_derivative_grad_matches_fd() {
        // β-seeded backward = ∇_θ ∂_t u, through the heat operator's
        // dual-order mask (no second-order duals on the time coordinate).
        let arch = [3usize, 5, 1];
        let mut rng = Rng::seed_from(9);
        let theta = init_params(&arch, &mut rng);
        let x = [0.2, 0.8, 0.5];
        let heat = DualOrder::new(3, 2);
        let mut tape = Tape::new(&arch);
        tape.forward(&theta, &x, heat);
        let mut grad = vec![0.0; theta.len()];
        tape.backward(&theta, 0, 0.0, &[0.0, 0.0, 1.0], &[], &mut grad);
        let dt_at = |t: &[f64], tape: &mut Tape| {
            tape.forward(t, &x, heat);
            tape.d1(0, 2)
        };
        let eps = 1e-6;
        for jj in 0..theta.len() {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let fd = (dt_at(&tp, &mut tape) - dt_at(&tm, &mut tape)) / (2.0 * eps);
            assert!(
                (grad[jj] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                "θ[{jj}]: {} vs fd {fd}",
                grad[jj]
            );
        }
    }

    /// The blocked kernels against the naive scalar reference: bitwise
    /// agreement of value/d1/d2 and of fused [`Tape::backward_batch`]
    /// adjoint-panel reverse passes, across random architectures, dual
    /// masks (`ncoords ∈ {0, 1, d}`, full and heat-style second-order
    /// prefixes), boundary-style value-only blocks, single-point panels,
    /// full blocks, and batched-vs-single-point entry points.
    #[test]
    fn prop_blocked_tape_matches_scalar_reference_bitwise() {
        run_prop("blocked tape == scalar tape (bitwise)", 24, |g| {
            let d = g.usize_in(1, 4);
            let mut arch = vec![d];
            for _ in 0..g.usize_in(1, 2) {
                arch.push(g.usize_in(2, 8));
            }
            arch.push(1);
            let nc = *g.rng().choice(&[0usize, 1, d]);
            let nc2 = if nc > 0 && g.bool() { nc - 1 } else { nc };
            let orders = DualOrder::new(nc, nc2);
            let seed = g.usize_in(0, 1 << 30) as u64;
            let mut rng = Rng::seed_from(seed);
            let theta = init_params(&arch, &mut rng);
            let mut tape = Tape::new(&arch);
            let mut scalar = ScalarTape::new(&arch);
            // Cover the panel extremes explicitly: single-point panels and
            // whole blocks (32 points for boundary-style ncoords = 0),
            // plus random interior sizes.
            let n_pts = match g.usize_in(0, 3) {
                0 => 1,
                1 => tape.block_points(orders),
                _ => g.usize_in(1, tape.block_points(orders).min(8)),
            };
            let mut xs = vec![0.0; n_pts * d];
            rng.fill_uniform(&mut xs, 0.05, 0.95);
            // Random nonzero seeds per point for the reverse passes.
            let mut alpha = vec![0.0; n_pts];
            let mut beta = vec![0.0; n_pts * nc];
            let mut gamma = vec![0.0; n_pts * nc2];
            rng.fill_uniform(&mut alpha, 0.1, 1.0);
            rng.fill_uniform(&mut beta, 0.1, 1.0);
            rng.fill_uniform(&mut gamma, 0.1, 1.0);
            // Sparse seeds: the reference skips zero-adjoint lanes, and
            // the fused sweep's per-lane guard fallbacks (t̄-only /
            // s̄-only / dead lanes) must skip identically.
            for v in beta.iter_mut().step_by(3) {
                *v = 0.0;
            }
            for v in gamma.iter_mut().step_by(2) {
                *v = 0.0;
            }

            let np = theta.len();
            tape.forward_batch(&theta, &xs, n_pts, orders);
            let mut rows = vec![0.0; n_pts * np];
            tape.backward_batch(&theta, n_pts, &alpha, &beta, &gamma, &mut rows);

            for b in 0..n_pts {
                let x = &xs[b * d..(b + 1) * d];
                let bs = &beta[b * nc..(b + 1) * nc];
                let gs = &gamma[b * nc2..(b + 1) * nc2];
                let row = &rows[b * np..(b + 1) * np];
                // Scalar reference carries full second order on all `nc`
                // coordinates; the mask is emulated by zero γ padding.
                scalar.forward(&theta, x, nc);
                let mut gref = vec![0.0; nc];
                gref[..nc2].copy_from_slice(gs);
                let mut ref_row = vec![0.0; np];
                scalar.backward(&theta, alpha[b], bs, &gref, &mut ref_row);

                if tape.value(b).to_bits() != scalar.value().to_bits() {
                    return Err(format!(
                        "point {b}: value {} vs scalar {}",
                        tape.value(b),
                        scalar.value()
                    ));
                }
                for i in 0..nc {
                    if tape.d1(b, i).to_bits() != scalar.d1(i).to_bits() {
                        return Err(format!("point {b}: d1[{i}] mismatch"));
                    }
                }
                for i in 0..nc2 {
                    if tape.d2(b, i).to_bits() != scalar.d2(i).to_bits() {
                        return Err(format!("point {b}: d2[{i}] mismatch"));
                    }
                }
                for (jj, (a, r)) in row.iter().zip(&ref_row).enumerate() {
                    if a.to_bits() != r.to_bits() {
                        return Err(format!("point {b}: row[{jj}] {a:.17e} vs scalar {r:.17e}"));
                    }
                }

                // Single-point blocked entry: bitwise the same lanes again.
                let mut single = vec![0.0; np];
                let mut tape1 = Tape::new(&arch);
                tape1.forward(&theta, x, orders);
                tape1.backward(&theta, 0, alpha[b], bs, gs, &mut single);
                if tape1.value(0).to_bits() != tape.value(b).to_bits() {
                    return Err(format!("point {b}: single-point value mismatch"));
                }
                for (jj, (a, s)) in row.iter().zip(&single).enumerate() {
                    if a.to_bits() != s.to_bits() {
                        return Err(format!("point {b}: single row[{jj}] mismatch"));
                    }
                }
            }
            Ok(())
        });
    }

    /// The fused adjoint-panel backward against per-point [`Tape::backward`]
    /// on deterministic edge blocks: boundary-only (`ncoords = 0`) value
    /// blocks (single-point and full 32-point panels), full-order and
    /// heat-masked interior blocks, and a dual block *followed by* a
    /// value-only block on the same tape (stale-lane hazard: the second
    /// backward must not read the first block's dual panels).
    #[test]
    fn fused_backward_panels_match_per_point_entry_bitwise() {
        let arch = [3usize, 7, 5, 1];
        let d = arch[0];
        let np = param_count(&arch);
        let mut rng = Rng::seed_from(0xFADE);
        let theta = init_params(&arch, &mut rng);
        let mut tape = Tape::new(&arch);
        let mut per_point = Tape::new(&arch);

        let full = DualOrder::full(d);
        let heat = DualOrder::new(d, d - 1);
        let none = DualOrder::NONE;
        let cases: Vec<(DualOrder, usize)> = vec![
            (none, 1),
            (none, tape.block_points(none)),
            (full, 1),
            (full, tape.block_points(full)),
            (heat, tape.block_points(heat)),
            // Stale-lane hazard: this value-only block runs on panels the
            // full-order blocks above just populated.
            (none, 5),
        ];
        for (case, &(orders, n_pts)) in cases.iter().enumerate() {
            let (nc, nc2) = (orders.first, orders.second);
            let mut xs = vec![0.0; n_pts * d];
            rng.fill_uniform(&mut xs, 0.05, 0.95);
            let mut alpha = vec![0.0; n_pts];
            let mut beta = vec![0.0; n_pts * nc];
            let mut gamma = vec![0.0; n_pts * nc2];
            rng.fill_uniform(&mut alpha, -1.0, 1.0);
            rng.fill_uniform(&mut beta, -1.0, 1.0);
            rng.fill_uniform(&mut gamma, -1.0, 1.0);
            for v in beta.iter_mut().step_by(3) {
                *v = 0.0;
            }
            for v in gamma.iter_mut().step_by(2) {
                *v = 0.0;
            }

            tape.forward_batch(&theta, &xs, n_pts, orders);
            let mut rows = vec![0.0; n_pts * np];
            tape.backward_batch(&theta, n_pts, &alpha, &beta, &gamma, &mut rows);

            per_point.forward_batch(&theta, &xs, n_pts, orders);
            let mut want = vec![0.0; n_pts * np];
            for b in 0..n_pts {
                per_point.backward(
                    &theta,
                    b,
                    alpha[b],
                    &beta[b * nc..(b + 1) * nc],
                    &gamma[b * nc2..(b + 1) * nc2],
                    &mut want[b * np..(b + 1) * np],
                );
            }
            for (jj, (a, w)) in rows.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    w.to_bits(),
                    "case {case} ({n_pts} pts, nc={nc}/{nc2}): fused row elem {jj}: {a:.17e} vs {w:.17e}"
                );
            }
        }
    }

    #[test]
    fn block_points_adapts_to_the_dual_mask() {
        let tape = Tape::new(&[2, 6, 1]);
        assert_eq!(tape.block_points(DualOrder::NONE), MAX_BLOCK_POINTS);
        assert_eq!(tape.block_points(DualOrder::full(2)), MAX_BLOCK_POINTS);
        let tape = Tape::new(&[100, 4, 1]);
        // 100 dual coordinates blow the lane budget: one point per block.
        assert_eq!(tape.block_points(DualOrder::full(100)), 1);
        assert_eq!(tape.block_points(DualOrder::NONE), MAX_BLOCK_POINTS);
        // Capacity still covers a full-order pass at d = 100.
        let mut tape = Tape::new(&[100, 4, 1]);
        let theta = vec![0.01; param_count(&[100, 4, 1])];
        let x = vec![0.5; 100];
        tape.forward(&theta, &x, DualOrder::full(100));
        assert!(tape.value(0).is_finite());
    }

    /// Fast-tier relative-error bound vs the bitwise per-element sequence:
    /// the fast kernels contract each `a*b+c` into one rounding and group
    /// reverse rows four at a time, but never reorder a lane's reduction,
    /// so the drift is a few ulps per term. `1e-10` relative (with an
    /// absolute floor of `1e-10` near zero) leaves orders of magnitude of
    /// headroom over observed errors for the paper's widths, and is the
    /// bound the module docs advertise.
    const FAST_TOL: f64 = 1e-10;

    fn fast_close(a: f64, want: f64) -> bool {
        (a - want).abs() <= FAST_TOL * want.abs().max(1.0)
    }

    /// The fast tier against the naive scalar reference: value/d1/d2 and
    /// fused reverse rows agree to [`FAST_TOL`] across random archs, dual
    /// masks (`ncoords ∈ {0, 1, d}`, heat-style prefixes), and block
    /// sizes — and *within* the fast tier, a single-point block is still
    /// bitwise the same lanes as the same point inside a larger block
    /// (blocking never mixes points in either tier).
    #[test]
    fn prop_fast_tape_matches_scalar_reference_within_tolerance() {
        run_prop("fast tape ~= scalar tape (1e-10 rel)", 24, |g| {
            let d = g.usize_in(1, 4);
            let mut arch = vec![d];
            for _ in 0..g.usize_in(1, 2) {
                arch.push(g.usize_in(2, 8));
            }
            arch.push(1);
            let nc = *g.rng().choice(&[0usize, 1, d]);
            let nc2 = if nc > 0 && g.bool() { nc - 1 } else { nc };
            let orders = DualOrder::new(nc, nc2);
            let seed = g.usize_in(0, 1 << 30) as u64;
            let mut rng = Rng::seed_from(seed);
            let theta = init_params(&arch, &mut rng);
            let mut tape = Tape::with_numerics(&arch, NumericsMode::Fast);
            let mut scalar = ScalarTape::new(&arch);
            let n_pts = match g.usize_in(0, 3) {
                0 => 1,
                1 => tape.block_points(orders),
                _ => g.usize_in(1, tape.block_points(orders).min(8)),
            };
            let mut xs = vec![0.0; n_pts * d];
            rng.fill_uniform(&mut xs, 0.05, 0.95);
            let mut alpha = vec![0.0; n_pts];
            let mut beta = vec![0.0; n_pts * nc];
            let mut gamma = vec![0.0; n_pts * nc2];
            rng.fill_uniform(&mut alpha, 0.1, 1.0);
            rng.fill_uniform(&mut beta, 0.1, 1.0);
            rng.fill_uniform(&mut gamma, 0.1, 1.0);
            // Sparse seeds still matter: the fast sweep's quad-level
            // guards must drop exactly the lanes whose whole quad is dead.
            for v in beta.iter_mut().step_by(3) {
                *v = 0.0;
            }
            for v in gamma.iter_mut().step_by(2) {
                *v = 0.0;
            }

            let np = theta.len();
            tape.forward_batch(&theta, &xs, n_pts, orders);
            let mut rows = vec![0.0; n_pts * np];
            tape.backward_batch(&theta, n_pts, &alpha, &beta, &gamma, &mut rows);

            for b in 0..n_pts {
                let x = &xs[b * d..(b + 1) * d];
                let bs = &beta[b * nc..(b + 1) * nc];
                let gs = &gamma[b * nc2..(b + 1) * nc2];
                let row = &rows[b * np..(b + 1) * np];
                scalar.forward(&theta, x, nc);
                let mut gref = vec![0.0; nc];
                gref[..nc2].copy_from_slice(gs);
                let mut ref_row = vec![0.0; np];
                scalar.backward(&theta, alpha[b], bs, &gref, &mut ref_row);

                if !fast_close(tape.value(b), scalar.value()) {
                    return Err(format!(
                        "point {b}: value {} vs scalar {}",
                        tape.value(b),
                        scalar.value()
                    ));
                }
                for i in 0..nc {
                    if !fast_close(tape.d1(b, i), scalar.d1(i)) {
                        return Err(format!(
                            "point {b}: d1[{i}] {} vs scalar {}",
                            tape.d1(b, i),
                            scalar.d1(i)
                        ));
                    }
                }
                for i in 0..nc2 {
                    if !fast_close(tape.d2(b, i), scalar.d2(i)) {
                        return Err(format!(
                            "point {b}: d2[{i}] {} vs scalar {}",
                            tape.d2(b, i),
                            scalar.d2(i)
                        ));
                    }
                }
                for (jj, (a, r)) in row.iter().zip(&ref_row).enumerate() {
                    if !fast_close(*a, *r) {
                        return Err(format!("point {b}: row[{jj}] {a:.17e} vs scalar {r:.17e}"));
                    }
                }

                // Per-point determinism within the tier: a 1-point fast
                // block reproduces the batched lanes bit-for-bit.
                let mut single = vec![0.0; np];
                let mut tape1 = Tape::with_numerics(&arch, NumericsMode::Fast);
                tape1.forward(&theta, x, orders);
                tape1.backward_batch(
                    &theta,
                    1,
                    &alpha[b..b + 1],
                    bs,
                    gs,
                    &mut single,
                );
                if tape1.value(0).to_bits() != tape.value(b).to_bits() {
                    return Err(format!("point {b}: fast single-point value mismatch"));
                }
                for (jj, (a, s)) in row.iter().zip(&single).enumerate() {
                    if a.to_bits() != s.to_bits() {
                        return Err(format!("point {b}: fast single row[{jj}] mismatch"));
                    }
                }
            }
            Ok(())
        });
    }

    /// The forced-scalar fast tier against the auto-detected vectorized
    /// one (`ENGD_SIMD=scalar` in CI forces the whole suite down this
    /// path): same blocked-pass structure, FMA contraction differences
    /// only, so results agree to [`FAST_TOL`]. On hosts without SIMD both
    /// tapes dispatch scalar and the comparison is trivially bitwise.
    #[test]
    fn fast_forced_scalar_tier_matches_vectorized_within_tolerance() {
        let arch = [3usize, 7, 5, 1];
        let d = arch[0];
        let np = param_count(&arch);
        let mut rng = Rng::seed_from(0xD15);
        let theta = init_params(&arch, &mut rng);
        let mut scalar_tier = Tape::with_tier(&arch, SimdTier::Scalar);
        let mut vector_tier = Tape::with_numerics(&arch, NumericsMode::Fast);
        assert_eq!(scalar_tier.tier(), SimdTier::Scalar);
        assert_eq!(scalar_tier.numerics(), NumericsMode::Fast);
        for orders in [DualOrder::full(d), DualOrder::new(d, d - 1), DualOrder::NONE] {
            let (nc, nc2) = (orders.first, orders.second);
            let n_pts = scalar_tier.block_points(orders).min(9);
            let mut xs = vec![0.0; n_pts * d];
            rng.fill_uniform(&mut xs, 0.05, 0.95);
            let mut alpha = vec![0.0; n_pts];
            let mut beta = vec![0.0; n_pts * nc];
            let mut gamma = vec![0.0; n_pts * nc2];
            rng.fill_uniform(&mut alpha, -1.0, 1.0);
            rng.fill_uniform(&mut beta, -1.0, 1.0);
            rng.fill_uniform(&mut gamma, -1.0, 1.0);
            let mut rows_s = vec![0.0; n_pts * np];
            let mut rows_v = vec![0.0; n_pts * np];
            scalar_tier.forward_batch(&theta, &xs, n_pts, orders);
            scalar_tier.backward_batch(&theta, n_pts, &alpha, &beta, &gamma, &mut rows_s);
            vector_tier.forward_batch(&theta, &xs, n_pts, orders);
            vector_tier.backward_batch(&theta, n_pts, &alpha, &beta, &gamma, &mut rows_v);
            for b in 0..n_pts {
                assert!(
                    fast_close(vector_tier.value(b), scalar_tier.value(b)),
                    "value[{b}] across tiers"
                );
            }
            for (jj, (v, s)) in rows_v.iter().zip(&rows_s).enumerate() {
                assert!(
                    fast_close(*v, *s),
                    "row elem {jj}: {v:.17e} (vector) vs {s:.17e} (forced scalar)"
                );
            }
        }
    }

    #[test]
    fn fast_mode_widens_blocks_and_clamps_unsupported_tiers() {
        let tape = Tape::with_numerics(&[2, 6, 1], NumericsMode::Fast);
        assert_eq!(tape.numerics(), NumericsMode::Fast);
        assert!(tape.tier().supported());
        assert_eq!(tape.block_points(DualOrder::NONE), simd::FAST_MAX_BLOCK_POINTS);
        // 128-lane budget / 2 coordinates, clamped to the 64-point cap.
        assert_eq!(tape.block_points(DualOrder::full(2)), simd::FAST_MAX_BLOCK_POINTS);
        // The bitwise caps are untouched by the fast tier's existence.
        let bit = Tape::new(&[2, 6, 1]);
        assert_eq!(bit.numerics(), NumericsMode::Bitwise);
        assert_eq!(bit.block_points(DualOrder::NONE), MAX_BLOCK_POINTS);
        // A tier this CPU cannot run is clamped to scalar, never UB.
        let clamped = Tape::with_tier(&[2, 6, 1], SimdTier::Neon);
        assert!(clamped.tier().supported());
        let clamped = Tape::with_tier(&[2, 6, 1], SimdTier::Avx512);
        assert!(clamped.tier().supported());
    }
}
