//! The relaxed-numerics (`fast`) SIMD kernel tier: accuracy mode, runtime
//! CPU-feature dispatch, and the FMA panel kernels behind
//! `Tape::forward_batch` / `Tape::backward_batch` in fast mode.
//!
//! ## The two-tier numerics contract
//!
//! The native backend ships two kernel tiers selected by [`NumericsMode`]
//! (`--numerics bitwise|fast`, `ENGD_NUMERICS`, or the `numerics` TOML
//! key):
//!
//! * **`bitwise`** (default) — the PR-4/5 blocked kernels in `tape.rs`:
//!   every lane preserves the scalar per-point FP operation sequence (no
//!   FMA contraction, no reassociation, ascending-`k`/`o` accumulation,
//!   per-lane zero-skip guards). Trajectories are bit-for-bit reproducible
//!   across block sizes, shard counts, and thread counts, and are mirrored
//!   exactly by `python/tools/tape_oracle.py`.
//! * **`fast`** — the kernels in this module: explicit FMA contraction
//!   (`f64::mul_add` compiled under per-tier `#[target_feature]`
//!   multiversioning), four-row blocked panel passes that keep each
//!   accumulator element register-resident across four consecutive
//!   reduction terms, coarser zero-skip guards, and wider point blocks.
//!   Per-element accumulation still walks the reduction index in ascending
//!   order, but each `a*b+c` may round once instead of twice and the
//!   reverse sweep groups weight rows four at a time — so results agree
//!   with the bitwise tier only to rounding-level tolerance (the property
//!   suite in `tape.rs` bounds the relative error at 1e-10 against
//!   [`super::tape::ScalarTape`], with observed errors orders of magnitude
//!   below that). `fast` trajectories are deterministic for a fixed
//!   binary, CPU tier, and thread count, but are **not** comparable
//!   bit-for-bit against `bitwise` runs — checkpoints record the mode and
//!   resume refuses a silent switch.
//!
//! ## Tier dispatch
//!
//! [`SimdTier::detect`] picks the widest instruction set the CPU supports
//! once per process (`ENGD_SIMD=scalar|avx2|avx512|neon` overrides it for
//! testing, clamped to what the CPU can actually run):
//!
//! * x86_64 — `avx2` requires AVX2+FMA; `avx512` additionally requires
//!   AVX-512F and currently lowers to the AVX2+FMA kernel instantiation
//!   (the MSRV predates stable `avx512f` target-feature codegen), so
//!   `detect` never selects it on its own;
//! * aarch64 — `neon` (baseline; scalar `fmadd` is native there);
//! * anything else — `scalar`, a fast-but-portable instantiation that
//!   keeps the blocked passes but uses plain `a*b + c` (on targets
//!   without hardware FMA, `f64::mul_add` would lower to a slow libm
//!   call).
//!
//! Each kernel has one generic `#[inline(always)]` body parameterized by
//! `const FMA: bool`, instantiated under per-tier
//! `#[target_feature]`-annotated wrappers; dispatch is a predictable
//! per-call branch on the tape's cached tier.

use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Accuracy mode of the native kernels (`--numerics bitwise|fast`). See
/// the module docs for the contract each tier provides.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum NumericsMode {
    /// Scalar-identical FP sequences; bit-for-bit reproducible (default).
    #[default]
    Bitwise,
    /// FMA + blocked-pass kernels; rounding-level differences allowed.
    Fast,
}

impl NumericsMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bitwise" => Ok(NumericsMode::Bitwise),
            "fast" => Ok(NumericsMode::Fast),
            _ => bail!("unknown numerics mode '{s}' (expected 'bitwise' or 'fast')"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NumericsMode::Bitwise => "bitwise",
            NumericsMode::Fast => "fast",
        }
    }

    /// Numeric encoding for the metrics CSV extras (string-free schema).
    pub fn code(self) -> f64 {
        match self {
            NumericsMode::Bitwise => 0.0,
            NumericsMode::Fast => 1.0,
        }
    }

    /// Mode requested by `ENGD_NUMERICS` (default `bitwise`; an invalid
    /// value warns and falls back rather than aborting a run).
    pub fn from_env() -> Self {
        match crate::config::envvars::read("ENGD_NUMERICS") {
            Some(s) => match Self::parse(&s) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("[engd] {e}; ignoring ENGD_NUMERICS");
                    NumericsMode::Bitwise
                }
            },
            None => NumericsMode::Bitwise,
        }
    }
}

/// Instruction-set tier the fast kernels dispatch to at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdTier {
    /// Portable fallback: blocked passes, plain `a*b + c`.
    Scalar,
    /// x86_64 AVX2 + FMA.
    Avx2,
    /// x86_64 AVX-512F (+AVX2/FMA); kernels currently alias the AVX2+FMA
    /// instantiation — see the module docs.
    Avx512,
    /// aarch64 NEON (FMA is baseline there).
    Neon,
}

impl SimdTier {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "scalar" => Ok(SimdTier::Scalar),
            "avx2" => Ok(SimdTier::Avx2),
            "avx512" => Ok(SimdTier::Avx512),
            "neon" => Ok(SimdTier::Neon),
            _ => bail!("unknown SIMD tier '{s}' (expected scalar|avx2|avx512|neon)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SimdTier::Scalar => "scalar",
            SimdTier::Avx2 => "avx2",
            SimdTier::Avx512 => "avx512",
            SimdTier::Neon => "neon",
        }
    }

    /// Numeric encoding for the metrics CSV extras.
    pub fn code(self) -> f64 {
        match self {
            SimdTier::Scalar => 0.0,
            SimdTier::Avx2 => 1.0,
            SimdTier::Avx512 => 2.0,
            SimdTier::Neon => 3.0,
        }
    }

    /// Whether this CPU can execute the tier's kernels (feature-detected
    /// at runtime; `Scalar` always can).
    pub fn supported(self) -> bool {
        match self {
            SimdTier::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx2 => {
                is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "x86_64")]
            SimdTier::Avx512 => {
                is_x86_feature_detected!("avx512f")
                    && is_x86_feature_detected!("avx2")
                    && is_x86_feature_detected!("fma")
            }
            #[cfg(target_arch = "aarch64")]
            SimdTier::Neon => true,
            _ => false,
        }
    }

    /// Widest tier `detect` auto-selects on this CPU.
    fn best_supported() -> SimdTier {
        #[cfg(target_arch = "x86_64")]
        {
            if SimdTier::Avx2.supported() {
                return SimdTier::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if SimdTier::Neon.supported() {
                return SimdTier::Neon;
            }
        }
        SimdTier::Scalar
    }

    /// The tier fast-mode tapes dispatch to, decided once per process:
    /// the `ENGD_SIMD` override if set and runnable on this CPU (else a
    /// warning + fallback), otherwise the widest supported tier.
    pub fn detect() -> SimdTier {
        static TIER: OnceLock<SimdTier> = OnceLock::new();
        *TIER.get_or_init(|| {
            if let Some(s) = crate::config::envvars::read("ENGD_SIMD") {
                match SimdTier::parse(&s) {
                    Ok(t) if t.supported() => return t,
                    Ok(t) => eprintln!(
                        "[engd] ENGD_SIMD={} is not runnable on this CPU; using {}",
                        t.name(),
                        SimdTier::best_supported().name()
                    ),
                    Err(e) => eprintln!("[engd] {e}; ignoring ENGD_SIMD"),
                }
            }
            SimdTier::best_supported()
        })
    }
}

/// Most points a fast-mode `forward_batch` carries for value-only passes
/// (double the bitwise cap: wider blocks amortize the per-layer `Wᵀ`
/// transpose and block-dispatch overhead further).
pub(crate) const FAST_MAX_BLOCK_POINTS: usize = 64;

/// Fast-mode dual-lane budget (double the bitwise cap; panel storage per
/// layer grows accordingly but stays L2-scale for the paper's widths).
pub(crate) const FAST_DUAL_LANE_BUDGET: usize = 128;

/// One fused multiply-add term: contracted when the tier guarantees
/// hardware FMA, plain `a*b + c` otherwise (`f64::mul_add` without the
/// guarantee lowers to a libm call far slower than two rounded ops).
#[inline(always)]
fn fmadd<const FMA: bool>(a: f64, b: f64, c: f64) -> f64 {
    if FMA {
        a.mul_add(b, c)
    } else {
        a * b + c
    }
}

// ---------------------------------------------------------------------------
// Generic kernel bodies (one per kernel, `const FMA: bool`), instantiated
// under per-tier `#[target_feature]` wrappers by `define_kernel!` below.
// ---------------------------------------------------------------------------

/// `dst[o] += Σ_k wt[k·fan_out + o] · coefs[k]` with `fan_out = dst.len()`,
/// walking `k` ascending but streaming four `Wᵀ` rows per pass so each
/// accumulator element is loaded and stored once per four terms.
#[inline(always)]
fn panel_axpy_impl<const FMA: bool>(wt: &[f64], coefs: &[f64], dst: &mut [f64]) {
    let fan_out = dst.len();
    debug_assert_eq!(wt.len(), coefs.len() * fan_out);
    let mut quads = coefs.chunks_exact(4);
    let mut rows = wt.chunks_exact(4 * fan_out);
    for (cq, rq) in quads.by_ref().zip(rows.by_ref()) {
        let (r0, rest) = rq.split_at(fan_out);
        let (r1, rest) = rest.split_at(fan_out);
        let (r2, r3) = rest.split_at(fan_out);
        for o in 0..fan_out {
            let mut acc = dst[o];
            acc = fmadd::<FMA>(r0[o], cq[0], acc);
            acc = fmadd::<FMA>(r1[o], cq[1], acc);
            acc = fmadd::<FMA>(r2[o], cq[2], acc);
            acc = fmadd::<FMA>(r3[o], cq[3], acc);
            dst[o] = acc;
        }
    }
    for (&c, row) in quads
        .remainder()
        .iter()
        .zip(rows.remainder().chunks_exact(fan_out))
    {
        for (dv, &wv) in dst.iter_mut().zip(row) {
            *dv = fmadd::<FMA>(wv, c, *dv);
        }
    }
}

/// The order-2 pair kernel: `tdst += Wᵀ·tc` and `sdst += Wᵀ·sc` fused so
/// each `Wᵀ` row quad is loaded once for both dual orders.
#[inline(always)]
fn panel_axpy2_impl<const FMA: bool>(
    wt: &[f64],
    tc: &[f64],
    sc: &[f64],
    tdst: &mut [f64],
    sdst: &mut [f64],
) {
    let fan_out = tdst.len();
    debug_assert_eq!(sdst.len(), fan_out);
    debug_assert_eq!(tc.len(), sc.len());
    debug_assert_eq!(wt.len(), tc.len() * fan_out);
    let mut tquads = tc.chunks_exact(4);
    let mut squads = sc.chunks_exact(4);
    let mut rows = wt.chunks_exact(4 * fan_out);
    for ((tq, sq), rq) in tquads.by_ref().zip(squads.by_ref()).zip(rows.by_ref()) {
        let (r0, rest) = rq.split_at(fan_out);
        let (r1, rest) = rest.split_at(fan_out);
        let (r2, r3) = rest.split_at(fan_out);
        for o in 0..fan_out {
            let mut tacc = tdst[o];
            let mut sacc = sdst[o];
            tacc = fmadd::<FMA>(r0[o], tq[0], tacc);
            sacc = fmadd::<FMA>(r0[o], sq[0], sacc);
            tacc = fmadd::<FMA>(r1[o], tq[1], tacc);
            sacc = fmadd::<FMA>(r1[o], sq[1], sacc);
            tacc = fmadd::<FMA>(r2[o], tq[2], tacc);
            sacc = fmadd::<FMA>(r2[o], sq[2], sacc);
            tacc = fmadd::<FMA>(r3[o], tq[3], tacc);
            sacc = fmadd::<FMA>(r3[o], sq[3], sacc);
            tdst[o] = tacc;
            sdst[o] = sacc;
        }
    }
    for ((&tck, &sck), row) in tquads
        .remainder()
        .iter()
        .zip(squads.remainder())
        .zip(rows.remainder().chunks_exact(fan_out))
    {
        for ((tv, sv), &wv) in tdst.iter_mut().zip(sdst.iter_mut()).zip(row) {
            *tv = fmadd::<FMA>(wv, tck, *tv);
            *sv = fmadd::<FMA>(wv, sck, *sv);
        }
    }
}

/// `dst[k] += c · src[k]`.
#[inline(always)]
fn axpy_impl<const FMA: bool>(dst: &mut [f64], src: &[f64], c: f64) {
    for (dv, &sv) in dst.iter_mut().zip(src) {
        *dv = fmadd::<FMA>(c, sv, *dv);
    }
}

/// `dst[k] += ca · a[k] + cb · b[k]` in one pass over `dst`.
#[inline(always)]
fn axpy2_impl<const FMA: bool>(dst: &mut [f64], a: &[f64], ca: f64, b: &[f64], cb: f64) {
    for ((dv, &av), &bv) in dst.iter_mut().zip(a).zip(b) {
        let mut acc = *dv;
        acc = fmadd::<FMA>(ca, av, acc);
        acc = fmadd::<FMA>(cb, bv, acc);
        *dv = acc;
    }
}

/// Four-row reverse sweep: `dst[k] += Σ_j c[j] · rows[j·n + k]` for four
/// consecutive weight rows (`rows.len() == 4·dst.len()`), keeping each
/// destination element register-resident across the quad.
#[inline(always)]
fn sweep4_impl<const FMA: bool>(dst: &mut [f64], rows: &[f64], c: [f64; 4]) {
    let n = dst.len();
    debug_assert_eq!(rows.len(), 4 * n);
    let (r0, rest) = rows.split_at(n);
    let (r1, rest) = rest.split_at(n);
    let (r2, r3) = rest.split_at(n);
    for k in 0..n {
        let mut acc = dst[k];
        acc = fmadd::<FMA>(r0[k], c[0], acc);
        acc = fmadd::<FMA>(r1[k], c[1], acc);
        acc = fmadd::<FMA>(r2[k], c[2], acc);
        acc = fmadd::<FMA>(r3[k], c[3], acc);
        dst[k] = acc;
    }
}

/// Four-row sweep for a live (t̄, s̄) lane pair: the row quad is loaded
/// once and pushed into both destination panels.
#[inline(always)]
fn sweep4_pair_impl<const FMA: bool>(
    tdst: &mut [f64],
    sdst: &mut [f64],
    rows: &[f64],
    tc: [f64; 4],
    sc: [f64; 4],
) {
    let n = tdst.len();
    debug_assert_eq!(sdst.len(), n);
    debug_assert_eq!(rows.len(), 4 * n);
    let (r0, rest) = rows.split_at(n);
    let (r1, rest) = rest.split_at(n);
    let (r2, r3) = rest.split_at(n);
    for k in 0..n {
        let mut tacc = tdst[k];
        let mut sacc = sdst[k];
        tacc = fmadd::<FMA>(r0[k], tc[0], tacc);
        sacc = fmadd::<FMA>(r0[k], sc[0], sacc);
        tacc = fmadd::<FMA>(r1[k], tc[1], tacc);
        sacc = fmadd::<FMA>(r1[k], sc[1], sacc);
        tacc = fmadd::<FMA>(r2[k], tc[2], tacc);
        sacc = fmadd::<FMA>(r2[k], sc[2], sacc);
        tacc = fmadd::<FMA>(r3[k], tc[3], tacc);
        sacc = fmadd::<FMA>(r3[k], sc[3], sacc);
        tdst[k] = tacc;
        sdst[k] = sacc;
    }
}

// ---------------------------------------------------------------------------
// Per-tier instantiation + dispatch
// ---------------------------------------------------------------------------

/// Instantiates one generic kernel body under per-tier `#[target_feature]`
/// wrappers and emits the runtime-dispatch entry point. The AVX-512 tier
/// aliases the AVX2+FMA instantiation (see the module docs); NEON uses the
/// FMA body (baseline on aarch64); every other tier takes the portable
/// non-FMA body.
macro_rules! define_kernel {
    ($body:ident, $avx2:ident, $neon:ident, $scalar:ident, $disp:ident,
     ( $( $arg:ident : $ty:ty ),* $(,)? )) => {
        // SAFETY: `unsafe` here is only the `#[target_feature]` calling
        // contract — the body is safe Rust; callers must prove AVX2+FMA
        // support, which `$disp` below does before every call.
        #[cfg(target_arch = "x86_64")]
        #[target_feature(enable = "avx2,fma")]
        unsafe fn $avx2( $( $arg : $ty ),* ) {
            $body::<true>( $( $arg ),* )
        }

        // SAFETY: as above for NEON (baseline on aarch64, but the wrapper
        // keeps the dispatch structure uniform across arches).
        #[cfg(target_arch = "aarch64")]
        #[target_feature(enable = "neon")]
        unsafe fn $neon( $( $arg : $ty ),* ) {
            $body::<true>( $( $arg ),* )
        }

        fn $scalar( $( $arg : $ty ),* ) {
            $body::<false>( $( $arg ),* )
        }

        #[inline]
        pub(super) fn $disp(tier: SimdTier, $( $arg : $ty ),* ) {
            match tier {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: fast-mode tapes only carry tiers that passed
                // `SimdTier::supported` on this CPU (`detect` / the
                // clamped `Tape::with_tier`).
                SimdTier::Avx2 | SimdTier::Avx512 => unsafe { $avx2( $( $arg ),* ) },
                #[cfg(target_arch = "aarch64")]
                // SAFETY: as above; NEON is baseline on aarch64.
                SimdTier::Neon => unsafe { $neon( $( $arg ),* ) },
                _ => $scalar( $( $arg ),* ),
            }
        }
    };
}

define_kernel!(panel_axpy_impl, panel_axpy_avx2, panel_axpy_neon, panel_axpy_scalar, panel_axpy,
    (wt: &[f64], coefs: &[f64], dst: &mut [f64]));
define_kernel!(panel_axpy2_impl, panel_axpy2_avx2, panel_axpy2_neon, panel_axpy2_scalar, panel_axpy2,
    (wt: &[f64], tc: &[f64], sc: &[f64], tdst: &mut [f64], sdst: &mut [f64]));
define_kernel!(axpy_impl, axpy_avx2, axpy_neon, axpy_scalar, axpy,
    (dst: &mut [f64], src: &[f64], c: f64));
define_kernel!(axpy2_impl, axpy2_avx2, axpy2_neon, axpy2_scalar, axpy2,
    (dst: &mut [f64], a: &[f64], ca: f64, b: &[f64], cb: f64));
define_kernel!(sweep4_impl, sweep4_avx2, sweep4_neon, sweep4_scalar, sweep4,
    (dst: &mut [f64], rows: &[f64], c: [f64; 4]));
define_kernel!(sweep4_pair_impl, sweep4_pair_avx2, sweep4_pair_neon, sweep4_pair_scalar, sweep4_pair,
    (tdst: &mut [f64], sdst: &mut [f64], rows: &[f64], tc: [f64; 4], sc: [f64; 4]));

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_and_tier_parse_roundtrip() {
        for m in [NumericsMode::Bitwise, NumericsMode::Fast] {
            assert_eq!(NumericsMode::parse(m.name()).unwrap(), m);
        }
        for t in [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512, SimdTier::Neon] {
            assert_eq!(SimdTier::parse(t.name()).unwrap(), t);
        }
        assert!(NumericsMode::parse("fused").is_err());
        assert!(SimdTier::parse("sse2").is_err());
        assert_eq!(NumericsMode::default(), NumericsMode::Bitwise);
    }

    #[test]
    fn detected_tier_is_supported_and_scalar_always_is() {
        assert!(SimdTier::Scalar.supported());
        assert!(SimdTier::detect().supported());
    }

    #[test]
    fn kernels_match_naive_loops_on_every_dispatchable_tier() {
        // The dispatch seam itself: every tier reachable on this CPU must
        // compute the same quantities as naive double-rounded loops, to
        // rounding-level tolerance (FMA tiers contract each a*b+c).
        let fan_in = 7; // exercises the 4-quad path plus a 3-row remainder
        let fan_out = 5;
        let wt: Vec<f64> = (0..fan_in * fan_out)
            .map(|i| ((i * 37 % 23) as f64 - 11.0) * 0.13)
            .collect();
        let coefs: Vec<f64> = (0..fan_in).map(|i| (i as f64 - 2.5) * 0.71).collect();
        let coefs2: Vec<f64> = (0..fan_in).map(|i| (i as f64).cos()).collect();
        let tol = 1e-14;
        let tiers: Vec<SimdTier> =
            [SimdTier::Scalar, SimdTier::Avx2, SimdTier::Avx512, SimdTier::Neon]
                .into_iter()
                .filter(|t| t.supported())
                .collect();
        for &tier in &tiers {
            // panel_axpy
            let mut dst = vec![0.25; fan_out];
            panel_axpy(tier, &wt, &coefs, &mut dst);
            for o in 0..fan_out {
                let mut want = 0.25;
                for k in 0..fan_in {
                    want += wt[k * fan_out + o] * coefs[k];
                }
                assert!((dst[o] - want).abs() <= tol * want.abs().max(1.0));
            }
            // panel_axpy2
            let (mut td, mut sd) = (vec![0.0; fan_out], vec![0.0; fan_out]);
            panel_axpy2(tier, &wt, &coefs, &coefs2, &mut td, &mut sd);
            for o in 0..fan_out {
                let (mut wt_sum, mut ws_sum) = (0.0, 0.0);
                for k in 0..fan_in {
                    wt_sum += wt[k * fan_out + o] * coefs[k];
                    ws_sum += wt[k * fan_out + o] * coefs2[k];
                }
                assert!((td[o] - wt_sum).abs() <= tol * wt_sum.abs().max(1.0));
                assert!((sd[o] - ws_sum).abs() <= tol * ws_sum.abs().max(1.0));
            }
            // axpy / axpy2
            let mut dst = coefs.clone();
            axpy(tier, &mut dst, &coefs2, 1.5);
            for k in 0..fan_in {
                let want = coefs[k] + 1.5 * coefs2[k];
                assert!((dst[k] - want).abs() <= tol * want.abs().max(1.0));
            }
            let mut dst = vec![0.5; fan_in];
            axpy2(tier, &mut dst, &coefs, -0.3, &coefs2, 2.0);
            for k in 0..fan_in {
                let want = 0.5 - 0.3 * coefs[k] + 2.0 * coefs2[k];
                assert!((dst[k] - want).abs() <= tol * want.abs().max(1.0));
            }
            // sweep4 / sweep4_pair over four consecutive rows
            let n = 6;
            let rows: Vec<f64> = (0..4 * n).map(|i| ((i % 11) as f64 - 5.0) * 0.4).collect();
            let c = [0.7, -1.1, 0.0, 2.3];
            let s = [1.3, 0.2, -0.8, 0.0];
            let mut dst = vec![1.0; n];
            sweep4(tier, &mut dst, &rows, c);
            let (mut td, mut sd) = (vec![1.0; n], vec![-1.0; n]);
            sweep4_pair(tier, &mut td, &mut sd, &rows, c, s);
            for k in 0..n {
                let mut want = 1.0;
                let mut wants = -1.0;
                for j in 0..4 {
                    want += rows[j * n + k] * c[j];
                    wants += rows[j * n + k] * s[j];
                }
                assert!((dst[k] - want).abs() <= tol * want.abs().max(1.0));
                assert!((td[k] - (want)).abs() <= tol * want.abs().max(1.0));
                assert!((sd[k] - wants).abs() <= tol * wants.abs().max(1.0));
            }
        }
    }
}
