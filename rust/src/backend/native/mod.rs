//! The native backend: artifact-free evaluation of the PINN training
//! objective in pure Rust.
//!
//! Everything PJRT does for the trainer — `loss`, `(r, J)`, `∇L`,
//! `u_pred` — is computed here with the hand-rolled AD in [`tape`]:
//! per-coordinate forward duals (to the order each coordinate actually
//! needs — the operator's [`crate::pde::DualOrder`] mask) give the PDE
//! operator (Laplacian / heat), and a structured reverse pass gives
//! per-sample Jacobian rows written straight into `Workspace`-pooled
//! row-major storage. Points run through the tape in **blocks**
//! ([`Tape::forward_batch`] / [`Tape::backward_batch`]): each worker's
//! chunk is split at the interior/boundary frontier and fed to the
//! coordinate-blocked SIMD kernels a point-block at a time. Both
//! directions are layer-outer/point-inner: the forward pass transposes
//! `W` once per layer per block, and the fused reverse pass keeps the
//! whole block's **adjoint panels** resident per layer and pushes them
//! through each `Wᵀ` in one sweep, so weight rows are loaded once per
//! layer per block (not once per point) and each block's Jacobian rows
//! land in one contiguous sub-block of J — the "adjoint panel" of the
//! block. Work is parallelized over collocation points
//! with [`crate::parallel`]; each worker thread owns one [`Tape`]
//! *persistently* — the tape lives in the thread's
//! [`crate::parallel::with_scratch`] slot and survives across evaluations
//! and training steps, so a warmed-up step (including every line-search
//! loss probe) rebuilds zero tape buffers and spawns zero threads.
//! Threads share nothing but the read-only inputs and their disjoint
//! output rows.
//!
//! Determinism: the loss / gradient reductions are laid out on a *chunk
//! grid* that depends only on `ENGD_THREADS` and the batch size (see
//! [`thread_chunks`]), never on runtime scheduling — and the same grid is
//! what [`super::sharded::ShardedEvaluator`] and the process tier
//! ([`super::process::ProcessEvaluator`]) partition across their
//! executors, which is why sharded results are bitwise-identical to this
//! backend for any shard count, schedule, and executor kind. The
//! `shard_*` methods below are that protocol — range-granular, so the
//! work-stealing scheduler can hand any sub-range to any executor.
//! Point-blocking changes none of it: every tape lane computes
//! the scalar per-point operation sequence, blocks never straddle a
//! reduction boundary, and per-point accumulations run in ascending row
//! order, so blocked results are bitwise those of per-point processing.
//!
//! ## Numerics tiers
//!
//! The backend carries a [`NumericsMode`] (`--numerics bitwise|fast`,
//! `ENGD_NUMERICS`, the `numerics` TOML key; [`NativeBackend::new`]
//! defaults from the environment) and threads it into every worker tape:
//!
//! * **`bitwise`** (default) — everything above holds bit-for-bit; the
//!   kernels never contract or reassociate a floating-point sequence.
//! * **`fast`** — worker tapes run the [`simd`] kernel tier (runtime
//!   CPU-dispatched FMA panel kernels, wider blocks). Per-point results
//!   change only at rounding level, and they stay *per-point
//!   deterministic* — independent of block, chunk, shard, and thread
//!   shape — so everything structural above (the chunk grid, shard ==
//!   unsharded, blocked == per-point) still holds exactly *within* fast
//!   mode; only comparisons across the two modes become approximate.
//!   Checkpoints record the mode, and resume refuses a silent switch.
//!
//! Residual convention (paper §3, mirrored from `python/compile/model.py`):
//!
//! ```text
//! r_Ω,i  = √(ω_Ω/N_Ω)   · (L u_θ(x_i) − f(x_i))
//! r_∂Ω,j = √(ω_∂Ω/N_∂Ω) · (u_θ(x_j) − g(x_j))
//! L(θ)   = ½‖r‖²,   J = ∂r/∂θ  (interior rows first)
//! ```
//!
//! with `L = −Δ` (Poisson) or `∂_t − Δ_x` (heat, time = last coordinate).

mod simd;
mod tape;

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};

use anyhow::{anyhow, ensure, Result};

use super::Evaluator;
use crate::linalg::{Matrix, Workspace, WorkspaceStats};
use crate::parallel::{self, SendPtr};
use crate::pde::{
    builtin_problem_map, exact_solution, DualOrder, ExactSolution, PdeOperator, ProblemSpec,
};

pub use simd::{NumericsMode, SimdTier};
pub use tape::{tape_builds, ScalarTape, Tape};

/// Pure-Rust implementation of [`Evaluator`]. Stateless apart from its
/// problem catalogue (built-ins by default; custom specs for tests), its
/// numerics mode, and a pooled scratch workspace for reduction partials.
pub struct NativeBackend {
    problems: BTreeMap<String, ProblemSpec>,
    /// Kernel tier every worker tape runs in (see the module docs).
    numerics: NumericsMode,
    /// Pooled storage for the `loss_and_grad` reduction partials (per-chunk
    /// losses and the flat `chunks × n_params` gradient block): `Evaluator`
    /// methods take `&self`, so the pool sits behind a mutex — the same
    /// zero-steady-state-allocation contract as the sharded evaluator's
    /// pool (`native_loss_grad_partials_are_pooled` in
    /// `rust/tests/pool.rs`).
    scratch: Mutex<Workspace>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// Backend over the built-in problem catalogue
    /// ([`crate::pde::builtin_problems`]), in the numerics mode requested
    /// by `ENGD_NUMERICS` (default bitwise) — so the env knob reaches
    /// every construction site, including the CI fast-tier jobs.
    pub fn new() -> Self {
        Self::with_numerics(NumericsMode::from_env())
    }

    /// Backend over the built-in catalogue in an explicit numerics mode
    /// (the config/CLI path).
    pub fn with_numerics(numerics: NumericsMode) -> Self {
        NativeBackend {
            problems: builtin_problem_map(),
            numerics,
            scratch: Mutex::new(Workspace::new()),
        }
    }

    /// Backend over a custom problem set (property tests use tiny nets),
    /// in the `ENGD_NUMERICS`-requested mode.
    pub fn with_problems(problems: Vec<ProblemSpec>) -> Self {
        Self::with_problems_numerics(problems, NumericsMode::from_env())
    }

    /// Custom problem set in an explicit numerics mode.
    pub fn with_problems_numerics(problems: Vec<ProblemSpec>, numerics: NumericsMode) -> Self {
        NativeBackend {
            problems: problems.into_iter().map(|p| (p.name.clone(), p)).collect(),
            numerics,
            scratch: Mutex::new(Workspace::new()),
        }
    }

    /// The numerics mode this backend's kernels run in.
    pub fn numerics(&self) -> NumericsMode {
        self.numerics
    }

    /// Allocation counters of the partial-buffer pool (tests assert
    /// `fresh_allocs` freezes after the first `loss_and_grad`).
    pub fn scratch_stats(&self) -> WorkspaceStats {
        self.lock_scratch().stats()
    }

    fn lock_scratch(&self) -> MutexGuard<'_, Workspace> {
        self.scratch.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    // --- shard protocol -------------------------------------------------
    //
    // These evaluate an arbitrary *range* of the global batch while
    // keeping every global quantity (residual scaling √(ω/N), the
    // reduction chunk grid) exactly as the unsharded backend computes it,
    // so any composition of these calls that tiles the batch — whichever
    // executor serves which range, in whatever order — is
    // bitwise-identical to one NativeBackend. Both sharded execution
    // tiers are built on them: the in-process `ShardedEvaluator` calls
    // them from pool threads, and the out-of-process tier's workers
    // (`crate::backend::process`) serve them over the frame protocol, one
    // call per `Range` request.

    /// Loss partials of the global reduction chunks `[c0, c1)` (see
    /// [`thread_chunks`]): `out[k] = Σ r_i²` over chunk `c0 + k`, rows in
    /// order. `out` must have `c1 - c0` entries.
    // lint: hot-path — shard protocol fns write caller-pooled slices (R4).
    pub(crate) fn shard_loss_partials(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        c0: usize,
        c1: usize,
        out: &mut [f64],
    ) -> Result<()> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let (chunks, chunk) = thread_chunks(n);
        ensure!(c0 <= c1 && c1 <= chunks, "chunk range [{c0}, {c1}) of {chunks}");
        ensure!(out.len() == c1 - c0, "partial buffer length mismatch");
        for (k, c) in (c0..c1).enumerate() {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            out[k] = chunk_loss(&ctx, theta, x_int, x_bnd, start, end);
        }
        Ok(())
    }

    /// Loss+gradient partials of the global reduction chunks `[c0, c1)`,
    /// written into caller-pooled flat storage: `loss_out[k] = Σ r_i²`
    /// over chunk `c0 + k` and `grad_out[k·P..(k+1)·P]` its `Σ r_i ∇r_i`
    /// partial (overwritten, not accumulated). Flat slices keep the
    /// sharded evaluator's steady state allocation-free — partials land
    /// in one `chunks × n_params` scratch block from its workspace pool.
    // lint: hot-path — shard protocol fns write caller-pooled slices (R4).
    pub(crate) fn shard_loss_grad_partials(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        c0: usize,
        c1: usize,
        loss_out: &mut [f64],
        grad_out: &mut [f64],
    ) -> Result<()> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let np = ctx.n_params;
        let (chunks, chunk) = thread_chunks(n);
        ensure!(c0 <= c1 && c1 <= chunks, "chunk range [{c0}, {c1}) of {chunks}");
        ensure!(loss_out.len() == c1 - c0, "loss partial buffer length mismatch");
        ensure!(grad_out.len() == (c1 - c0) * np, "grad partial buffer length mismatch");
        for (k, c) in (c0..c1).enumerate() {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            loss_out[k] = chunk_loss_grad_into(
                &ctx,
                theta,
                x_int,
                x_bnd,
                start,
                end,
                &mut grad_out[k * np..(k + 1) * np],
            );
        }
        Ok(())
    }

    /// Residual entries and Jacobian rows of the global row range
    /// `[row0, row1)`, written into caller slices: `r_out` gets the
    /// `row1 - row0` residuals, `j_out` the matching row-major
    /// `(row1 - row0) × n_params` block. `j_out` must be zeroed (the
    /// reverse pass accumulates). Rows are pointwise-deterministic, so any
    /// contiguous partition reproduces the unsharded Jacobian bitwise.
    // lint: hot-path — shard protocol fns write caller-pooled slices (R4).
    pub(crate) fn shard_rows_into(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        row0: usize,
        row1: usize,
        r_out: &mut [f64],
        j_out: &mut [f64],
    ) -> Result<()> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let np = ctx.n_params;
        ensure!(row0 <= row1 && row1 <= n, "row range [{row0}, {row1}) of {n}");
        ensure!(r_out.len() == row1 - row0, "residual slice length mismatch");
        ensure!(j_out.len() == (row1 - row0) * np, "Jacobian slice length mismatch");
        rows_into(&ctx, theta, x_int, x_bnd, row0, row1, r_out, j_out);
        Ok(())
    }

    /// Predictions `u_θ` for evaluation points `[i0, i1)` of a row-major
    /// point set, written into `out` (`i1 - i0` entries).
    // lint: hot-path — shard protocol fns write caller-pooled slices (R4).
    pub(crate) fn shard_u_pred_into(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_eval: &[f64],
        i0: usize,
        i1: usize,
        out: &mut [f64],
    ) -> Result<()> {
        let ctx = Ctx::new(p, self.numerics)?;
        ensure!(
            theta.len() == ctx.n_params,
            "θ has {} params, problem wants {}",
            theta.len(),
            ctx.n_params
        );
        ensure!(
            x_eval.len() % ctx.dim == 0 && i1 * ctx.dim <= x_eval.len() && i0 <= i1,
            "evaluation range [{i0}, {i1}) outside the point set"
        );
        ensure!(out.len() == i1 - i0, "prediction slice length mismatch");
        u_pred_into(&ctx, theta, x_eval, i0, i1, out);
        Ok(())
    }
}

/// Per-problem evaluation context: everything a worker needs, precomputed.
struct Ctx {
    arch: Vec<usize>,
    dim: usize,
    operator: PdeOperator,
    /// Interior-pass dual mask: which coordinates carry which dual orders
    /// (`orders.second` doubles as the Laplacian's coordinate count).
    orders: DualOrder,
    /// Kernel tier worker tapes for this evaluation run in.
    numerics: NumericsMode,
    exact: ExactSolution,
    /// √(ω_Ω/N_Ω), √(ω_∂Ω/N_∂Ω).
    scale_int: f64,
    scale_bnd: f64,
    n_int: usize,
    n_bnd: usize,
    n_params: usize,
}

impl Ctx {
    fn new(p: &ProblemSpec, numerics: NumericsMode) -> Result<Ctx> {
        ensure!(p.n_interior > 0 && p.n_boundary > 0, "empty batch in '{}'", p.name);
        ensure!(
            p.arch.first() == Some(&p.dim) && p.arch.last() == Some(&1),
            "problem '{}': arch {:?} must run dim -> 1",
            p.name,
            p.arch
        );
        ensure!(
            p.n_params == crate::pde::param_count(&p.arch),
            "problem '{}': n_params {} != param_count(arch) {}",
            p.name,
            p.n_params,
            crate::pde::param_count(&p.arch)
        );
        ensure!(
            p.operator != PdeOperator::Heat || p.dim >= 2,
            "heat operator needs at least one spatial + one time coordinate"
        );
        Ok(Ctx {
            arch: p.arch.clone(), // lint: allow(alloc) — tiny once-per-dispatch setup copy
            dim: p.dim,
            operator: p.operator,
            orders: p.operator.dual_orders(p.dim),
            numerics,
            exact: exact_solution(&p.pde)?,
            scale_int: (p.interior_weight / p.n_interior as f64).sqrt(),
            scale_bnd: (p.boundary_weight / p.n_boundary as f64).sqrt(),
            n_int: p.n_interior,
            n_bnd: p.n_boundary,
            n_params: p.n_params,
        })
    }

    fn check_inputs(&self, theta: &[f64], x_int: &[f64], x_bnd: &[f64]) -> Result<()> {
        ensure!(
            theta.len() == self.n_params,
            "θ has {} params, problem wants {}",
            theta.len(),
            self.n_params
        );
        ensure!(
            x_int.len() == self.n_int * self.dim,
            "interior batch has {} values, problem wants {}×{}",
            x_int.len(),
            self.n_int,
            self.dim
        );
        ensure!(
            x_bnd.len() == self.n_bnd * self.dim,
            "boundary batch has {} values, problem wants {}×{}",
            x_bnd.len(),
            self.n_bnd,
            self.dim
        );
        Ok(())
    }
}

/// One worker thread's state: the AD tape plus per-block seed buffers for
/// the batched reverse passes (α per point; β/γ per point × coordinate,
/// sized for the problem's dual mask at the tape's block width).
struct Worker {
    tape: Tape,
    alpha: Vec<f64>,
    beta: Vec<f64>,
    gamma: Vec<f64>,
}

impl Worker {
    fn new(ctx: &Ctx) -> Worker {
        let tape = Tape::with_numerics(&ctx.arch, ctx.numerics);
        let interior_block = tape.block_points(ctx.orders);
        let value_block = tape.block_points(DualOrder::NONE);
        Worker {
            alpha: vec![0.0; interior_block.max(value_block)],
            beta: vec![0.0; interior_block * ctx.orders.first],
            gamma: vec![0.0; interior_block * ctx.orders.second],
            tape,
        }
    }

    /// Residual of block point `b` of the last `forward_batch` (`x` is
    /// that point's coordinates; `interior` selects the operator residual
    /// vs the boundary one).
    fn residual_at(&self, ctx: &Ctx, x: &[f64], b: usize, interior: bool) -> f64 {
        if interior {
            let f = ctx.exact.forcing(x);
            // The Laplacian runs over exactly the order-2 coordinates.
            let mut lap = 0.0;
            for i in 0..ctx.orders.second {
                lap += self.tape.d2(b, i);
            }
            match ctx.operator {
                PdeOperator::Poisson => ctx.scale_int * (-lap - f),
                PdeOperator::Heat => ctx.scale_int * (self.tape.d1(b, ctx.dim - 1) - lap - f),
            }
        } else {
            ctx.scale_bnd * (self.tape.value(b) - ctx.exact.boundary(x))
        }
    }
}

/// Coordinates of global batch row `idx` (interior rows first).
fn point_of<'a>(ctx: &Ctx, x_int: &'a [f64], x_bnd: &'a [f64], idx: usize) -> &'a [f64] {
    let d = ctx.dim;
    if idx < ctx.n_int {
        &x_int[idx * d..(idx + 1) * d]
    } else {
        let q = idx - ctx.n_int;
        &x_bnd[q * d..(q + 1) * d]
    }
}

/// Drive the tape over global rows `[start, end)` in point blocks: the
/// range is split at the interior/boundary frontier, each side is fed to
/// [`Tape::forward_batch`] a block at a time (interior blocks carry the
/// operator's dual mask, boundary blocks none), and `f(worker, p0, n,
/// interior)` consumes each forwarded block of rows `p0..p0+n`. Blocks
/// and the points inside them run in ascending row order, and every tape
/// lane computes the scalar per-point operation sequence, so any
/// row-ordered consumer sees bitwise the results of per-point processing.
fn run_blocks<F>(
    worker: &mut Worker,
    ctx: &Ctx,
    theta: &[f64],
    x_int: &[f64],
    x_bnd: &[f64],
    start: usize,
    end: usize,
    mut f: F,
) where
    F: FnMut(&mut Worker, usize, usize, bool),
{
    let d = ctx.dim;
    let int_end = end.min(ctx.n_int);
    if start < int_end {
        let block = worker.tape.block_points(ctx.orders);
        let mut p = start;
        while p < int_end {
            let n = block.min(int_end - p);
            worker.tape.forward_batch(theta, &x_int[p * d..(p + n) * d], n, ctx.orders);
            f(worker, p, n, true);
            p += n;
        }
    }
    let bnd_start = start.max(ctx.n_int);
    if bnd_start < end {
        let block = worker.tape.block_points(DualOrder::NONE);
        let mut p = bnd_start;
        while p < end {
            let n = block.min(end - p);
            let lo = (p - ctx.n_int) * d;
            worker.tape.forward_batch(theta, &x_bnd[lo..lo + n * d], n, DualOrder::NONE);
            f(worker, p, n, false);
            p += n;
        }
    }
}

/// Residuals and Jacobian rows of global rows `[row0, row1)`, written into
/// caller slices (`r_out`: `row1 − row0` residuals; `j_out`: the matching
/// zero-initialized row-major `(row1 − row0) × n_params` block). Each
/// block's rows are handed to the fused [`Tape::backward_batch`] as one
/// contiguous J sub-block — the block's adjoint panel — with per-point
/// seeds, so the layer-outer reverse sweep retires a weight panel once
/// per block while filling all of the panel's rows. The same layout is
/// what `shard_rows_into` hands each shard: any contiguous row partition
/// splits into whole panels plus at most two partial ones, all bitwise
/// equal to unsharded processing.
fn rows_into(
    ctx: &Ctx,
    theta: &[f64],
    x_int: &[f64],
    x_bnd: &[f64],
    row0: usize,
    row1: usize,
    r_out: &mut [f64],
    j_out: &mut [f64],
) {
    let np = ctx.n_params;
    with_worker(ctx, |worker| {
        run_blocks(worker, ctx, theta, x_int, x_bnd, row0, row1, |w, p0, n, interior| {
            for b in 0..n {
                let idx = p0 + b;
                let x = point_of(ctx, x_int, x_bnd, idx);
                r_out[idx - row0] = w.residual_at(ctx, x, b, interior);
            }
            let Worker { tape, alpha, beta, gamma } = w;
            let out = &mut j_out[(p0 - row0) * np..(p0 - row0 + n) * np];
            if interior {
                // One Jacobian row per point: γ ≡ −s on the Laplacian
                // coordinates (+ β_t = s for heat's time derivative).
                let (nc, nc2) = (ctx.orders.first, ctx.orders.second);
                let (nb, ng) = (n * nc, n * nc2);
                let c = ctx.scale_int;
                for a in alpha[..n].iter_mut() {
                    *a = 0.0;
                }
                for v in beta[..nb].iter_mut() {
                    *v = 0.0;
                }
                for v in gamma[..ng].iter_mut() {
                    *v = -c;
                }
                if ctx.operator == PdeOperator::Heat {
                    for b in 0..n {
                        beta[b * nc + nc - 1] = c;
                    }
                }
                tape.backward_batch(theta, n, &alpha[..n], &beta[..nb], &gamma[..ng], out);
            } else {
                for a in alpha[..n].iter_mut() {
                    *a = ctx.scale_bnd;
                }
                tape.backward_batch(theta, n, &alpha[..n], &[], &[], out);
            }
        });
    });
}

/// Predictions `u_θ` for evaluation points `[i0, i1)` of a row-major point
/// set, written into `out` — value-only forward blocks.
fn u_pred_into(ctx: &Ctx, theta: &[f64], x_eval: &[f64], i0: usize, i1: usize, out: &mut [f64]) {
    let d = ctx.dim;
    with_worker(ctx, |worker| {
        let block = worker.tape.block_points(DualOrder::NONE);
        let mut p = i0;
        while p < i1 {
            let n = block.min(i1 - p);
            worker.tape.forward_batch(theta, &x_eval[p * d..(p + n) * d], n, DualOrder::NONE);
            for b in 0..n {
                out[p + b - i0] = worker.tape.value(b);
            }
            p += n;
        }
    });
}

/// The canonical `(chunks, chunk_len)` reduction grid for an `n`-row batch:
/// one contiguous chunk per worker slot, a pure function of `ENGD_THREADS`
/// and `n`. Every floating-point reduction in this backend (and in the
/// sharded evaluator, which partitions these same chunks across inner
/// evaluators) sums per-chunk partials in chunk order, so results are
/// bitwise-reproducible for a fixed `ENGD_THREADS` regardless of scheduling
/// or shard count.
pub(crate) fn thread_chunks(n: usize) -> (usize, usize) {
    let workers = parallel::num_threads().min(n.max(1));
    (workers, n.div_ceil(workers.max(1)))
}

/// A thread's persistent worker-state slot: the tape plus seed buffers,
/// keyed by (architecture, dual mask, numerics mode) and rebuilt only when
/// one of those changes — the mask determines the seed-buffer sizing and
/// the mode determines the tape's kernel tier and block caps, so both are
/// part of the key (constant within any one training run).
#[derive(Default)]
struct WorkerSlot {
    arch: Vec<usize>,
    orders: DualOrder,
    mode: NumericsMode,
    worker: Option<Worker>,
}

/// Run `f` with this thread's persistent [`Worker`] for `ctx`'s
/// architecture and numerics mode (building it on first use / key change).
fn with_worker<R>(ctx: &Ctx, f: impl FnOnce(&mut Worker) -> R) -> R {
    parallel::with_scratch::<WorkerSlot, R>(|slot| {
        if slot.worker.is_none()
            || slot.arch != ctx.arch
            || slot.orders != ctx.orders
            || slot.mode != ctx.numerics
        {
            slot.worker = Some(Worker::new(ctx));
            slot.arch = ctx.arch.clone();
            slot.orders = ctx.orders;
            slot.mode = ctx.numerics;
        }
        f(slot.worker.as_mut().expect("worker slot populated above"))
    })
}

/// `Σ r_i²` over global rows `[start, end)` — one reduction chunk's loss
/// partial, accumulated in row order (point-blocked forwards, scalar-order
/// accumulation).
fn chunk_loss(
    ctx: &Ctx,
    theta: &[f64],
    x_int: &[f64],
    x_bnd: &[f64],
    start: usize,
    end: usize,
) -> f64 {
    with_worker(ctx, |worker| {
        let mut acc = 0.0;
        run_blocks(worker, ctx, theta, x_int, x_bnd, start, end, |w, p0, n, interior| {
            for b in 0..n {
                let idx = p0 + b;
                let x = point_of(ctx, x_int, x_bnd, idx);
                let r = w.residual_at(ctx, x, b, interior);
                acc += r * r;
            }
        });
        acc
    })
}

/// One reduction chunk's `Σ r_i²`, with the chunk's contribution to
/// `∇L = Jᵀr` accumulated into caller storage (`grad`, zeroed here) and
/// no J materialization: each point's reverse pass is seeded by its own
/// residual value, accumulated into the shared chunk gradient in
/// ascending row order — bitwise the same partial however the buffer is
/// provided, which is what keeps the sharded evaluator's pooled-scratch
/// path identical to the unsharded one.
fn chunk_loss_grad_into(
    ctx: &Ctx,
    theta: &[f64],
    x_int: &[f64],
    x_bnd: &[f64],
    start: usize,
    end: usize,
    grad: &mut [f64],
) -> f64 {
    debug_assert_eq!(grad.len(), ctx.n_params);
    grad.fill(0.0);
    with_worker(ctx, |worker| {
        let mut acc = 0.0;
        run_blocks(worker, ctx, theta, x_int, x_bnd, start, end, |w, p0, n, interior| {
            for b in 0..n {
                let idx = p0 + b;
                let x = point_of(ctx, x_int, x_bnd, idx);
                let val = w.residual_at(ctx, x, b, interior);
                acc += val * val;
                let Worker { tape, beta, gamma, .. } = w;
                if interior {
                    let (nc, nc2) = (ctx.orders.first, ctx.orders.second);
                    let c = ctx.scale_int * val;
                    for v in beta[..nc].iter_mut() {
                        *v = 0.0;
                    }
                    for v in gamma[..nc2].iter_mut() {
                        *v = -c;
                    }
                    if ctx.operator == PdeOperator::Heat {
                        beta[nc - 1] = c;
                    }
                    tape.backward(theta, b, 0.0, &beta[..nc], &gamma[..nc2], grad);
                } else {
                    let a = ctx.scale_bnd * val;
                    tape.backward(theta, b, a, &[], &[], grad);
                }
            }
        });
        acc
    })
}

impl Evaluator for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn problem(&self, name: &str) -> Result<ProblemSpec> {
        self.problems.get(name).cloned().ok_or_else(|| {
            anyhow!(
                "native backend has no problem '{}' (have: {:?})",
                name,
                self.problems.keys().collect::<Vec<_>>()
            )
        })
    }

    fn problem_names(&self) -> Vec<String> {
        self.problems.keys().cloned().collect()
    }

    fn loss(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<f64> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let (workers, chunk) = thread_chunks(n);
        // Fixed chunk→partial mapping keeps the reduction order (and thus
        // the f64 sum) deterministic for a given `ENGD_THREADS`.
        let partials = parallel::par_map(workers, |w| {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            chunk_loss(&ctx, theta, x_int, x_bnd, start, end)
        });
        Ok(0.5 * partials.iter().sum::<f64>())
    }

    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let np = ctx.n_params;
        let (workers, chunk) = thread_chunks(n);
        // ∇L = Jᵀ r accumulated per reduction chunk with no J
        // materialization: each point's reverse pass is seeded by its own
        // residual value. Partials live in pooled flat scratch — one loss
        // entry and one contiguous P-long gradient block per chunk — so a
        // warmed-up step (including every line-search probe) allocates
        // nothing here. Scratch is fine uninitialized: every chunk's
        // entries are overwritten (`chunk_loss_grad_into` zeroes its
        // block), and the pool lock covers only checkout/check-in.
        let (mut loss_parts, mut grad_parts) = {
            let mut ws = self.lock_scratch();
            (ws.take_scratch(workers), ws.take_scratch(workers * np))
        };
        {
            let lptr = SendPtr(loss_parts.as_mut_ptr());
            let gptr = SendPtr(grad_parts.as_mut_ptr());
            parallel::par_map(workers, |w| {
                let start = w * chunk;
                let end = ((w + 1) * chunk).min(n);
                // SAFETY: worker index `w` owns loss entry `w` and gradient
                // block `w` exclusively; both flat buffers outlive the
                // dispatch.
                let grad_out = unsafe {
                    std::slice::from_raw_parts_mut(gptr.get().add(w * np), np)
                };
                let l = chunk_loss_grad_into(&ctx, theta, x_int, x_bnd, start, end, grad_out);
                // SAFETY: same disjointness — loss slot `w` is written by
                // this worker only, and the buffer outlives the dispatch.
                unsafe { *lptr.get().add(w) = l };
            });
        }
        // Fixed chunk-order reduction — the exact f64 sequence of the
        // previous per-chunk-Vec implementation.
        let mut grad = vec![0.0; np]; // lint: allow(alloc) — returned gradient, owned by caller
        let mut loss = 0.0;
        for k in 0..workers {
            loss += loss_parts[k];
            for (total, gi) in grad.iter_mut().zip(&grad_parts[k * np..(k + 1) * np]) {
                *total += gi;
            }
        }
        {
            let mut ws = self.lock_scratch();
            ws.recycle(loss_parts);
            ws.recycle(grad_parts);
        }
        Ok((0.5 * loss, grad))
    }

    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)> {
        let ctx = Ctx::new(p, self.numerics)?;
        ctx.check_inputs(theta, x_int, x_bnd)?;
        let n = ctx.n_int + ctx.n_bnd;
        let np = ctx.n_params;
        // Zero-filled pooled storage: the reverse pass accumulates (+=)
        // into its row.
        let mut j = ws.take_matrix(n, np);
        let mut r = vec![0.0; n]; // lint: allow(alloc) — returned residual, owned by caller
        {
            let jptr = SendPtr(j.data_mut().as_mut_ptr());
            let rptr = SendPtr(r.as_mut_ptr());
            parallel::par_chunks(n, |start, end| {
                // SAFETY: chunks are disjoint, so each chunk's row-block of
                // J and residual range of r are written by exactly one
                // thread; both buffers outlive the dispatch.
                let (r_sub, j_sub) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(rptr.get().add(start), end - start),
                        std::slice::from_raw_parts_mut(
                            jptr.get().add(start * np),
                            (end - start) * np,
                        ),
                    )
                };
                rows_into(&ctx, theta, x_int, x_bnd, start, end, r_sub, j_sub);
            });
        }
        Ok((r, j))
    }

    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>> {
        let ctx = Ctx::new(p, self.numerics)?;
        ensure!(
            theta.len() == ctx.n_params,
            "θ has {} params, problem wants {}",
            theta.len(),
            ctx.n_params
        );
        ensure!(
            x_eval.len() % ctx.dim == 0,
            "evaluation set length {} is not a multiple of dim {}",
            x_eval.len(),
            ctx.dim
        );
        let m = x_eval.len() / ctx.dim;
        let mut out = vec![0.0; m];
        {
            let optr = SendPtr(out.as_mut_ptr());
            parallel::par_chunks(m, |start, end| {
                // SAFETY: disjoint chunks — each prediction range is
                // written by exactly one thread; `out` outlives the
                // dispatch.
                let sub = unsafe {
                    std::slice::from_raw_parts_mut(optr.get().add(start), end - start)
                };
                u_pred_into(&ctx, theta, x_eval, start, end, sub);
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::{builtin_problem, init_params, mlp_forward};
    use crate::rng::Rng;

    #[test]
    fn u_pred_matches_mlp_oracle() {
        let be = NativeBackend::new();
        let p = be.problem("poisson2d").unwrap();
        let mut rng = Rng::seed_from(42);
        let theta = init_params(&p.arch, &mut rng);
        let mut xs = vec![0.0; 33 * p.dim];
        rng.fill_uniform(&mut xs, 0.0, 1.0);
        let u = be.u_pred(&p, &theta, &xs).unwrap();
        for (i, x) in xs.chunks_exact(p.dim).enumerate() {
            let want = mlp_forward(&theta, &p.arch, x);
            assert!((u[i] - want).abs() < 1e-13, "point {i}: {} vs {want}", u[i]);
        }
    }

    #[test]
    fn loss_is_half_residual_norm() {
        let be = NativeBackend::new();
        let p = be.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(3);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64; // alternate the two 1d boundary points
        }
        let mut ws = Workspace::new();
        let (r, _j) = be.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        let want = 0.5 * r.iter().map(|x| x * x).sum::<f64>();
        let loss = be.loss(&p, &theta, &xi, &xb).unwrap();
        assert!(
            (loss - want).abs() < 1e-12 * (1.0 + want),
            "loss {loss} vs ½‖r‖² {want}"
        );
    }

    #[test]
    fn grad_matches_jacobian_transpose_times_r() {
        let be = NativeBackend::new();
        let p = builtin_problem("poisson2d").unwrap();
        let mut rng = Rng::seed_from(17);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        rng.fill_uniform(&mut xb, 0.0, 1.0);
        for row in xb.chunks_exact_mut(p.dim) {
            row[0] = 0.0;
        }
        let mut ws = Workspace::new();
        let (r, j) = be.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        let want = j.tr_matvec(&r);
        let (loss, grad) = be.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        assert!(loss.is_finite());
        let scale = want.iter().fold(0.0f64, |m, x| m.max(x.abs())).max(1.0);
        for (a, b) in grad.iter().zip(&want) {
            assert!((a - b).abs() < 1e-10 * scale, "{a} vs {b}");
        }
    }

    #[test]
    fn heat_operator_runs_end_to_end() {
        let be = NativeBackend::new();
        let p = be.problem("heat2d").unwrap();
        let mut rng = Rng::seed_from(5);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        rng.fill_uniform(&mut xb, 0.0, 1.0);
        let mut ws = Workspace::new();
        let (r, j) = be.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        assert_eq!(r.len(), p.n_total());
        assert_eq!((j.rows(), j.cols()), (p.n_total(), p.n_params));
        assert!(r.iter().all(|x| x.is_finite()));
        assert!(j.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn jacobian_storage_is_pooled_across_calls() {
        let be = NativeBackend::new();
        let p = be.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(9);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        rng.fill_uniform(&mut xb, 0.0, 1.0);
        let mut ws = Workspace::new();
        let (_r, j) = be.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        ws.recycle_matrix(j);
        let fresh = ws.stats().fresh_allocs;
        let (_r, j) = be.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        ws.recycle_matrix(j);
        assert_eq!(ws.stats().fresh_allocs, fresh, "second J must reuse the pool");
    }

    #[test]
    fn fast_mode_matches_bitwise_within_tolerance() {
        // End-to-end cross-tier check on a real problem: loss, gradient,
        // residuals, and Jacobian agree to rounding-level tolerance
        // (explicit modes on both sides so the test is meaningful under
        // the CI `ENGD_NUMERICS=fast` jobs too).
        let bit = NativeBackend::with_numerics(NumericsMode::Bitwise);
        let fast = NativeBackend::with_numerics(NumericsMode::Fast);
        let p = bit.problem("poisson2d").unwrap();
        let mut rng = Rng::seed_from(23);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        rng.fill_uniform(&mut xb, 0.0, 1.0);
        for row in xb.chunks_exact_mut(p.dim) {
            row[0] = 0.0;
        }
        let close = |a: f64, b: f64, scale: f64| (a - b).abs() <= 1e-9 * scale.max(1e-12);
        let la = bit.loss(&p, &theta, &xi, &xb).unwrap();
        let lb = fast.loss(&p, &theta, &xi, &xb).unwrap();
        assert!(close(la, lb, la.abs()), "loss {la} (bitwise) vs {lb} (fast)");
        let (_, ga) = bit.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        let (_, gb) = fast.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        let gscale = ga.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        for (k, (a, b)) in ga.iter().zip(&gb).enumerate() {
            assert!(close(*a, *b, gscale), "grad[{k}]: {a} vs {b}");
        }
        let mut ws = Workspace::new();
        let (ra, ja) = bit.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        let (rb, jb) = fast.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
        let rscale = ra.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        for (k, (a, b)) in ra.iter().zip(&rb).enumerate() {
            assert!(close(*a, *b, rscale), "r[{k}]: {a} vs {b}");
        }
        let jscale = ja.data().iter().fold(0.0f64, |m, x| m.max(x.abs()));
        for (k, (a, b)) in ja.data().iter().zip(jb.data()).enumerate() {
            assert!(close(*a, *b, jscale), "J elem {k}: {a} vs {b}");
        }
    }

    #[test]
    fn interleaving_modes_rekeys_worker_tapes() {
        // Worker scratch slots are keyed by (arch, mask, mode): alternating
        // backends of different modes on the same thread pool must rebuild
        // tapes rather than silently reusing the other tier's — checked by
        // bitwise-mode results staying bitwise-stable across the
        // interleaving.
        let bit = NativeBackend::with_numerics(NumericsMode::Bitwise);
        let fast = NativeBackend::with_numerics(NumericsMode::Fast);
        let p = bit.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(31);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let l1 = bit.loss(&p, &theta, &xi, &xb).unwrap();
        let lf1 = fast.loss(&p, &theta, &xi, &xb).unwrap();
        let l2 = bit.loss(&p, &theta, &xi, &xb).unwrap();
        let lf2 = fast.loss(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits(), "bitwise loss drifted across interleaving");
        assert_eq!(lf1.to_bits(), lf2.to_bits(), "fast loss is deterministic per tier");
        assert!((l1 - lf1).abs() <= 1e-9 * l1.abs().max(1.0));
    }
}
