//! [`Evaluator`] over the PJRT artifact runtime.
//!
//! Thin adapter: every method is one artifact execution with the manifest-
//! declared signature (`python/compile/aot.py` lowers them). Semantics are
//! unchanged from the pre-trait runtime — the trait only names the calls.

use anyhow::Result;

use super::Evaluator;
use crate::linalg::{Matrix, Workspace};
use crate::pde::ProblemSpec;
use crate::runtime::Runtime;

impl Evaluator for Runtime {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn problem(&self, name: &str) -> Result<ProblemSpec> {
        Ok(self.manifest().problem(name)?.clone())
    }

    fn problem_names(&self) -> Vec<String> {
        self.manifest().problems.keys().cloned().collect()
    }

    fn loss(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<f64> {
        let art = self.artifact(&p.name, "loss")?;
        Ok(art.call(&[theta, x_int, x_bnd])?[0][0])
    }

    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)> {
        let art = self.artifact(&p.name, "grad")?;
        let mut out = art.call(&[theta, x_int, x_bnd])?;
        let g = out.pop().expect("grad output");
        let l = out.pop().expect("loss output")[0];
        Ok((l, g))
    }

    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        _ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)> {
        // The artifact hands back freshly transferred buffers; J wraps the
        // transfer directly (no pooled copy would save anything here).
        let art = self.artifact(&p.name, "residuals_jacobian")?;
        let mut out = art.call(&[theta, x_int, x_bnd])?;
        let j = out.pop().expect("jacobian output");
        let r = out.pop().expect("r output");
        Ok((r, Matrix::from_vec(p.n_total(), p.n_params, j)))
    }

    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>> {
        let art = self.artifact(&p.name, "u_pred")?;
        let mut out = art.call(&[theta, x_eval])?;
        Ok(out.pop().expect("u_pred output"))
    }

    fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.borrow()
    }

    fn as_pjrt(&self) -> Option<&Runtime> {
        Some(self)
    }
}
