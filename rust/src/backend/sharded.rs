//! A sharded [`Evaluator`]: the collocation batch split into contiguous
//! shards across inner evaluators.
//!
//! This is the batch-partitioned execution layout of Dual Natural Gradient
//! Descent (Jnini & Vella, 2025) and the randomized-NLA ENGD line (Bioli et
//! al., 2025) — per-sample residual/Jacobian work scales by splitting the
//! collocation batch across executors, while the kernel solve stays global.
//! Today the inner evaluators are in-process [`NativeBackend`] instances
//! dispatched on the [`crate::parallel`] worker pool; the shard protocol
//! (`NativeBackend::shard_*`) is shaped so the same composite can later
//! front per-process or per-device executors.
//!
//! ## Bitwise contract
//!
//! `ShardedEvaluator` results are **bitwise identical** to the unsharded
//! [`NativeBackend`] for any shard count, because nothing about the math
//! depends on the shard layout:
//!
//! * residuals, Jacobian rows, and predictions are pointwise — each shard
//!   computes its rows exactly as the unsharded backend would (through the
//!   same point-blocked tape kernels, whose lanes preserve the scalar
//!   per-point FP sequence) and writes them into disjoint ranges of the
//!   shared output (`Workspace`-pooled J, the residual vector, the
//!   prediction buffer);
//! * the loss / gradient reductions reuse the native backend's global
//!   chunk grid (`thread_chunks`, a pure function of `ENGD_THREADS` and
//!   the batch size): shards compute whole chunks' partials and the final
//!   sum runs over chunks in fixed order, so the f64 reduction sequence is
//!   byte-for-byte the unsharded one.
//!
//! `rust/tests/pool.rs` cross-checks all four evaluation entry points (and
//! a whole training trajectory) against the unsharded backend bitwise.

use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Result};

use super::native::{thread_chunks, NativeBackend, NumericsMode};
use super::Evaluator;
use crate::linalg::{Matrix, Workspace, WorkspaceStats};
use crate::parallel::{self, SendPtr};
use crate::pde::ProblemSpec;

/// Composite evaluator: `shards` inner native evaluators, each serving a
/// contiguous slice of every batch.
pub struct ShardedEvaluator {
    inner: Vec<NativeBackend>,
    /// Pooled storage for the reduction partials (per-chunk losses and the
    /// flat `chunks × n_params` gradient block): `Evaluator` methods take
    /// `&self`, so the pool sits behind a mutex. Steady-state loss/grad
    /// steps draw every partial buffer from here — the same
    /// zero-allocation contract the `Workspace` tests assert on the step
    /// pool (see `sharded_loss_grad_partials_are_pooled` in
    /// `rust/tests/pool.rs`).
    scratch: Mutex<Workspace>,
}

impl ShardedEvaluator {
    /// `shards` inner evaluators over the built-in problem catalogue
    /// (clamped to ≥ 1), in the `ENGD_NUMERICS`-requested numerics mode.
    /// `parallel::num_threads()` shards saturate the worker pool; more
    /// simply makes shards finer.
    pub fn new(shards: usize) -> Self {
        Self::build(shards, NativeBackend::new)
    }

    /// Built-in catalogue in an explicit numerics mode, threaded into
    /// every inner evaluator (the config/CLI path). Fast-mode shards stay
    /// bitwise-identical to the fast-mode unsharded backend — the fast
    /// kernels are per-point deterministic, so the shard protocol's
    /// chunk-grid argument is mode-independent.
    pub fn with_numerics(shards: usize, numerics: NumericsMode) -> Self {
        Self::build(shards, || NativeBackend::with_numerics(numerics))
    }

    /// Sharded evaluator over a custom problem set (tests).
    pub fn with_problems(problems: Vec<ProblemSpec>, shards: usize) -> Self {
        Self::build(shards, || NativeBackend::with_problems(problems.clone()))
    }

    /// Custom problem set in an explicit numerics mode (tests).
    pub fn with_problems_numerics(
        problems: Vec<ProblemSpec>,
        shards: usize,
        numerics: NumericsMode,
    ) -> Self {
        Self::build(shards, || {
            NativeBackend::with_problems_numerics(problems.clone(), numerics)
        })
    }

    fn build(shards: usize, mk: impl Fn() -> NativeBackend) -> Self {
        ShardedEvaluator {
            inner: (0..shards.max(1)).map(|_| mk()).collect(),
            scratch: Mutex::new(Workspace::new()),
        }
    }

    /// Number of shards the batch is split into.
    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    /// Allocation counters of the partial-buffer pool (tests assert
    /// `fresh_allocs` freezes after the first loss/grad evaluation).
    pub fn scratch_stats(&self) -> WorkspaceStats {
        self.lock_scratch().stats()
    }

    fn lock_scratch(&self) -> MutexGuard<'_, Workspace> {
        self.scratch.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    /// Contiguous, balanced range of work units owned by shard `s`.
    fn shard_range(units: usize, shards: usize, s: usize) -> (usize, usize) {
        (units * s / shards, units * (s + 1) / shards)
    }

    /// Dispatch `f(shard, lo, hi)` for every shard's slice of `units` work
    /// units across the pool, surfacing the first shard failure (if any).
    fn for_shards(
        &self,
        units: usize,
        f: impl Fn(usize, usize, usize) -> Result<()> + Sync,
    ) -> Result<()> {
        let shards = self.inner.len();
        let failures = parallel::par_map(shards, |s| {
            let (lo, hi) = Self::shard_range(units, shards, s);
            f(s, lo, hi).err().map(|e| format!("shard {s}: {e:#}"))
        });
        if let Some(msg) = failures.into_iter().flatten().next() {
            bail!("{msg}");
        }
        Ok(())
    }
}

impl Evaluator for ShardedEvaluator {
    fn backend_name(&self) -> &'static str {
        "sharded"
    }

    fn problem(&self, name: &str) -> Result<ProblemSpec> {
        self.inner[0].problem(name)
    }

    fn problem_names(&self) -> Vec<String> {
        self.inner[0].problem_names()
    }

    fn loss(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<f64> {
        let n = p.n_total();
        let (chunks, _) = thread_chunks(n);
        // Scratch is fine uninitialized: the shard ranges tile `0..chunks`,
        // so every entry is overwritten before the reduction reads it. The
        // pool lock covers only the checkout/check-in bookkeeping — the
        // buffer is owned across the dispatch, so concurrent evaluations
        // don't serialize on the mutex.
        let mut partials = self.lock_scratch().take_scratch(chunks);
        let dispatched = {
            let pptr = SendPtr(partials.as_mut_ptr());
            self.for_shards(chunks, |s, c0, c1| {
                // SAFETY: shards own disjoint chunk ranges of `partials`,
                // which outlives the dispatch.
                let out = unsafe {
                    std::slice::from_raw_parts_mut(pptr.get().add(c0), c1 - c0)
                };
                self.inner[s].shard_loss_partials(p, theta, x_int, x_bnd, c0, c1, out)
            })
        };
        // Fixed chunk order — the unsharded backend's exact reduction
        // (skipped on dispatch failure: the buffer may hold stale pool
        // contents where the failed shard never wrote).
        let loss = if dispatched.is_ok() {
            0.5 * partials.iter().sum::<f64>()
        } else {
            f64::NAN
        };
        self.lock_scratch().recycle(partials);
        dispatched?;
        Ok(loss)
    }

    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)> {
        let n = p.n_total();
        let np = p.n_params;
        let (chunks, _) = thread_chunks(n);
        // Pooled flat partials: one loss entry and one contiguous P-long
        // gradient block per reduction chunk, drawn from the scratch pool
        // instead of `chunks` fresh `Vec`s per call. The inner shard calls
        // overwrite every entry (gradient blocks are zeroed by
        // `chunk_loss_grad_into`), so scratch is fine uninitialized; the
        // pool lock is held only for checkout/check-in, not the dispatch.
        let (mut loss_parts, mut grad_parts) = {
            let mut ws = self.lock_scratch();
            (ws.take_scratch(chunks), ws.take_scratch(chunks * np))
        };
        let dispatched = {
            let lptr = SendPtr(loss_parts.as_mut_ptr());
            let gptr = SendPtr(grad_parts.as_mut_ptr());
            self.for_shards(chunks, |s, c0, c1| {
                // SAFETY: disjoint chunk ranges per shard (see `loss`) of
                // both flat buffers; both outlive the dispatch.
                let (loss_out, grad_out) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(lptr.get().add(c0), c1 - c0),
                        std::slice::from_raw_parts_mut(
                            gptr.get().add(c0 * np),
                            (c1 - c0) * np,
                        ),
                    )
                };
                self.inner[s].shard_loss_grad_partials(
                    p, theta, x_int, x_bnd, c0, c1, loss_out, grad_out,
                )
            })
        };
        // Fixed chunk order over the flat blocks — byte-for-byte the
        // unsharded backend's reduction sequence.
        let mut grad = vec![0.0; np];
        let mut loss = 0.0;
        if dispatched.is_ok() {
            for k in 0..chunks {
                loss += loss_parts[k];
                for (total, gi) in grad.iter_mut().zip(&grad_parts[k * np..(k + 1) * np]) {
                    *total += gi;
                }
            }
        }
        {
            let mut ws = self.lock_scratch();
            ws.recycle(loss_parts);
            ws.recycle(grad_parts);
        }
        dispatched?;
        Ok((0.5 * loss, grad))
    }

    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)> {
        let n = p.n_total();
        let np = p.n_params;
        // One shared output: shards write disjoint Jacobian row-blocks and
        // residual ranges straight into the pooled storage.
        let mut j = ws.take_matrix(n, np);
        let mut r = vec![0.0; n];
        {
            let jptr = SendPtr(j.data_mut().as_mut_ptr());
            let rptr = SendPtr(r.as_mut_ptr());
            self.for_shards(n, |s, row0, row1| {
                // SAFETY: shards own disjoint row ranges of J and r; both
                // buffers outlive the dispatch.
                let (r_out, j_out) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(rptr.get().add(row0), row1 - row0),
                        std::slice::from_raw_parts_mut(
                            jptr.get().add(row0 * np),
                            (row1 - row0) * np,
                        ),
                    )
                };
                self.inner[s].shard_rows_into(p, theta, x_int, x_bnd, row0, row1, r_out, j_out)
            })?;
        }
        Ok((r, j))
    }

    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>> {
        let m = x_eval.len() / p.dim.max(1);
        let mut out = vec![0.0; m];
        {
            let optr = SendPtr(out.as_mut_ptr());
            self.for_shards(m, |s, i0, i1| {
                // SAFETY: disjoint prediction ranges per shard.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(optr.get().add(i0), i1 - i0)
                };
                self.inner[s].shard_u_pred_into(p, theta, x_eval, i0, i1, slice)
            })?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::init_params;
    use crate::rng::Rng;

    #[test]
    fn shard_ranges_cover_and_balance() {
        for units in [0usize, 1, 5, 17, 64, 100] {
            for shards in [1usize, 2, 3, 7, 16] {
                let mut next = 0;
                for s in 0..shards {
                    let (lo, hi) = ShardedEvaluator::shard_range(units, shards, s);
                    assert_eq!(lo, next, "gap at shard {s} ({units} units, {shards} shards)");
                    assert!(hi >= lo);
                    assert!(hi - lo <= units.div_ceil(shards), "imbalanced shard {s}");
                    next = hi;
                }
                assert_eq!(next, units);
            }
        }
    }

    #[test]
    fn sharded_loss_matches_native_bitwise_smoke() {
        // The full cross-check matrix lives in rust/tests/pool.rs; this is
        // the in-module smoke version on one problem.
        let native = NativeBackend::new();
        let sharded = ShardedEvaluator::new(3);
        let p = native.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(11);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let a = native.loss(&p, &theta, &xi, &xb).unwrap();
        let b = sharded.loss(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
    }

    #[test]
    fn fast_mode_sharded_matches_fast_native_bitwise() {
        // The shard == unsharded identity is mode-independent: fast
        // kernels are per-point deterministic and the reduction reuses the
        // same chunk grid, so fast-sharded equals fast-native bit-for-bit.
        let native = NativeBackend::with_numerics(NumericsMode::Fast);
        let sharded = ShardedEvaluator::with_numerics(3, NumericsMode::Fast);
        let p = native.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(13);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let a = native.loss(&p, &theta, &xi, &xb).unwrap();
        let b = sharded.loss(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        let (la, ga) = native.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        let (lb, gb) = sharded.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits());
        for (x, y) in ga.iter().zip(&gb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
