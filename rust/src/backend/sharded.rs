//! A sharded [`Evaluator`]: the collocation batch split into shards across
//! inner evaluators, assigned by a work-stealing range scheduler.
//!
//! This is the batch-partitioned execution layout of Dual Natural Gradient
//! Descent (Jnini & Vella, 2025) and the randomized-NLA ENGD line (Bioli et
//! al., 2025) — per-sample residual/Jacobian work scales by splitting the
//! collocation batch across executors, while the kernel solve stays global.
//! Here the inner evaluators are in-process [`NativeBackend`] instances
//! dispatched on the [`crate::parallel`] worker pool; the same shard
//! protocol (`NativeBackend::shard_*`) and the same scheduler back the
//! out-of-process tier in [`crate::backend::process`].
//!
//! ## Bitwise contract
//!
//! `ShardedEvaluator` results are **bitwise identical** to the unsharded
//! [`NativeBackend`] for any shard count and either [`Schedule`], because
//! nothing about the math depends on which shard computes which range:
//!
//! * residuals, Jacobian rows, and predictions are pointwise — each range
//!   is computed exactly as the unsharded backend would compute those rows
//!   (through the same point-blocked tape kernels, whose lanes preserve the
//!   scalar per-point FP sequence) and lands in its deterministic slot of
//!   the shared output (`Workspace`-pooled J, the residual vector, the
//!   prediction buffer) regardless of completion order;
//! * the loss / gradient reductions reuse the native backend's global
//!   chunk grid (`thread_chunks`, a pure function of `ENGD_THREADS` and
//!   the batch size): ranges are measured in whole chunks and the final
//!   sum runs over chunks in fixed order, so the f64 reduction sequence is
//!   byte-for-byte the unsharded one.
//!
//! ## Range scheduling
//!
//! Static contiguous splits are straggler-bound on non-uniform batches
//! (boundary rows are far cheaper than interior rows; mixed-operator
//! batches differ per range). [`RangeQueue`] therefore cuts each shard's
//! contiguous slice into [`OVERSUB`] sub-ranges and lets idle shards steal
//! from the busiest peer once their own slice is drained
//! ([`Schedule::WorkSteal`], the default; `ENGD_SHARD_SCHEDULE=static`
//! restores the old layout for A/B runs — `benches/shard_scale.rs` measures
//! the gap). [`SchedState`] counts ranges/steals/requeues and per-shard
//! busy time; the trainer surfaces the per-step deltas as CSV extras.
//!
//! `rust/tests/pool.rs` cross-checks all four evaluation entry points (and
//! a whole training trajectory) against the unsharded backend bitwise;
//! `rust/tests/process.rs` extends the same matrix to worker processes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::native::{thread_chunks, NativeBackend, NumericsMode};
use super::Evaluator;
use crate::linalg::{Matrix, Workspace, WorkspaceStats};
use crate::parallel::{self, SendPtr};
use crate::pde::ProblemSpec;

/// Sub-ranges per shard under [`Schedule::WorkSteal`]: enough slack for
/// idle shards to steal, coarse enough that per-range overhead (context
/// setup in-process, a frame round-trip out-of-process) stays negligible.
pub(crate) const OVERSUB: usize = 4;

/// Work-assignment policy shared by the thread tier ([`ShardedEvaluator`])
/// and the process tier ([`crate::backend::process::ProcessEvaluator`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// One contiguous range per shard — the pre-scheduler layout, kept for
    /// A/B benchmarking (`benches/shard_scale.rs`).
    Static,
    /// Each shard's slice is cut into [`OVERSUB`] sub-ranges on a shared
    /// queue; a shard that drains its own slice steals from the busiest
    /// peer. Output slots are fixed per range, so results are bitwise
    /// independent of the assignment.
    WorkSteal,
}

impl Schedule {
    /// Policy requested by `ENGD_SHARD_SCHEDULE` (`static` | `steal`),
    /// defaulting to work stealing.
    pub fn from_env() -> Self {
        match crate::config::envvars::read("ENGD_SHARD_SCHEDULE").as_deref() {
            Some("static") => Schedule::Static,
            _ => Schedule::WorkSteal,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Static => "static",
            Schedule::WorkSteal => "steal",
        }
    }
}

/// Contiguous, balanced slice of `units` work units owned by shard `s`.
pub(crate) fn split_range(units: usize, shards: usize, s: usize) -> (usize, usize) {
    (units * s / shards, units * (s + 1) / shards)
}

/// Shared per-evaluation range queue: one FIFO of `(lo, hi)` sub-ranges per
/// home shard, cut from the shard's static slice. `pop_for(s)` serves shard
/// `s` its own ranges first; under [`Schedule::WorkSteal`] it then steals
/// the tail of the fullest peer queue. The supervisor requeues a crashed
/// worker's in-flight range at the front of its home queue so any live
/// shard picks it up.
pub(crate) struct RangeQueue {
    queues: Mutex<Vec<VecDeque<(usize, usize)>>>,
    steal: bool,
    poisoned: AtomicBool,
}

impl RangeQueue {
    pub(crate) fn new(units: usize, shards: usize, schedule: Schedule) -> Self {
        let oversub = match schedule {
            Schedule::Static => 1,
            Schedule::WorkSteal => OVERSUB,
        };
        let mut queues = vec![VecDeque::new(); shards];
        for (s, q) in queues.iter_mut().enumerate() {
            let (lo, hi) = split_range(units, shards, s);
            let len = hi - lo;
            let subs = oversub.min(len.max(1));
            for k in 0..subs {
                let a = lo + len * k / subs;
                let b = lo + len * (k + 1) / subs;
                if a < b {
                    q.push_back((a, b));
                }
            }
        }
        RangeQueue {
            queues: Mutex::new(queues),
            steal: schedule == Schedule::WorkSteal,
            poisoned: AtomicBool::new(false),
        }
    }

    /// Next range for shard `s` as `(lo, hi, stolen)`, or `None` when
    /// nothing is available to it (drained, or static mode with its own
    /// slice done, or the queue is poisoned).
    pub(crate) fn pop_for(&self, s: usize) -> Option<(usize, usize, bool)> {
        if self.is_poisoned() {
            return None;
        }
        let mut qs = self.queues.lock().unwrap_or_else(|p| p.into_inner());
        if let Some((lo, hi)) = qs[s].pop_front() {
            return Some((lo, hi, false));
        }
        if !self.steal {
            return None;
        }
        // Steal from the back of the fullest peer queue: the tail of a
        // contiguous slice is the work its owner is furthest from.
        let victim = (0..qs.len())
            .filter(|&v| v != s && !qs[v].is_empty())
            .max_by_key(|&v| qs[v].len())?;
        qs[victim].pop_back().map(|(lo, hi)| (lo, hi, true))
    }

    /// Put a failed worker's in-flight range back at the front of its home
    /// queue, ahead of untouched work.
    pub(crate) fn requeue(&self, home: usize, lo: usize, hi: usize) {
        let mut qs = self.queues.lock().unwrap_or_else(|p| p.into_inner());
        qs[home].push_front((lo, hi));
    }

    /// Stop handing out ranges (a shard hit a deterministic error — every
    /// peer would hit it too).
    pub(crate) fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    pub(crate) fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }
}

/// Cumulative scheduler counters, shared by both executor tiers. Snapshots
/// surface through [`Evaluator::sched_stats`]; the trainer logs per-step
/// deltas to the metrics CSV.
pub(crate) struct SchedState {
    busy_us: Vec<AtomicU64>,
    ranges: AtomicU64,
    steals: AtomicU64,
    requeues: AtomicU64,
    respawns: AtomicU64,
}

impl SchedState {
    pub(crate) fn new(shards: usize) -> Self {
        SchedState {
            busy_us: (0..shards).map(|_| AtomicU64::new(0)).collect(),
            ranges: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            requeues: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
        }
    }

    pub(crate) fn note_range(&self, stolen: bool) {
        self.ranges.fetch_add(1, Ordering::Relaxed);
        if stolen {
            self.steals.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub(crate) fn note_requeue(&self) {
        self.requeues.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_respawn(&self) {
        self.respawns.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add_busy(&self, s: usize, d: Duration) {
        self.busy_us[s].fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    pub(crate) fn snapshot(&self) -> SchedSnapshot {
        SchedSnapshot {
            shard_busy_s: self
                .busy_us
                .iter()
                .map(|us| us.load(Ordering::Relaxed) as f64 * 1e-6)
                .collect(),
            ranges: self.ranges.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            requeues: self.requeues.load(Ordering::Relaxed),
            respawns: self.respawns.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of a shard executor's scheduler counters (cumulative
/// since construction). `delta_since` turns two snapshots into the
/// per-step numbers the metrics CSV records.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedSnapshot {
    /// Per-shard busy wall time in seconds (dispatch-loop time: compute
    /// plus, for the process tier, frame I/O).
    pub shard_busy_s: Vec<f64>,
    /// Ranges served (a static schedule serves exactly one per shard per
    /// evaluation; work stealing serves up to `OVERSUB×` as many).
    pub ranges: u64,
    /// Ranges a shard pulled from another shard's queue.
    pub steals: u64,
    /// In-flight ranges returned to the queue after a worker died or hit
    /// its reply deadline (process tier only).
    pub requeues: u64,
    /// Worker processes restarted after a crash/hang (process tier only).
    pub respawns: u64,
}

impl SchedSnapshot {
    /// Counter increments between `prev` (earlier) and `self` (later).
    pub fn delta_since(&self, prev: &SchedSnapshot) -> SchedSnapshot {
        SchedSnapshot {
            shard_busy_s: self
                .shard_busy_s
                .iter()
                .enumerate()
                .map(|(i, s)| (s - prev.shard_busy_s.get(i).copied().unwrap_or(0.0)).max(0.0))
                .collect(),
            ranges: self.ranges.saturating_sub(prev.ranges),
            steals: self.steals.saturating_sub(prev.steals),
            requeues: self.requeues.saturating_sub(prev.requeues),
            respawns: self.respawns.saturating_sub(prev.respawns),
        }
    }
}

/// Composite evaluator: `shards` inner native evaluators serving ranges of
/// every batch from a shared [`RangeQueue`].
pub struct ShardedEvaluator {
    inner: Vec<NativeBackend>,
    schedule: Schedule,
    sched: SchedState,
    /// Pooled storage for the reduction partials (per-chunk losses and the
    /// flat `chunks × n_params` gradient block): `Evaluator` methods take
    /// `&self`, so the pool sits behind a mutex. Steady-state loss/grad
    /// steps draw every partial buffer from here — the same
    /// zero-allocation contract the `Workspace` tests assert on the step
    /// pool (see `sharded_loss_grad_partials_are_pooled` in
    /// `rust/tests/pool.rs`).
    scratch: Mutex<Workspace>,
}

impl ShardedEvaluator {
    /// `shards` inner evaluators over the built-in problem catalogue, in
    /// the `ENGD_NUMERICS`-requested numerics mode.
    /// `parallel::num_threads()` shards saturate the worker pool; more
    /// simply makes shards finer.
    ///
    /// Panics if `shards == 0` — the config layer
    /// (`crate::backend::validate_backend`) rejects `sharded:0` before it
    /// can reach here.
    pub fn new(shards: usize) -> Self {
        Self::build(shards, NativeBackend::new)
    }

    /// Built-in catalogue in an explicit numerics mode, threaded into
    /// every inner evaluator (the config/CLI path). Fast-mode shards stay
    /// bitwise-identical to the fast-mode unsharded backend — the fast
    /// kernels are per-point deterministic, so the shard protocol's
    /// chunk-grid argument is mode-independent.
    pub fn with_numerics(shards: usize, numerics: NumericsMode) -> Self {
        Self::build(shards, || NativeBackend::with_numerics(numerics))
    }

    /// Sharded evaluator over a custom problem set (tests).
    pub fn with_problems(problems: Vec<ProblemSpec>, shards: usize) -> Self {
        Self::build(shards, || NativeBackend::with_problems(problems.clone()))
    }

    /// Custom problem set in an explicit numerics mode (tests).
    pub fn with_problems_numerics(
        problems: Vec<ProblemSpec>,
        shards: usize,
        numerics: NumericsMode,
    ) -> Self {
        Self::build(shards, || {
            NativeBackend::with_problems_numerics(problems.clone(), numerics)
        })
    }

    /// Replace the `ENGD_SHARD_SCHEDULE` default with an explicit policy
    /// (benchmarks and A/B tests).
    pub fn with_schedule(mut self, schedule: Schedule) -> Self {
        self.schedule = schedule;
        self
    }

    fn build(shards: usize, mk: impl Fn() -> NativeBackend) -> Self {
        assert!(shards > 0, "ShardedEvaluator needs at least one shard (got 0)");
        ShardedEvaluator {
            inner: (0..shards).map(|_| mk()).collect(),
            schedule: Schedule::from_env(),
            sched: SchedState::new(shards),
            scratch: Mutex::new(Workspace::new()),
        }
    }

    /// Number of shards the batch is split into.
    pub fn shards(&self) -> usize {
        self.inner.len()
    }

    /// Active work-assignment policy.
    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    /// Allocation counters of the partial-buffer pool (tests assert
    /// `fresh_allocs` freezes after the first loss/grad evaluation).
    pub fn scratch_stats(&self) -> WorkspaceStats {
        self.lock_scratch().stats()
    }

    fn lock_scratch(&self) -> MutexGuard<'_, Workspace> {
        self.scratch.lock().unwrap_or_else(|poison| poison.into_inner())
    }

    /// Dispatch `f(shard, lo, hi)` over `units` work units across the
    /// pool: every shard loops on the shared [`RangeQueue`] until it (and,
    /// under work stealing, everyone's) slice is drained. The first shard
    /// failure poisons the queue and is surfaced after the join.
    fn for_shards(
        &self,
        units: usize,
        f: impl Fn(usize, usize, usize) -> Result<()> + Sync,
    ) -> Result<()> {
        let shards = self.inner.len();
        let queue = RangeQueue::new(units, shards, self.schedule);
        let failures = parallel::par_map(shards, |s| {
            let t0 = Instant::now();
            let mut err = None;
            while let Some((lo, hi, stolen)) = queue.pop_for(s) {
                self.sched.note_range(stolen);
                if let Err(e) = f(s, lo, hi) {
                    queue.poison();
                    err = Some(format!("shard {s} (range [{lo}, {hi})): {e:#}"));
                    break;
                }
            }
            self.sched.add_busy(s, t0.elapsed());
            err
        });
        if let Some(msg) = failures.into_iter().flatten().next() {
            bail!("{msg}");
        }
        Ok(())
    }
}

impl Evaluator for ShardedEvaluator {
    fn backend_name(&self) -> &'static str {
        "sharded"
    }

    fn problem(&self, name: &str) -> Result<ProblemSpec> {
        self.inner[0].problem(name)
    }

    fn problem_names(&self) -> Vec<String> {
        self.inner[0].problem_names()
    }

    fn sched_stats(&self) -> Option<super::SchedSnapshot> {
        Some(self.sched.snapshot())
    }

    fn loss(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<f64> {
        let n = p.n_total();
        let (chunks, _) = thread_chunks(n);
        // Scratch is fine uninitialized: the queued ranges tile `0..chunks`,
        // so every entry is overwritten before the reduction reads it. The
        // pool lock covers only the checkout/check-in bookkeeping — the
        // buffer is owned across the dispatch, so concurrent evaluations
        // don't serialize on the mutex.
        let mut partials = self.lock_scratch().take_scratch(chunks);
        let dispatched = {
            let pptr = SendPtr(partials.as_mut_ptr());
            self.for_shards(chunks, |s, c0, c1| {
                // SAFETY: queued chunk ranges are disjoint and `partials`
                // outlives the dispatch.
                let out = unsafe {
                    std::slice::from_raw_parts_mut(pptr.get().add(c0), c1 - c0)
                };
                self.inner[s].shard_loss_partials(p, theta, x_int, x_bnd, c0, c1, out)
            })
        };
        // Fixed chunk order — the unsharded backend's exact reduction
        // (skipped on dispatch failure: the buffer may hold stale pool
        // contents where the failed shard never wrote).
        let loss = if dispatched.is_ok() {
            0.5 * partials.iter().sum::<f64>()
        } else {
            f64::NAN
        };
        self.lock_scratch().recycle(partials);
        dispatched?;
        Ok(loss)
    }

    fn loss_and_grad(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
    ) -> Result<(f64, Vec<f64>)> {
        let n = p.n_total();
        let np = p.n_params;
        let (chunks, _) = thread_chunks(n);
        // Pooled flat partials: one loss entry and one contiguous P-long
        // gradient block per reduction chunk, drawn from the scratch pool
        // instead of `chunks` fresh `Vec`s per call. The inner shard calls
        // overwrite every entry (gradient blocks are zeroed by
        // `chunk_loss_grad_into`), so scratch is fine uninitialized; the
        // pool lock is held only for checkout/check-in, not the dispatch.
        let (mut loss_parts, mut grad_parts) = {
            let mut ws = self.lock_scratch();
            (ws.take_scratch(chunks), ws.take_scratch(chunks * np))
        };
        let dispatched = {
            let lptr = SendPtr(loss_parts.as_mut_ptr());
            let gptr = SendPtr(grad_parts.as_mut_ptr());
            self.for_shards(chunks, |s, c0, c1| {
                // SAFETY: disjoint chunk ranges per queued range (see
                // `loss`) of both flat buffers; both outlive the dispatch.
                let (loss_out, grad_out) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(lptr.get().add(c0), c1 - c0),
                        std::slice::from_raw_parts_mut(
                            gptr.get().add(c0 * np),
                            (c1 - c0) * np,
                        ),
                    )
                };
                self.inner[s].shard_loss_grad_partials(
                    p, theta, x_int, x_bnd, c0, c1, loss_out, grad_out,
                )
            })
        };
        // Fixed chunk order over the flat blocks — byte-for-byte the
        // unsharded backend's reduction sequence.
        let mut grad = vec![0.0; np]; // lint: allow(alloc) — returned gradient, owned by caller
        let mut loss = 0.0;
        if dispatched.is_ok() {
            for k in 0..chunks {
                loss += loss_parts[k];
                for (total, gi) in grad.iter_mut().zip(&grad_parts[k * np..(k + 1) * np]) {
                    *total += gi;
                }
            }
        }
        {
            let mut ws = self.lock_scratch();
            ws.recycle(loss_parts);
            ws.recycle(grad_parts);
        }
        dispatched?;
        Ok((0.5 * loss, grad))
    }

    fn residuals_jacobian(
        &self,
        p: &ProblemSpec,
        theta: &[f64],
        x_int: &[f64],
        x_bnd: &[f64],
        ws: &mut Workspace,
    ) -> Result<(Vec<f64>, Matrix)> {
        let n = p.n_total();
        let np = p.n_params;
        // One shared output: ranges land as disjoint Jacobian row-blocks
        // and residual slices straight in the pooled storage, whichever
        // shard served them.
        let mut j = ws.take_matrix(n, np);
        let mut r = vec![0.0; n]; // lint: allow(alloc) — returned residual, owned by caller
        let dispatched = {
            let jptr = SendPtr(j.data_mut().as_mut_ptr());
            let rptr = SendPtr(r.as_mut_ptr());
            self.for_shards(n, |s, row0, row1| {
                // SAFETY: queued row ranges are disjoint slices of J and r;
                // both buffers outlive the dispatch.
                let (r_out, j_out) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(rptr.get().add(row0), row1 - row0),
                        std::slice::from_raw_parts_mut(
                            jptr.get().add(row0 * np),
                            (row1 - row0) * np,
                        ),
                    )
                };
                self.inner[s].shard_rows_into(p, theta, x_int, x_bnd, row0, row1, r_out, j_out)
            })
        };
        if let Err(e) = dispatched {
            // A failed shard sweep must not strand the pooled Jacobian: the
            // evaluator (and its caller's Workspace) outlive this error
            // (engd-lint R6).
            ws.recycle_matrix(j);
            return Err(e);
        }
        Ok((r, j))
    }

    fn u_pred(&self, p: &ProblemSpec, theta: &[f64], x_eval: &[f64]) -> Result<Vec<f64>> {
        let m = x_eval.len() / p.dim.max(1);
        let mut out = vec![0.0; m];
        {
            let optr = SendPtr(out.as_mut_ptr());
            self.for_shards(m, |s, i0, i1| {
                // SAFETY: disjoint prediction ranges per queued range.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(optr.get().add(i0), i1 - i0)
                };
                self.inner[s].shard_u_pred_into(p, theta, x_eval, i0, i1, slice)
            })?;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pde::init_params;
    use crate::rng::Rng;

    /// Pop everything a queue will serve to shard `s` before moving on.
    fn drain(q: &RangeQueue, shards: usize) -> Vec<(usize, usize, bool)> {
        let mut got = Vec::new();
        for s in 0..shards {
            while let Some(r) = q.pop_for(s) {
                got.push(r);
            }
        }
        got
    }

    #[test]
    fn range_plans_tile_the_units() {
        for units in [0usize, 1, 5, 17, 64, 100, 1000] {
            for shards in [1usize, 2, 3, 7, 16] {
                for schedule in [Schedule::Static, Schedule::WorkSteal] {
                    let q = RangeQueue::new(units, shards, schedule);
                    let mut covered = vec![0u32; units];
                    for (lo, hi, _) in drain(&q, shards) {
                        assert!(lo < hi && hi <= units);
                        for c in &mut covered[lo..hi] {
                            *c += 1;
                        }
                    }
                    assert!(
                        covered.iter().all(|&c| c == 1),
                        "hole or overlap: {units} units, {shards} shards, {schedule:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn static_ranges_are_contiguous_and_balanced() {
        for units in [0usize, 1, 5, 17, 64, 100] {
            for shards in [1usize, 2, 3, 7, 16] {
                let mut next = 0;
                for s in 0..shards {
                    let (lo, hi) = split_range(units, shards, s);
                    assert_eq!(lo, next, "gap at shard {s} ({units} units, {shards} shards)");
                    assert!(hi >= lo);
                    assert!(hi - lo <= units.div_ceil(shards), "imbalanced shard {s}");
                    next = hi;
                }
                assert_eq!(next, units);
            }
        }
    }

    #[test]
    fn static_schedule_never_steals() {
        let q = RangeQueue::new(64, 4, Schedule::Static);
        // Shard 0's single contiguous range, then nothing — even though
        // shards 1..4 still have work queued.
        let (lo, hi, stolen) = q.pop_for(0).unwrap();
        assert_eq!((lo, hi, stolen), (0, 16, false));
        assert!(q.pop_for(0).is_none());
        assert!(q.pop_for(1).is_some());
    }

    #[test]
    fn work_stealing_drains_everything_through_one_shard() {
        let q = RangeQueue::new(64, 4, Schedule::WorkSteal);
        let mut own = 0;
        let mut stolen = 0;
        let mut covered = vec![0u32; 64];
        while let Some((lo, hi, s)) = q.pop_for(0) {
            if s {
                stolen += 1;
            } else {
                own += 1;
            }
            for c in &mut covered[lo..hi] {
                *c += 1;
            }
        }
        assert_eq!(own, OVERSUB);
        assert_eq!(stolen, 3 * OVERSUB, "shard 0 should steal every peer range");
        assert!(covered.iter().all(|&c| c == 1));
    }

    #[test]
    fn requeued_range_is_served_again_and_poison_stops_service() {
        let q = RangeQueue::new(8, 2, Schedule::WorkSteal);
        let (lo, hi, _) = q.pop_for(0).unwrap();
        q.requeue(0, lo, hi);
        assert_eq!(q.pop_for(0).unwrap(), (lo, hi, false));
        q.poison();
        assert!(q.pop_for(0).is_none());
        assert!(q.pop_for(1).is_none());
    }

    #[test]
    fn sched_snapshot_deltas_saturate() {
        let a = SchedSnapshot {
            shard_busy_s: vec![1.0, 2.0],
            ranges: 10,
            steals: 3,
            requeues: 1,
            respawns: 0,
        };
        let b = SchedSnapshot {
            shard_busy_s: vec![1.5, 2.25],
            ranges: 14,
            steals: 3,
            requeues: 2,
            respawns: 1,
        };
        let d = b.delta_since(&a);
        assert_eq!(d.ranges, 4);
        assert_eq!(d.steals, 0);
        assert_eq!(d.requeues, 1);
        assert_eq!(d.respawns, 1);
        assert!((d.shard_busy_s[0] - 0.5).abs() < 1e-12);
        // Deltas never go negative, and a missing prev shard reads as 0.
        assert_eq!(a.delta_since(&b).ranges, 0);
        assert_eq!(b.delta_since(&SchedSnapshot::default()).shard_busy_s.len(), 2);
    }

    #[test]
    fn sharded_loss_matches_native_bitwise_smoke() {
        // The full cross-check matrix lives in rust/tests/pool.rs; this is
        // the in-module smoke version on one problem, under both schedules.
        let native = NativeBackend::new();
        let p = native.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(11);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let a = native.loss(&p, &theta, &xi, &xb).unwrap();
        for schedule in [Schedule::Static, Schedule::WorkSteal] {
            let sharded = ShardedEvaluator::new(3).with_schedule(schedule);
            let b = sharded.loss(&p, &theta, &xi, &xb).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b} ({schedule:?})");
            let snap = sharded.sched_stats().unwrap();
            assert!(snap.ranges > 0);
            if schedule == Schedule::Static {
                assert_eq!(snap.steals, 0, "static schedule must not steal");
            }
            assert_eq!(snap.requeues + snap.respawns, 0);
        }
    }

    #[test]
    fn fast_mode_sharded_matches_fast_native_bitwise() {
        // The shard == unsharded identity is mode-independent: fast
        // kernels are per-point deterministic and the reduction reuses the
        // same chunk grid, so fast-sharded equals fast-native bit-for-bit.
        let native = NativeBackend::with_numerics(NumericsMode::Fast);
        let sharded = ShardedEvaluator::with_numerics(3, NumericsMode::Fast);
        let p = native.problem("poisson1d").unwrap();
        let mut rng = Rng::seed_from(13);
        let theta = init_params(&p.arch, &mut rng);
        let mut xi = vec![0.0; p.n_interior * p.dim];
        let mut xb = vec![0.0; p.n_boundary * p.dim];
        rng.fill_uniform(&mut xi, 0.0, 1.0);
        for (k, v) in xb.iter_mut().enumerate() {
            *v = (k % 2) as f64;
        }
        let a = native.loss(&p, &theta, &xi, &xb).unwrap();
        let b = sharded.loss(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        let (la, ga) = native.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        let (lb, gb) = sharded.loss_and_grad(&p, &theta, &xi, &xb).unwrap();
        assert_eq!(la.to_bits(), lb.to_bits());
        for (x, y) in ga.iter().zip(&gb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_panics() {
        let _ = ShardedEvaluator::new(0);
    }
}
