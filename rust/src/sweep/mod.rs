//! Random-search hyperparameter sweeps — the paper's tuning protocol
//! (Appendix A.1): log-uniform/uniform/choice spaces per optimizer, runs
//! ranked by the best relative L2 error on the fixed validation set.
//!
//! The search spaces below are the paper's *refined* (second-stage) spaces,
//! verbatim where given.

use anyhow::Result;

use crate::backend::Evaluator;
use crate::config::run::{BiasMode, ExecPath, OptimizerKind, SolveMode};
use crate::config::{OptimizerConfig, RunConfig};
use crate::coordinator::{train, TrainReport};
use crate::rng::Rng;

/// A sampled hyperparameter assignment with its run outcome.
#[derive(Debug, Clone)]
pub struct Trial {
    pub index: usize,
    pub optimizer: OptimizerConfig,
    pub report: TrainReport,
}

/// Sample one optimizer configuration from the paper's A.1 search space.
pub fn sample_config(kind: &OptimizerKind, base: &OptimizerConfig, rng: &mut Rng) -> OptimizerConfig {
    let mut o = base.clone();
    o.kind = kind.clone();
    match kind {
        OptimizerKind::Sgd => {
            // lr ∈ LU[1e-3, 1e-2], momentum ∈ {0, 0.3, 0.6, 0.9}
            o.lr = rng.log_uniform(1e-3, 1e-2);
            o.momentum = *rng.choice(&[0.0, 0.3, 0.6, 0.9]);
        }
        OptimizerKind::Adam => {
            // lr ∈ LU[1e-4, 5e-1]
            o.lr = rng.log_uniform(1e-4, 5e-1);
        }
        OptimizerKind::EngdDense => {
            // damping ∈ {1e-8..1e-12, 0}→(we keep >0 for the solver),
            // ema ∈ {0, 0.3, 0.6, 0.9}, identity init ∈ {no, yes}
            o.damping = *rng.choice(&[1e-8, 1e-9, 1e-10, 1e-11, 1e-12]);
            o.ema = *rng.choice(&[0.0, 0.3, 0.6, 0.9]);
            o.gramian_identity_init = rng.below(2) == 1;
            o.path = ExecPath::Decomposed;
        }
        OptimizerKind::EngdW => {
            // damping ∈ LU[1e-7, 1]; lr ∈ LU[1e-4, 1e-1] when fixed
            o.damping = rng.log_uniform(1e-7, 1.0);
            if !o.line_search {
                o.lr = rng.log_uniform(1e-4, 1e-1);
            }
            if o.solve != SolveMode::Exact {
                o.path = ExecPath::Decomposed;
            }
        }
        OptimizerKind::Spring => {
            // damping ∈ LU[1e-10, 1e-3]; momentum ∈ LU[0.6, 0.999]
            // (A.2.1 narrows momentum to [0.8, 0.999] for fixed lr).
            o.damping = rng.log_uniform(1e-10, 1e-3);
            o.momentum = if o.line_search {
                rng.log_uniform(0.6, 0.999)
            } else {
                rng.log_uniform(0.8, 0.999)
            };
            if !o.line_search {
                o.lr = rng.log_uniform(1e-4, 1e-1);
            }
            if o.solve != SolveMode::Exact {
                o.path = ExecPath::Decomposed;
            }
            o.bias = BiasMode::Adam;
        }
        OptimizerKind::HessianFree => {
            // damping ∈ {100, 50, 10, 5, 1, 0.5, 0.1, 0.05},
            // max CG iters ∈ {100, 150, ..., 350}
            o.damping = *rng.choice(&[100.0, 50.0, 10.0, 5.0, 1.0, 0.5, 0.1, 0.05]);
            o.cg_iters = *rng.choice(&[100.0, 150.0, 200.0, 250.0, 300.0, 350.0]) as usize;
            o.path = ExecPath::Decomposed;
        }
    }
    o
}

/// Run `trials` random-search trials of `base.optimizer.kind` and return
/// them ranked by best L2 (ascending — best first).
pub fn run_sweep(
    base: &RunConfig,
    eval: &dyn Evaluator,
    trials: usize,
    echo: bool,
) -> Result<Vec<Trial>> {
    let mut rng = Rng::seed_from(base.seed ^ 0x5377_EEB5);
    let mut results = Vec::with_capacity(trials);
    for index in 0..trials {
        let optimizer = sample_config(&base.optimizer.kind, &base.optimizer, &mut rng);
        let mut cfg = base.clone();
        cfg.optimizer = optimizer.clone();
        cfg.name = format!("{}-trial{index:03}", base.name);
        cfg.seed = base.seed.wrapping_add(index as u64);
        if echo {
            println!(
                "[sweep] trial {index}: {}",
                crate::optim::build_from_opt(&optimizer)?.describe()
            );
        }
        match train(cfg, eval, false) {
            Ok(report) => {
                if echo {
                    println!(
                        "[sweep]   best L2 = {:.3e} ({} steps, {:.1}s)",
                        report.best_l2, report.steps_done, report.wall_s
                    );
                }
                results.push(Trial {
                    index,
                    optimizer,
                    report,
                });
            }
            Err(e) => {
                // A failed trial (e.g. non-PD at tiny damping) is a valid
                // search outcome, not a sweep abort — record and continue.
                if echo {
                    println!("[sweep]   trial {index} failed: {e:#}");
                }
            }
        }
    }
    rank_trials(&mut results);
    Ok(results)
}

/// Rank trials by `best_l2` ascending with NaN last: a diverged trial
/// reports `best_l2 = NaN`, and the previous
/// `partial_cmp(..).unwrap_or(Equal)` comparator left it wherever the
/// unstable sort happened to place it — including rank 1, where downstream
/// "best config" selection would pick a diverged run. Keying on
/// `(is_nan, value)` gives a total order that always sinks diverged trials
/// to the bottom.
fn rank_trials(results: &mut [Trial]) {
    results.sort_by(|a, b| {
        let key = |t: &Trial| (t.report.best_l2.is_nan(), t.report.best_l2);
        key(a)
            .partial_cmp(&key(b))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampled_configs_stay_in_paper_spaces() {
        let base = OptimizerConfig::default();
        let mut rng = Rng::seed_from(1);
        for _ in 0..200 {
            let o = sample_config(&OptimizerKind::Spring, &base, &mut rng);
            assert!(o.damping >= 1e-10 * 0.999 && o.damping <= 1e-3 * 1.001);
            assert!(o.momentum >= 0.6 * 0.999 && o.momentum < 1.0);
            o.validate().unwrap();

            let o = sample_config(&OptimizerKind::Adam, &base, &mut rng);
            assert!(o.lr >= 1e-4 * 0.999 && o.lr <= 5e-1 * 1.001);

            let o = sample_config(&OptimizerKind::HessianFree, &base, &mut rng);
            assert!(o.cg_iters >= 100 && o.cg_iters <= 350);
        }
    }

    fn trial_with_l2(index: usize, best_l2: f64) -> Trial {
        Trial {
            index,
            optimizer: OptimizerConfig::default(),
            report: crate::coordinator::TrainReport {
                name: format!("trial{index}"),
                backend: "native".into(),
                steps_done: 1,
                wall_s: 0.0,
                final_loss: best_l2,
                losses: vec![best_l2],
                best_l2,
                time_to: Vec::new(),
                compile_s: 0.0,
                eval_s: 0.0,
            },
        }
    }

    #[test]
    fn diverged_nan_trials_rank_last() {
        // Regression: a diverged trial's NaN best_l2 used to be able to
        // rank first because partial_cmp's Equal fallback let the unstable
        // sort place it anywhere.
        let mut trials = vec![
            trial_with_l2(0, f64::NAN),
            trial_with_l2(1, 3e-2),
            trial_with_l2(2, f64::NAN),
            trial_with_l2(3, 1e-4),
            trial_with_l2(4, f64::INFINITY),
        ];
        rank_trials(&mut trials);
        assert_eq!(trials[0].index, 3);
        assert_eq!(trials[1].index, 1);
        assert_eq!(trials[2].index, 4); // ∞ beats NaN: it still orders
        assert!(trials[3].report.best_l2.is_nan());
        assert!(trials[4].report.best_l2.is_nan());
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let base = OptimizerConfig::default();
        let mut r1 = Rng::seed_from(9);
        let mut r2 = Rng::seed_from(9);
        for _ in 0..10 {
            let a = sample_config(&OptimizerKind::EngdW, &base, &mut r1);
            let b = sample_config(&OptimizerKind::EngdW, &base, &mut r2);
            assert_eq!(a.damping, b.damping);
            assert_eq!(a.lr, b.lr);
        }
    }
}
