//! xoshiro256++ and SplitMix64 (Blackman & Vigna, public-domain reference
//! implementations transcribed to Rust).

/// SplitMix64: used to expand a 64-bit seed into xoshiro state and to derive
/// independent substreams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second Box–Muller variate (see `normal()` in mod.rs).
    pub(crate) spare: Option<f64>,
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256pp {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare: None,
        }
    }

    /// Derive an independent stream (re-seeds through SplitMix64 so the
    /// child is decorrelated from the parent's future output).
    pub fn split(&mut self) -> Self {
        Self::seed_from(self.next_u64() ^ 0xDEADBEEFCAFEF00D)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 0 from the public-domain C implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn xoshiro_not_degenerate() {
        let mut x = Xoshiro256pp::seed_from(0);
        let vals: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        // All distinct, none zero.
        for (i, v) in vals.iter().enumerate() {
            assert_ne!(*v, 0);
            for w in &vals[i + 1..] {
                assert_ne!(v, w);
            }
        }
    }
}
