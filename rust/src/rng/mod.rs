//! PRNG substrate (the `rand` crate is unavailable offline).
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Xoshiro256pp`] — the workhorse: xoshiro256++ (Blackman & Vigna), the
//!   same family JAX's host-side RNGs and `rand`'s `SmallRng` draw from.
//! * Gaussian sampling via Box–Muller (needed for Nyström test matrices Ω),
//!   log-uniform sampling (the paper's hyperparameter search spaces, A.1),
//!   and Fisher–Yates shuffling.
//!
//! Everything is deterministic given a seed; parallel streams are derived by
//! `split()`, which jumps through SplitMix64 so streams are uncorrelated.

mod xoshiro;

pub use xoshiro::{SplitMix64, Xoshiro256pp};

/// Convenience alias: the default RNG used across the crate.
pub type Rng = Xoshiro256pp;

impl Xoshiro256pp {
    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Log-uniform in [lo, hi] (paper Appendix A.1's `LU` distribution).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi >= lo, "log_uniform needs 0 < lo <= hi");
        (self.uniform_in(lo.ln(), hi.ln())).exp()
    }

    /// Standard normal via Box–Muller (both branches used alternately).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Avoid u1 == 0 (log singularity).
        let u1 = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Fill a buffer with standard normals (Nyström test matrices).
    pub fn fill_normal(&mut self, buf: &mut [f64]) {
        for x in buf.iter_mut() {
            *x = self.normal();
        }
    }

    /// Fill a buffer with U[lo, hi) samples (collocation points).
    pub fn fill_uniform(&mut self, buf: &mut [f64], lo: f64, hi: f64) {
        for x in buf.iter_mut() {
            *x = self.uniform_in(lo, hi);
        }
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free multiply-shift (Lemire); bias < 2^-64, fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(7);
        let mut b = Rng::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::seed_from(7);
        let mut b = a.split();
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_moments() {
        let mut rng = Rng::seed_from(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(13);
        let n = 200_000;
        let (mut sum, mut sq, mut cube) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
            cube += x * x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        let skew = cube / n as f64;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
        assert!(skew.abs() < 3e-2, "skew={skew}");
    }

    #[test]
    fn log_uniform_respects_bounds_and_median() {
        let mut rng = Rng::seed_from(17);
        let (lo, hi) = (1e-10f64, 1e-3f64);
        let mut below_geomean = 0usize;
        let geomean = (lo.ln() + hi.ln()) / 2.0;
        let n = 50_000;
        for _ in 0..n {
            let x = rng.log_uniform(lo, hi);
            assert!(x >= lo * 0.999 && x <= hi * 1.001);
            if x.ln() < geomean {
                below_geomean += 1;
            }
        }
        // Median of a log-uniform is the geometric mean of the bounds.
        let frac = below_geomean as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "frac={frac}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seed_from(19);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::seed_from(23);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
