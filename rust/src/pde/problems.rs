//! The problem catalogue: every benchmark PDE as a [`ProblemSpec`].
//!
//! This is the Rust mirror of `python/compile/problems.py` (which remains
//! the source of truth for *artifact* shapes). Moving the spec type here —
//! out of the PJRT manifest — makes the problem definition a PDE-level
//! concept shared by every backend: the PJRT runtime parses specs from
//! `artifacts/manifest.json`, while the native backend serves them from
//! [`builtin_problems`] with no files on disk at all.
//!
//! Batch sizes and architectures are the scaled CPU variants (see
//! DESIGN.md §Substitutions); the `*_full` entries keep the paper's exact
//! setups. `poisson1d` is a native-only warm-up problem (no artifact set
//! exists for it) used by the end-to-end convergence suite.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::params::param_count;

/// The differential operator of a problem's residual.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PdeOperator {
    /// `−Δu = f` on the unit cube (paper §2).
    Poisson,
    /// `∂_t u − Δ_x u = f` with time as the last coordinate.
    Heat,
}

/// Per-coordinate dual-order mask for the forward-mode AD tape: how many
/// input coordinates carry derivative duals, and to what order.
///
/// Coordinates `0..first` carry first-order duals `∂_i u`; of those, the
/// prefix `0..second` also carries second-order duals `∂²_i u`
/// (`second ≤ first`). The prefix convention matches the coordinate layout
/// of every built-in operator: Poisson needs `∂²_i` for all coordinates,
/// while the heat operator — time as the *last* coordinate — needs
/// `∂²_i` only for the spatial prefix plus `∂_t` for the trailing time
/// coordinate. Dropping the unused second-order time dual removes two
/// matrix-panel products per layer from the heat forward pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DualOrder {
    /// Coordinates carrying first-order duals (a prefix of the input).
    pub first: usize,
    /// Coordinates (a prefix of `first`) also carrying second-order duals.
    pub second: usize,
}

impl DualOrder {
    /// No duals at all: a plain value-only forward pass.
    pub const NONE: DualOrder = DualOrder {
        first: 0,
        second: 0,
    };

    /// Duals on the first `first` coordinates, second-order on the
    /// `second`-long prefix of those.
    pub fn new(first: usize, second: usize) -> DualOrder {
        assert!(second <= first, "order-2 coordinates must be a prefix");
        DualOrder { first, second }
    }

    /// Every one of `dim` coordinates carries both orders (the Laplacian).
    pub fn full(dim: usize) -> DualOrder {
        DualOrder::new(dim, dim)
    }
}

impl PdeOperator {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "poisson" => Self::Poisson,
            "heat" => Self::Heat,
            _ => bail!("unknown PDE operator '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Heat => "heat",
        }
    }

    /// Operator implied by an exact-solution family tag (used when a
    /// manifest predates the explicit `operator` field).
    pub fn from_pde_tag(tag: &str) -> Self {
        if tag == "heat_product" {
            Self::Heat
        } else {
            Self::Poisson
        }
    }

    /// The dual orders this operator's interior residual needs from a
    /// `dim`-dimensional forward pass (see [`DualOrder`]): Poisson reads
    /// `∂²_i` everywhere; heat reads `∂²_i` on the spatial prefix and only
    /// `∂_t` on the trailing time coordinate.
    pub fn dual_orders(&self, dim: usize) -> DualOrder {
        match self {
            Self::Poisson => DualOrder::full(dim),
            Self::Heat => DualOrder::new(dim, dim.saturating_sub(1)),
        }
    }
}

/// One PINN problem: dimensions, architecture, batch sizes, loss weights.
///
/// Backend-neutral: the PJRT runtime attaches its artifact set separately
/// (see `crate::runtime::Manifest`), and the native backend needs nothing
/// beyond these fields plus the `pde` tag's exact-solution family.
#[derive(Debug, Clone)]
pub struct ProblemSpec {
    pub name: String,
    pub dim: usize,
    pub arch: Vec<usize>,
    pub n_params: usize,
    pub n_interior: usize,
    pub n_boundary: usize,
    pub n_eval: usize,
    pub interior_weight: f64,
    pub boundary_weight: f64,
    /// Exact-solution family tag (see [`super::exact::ExactSolution`]).
    pub pde: String,
    pub operator: PdeOperator,
}

impl ProblemSpec {
    pub fn n_total(&self) -> usize {
        self.n_interior + self.n_boundary
    }
}

fn spec(
    name: &str,
    dim: usize,
    arch: &[usize],
    n_interior: usize,
    n_boundary: usize,
    n_eval: usize,
    pde: &str,
    operator: PdeOperator,
) -> ProblemSpec {
    ProblemSpec {
        name: name.to_string(),
        dim,
        arch: arch.to_vec(),
        n_params: param_count(arch),
        n_interior,
        n_boundary,
        n_eval,
        interior_weight: 1.0,
        boundary_weight: 1.0,
        pde: pde.to_string(),
        operator,
    }
}

/// The built-in problem set served by the native backend — the mirror of
/// `python/compile/problems.py` plus the native-only `poisson1d`.
pub fn builtin_problems() -> Vec<ProblemSpec> {
    use PdeOperator::{Heat, Poisson};
    let mut out = vec![
        // Native-only 1d warm-up: u* = sin(πx), tiny net, converges in a
        // handful of ENGD steps — the convergence suite's fastest case.
        spec("poisson1d", 1, &[1, 24, 24, 1], 64, 16, 256, "sine_product", Poisson),
        spec("poisson2d", 2, &[2, 32, 32, 1], 128, 32, 512, "sine_product", Poisson),
        spec("poisson5d", 5, &[5, 64, 64, 48, 48, 1], 384, 64, 2000, "cosine_sum", Poisson),
        spec(
            "poisson5d_full",
            5,
            &[5, 64, 64, 48, 48, 1],
            3000,
            500,
            2000,
            "cosine_sum",
            Poisson,
        ),
        spec("poisson10d", 10, &[10, 96, 96, 64, 64, 1], 256, 64, 2000, "harmonic", Poisson),
        spec(
            "poisson10d_full",
            10,
            &[10, 256, 256, 128, 128, 1],
            3000,
            1000,
            2000,
            "harmonic",
            Poisson,
        ),
        spec(
            "poisson100d",
            100,
            &[100, 192, 192, 128, 128, 1],
            128,
            32,
            1000,
            "harmonic",
            Poisson,
        ),
        spec(
            "poisson100d_sq",
            100,
            &[100, 192, 192, 128, 128, 1],
            128,
            32,
            1000,
            "sqnorm",
            Poisson,
        ),
        spec("heat2d", 3, &[3, 48, 48, 1], 192, 64, 1000, "heat_product", Heat),
    ];
    // Large-batch variants for the randomization experiments (Fig. 4/9/10),
    // batch splits exactly as in problems.py.
    for n in [512usize, 1024, 2048] {
        let ni = n * 6 / 7;
        out.push(spec(
            &format!("poisson5d_n{n}"),
            5,
            &[5, 64, 64, 48, 48, 1],
            ni,
            n - ni,
            2000,
            "cosine_sum",
            Poisson,
        ));
    }
    // The 8192..40960 rungs are the large-batch dual-space ladder
    // (`benches/large_batch`): 40960 is 10× the previous 4096 ceiling and
    // is only tractable through the pooled matrix-free solve tier — the
    // N×N kernel is never formed and the sketches stay O(N·ℓ).
    for n in [1024usize, 4096, 8192, 16384, 40960] {
        let ni = n * 8 / 10;
        out.push(spec(
            &format!("poisson2d_n{n}"),
            2,
            &[2, 32, 32, 1],
            ni,
            n - ni,
            512,
            "sine_product",
            Poisson,
        ));
    }
    out
}

/// Built-in problems as a name-keyed map.
pub fn builtin_problem_map() -> BTreeMap<String, ProblemSpec> {
    builtin_problems()
        .into_iter()
        .map(|p| (p.name.clone(), p))
        .collect()
}

/// Look up one built-in problem by name.
pub fn builtin_problem(name: &str) -> Result<ProblemSpec> {
    builtin_problems()
        .into_iter()
        .find(|p| p.name == name)
        .ok_or_else(|| {
            anyhow!(
                "no built-in problem '{name}' (have: {:?})",
                builtin_problems().iter().map(|p| p.name.clone()).collect::<Vec<_>>()
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_are_consistent() {
        for p in builtin_problems() {
            assert_eq!(p.arch[0], p.dim, "{}: arch[0] != dim", p.name);
            assert_eq!(*p.arch.last().unwrap(), 1, "{}: head width != 1", p.name);
            assert_eq!(p.n_params, param_count(&p.arch), "{}", p.name);
            assert!(p.n_interior > 0 && p.n_boundary > 0 && p.n_eval > 0, "{}", p.name);
            // Every tag resolves to an exact solution.
            super::super::exact_solution(&p.pde).unwrap();
            assert_eq!(p.operator, PdeOperator::from_pde_tag(&p.pde), "{}", p.name);
        }
    }

    #[test]
    fn mirrors_python_batch_splits() {
        let m = builtin_problem_map();
        // problems.py: poisson5d_n1024 uses int(1024*6/7) = 877 interior.
        assert_eq!(m["poisson5d_n1024"].n_interior, 877);
        assert_eq!(m["poisson5d_n1024"].n_boundary, 147);
        assert_eq!(m["poisson2d_n4096"].n_interior, 3276);
        assert_eq!(m["poisson2d_n4096"].n_boundary, 820);
        // Paper architectures keep their parameter counts.
        assert_eq!(m["poisson5d"].n_params, 10_065);
        assert_eq!(m["poisson10d_full"].n_params, 118_145);
    }

    #[test]
    fn unknown_builtin_is_an_error() {
        assert!(builtin_problem("nope").is_err());
    }

    #[test]
    fn dual_order_masks_match_the_operators() {
        assert_eq!(PdeOperator::Poisson.dual_orders(5), DualOrder::new(5, 5));
        // Heat: second-order on the spatial prefix, first-order on time.
        assert_eq!(PdeOperator::Heat.dual_orders(3), DualOrder::new(3, 2));
        assert_eq!(DualOrder::NONE, DualOrder::new(0, 0));
        assert_eq!(DualOrder::full(2), DualOrder::new(2, 2));
        for p in builtin_problems() {
            let o = p.operator.dual_orders(p.dim);
            assert!(o.second <= o.first && o.first == p.dim, "{}", p.name);
        }
    }
}
