//! Collocation-point sampling on the unit cube [0,1]^d.
//!
//! Matches the paper's protocol (§4): every optimizer draws a fresh batch of
//! interior + boundary points each iteration; the L2 evaluation set is a
//! fixed uniform sample drawn once per run.

use crate::rng::Rng;

/// Sampler for one problem's domain.
pub struct Sampler {
    dim: usize,
    rng: Rng,
}

impl Sampler {
    pub fn new(dim: usize, seed: u64) -> Self {
        Sampler {
            dim,
            rng: Rng::seed_from(seed),
        }
    }

    /// `n` interior points, uniform in (0,1)^d, row-major (n × d).
    pub fn interior(&mut self, n: usize) -> Vec<f64> {
        let mut pts = vec![0.0; n * self.dim];
        self.rng.fill_uniform(&mut pts, 0.0, 1.0);
        pts
    }

    /// `n` boundary points: pick a face (coordinate i, side 0/1) uniformly,
    /// fix that coordinate, sample the rest uniformly.
    pub fn boundary(&mut self, n: usize) -> Vec<f64> {
        let mut pts = vec![0.0; n * self.dim];
        for row in pts.chunks_exact_mut(self.dim) {
            self.rng.fill_uniform(row, 0.0, 1.0);
            let face = self.rng.below(self.dim);
            let side = if self.rng.below(2) == 0 { 0.0 } else { 1.0 };
            row[face] = side;
        }
        pts
    }

    /// Evaluation set: uniform interior points (matches the paper's fixed
    /// validation set with known solution).
    pub fn eval_set(&mut self, n: usize) -> Vec<f64> {
        self.interior(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_points_are_inside() {
        let mut s = Sampler::new(5, 1);
        let pts = s.interior(100);
        assert_eq!(pts.len(), 500);
        assert!(pts.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn boundary_points_are_on_faces() {
        let mut s = Sampler::new(4, 2);
        let pts = s.boundary(200);
        for row in pts.chunks_exact(4) {
            let on_face = row.iter().any(|&x| x == 0.0 || x == 1.0);
            assert!(on_face, "row {row:?} is not on the boundary");
            assert!(row.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn boundary_covers_all_faces_eventually() {
        let mut s = Sampler::new(2, 3);
        let pts = s.boundary(400);
        let mut seen = [false; 4]; // (dim0,lo),(dim0,hi),(dim1,lo),(dim1,hi)
        for row in pts.chunks_exact(2) {
            for d in 0..2 {
                if row[d] == 0.0 {
                    seen[2 * d] = true;
                }
                if row[d] == 1.0 {
                    seen[2 * d + 1] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "faces seen: {seen:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Sampler::new(3, 7).interior(10);
        let b = Sampler::new(3, 7).interior(10);
        assert_eq!(a, b);
    }
}
