//! Flat-parameter MLP: the Rust mirror of `python/compile/model.py`.
//!
//! Layout per layer ℓ: weights W_ℓ (row-major, out × in), then biases b_ℓ.
//! `mlp_forward` is the independent oracle used to cross-check the `u_pred`
//! artifact in integration tests; `init_params` seeds training runs with a
//! PyTorch-default-style U(−1/√fan_in, 1/√fan_in) init, matching the paper's
//! baseline implementation.

use crate::rng::Rng;

/// Number of parameters of an MLP with the given layer widths.
pub fn param_count(arch: &[usize]) -> usize {
    arch.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
}

/// U(−1/√fan_in, 1/√fan_in) initialization over the flat layout.
pub fn init_params(arch: &[usize], rng: &mut Rng) -> Vec<f64> {
    let mut theta = Vec::with_capacity(param_count(arch));
    for w in arch.windows(2) {
        let (fan_in, fan_out) = (w[0], w[1]);
        let bound = 1.0 / (fan_in as f64).sqrt();
        for _ in 0..fan_in * fan_out + fan_out {
            theta.push(rng.uniform_in(-bound, bound));
        }
    }
    theta
}

/// Tanh-MLP forward pass u_θ(x) for a single point.
pub fn mlp_forward(theta: &[f64], arch: &[usize], x: &[f64]) -> f64 {
    assert_eq!(x.len(), arch[0], "input dim mismatch");
    assert_eq!(theta.len(), param_count(arch), "param count mismatch");
    let mut h: Vec<f64> = x.to_vec();
    let mut offset = 0;
    let last = arch.len() - 2;
    for (layer, w) in arch.windows(2).enumerate() {
        let (fan_in, fan_out) = (w[0], w[1]);
        let weights = &theta[offset..offset + fan_in * fan_out];
        offset += fan_in * fan_out;
        let biases = &theta[offset..offset + fan_out];
        offset += fan_out;
        let mut next = vec![0.0; fan_out];
        for o in 0..fan_out {
            let row = &weights[o * fan_in..(o + 1) * fan_in];
            let mut s = biases[o];
            for (wi, hi) in row.iter().zip(&h) {
                s += wi * hi;
            }
            next[o] = if layer == last { s } else { s.tanh() };
        }
        h = next;
    }
    h[0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_matches_known_architectures() {
        // Paper 5d architecture: P = 10 065.
        assert_eq!(param_count(&[5, 64, 64, 48, 48, 1]), 10_065);
        // Paper 10d architecture: P = 118 145.
        assert_eq!(param_count(&[10, 256, 256, 128, 128, 1]), 118_145);
        // Paper 100d architecture: P = 1 325 057.
        assert_eq!(param_count(&[100, 768, 768, 512, 512, 1]), 1_325_057);
    }

    #[test]
    fn forward_identity_network() {
        // 1-16-1 with zero weights → output is just the output bias.
        let arch = [1usize, 16, 1];
        let mut theta = vec![0.0; param_count(&arch)];
        *theta.last_mut().unwrap() = 3.25;
        assert_eq!(mlp_forward(&theta, &arch, &[0.7]), 3.25);
    }

    #[test]
    fn forward_known_tiny_network() {
        // 1-1-1: u(x) = w2 * tanh(w1 x + b1) + b2.
        let arch = [1usize, 1, 1];
        let theta = [2.0, 0.5, 3.0, -1.0]; // w1, b1, w2, b2
        let x = 0.3f64;
        let want = 3.0 * (2.0 * x + 0.5).tanh() - 1.0;
        assert!((mlp_forward(&theta, &arch, &[x]) - want).abs() < 1e-15);
    }

    #[test]
    fn init_respects_bounds() {
        let arch = [5usize, 64, 64, 48, 48, 1];
        let mut rng = Rng::seed_from(1);
        let theta = init_params(&arch, &mut rng);
        assert_eq!(theta.len(), 10_065);
        // First layer bound 1/sqrt(5).
        let bound = 1.0 / 5f64.sqrt();
        assert!(theta[..5 * 64 + 64].iter().all(|&x| x.abs() <= bound));
        // Init is not degenerate.
        let nonzero = theta.iter().filter(|&&x| x != 0.0).count();
        assert!(nonzero > 10_000);
    }
}
