//! Exact solutions u* for the benchmark PDEs and the L2-error reduction.
//!
//! Tags match the `pde` field of the manifest (written by
//! `python/compile/problems.py`):
//!   * `sine_product` — u* = Π sin(πx_i)          (2d quickstart)
//!   * `cosine_sum`   — u* = Σ cos(πx_i)          (paper 5d, A.2)
//!   * `harmonic`     — u* = Σ x_{2i-1} x_{2i}    (paper 10d/100d, A.3–A.4)
//!   * `sqnorm`       — u* = ‖x‖²                 (paper §4 100d variant)
//!   * `heat_product` — u* = e^{−2π²t} sin(πx₀)sin(πx₁)  (heat2d extension)

use anyhow::{bail, Result};

/// An exact solution family, evaluated pointwise.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExactSolution {
    SineProduct,
    CosineSum,
    Harmonic,
    SqNorm,
    /// Heat kernel product solution; the last coordinate is time.
    HeatProduct,
}

impl ExactSolution {
    pub fn from_tag(tag: &str) -> Result<Self> {
        Ok(match tag {
            "sine_product" => Self::SineProduct,
            "cosine_sum" => Self::CosineSum,
            "harmonic" => Self::Harmonic,
            "sqnorm" => Self::SqNorm,
            "heat_product" => Self::HeatProduct,
            _ => bail!("unknown pde tag '{tag}'"),
        })
    }

    /// u*(x).
    pub fn eval(&self, x: &[f64]) -> f64 {
        match self {
            Self::SineProduct => x.iter().map(|&xi| (std::f64::consts::PI * xi).sin()).product(),
            Self::CosineSum => x.iter().map(|&xi| (std::f64::consts::PI * xi).cos()).sum(),
            Self::Harmonic => x.chunks_exact(2).map(|p| p[0] * p[1]).sum(),
            Self::SqNorm => x.iter().map(|&xi| xi * xi).sum(),
            Self::HeatProduct => {
                let pi = std::f64::consts::PI;
                let t = x[x.len() - 1];
                (-2.0 * pi * pi * t).exp() * (pi * x[0]).sin() * (pi * x[1]).sin()
            }
        }
    }

    /// Batched evaluation over row-major points (m × d).
    pub fn eval_batch(&self, xs: &[f64], dim: usize) -> Vec<f64> {
        xs.chunks_exact(dim).map(|x| self.eval(x)).collect()
    }

    /// Manufactured forcing `f` of the benchmark problem built on this
    /// family: `f = −Δu*` for the Poisson problems, `f = ∂_t u* − Δ_x u*`
    /// for the heat problem (zero: u* solves the homogeneous equation).
    /// Mirrors the `f` callables in `python/compile/problems.py`.
    pub fn forcing(&self, x: &[f64]) -> f64 {
        let pi = std::f64::consts::PI;
        match self {
            // −Δ Πsin(πx_i) = d·π²·Πsin(πx_i)
            Self::SineProduct => {
                x.len() as f64
                    * pi
                    * pi
                    * x.iter().map(|&xi| (pi * xi).sin()).product::<f64>()
            }
            // −Δ Σcos(πx_i) = π² Σcos(πx_i)
            Self::CosineSum => pi * pi * x.iter().map(|&xi| (pi * xi).cos()).sum::<f64>(),
            // Harmonic: −Δu* = 0.
            Self::Harmonic => 0.0,
            // −Δ‖x‖² = −2d.
            Self::SqNorm => -2.0 * x.len() as f64,
            // u* solves u_t = Δ_x u exactly.
            Self::HeatProduct => 0.0,
        }
    }

    /// Dirichlet boundary data `g` of the benchmark problem: the trace of
    /// the exact solution (`python/compile/problems.py` uses `g = u*`; the
    /// 2d quickstart's literal `g = 0` equals the trace up to one ulp of
    /// `sin(π·1)`).
    pub fn boundary(&self, x: &[f64]) -> f64 {
        self.eval(x)
    }
}

/// Exact solution for a manifest problem tag.
pub fn exact_solution(tag: &str) -> Result<ExactSolution> {
    ExactSolution::from_tag(tag)
}

/// Relative L2 error ‖u_pred − u*‖ / ‖u*‖ over the evaluation set — the
/// paper's ranking metric (Appendix A.1).
pub fn l2_relative_error(u_pred: &[f64], u_star: &[f64]) -> f64 {
    assert_eq!(u_pred.len(), u_star.len());
    let num: f64 = u_pred
        .iter()
        .zip(u_star)
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let den: f64 = u_star.iter().map(|b| b * b).sum();
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values() {
        let e = ExactSolution::SineProduct;
        assert!((e.eval(&[0.5, 0.5]) - 1.0).abs() < 1e-15);
        assert!(e.eval(&[0.0, 0.3]).abs() < 1e-15);

        let e = ExactSolution::CosineSum;
        assert!((e.eval(&[0.0; 5]) - 5.0).abs() < 1e-15);
        assert!((e.eval(&[1.0; 5]) + 5.0).abs() < 1e-12);

        let e = ExactSolution::Harmonic;
        assert!((e.eval(&[2.0, 3.0, 4.0, 5.0]) - 26.0).abs() < 1e-15);

        let e = ExactSolution::SqNorm;
        assert!((e.eval(&[3.0, 4.0]) - 25.0).abs() < 1e-15);
    }

    #[test]
    fn batch_matches_pointwise() {
        let e = ExactSolution::Harmonic;
        let xs = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8];
        let vals = e.eval_batch(&xs, 4);
        assert_eq!(vals.len(), 2);
        assert!((vals[0] - e.eval(&xs[..4])).abs() < 1e-15);
        assert!((vals[1] - e.eval(&xs[4..])).abs() < 1e-15);
    }

    #[test]
    fn l2_error_basics() {
        assert_eq!(l2_relative_error(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        // Doubling every entry gives relative error 1.
        let err = l2_relative_error(&[2.0, 4.0], &[1.0, 2.0]);
        assert!((err - 1.0).abs() < 1e-15);
    }

    #[test]
    fn heat_product_values() {
        let e = ExactSolution::HeatProduct;
        // t = 0: plain sine product.
        assert!((e.eval(&[0.5, 0.5, 0.0]) - 1.0).abs() < 1e-15);
        // Decay in time by e^{-2π² t}.
        let pi = std::f64::consts::PI;
        let want = (-2.0 * pi * pi * 0.1f64).exp();
        assert!((e.eval(&[0.5, 0.5, 0.1]) - want).abs() < 1e-12);
        // Zero on the spatial boundary at any time.
        assert!(e.eval(&[0.0, 0.3, 0.7]).abs() < 1e-15);
    }

    #[test]
    fn unknown_tag_is_error() {
        assert!(exact_solution("nope").is_err());
    }

    /// Central-difference Laplacian of u* must match the manufactured
    /// forcing (f = −Δu*) for every Poisson family.
    #[test]
    fn forcing_matches_fd_laplacian() {
        let cases: &[(ExactSolution, &[f64])] = &[
            (ExactSolution::SineProduct, &[0.31, 0.62]),
            (ExactSolution::CosineSum, &[0.1, 0.2, 0.3, 0.4, 0.5]),
            (ExactSolution::Harmonic, &[0.3, 0.7, 0.2, 0.9]),
            (ExactSolution::SqNorm, &[0.25, 0.5, 0.75]),
        ];
        let h = 1e-4;
        for (e, x) in cases {
            let d = x.len();
            let mut lap = 0.0;
            for i in 0..d {
                let mut xp = x.to_vec();
                let mut xm = x.to_vec();
                xp[i] += h;
                xm[i] -= h;
                lap += (e.eval(&xp) - 2.0 * e.eval(x) + e.eval(&xm)) / (h * h);
            }
            let want = -lap;
            let got = e.forcing(x);
            assert!(
                (got - want).abs() < 1e-5 * (1.0 + want.abs()),
                "{e:?}: forcing {got} vs -lap(u*) {want}"
            );
        }
    }

    /// Heat family: ∂_t u* − Δ_x u* = 0 by finite differences.
    #[test]
    fn heat_family_is_homogeneous() {
        let e = ExactSolution::HeatProduct;
        let x = [0.37, 0.61, 0.23];
        let h = 1e-4;
        let mut xt_p = x;
        let mut xt_m = x;
        xt_p[2] += h;
        xt_m[2] -= h;
        let ut = (e.eval(&xt_p) - e.eval(&xt_m)) / (2.0 * h);
        let mut lap = 0.0;
        for i in 0..2 {
            let mut xp = x;
            let mut xm = x;
            xp[i] += h;
            xm[i] -= h;
            lap += (e.eval(&xp) - 2.0 * e.eval(&x) + e.eval(&xm)) / (h * h);
        }
        assert!((ut - lap - e.forcing(&x)).abs() < 1e-5, "residual {}", ut - lap);
    }
}
