//! PDE problem library (Rust mirror of `python/compile/problems.py`).
//!
//! The Python side is the source of truth for artifacts (shapes, batches);
//! this module supplies everything the *coordinator* needs at run time:
//! exact solutions for L2 evaluation, collocation-point samplers, and an
//! independent MLP forward oracle used to cross-check the parameter layout
//! against the `u_pred` artifact.

mod exact;
mod params;
mod sampler;

pub use exact::{exact_solution, l2_relative_error, ExactSolution};
pub use params::{init_params, mlp_forward, param_count};
pub use sampler::Sampler;
