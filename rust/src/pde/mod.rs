//! PDE problem library — the backend-neutral core of the stack.
//!
//! Historically this module was a thin run-time mirror of
//! `python/compile/problems.py`: exact solutions for L2 evaluation,
//! collocation samplers, and an independent MLP "forward oracle" used only
//! to cross-check the parameter layout against the `u_pred` artifact.
//!
//! The native-backend refactor promoted it to the shared problem layer:
//!
//! * [`ProblemSpec`] / [`PdeOperator`] — the problem definition itself,
//!   consumed by every backend (the PJRT manifest parses specs from JSON;
//!   [`builtin_problems`] serves the same catalogue with no files at all);
//! * [`mlp_forward`] — no longer just a cross-check: it is the reference
//!   semantics for `crate::backend::native`, whose taped forward pass and
//!   hand-rolled AD are property-tested against it and against finite
//!   differences;
//! * [`ExactSolution::forcing`] / [`ExactSolution::boundary`] — the
//!   manufactured right-hand sides, so residuals can be evaluated entirely
//!   in Rust.

mod exact;
mod params;
mod problems;
mod sampler;

pub use exact::{exact_solution, l2_relative_error, ExactSolution};
pub use params::{init_params, mlp_forward, param_count};
pub use problems::{
    builtin_problem, builtin_problem_map, builtin_problems, DualOrder, PdeOperator, ProblemSpec,
};
pub use sampler::Sampler;
