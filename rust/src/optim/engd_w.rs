//! ENGD-W: energy natural gradient descent in the Woodbury/kernel form
//! (paper §3.1, eq. 5):
//!
//! `φ = Jᵀ (J Jᵀ + λI)⁻¹ r`,   `θ ← θ − η φ`
//!
//! The N×N kernel replaces the P×P Gramian, dropping the per-step cost from
//! O(P³) to O(N²P) — *exactly* the same update as dense ENGD (up to floating
//! point), which is the paper's headline claim.
//!
//! Execution paths:
//! * **Fused** (default): the `engd_w_dir` / `engd_w_step` artifacts run the
//!   full pipeline (Jacobian → Pallas gram → Cholesky → map-back) as one XLA
//!   program; Rust contributes only the line search and the θ update.
//!   PJRT-only — on other backends the step transparently decomposes.
//! * **Decomposed**: the backend supplies (r, J) and all linear algebra
//!   runs in `crate::linalg` / `crate::nystrom`; required for the
//!   randomized solves (eq. 9) and the d_eff diagnostics (§3.4). Works on
//!   every backend.

use anyhow::Result;

use super::{
    grid_line_search, kernel_solve, JacobianKernel, KernelOp, Optimizer, StepEnv, StepInfo,
};
use crate::config::run::{ExecPath, SolveMode};
use crate::config::OptimizerConfig;

pub struct EngdW {
    cfg: OptimizerConfig,
}

impl EngdW {
    pub fn new(o: &OptimizerConfig) -> Self {
        EngdW { cfg: o.clone() }
    }

    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn fused_step(&self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        if !self.cfg.line_search {
            // Single-artifact hot path: θ' computed inside XLA.
            let art = env.artifact("engd_w_step")?;
            let out = art.call(&[
                theta,
                env.x_int,
                env.x_bnd,
                &[self.cfg.damping],
                &[self.cfg.lr],
            ])?;
            theta.copy_from_slice(&out[0]);
            return Ok(StepInfo {
                loss: out[1][0],
                lr_used: self.cfg.lr,
                extra: vec![], // lint: allow(alloc) — empty reporting vec
            });
        }
        // Direction artifact + grid line search on the backend loss.
        let art = env.artifact("engd_w_dir")?;
        let out = art.call(&[theta, env.x_int, env.x_bnd, &[self.cfg.damping]])?;
        let phi = &out[0];
        let loss = out[1][0];
        let ls = grid_line_search(env, theta, phi, loss, self.cfg.ls_eta_max, self.cfg.ls_grid)?;
        for (t, p) in theta.iter_mut().zip(phi) {
            *t -= ls.eta * p;
        }
        Ok(StepInfo {
            loss,
            lr_used: ls.eta,
            // Reporting tuple handed to the metrics logger, not kernel math.
            extra: vec![("ls_evals".into(), ls.evals as f64)], // lint: allow(alloc)
        })
    }

    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn decomposed_step(&self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let (r, j) = env.residuals_jacobian(theta)?;
        let loss = 0.5 * crate::linalg::dot(&r, &r);
        let op = JacobianKernel::with_numerics(&j, env.numerics);
        let (a, mut extra) =
            match kernel_solve(&op, &r, &self.cfg, env.rng, env.ws, env.diagnostics) {
                Ok(out) => out,
                Err(e) => {
                    // Error paths recycle live checkouts (engd-lint R6).
                    drop(op);
                    env.ws.recycle_matrix(j);
                    return Err(e);
                }
            };
        let mut phi = env.ws.take_scratch(theta.len());
        op.apply_t_into(&a, &mut phi);
        env.ws.recycle(a);
        drop(op);
        env.ws.recycle_matrix(j);
        let eta = if self.cfg.line_search {
            let ls = match grid_line_search(env, theta, &phi, loss, self.cfg.ls_eta_max, self.cfg.ls_grid)
            {
                Ok(ls) => ls,
                Err(e) => {
                    env.ws.recycle(phi);
                    return Err(e);
                }
            };
            extra.push(("ls_evals".into(), ls.evals as f64));
            ls.eta
        } else {
            self.cfg.lr
        };
        for (t, p) in theta.iter_mut().zip(&phi) {
            *t -= eta * p;
        }
        extra.push(("phi_norm".into(), crate::linalg::norm2(&phi)));
        env.ws.recycle(phi);
        Ok(StepInfo {
            loss,
            lr_used: eta,
            extra,
        })
    }
}

impl Optimizer for EngdW {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        match self.cfg.path {
            // Fused artifacts exist only on the PJRT backend; elsewhere the
            // decomposed path computes the identical update (paper eq. 5).
            ExecPath::Fused if env.fused_available() => self.fused_step(theta, env),
            _ => self.decomposed_step(theta, env),
        }
    }

    fn describe(&self) -> String {
        let solve = match self.cfg.solve {
            SolveMode::Exact => "exact".to_string(),
            m => format!("{}@{:.0}%N", m.name(), self.cfg.sketch_ratio * 100.0),
        };
        format!(
            "engd_w(λ={:.3e}, {}, {})",
            self.cfg.damping,
            if self.cfg.line_search {
                "line-search".to_string()
            } else {
                format!("lr={:.3e}", self.cfg.lr)
            },
            solve
        )
    }
}
