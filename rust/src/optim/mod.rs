//! The optimizer suite (paper §3–§4).
//!
//! Paper contributions:
//! * [`engd_w`] — ENGD via the Woodbury/kernel identity (eq. 5), fused-
//!   artifact or Rust-linalg paths, with optional randomized Nyström solves
//!   (eq. 9).
//! * [`spring`] — SPRING momentum (eqs. 7–8, Algorithm 1) with the paper's
//!   bias correction.
//!
//! Baselines the paper evaluates against (§4, Appendix A.1):
//! * [`engd_dense`] — the original O(P³) ENGD (Müller–Zeinhofer 2023) with
//!   Gramian EMA and identity init,
//! * [`hessian_free`] — truncated-CG Gauss–Newton (Martens 2010),
//! * [`sgd`] / [`adam`] — tuned first-order baselines.

mod adam;
mod engd_dense;
mod engd_w;
mod hessian_free;
mod line_search;
mod sgd;
mod spring;

pub use adam::Adam;
pub use engd_dense::EngdDense;
pub use engd_w::EngdW;
pub use hessian_free::HessianFree;
pub use line_search::{golden_section, grid_line_search, grid_search, LineSearchResult};
pub use sgd::Sgd;
pub use spring::Spring;

use anyhow::Result;

use crate::config::{OptimizerConfig, RunConfig};
use crate::linalg::Matrix;
use crate::rng::Rng;
use crate::runtime::{ProblemSpec, Runtime};

/// Everything an optimizer can see during one step.
pub struct StepEnv<'a> {
    pub rt: &'a Runtime,
    pub problem: &'a ProblemSpec,
    /// Interior collocation points, row-major (N_Ω × d).
    pub x_int: &'a [f64],
    /// Boundary points, row-major (N_∂Ω × d).
    pub x_bnd: &'a [f64],
    /// 1-based step index (drives SPRING's bias correction).
    pub k: usize,
    /// Per-run RNG stream (sketches, etc.).
    pub rng: &'a mut Rng,
    /// If true, this step should also compute diagnostics (d_eff).
    pub diagnostics: bool,
}

impl StepEnv<'_> {
    /// Evaluate the loss artifact at `theta` (used by line searches).
    pub fn eval_loss(&self, theta: &[f64]) -> Result<f64> {
        let art = self.rt.artifact(&self.problem.name, "loss")?;
        Ok(art.call(&[theta, self.x_int, self.x_bnd])?[0][0])
    }

    /// Fetch `(r, J)` from the `residuals_jacobian` artifact.
    pub fn residuals_jacobian(&self, theta: &[f64]) -> Result<(Vec<f64>, Matrix)> {
        let art = self.rt.artifact(&self.problem.name, "residuals_jacobian")?;
        let mut out = art.call(&[theta, self.x_int, self.x_bnd])?;
        let j = out.pop().expect("jacobian output");
        let r = out.pop().expect("r output");
        let n = self.problem.n_total();
        let p = self.problem.n_params;
        Ok((r, Matrix::from_vec(n, p, j)))
    }

    /// Fetch `(loss, ∇L)` from the `grad` artifact.
    pub fn loss_and_grad(&self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        let art = self.rt.artifact(&self.problem.name, "grad")?;
        let mut out = art.call(&[theta, self.x_int, self.x_bnd])?;
        let g = out.pop().expect("grad output");
        let l = out.pop().expect("loss output")[0];
        Ok((l, g))
    }
}

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepInfo {
    /// Training loss at the *pre-update* iterate (as the artifacts report).
    pub loss: f64,
    /// Step size actually applied (post line search).
    pub lr_used: f64,
    /// Optimizer-specific scalars (d_eff, cg iterations, sketch size, ...).
    pub extra: Vec<(String, f64)>,
}

/// A PINN optimizer: updates θ in place using the step environment.
pub trait Optimizer {
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo>;

    /// Human-readable identity for logs.
    fn describe(&self) -> String;

    /// Flat auxiliary state for checkpointing (SPRING's φ; empty otherwise).
    fn state(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Restore auxiliary state from a checkpoint (no-op by default).
    fn restore_state(&mut self, _state: Vec<f64>) {}
}

/// Build the optimizer described by a run configuration.
pub fn build_optimizer(cfg: &RunConfig) -> Result<Box<dyn Optimizer>> {
    build_from_opt(&cfg.optimizer)
}

/// Build from an [`OptimizerConfig`] directly (bench harness entry point).
pub fn build_from_opt(o: &OptimizerConfig) -> Result<Box<dyn Optimizer>> {
    use crate::config::run::OptimizerKind::*;
    o.validate()?;
    Ok(match o.kind {
        Sgd => Box::new(sgd::Sgd::new(o)),
        Adam => Box::new(adam::Adam::new(o)),
        EngdDense => Box::new(engd_dense::EngdDense::new(o)),
        EngdW => Box::new(engd_w::EngdW::new(o)),
        Spring => Box::new(spring::Spring::new(o)),
        HessianFree => Box::new(hessian_free::HessianFree::new(o)),
    })
}

/// Shared helper: solve the damped kernel system `(K̂+λI) a = rhs` according
/// to the configured [`crate::config::run::SolveMode`], where `K = J Jᵀ` and
/// the randomized modes sketch `Y = J (Jᵀ Ω)` without forming K (the O(NPℓ)
/// shortcut that motivates eq. 9). Returns the solution plus reporting tags.
pub(crate) fn kernel_solve(
    j: &Matrix,
    rhs: &[f64],
    o: &OptimizerConfig,
    rng: &mut Rng,
    diagnostics: bool,
) -> Result<(Vec<f64>, Vec<(String, f64)>)> {
    use crate::config::run::SolveMode;
    let n = j.rows();
    let mut extra = Vec::new();
    let a = match o.solve {
        SolveMode::Exact => {
            let k = j.gram();
            if diagnostics {
                let d_eff = crate::nystrom::effective_dimension(&k, o.damping)?;
                extra.push(("d_eff".to_string(), d_eff));
                extra.push(("d_eff_ratio".to_string(), d_eff / n as f64));
            }
            let ch = crate::linalg::Cholesky::factor(&k.add_diag(o.damping))?;
            ch.solve(rhs)
        }
        SolveMode::NystromGpu => {
            let nys = build_gpu_nystrom(j, o, rng, &mut extra)?;
            crate::nystrom::NystromApprox::inv_apply(&nys, rhs)
        }
        SolveMode::NystromStable => {
            let sketch = sketch_size(n, o.sketch_ratio);
            let mut g = Matrix::zeros(n, sketch);
            rng.fill_normal(g.data_mut());
            let omega = crate::linalg::thin_qr(&g);
            let jt_omega = j.transpose().matmul(&omega);
            let y = j.matmul(&jt_omega);
            let nys = crate::nystrom::StableNystrom::from_sketch(omega, y, o.damping)?;
            extra.push(("sketch".to_string(), sketch as f64));
            crate::nystrom::NystromApprox::inv_apply(&nys, rhs)
        }
        SolveMode::NystromPcg => {
            // Sketch-and-precondition (paper §3.3): Nyström preconditioner +
            // CG on the exact damped kernel, with matvecs K v = J(Jᵀv).
            let nys = build_gpu_nystrom(j, o, rng, &mut extra)?;
            let lam = o.damping;
            let out = crate::nystrom::nystrom_pcg(
                |v| {
                    let jtv = j.tr_matvec(v);
                    let mut kv = j.matvec(&jtv);
                    for (kvi, vi) in kv.iter_mut().zip(v) {
                        *kvi += lam * vi;
                    }
                    kv
                },
                &nys,
                rhs,
                o.cg_iters,
                o.cg_tol.max(1e-12),
            )?;
            extra.push(("pcg_iters".to_string(), out.iterations as f64));
            extra.push(("pcg_rel_res".to_string(), out.rel_residual));
            out.x
        }
    };
    Ok((a, extra))
}

pub(crate) fn sketch_size(n: usize, ratio: f64) -> usize {
    ((n as f64 * ratio).round() as usize).clamp(1, n)
}

/// GPU-efficient Nyström of `K = J Jᵀ` from Jacobian sketches, honoring the
/// configured rank policy (fixed = paper default, adaptive = paper §5
/// future work).
fn build_gpu_nystrom(
    j: &Matrix,
    o: &OptimizerConfig,
    rng: &mut Rng,
    extra: &mut Vec<(String, f64)>,
) -> Result<crate::nystrom::GpuNystrom> {
    use crate::config::run::RankPolicy;
    let n = j.rows();
    match o.rank_policy {
        RankPolicy::Fixed => {
            let sketch = sketch_size(n, o.sketch_ratio);
            let mut omega = Matrix::zeros(n, sketch);
            rng.fill_normal(omega.data_mut());
            // Y = J (Jᵀ Ω): two tall products, never the N×N kernel.
            let jt_omega = j.transpose().matmul(&omega);
            let y = j.matmul(&jt_omega);
            extra.push(("sketch".to_string(), sketch as f64));
            crate::nystrom::GpuNystrom::from_sketch(omega, y, o.damping)
        }
        RankPolicy::Adaptive => {
            let out = crate::nystrom::adaptive_nystrom_from_jacobian(
                j,
                o.damping,
                o.sketch_ratio,
                o.sketch_max_ratio,
                10.0,
                rng,
            )?;
            let sketch = crate::nystrom::NystromApprox::sketch_size(&out.approx);
            extra.push(("sketch".to_string(), sketch as f64));
            extra.push(("rank_retries".to_string(), (out.schedule.len() - 1) as f64));
            Ok(out.approx)
        }
    }
}
