//! The optimizer suite (paper §3–§4).
//!
//! Paper contributions:
//! * [`engd_w`] — ENGD via the Woodbury/kernel identity (eq. 5), fused-
//!   artifact or Rust-linalg paths, with optional randomized Nyström solves
//!   (eq. 9).
//! * [`spring`] — SPRING momentum (eqs. 7–8, Algorithm 1) with the paper's
//!   bias correction.
//!
//! Baselines the paper evaluates against (§4, Appendix A.1):
//! * [`engd_dense`] — the original O(P³) ENGD (Müller–Zeinhofer 2023) with
//!   Gramian EMA and identity init,
//! * [`hessian_free`] — truncated-CG Gauss–Newton (Martens 2010),
//! * [`sgd`] / [`adam`] — tuned first-order baselines.
//!
//! All second-order paths are written against the [`KernelOp`] operator
//! abstraction (see [`kernel`]) and draw their dense temporaries from the
//! trainer-owned [`Workspace`] threaded through [`StepEnv`], so the hot
//! loop never materializes a transpose and reuses its buffers every step.
//! The operator exposes pooled matvec twins (`apply_into` / `apply_t_into`
//! / `apply_j_into`) alongside the allocating forms; [`kernel_solve`] and
//! every optimizer's inner loop (SPRING's ζ/φ pipeline, Hessian-free CG,
//! the PCG matvec loop) run exclusively on the pooled variants, so after
//! one warm-up step the matvec loops allocate nothing — `scratch_stats()`
//! stays frozen. Solution vectors returned by [`kernel_solve`] live in
//! pooled storage and are recycled by their consumers.
//!
//! Model evaluation goes through the [`crate::backend::Evaluator`] seam:
//! optimizers see only `loss` / `(r, J)` / `∇L`, so the same suite runs on
//! the PJRT artifact runtime and on the pure-Rust native backend. Fused
//! single-artifact steps remain PJRT-specific and fall back to the
//! decomposed path elsewhere.

mod adam;
mod engd_dense;
mod engd_w;
mod hessian_free;
pub mod kernel;
mod line_search;
mod sgd;
mod spring;

pub use adam::Adam;
pub use engd_dense::EngdDense;
pub use engd_w::EngdW;
pub use hessian_free::HessianFree;
pub use kernel::{DenseKernel, JacobianKernel, KernelOp};
pub use line_search::{golden_section, grid_line_search, grid_search, LineSearchResult};
pub use sgd::Sgd;
pub use spring::Spring;

use anyhow::{anyhow, Result};

use crate::backend::{Evaluator, NumericsMode};
use crate::config::{OptimizerConfig, RunConfig};
use crate::linalg::{Matrix, Workspace};
use crate::pde::ProblemSpec;
use crate::rng::Rng;

/// Everything an optimizer can see during one step.
pub struct StepEnv<'a> {
    /// The evaluation backend (PJRT artifacts or native Rust AD).
    pub eval: &'a dyn Evaluator,
    pub problem: &'a ProblemSpec,
    /// Interior collocation points, row-major (N_Ω × d).
    pub x_int: &'a [f64],
    /// Boundary points, row-major (N_∂Ω × d).
    pub x_bnd: &'a [f64],
    /// 1-based step index (drives SPRING's bias correction).
    pub k: usize,
    /// Per-step RNG stream (sketches, etc.), derived from (run seed, k) so
    /// resumed runs reproduce the uninterrupted trajectory bit-for-bit.
    pub rng: &'a mut Rng,
    /// Trainer-owned step-buffer pool: Gram matrices, sketches, Nyström
    /// factors, and native-backend Jacobians are checked out here and
    /// recycled across steps.
    pub ws: &'a mut Workspace,
    /// If true, this step should also compute diagnostics (d_eff).
    pub diagnostics: bool,
    /// Numerics tier for dense kernel stages (`--numerics`): `Bitwise`
    /// keeps every product in fixed-order f64; `Fast` lets Gram/sketch
    /// panels run f32-compute/f64-accumulate through the operator layer.
    pub numerics: NumericsMode,
}

impl StepEnv<'_> {
    /// Evaluate `L(θ)` on this step's batch (used by line searches).
    pub fn eval_loss(&self, theta: &[f64]) -> Result<f64> {
        self.eval.loss(self.problem, theta, self.x_int, self.x_bnd)
    }

    /// `(r, J)` on this step's batch; dense J storage comes from the step
    /// workspace — recycle it (`env.ws.recycle_matrix(j)`) when done.
    pub fn residuals_jacobian(&mut self, theta: &[f64]) -> Result<(Vec<f64>, Matrix)> {
        self.eval
            .residuals_jacobian(self.problem, theta, self.x_int, self.x_bnd, self.ws)
    }

    /// `(loss, ∇L)` on this step's batch (the first-order path).
    pub fn loss_and_grad(&self, theta: &[f64]) -> Result<(f64, Vec<f64>)> {
        self.eval
            .loss_and_grad(self.problem, theta, self.x_int, self.x_bnd)
    }

    /// Whether the backend offers fused step artifacts (PJRT only). The
    /// fused optimizer paths fall back to decomposed when it doesn't.
    pub fn fused_available(&self) -> bool {
        self.eval.as_pjrt().is_some()
    }

    /// A fused step artifact by name (errors on non-PJRT backends — guard
    /// with [`StepEnv::fused_available`]).
    pub fn artifact(&self, name: &str) -> Result<std::rc::Rc<crate::runtime::Artifact>> {
        let rt = self.eval.as_pjrt().ok_or_else(|| {
            anyhow!(
                "artifact '{name}' requested on the '{}' backend (fused paths are PJRT-only)",
                self.eval.backend_name()
            )
        })?;
        rt.artifact(&self.problem.name, name)
    }
}

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepInfo {
    /// Training loss at the *pre-update* iterate (as the artifacts report).
    pub loss: f64,
    /// Step size actually applied (post line search).
    pub lr_used: f64,
    /// Optimizer-specific scalars (d_eff, cg iterations, sketch size, ...).
    pub extra: Vec<(String, f64)>,
}

/// A PINN optimizer: updates θ in place using the step environment.
pub trait Optimizer {
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo>;

    /// Human-readable identity for logs.
    fn describe(&self) -> String;

    /// Flat auxiliary state for checkpointing, sufficient for bit-exact
    /// resume: SPRING's φ, Adam's `[t, m, v]`, SGD's velocity,
    /// Hessian-free's `[λ, CG warm start]`, dense ENGD's `[P, EMA Gramian]`;
    /// empty for stateless optimizers.
    fn state(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Restore auxiliary state from a checkpoint (no-op by default).
    fn restore_state(&mut self, _state: Vec<f64>) {}
}

/// Build the optimizer described by a run configuration.
pub fn build_optimizer(cfg: &RunConfig) -> Result<Box<dyn Optimizer>> {
    build_from_opt(&cfg.optimizer)
}

/// Build from an [`OptimizerConfig`] directly (bench harness entry point).
pub fn build_from_opt(o: &OptimizerConfig) -> Result<Box<dyn Optimizer>> {
    use crate::config::run::OptimizerKind::*;
    o.validate()?;
    Ok(match o.kind {
        Sgd => Box::new(sgd::Sgd::new(o)),
        Adam => Box::new(adam::Adam::new(o)),
        EngdDense => Box::new(engd_dense::EngdDense::new(o)),
        EngdW => Box::new(engd_w::EngdW::new(o)),
        Spring => Box::new(spring::Spring::new(o)),
        HessianFree => Box::new(hessian_free::HessianFree::new(o)),
    })
}

/// Unified solve path: solve the damped kernel system `(K̂+λI) a = rhs`
/// according to the configured [`crate::config::run::SolveMode`], where the
/// kernel is presented as a [`KernelOp`] — so the same code serves the dense
/// Jacobian path today and a sharded/PJRT-backed operator later. Dense
/// temporaries (Gram, sketches, Nyström factors) come from — and return to —
/// the caller's [`Workspace`], so repeated calls with fixed shapes allocate
/// only on the first. The returned solution vector also lives in pooled
/// storage: recycle it (`ws.recycle(a)`) once it has been consumed, or the
/// steady-state freeze breaks. Returns the solution plus reporting tags.
pub fn kernel_solve(
    op: &dyn KernelOp,
    rhs: &[f64],
    o: &OptimizerConfig,
    rng: &mut Rng,
    ws: &mut Workspace,
    diagnostics: bool,
) -> Result<(Vec<f64>, Vec<(String, f64)>)> {
    use crate::config::run::SolveMode;
    let n = op.size();
    let mut extra = Vec::new(); // lint: allow(alloc) — returned reporting tags
    let a = match o.solve {
        SolveMode::Exact => {
            let mut k = op.gram(ws);
            if diagnostics {
                let d_eff = crate::nystrom::effective_dimension(&k, o.damping)?;
                extra.push(("d_eff".to_string(), d_eff));
                extra.push(("d_eff_ratio".to_string(), d_eff / n as f64));
            }
            k.add_diag_in_place(o.damping);
            let ch = crate::linalg::Cholesky::factor_from(k)?;
            let mut x = ws.take_scratch(n);
            ch.solve_into(rhs, &mut x);
            ws.recycle_matrix(ch.into_factor());
            x
        }
        SolveMode::NystromGpu => {
            let nys = build_gpu_nystrom(op, o, rng, ws, &mut extra)?;
            let mut x = ws.take_scratch(n);
            crate::nystrom::NystromApprox::inv_apply_into(&nys, rhs, &mut x, ws);
            nys.recycle(ws);
            x
        }
        SolveMode::NystromStable => {
            let sketch = sketch_size(n, o.sketch_ratio);
            let mut g = ws.take_matrix_scratch(n, sketch);
            rng.fill_normal(g.data_mut());
            let mut omega = ws.take_matrix_scratch(n, sketch);
            crate::linalg::thin_qr_into(&g, &mut omega, ws);
            ws.recycle_matrix(g);
            let y = op.sketch_y(&omega, ws);
            let nys = crate::nystrom::StableNystrom::from_sketch(omega, y, o.damping, ws)?;
            extra.push(("sketch".to_string(), sketch as f64));
            let mut x = ws.take_scratch(n);
            crate::nystrom::NystromApprox::inv_apply_into(&nys, rhs, &mut x, ws);
            nys.recycle(ws);
            x
        }
        SolveMode::NystromPcg => {
            // Sketch-and-precondition (paper §3.3): Nyström preconditioner +
            // CG on the exact damped kernel, with matvecs K v = J(Jᵀv)
            // supplied by the operator.
            let nys = build_gpu_nystrom(op, o, rng, ws, &mut extra)?;
            let out = crate::nystrom::nystrom_pcg(
                op,
                o.damping,
                &nys,
                rhs,
                o.cg_iters,
                o.cg_tol.max(1e-12),
                ws,
            )?;
            extra.push(("pcg_iters".to_string(), out.iterations as f64));
            extra.push(("pcg_rel_res".to_string(), out.rel_residual));
            nys.recycle(ws);
            out.x
        }
    };
    Ok((a, extra))
}

pub(crate) fn sketch_size(n: usize, ratio: f64) -> usize {
    ((n as f64 * ratio).round() as usize).clamp(1, n)
}

/// GPU-efficient Nyström of the operator's kernel from sketches, honoring
/// the configured rank policy (fixed = paper default, adaptive = paper §5
/// future work).
fn build_gpu_nystrom(
    op: &dyn KernelOp,
    o: &OptimizerConfig,
    rng: &mut Rng,
    ws: &mut Workspace,
    extra: &mut Vec<(String, f64)>,
) -> Result<crate::nystrom::GpuNystrom> {
    use crate::config::run::RankPolicy;
    let n = op.size();
    match o.rank_policy {
        RankPolicy::Fixed => {
            let sketch = sketch_size(n, o.sketch_ratio);
            let mut omega = ws.take_matrix_scratch(n, sketch);
            rng.fill_normal(omega.data_mut());
            // Y = J (Jᵀ Ω): two tall products, never the N×N kernel.
            let y = op.sketch_y(&omega, ws);
            extra.push(("sketch".to_string(), sketch as f64));
            crate::nystrom::GpuNystrom::from_sketch(omega, y, o.damping, ws)
        }
        RankPolicy::Adaptive => {
            let out = crate::nystrom::adaptive_nystrom(
                op,
                o.damping,
                o.sketch_ratio,
                o.sketch_max_ratio,
                10.0,
                rng,
                ws,
            )?;
            let sketch = crate::nystrom::NystromApprox::sketch_size(&out.approx);
            extra.push(("sketch".to_string(), sketch as f64));
            extra.push(("rank_retries".to_string(), (out.schedule.len() - 1) as f64));
            Ok(out.approx)
        }
    }
}
