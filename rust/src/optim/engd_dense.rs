//! Original (dense) ENGD — Müller & Zeinhofer 2023, the paper's eq. (1)–(4):
//!
//! `θ ← θ − η (G + λI)⁻¹ ∇L`,   `G = Jᵀ J ∈ R^{P×P}`
//!
//! This is the O(P³) baseline the Woodbury identity obsoletes. Forming and
//! factoring the P×P Gramian is *supposed* to be slow — Fig. 2's point is
//! that ENGD-W takes 30× more steps in the same wall-clock budget. Appendix
//! A.1 tunes: damping, Gramian EMA factor, and identity-vs-zero Gramian
//! initialization; all three are implemented here.
//!
//! A guard refuses P > `MAX_DENSE_PARAMS` (the paper's ENGD likewise OOMs on
//! the 10d/100d networks and is excluded there, Appendix A.3).

use anyhow::{bail, Result};

use super::{grid_line_search, JacobianKernel, KernelOp, Optimizer, StepEnv, StepInfo};
use crate::config::OptimizerConfig;
use crate::linalg::{Cholesky, Matrix};

/// Dense ENGD refuses to run above this parameter count (24 GiB-class guard,
/// mirroring the paper's OOM boundary).
pub const MAX_DENSE_PARAMS: usize = 20_000;

pub struct EngdDense {
    cfg: OptimizerConfig,
    /// EMA-accumulated Gramian (P×P), lazily initialized.
    gramian: Option<Matrix>,
}

impl EngdDense {
    pub fn new(o: &OptimizerConfig) -> Self {
        EngdDense {
            cfg: o.clone(),
            gramian: None,
        }
    }
}

impl Optimizer for EngdDense {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let p = env.problem.n_params;
        if p > MAX_DENSE_PARAMS {
            bail!(
                "dense ENGD: P = {p} exceeds {MAX_DENSE_PARAMS} — the paper's \
                 original ENGD runs out of memory here too (A.3); use engd_w"
            );
        }
        let (r, j) = env.residuals_jacobian(theta)?;
        let loss = 0.5 * crate::linalg::dot(&r, &r);
        let op = JacobianKernel::with_numerics(&j, env.numerics);
        let mut grad = env.ws.take_scratch(p);
        op.apply_t_into(&r, &mut grad);

        // G_batch = Jᵀ J through the operator (fused — Jᵀ is never
        // materialized), drawn from the step workspace, then EMA'd into the
        // accumulator.
        let g_batch = op.gram_t(env.ws);
        let ema = self.cfg.ema;
        let gram = match self.gramian.take() {
            None => {
                if ema > 0.0 {
                    // First step of the EMA recursion G_k = ema·G_{k−1} +
                    // (1−ema)·G_batch from the configured G₀ (Appendix
                    // A.1's identity-vs-zero distinction): the (1−ema)
                    // scaling applies either way — skipping it for the
                    // zero init made G₁ the raw batch Gramian, i.e. the
                    // two inits were indistinguishable on step 1.
                    let mut g = g_batch;
                    g.scale_in_place(1.0 - ema);
                    if self.cfg.gramian_identity_init {
                        // G₀ = I: ema·I joins the batch term.
                        for i in 0..p {
                            g[(i, i)] += ema;
                        }
                    }
                    g
                } else {
                    g_batch
                }
            }
            Some(mut acc) => {
                if ema > 0.0 {
                    acc.scale_in_place(ema);
                    acc.add_scaled(&g_batch, 1.0 - ema);
                    env.ws.recycle_matrix(g_batch);
                    acc
                } else {
                    env.ws.recycle_matrix(acc);
                    g_batch
                }
            }
        };

        // Damped copy in a pooled buffer, factored in place — the persistent
        // EMA accumulator itself is left untouched.
        let mut damped = env.ws.take_matrix_scratch(p, p);
        damped.data_mut().copy_from_slice(gram.data());
        damped.add_diag_in_place(self.cfg.damping);
        let ch = match Cholesky::factor_from(damped) {
            Ok(ch) => ch,
            Err(e) => {
                // A non-SPD Gramian aborts the step: keep the EMA state and
                // return every live checkout to the pool (engd-lint R6).
                self.gramian = Some(gram);
                drop(op);
                env.ws.recycle_matrix(j);
                env.ws.recycle(grad);
                return Err(e);
            }
        };
        let mut phi = env.ws.take_scratch(p);
        ch.solve_into(&grad, &mut phi);
        env.ws.recycle_matrix(ch.into_factor());
        self.gramian = Some(gram);
        drop(op);
        env.ws.recycle_matrix(j);

        let eta = if self.cfg.line_search {
            let ls = match grid_line_search(env, theta, &phi, loss, self.cfg.ls_eta_max, self.cfg.ls_grid)
            {
                Ok(ls) => ls,
                Err(e) => {
                    env.ws.recycle(phi);
                    env.ws.recycle(grad);
                    return Err(e);
                }
            };
            ls.eta
        } else {
            self.cfg.lr
        };
        for (t, d) in theta.iter_mut().zip(&phi) {
            *t -= eta * d;
        }
        let grad_norm = crate::linalg::norm2(&grad);
        env.ws.recycle(phi);
        env.ws.recycle(grad);
        Ok(StepInfo {
            loss,
            lr_used: eta,
            // Reporting tuple handed to the metrics logger, not kernel math.
            extra: vec![("grad_norm".into(), grad_norm)], // lint: allow(alloc)
        })
    }

    /// Checkpoint layout: `[P, G…]` — the accumulator dimension (doubling
    /// as the "EMA initialized" flag) followed by the flattened P×P EMA
    /// Gramian; empty before the first step. Without this state a resumed
    /// dense-ENGD run would silently restart the EMA recursion from
    /// scratch instead of replaying the uninterrupted trajectory.
    fn state(&self) -> Vec<f64> {
        match &self.gramian {
            None => Vec::new(),
            Some(g) => {
                let mut s = Vec::with_capacity(1 + g.data().len());
                s.push(g.rows() as f64);
                s.extend_from_slice(g.data());
                s
            }
        }
    }

    fn restore_state(&mut self, state: Vec<f64>) {
        if state.is_empty() {
            self.gramian = None;
            return;
        }
        let p = state[0] as usize;
        // A malformed vector (wrong optimizer, truncated or hand-edited
        // file) is dropped rather than misread; the trainer's kind check
        // should have caught it already. The MAX_DENSE_PARAMS bound also
        // keeps p*p from overflowing on a garbage dimension scalar.
        if p <= MAX_DENSE_PARAMS && state.len() == 1 + p * p {
            self.gramian = Some(Matrix::from_vec(p, p, state[1..].to_vec()));
        }
    }

    fn describe(&self) -> String {
        format!(
            "engd_dense(λ={:.3e}, ema={}, {})",
            self.cfg.damping,
            self.cfg.ema,
            if self.cfg.line_search {
                "line-search".to_string()
            } else {
                format!("lr={:.3e}", self.cfg.lr)
            }
        )
    }
}
