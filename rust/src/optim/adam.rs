//! Adam — first-order baseline (paper §4; only the learning rate is tuned,
//! Appendix A.1).

use anyhow::Result;

use super::{Optimizer, StepEnv, StepInfo};
use crate::config::OptimizerConfig;

pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl Adam {
    pub fn new(o: &OptimizerConfig) -> Self {
        Adam {
            lr: o.lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let (loss, grad) = env.loss_and_grad(theta)?;
        if self.m.is_empty() {
            self.m = vec![0.0; theta.len()];
            self.v = vec![0.0; theta.len()];
        }
        let k = env.k as i32;
        let bc1 = 1.0 - self.beta1.powi(k);
        let bc2 = 1.0 - self.beta2.powi(k);
        for i in 0..theta.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            theta[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
        Ok(StepInfo {
            loss,
            lr_used: self.lr,
            extra: vec![("grad_norm".into(), crate::linalg::norm2(&grad))],
        })
    }

    fn describe(&self) -> String {
        format!("adam(lr={:.3e})", self.lr)
    }
}
