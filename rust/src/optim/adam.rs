//! Adam — first-order baseline (paper §4; only the learning rate is tuned,
//! Appendix A.1).

use anyhow::Result;

use super::{Optimizer, StepEnv, StepInfo};
use crate::config::OptimizerConfig;

pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    /// Number of updates applied (drives the bias corrections; checkpointed
    /// so resumed runs correct with the true global step count).
    t: usize,
    m: Vec<f64>,
    v: Vec<f64>,
}

impl Adam {
    pub fn new(o: &OptimizerConfig) -> Self {
        Adam {
            lr: o.lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl Optimizer for Adam {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let (loss, grad) = env.loss_and_grad(theta)?;
        if self.m.is_empty() {
            // First-step lazy init only; both vectors persist across steps.
            self.m = vec![0.0; theta.len()]; // lint: allow(alloc)
            self.v = vec![0.0; theta.len()]; // lint: allow(alloc)
        }
        self.t += 1;
        let k = self.t as i32;
        let bc1 = 1.0 - self.beta1.powi(k);
        let bc2 = 1.0 - self.beta2.powi(k);
        for i in 0..theta.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            theta[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
        Ok(StepInfo {
            loss,
            lr_used: self.lr,
            // Reporting tuple handed to the metrics logger, not kernel math.
            extra: vec![("grad_norm".into(), crate::linalg::norm2(&grad))], // lint: allow(alloc)
        })
    }

    /// Checkpoint layout: `[t, m…, v…]` — everything a resumed run needs to
    /// reproduce the uninterrupted update sequence bit-for-bit.
    fn state(&self) -> Vec<f64> {
        if self.m.is_empty() {
            return Vec::new();
        }
        let mut s = Vec::with_capacity(1 + self.m.len() + self.v.len());
        s.push(self.t as f64);
        s.extend_from_slice(&self.m);
        s.extend_from_slice(&self.v);
        s
    }

    fn restore_state(&mut self, state: Vec<f64>) {
        if state.is_empty() {
            return;
        }
        self.t = state[0] as usize;
        let rest = &state[1..];
        let half = rest.len() / 2;
        self.m = rest[..half].to_vec();
        self.v = rest[half..].to_vec();
    }

    fn describe(&self) -> String {
        format!("adam(lr={:.3e})", self.lr)
    }
}
