//! The kernel-operator abstraction: `K = J Jᵀ` as an operator, not a matrix.
//!
//! Every second-order path in the paper touches the kernel only through a
//! handful of primitives — apply it (`Kv`), map back (`Jᵀa`), push forward
//! (`Jw`), densify it (eq. 5's exact solve), or sketch it (`Y = KΩ`,
//! eq. 9 / Algorithm 2, formed as two tall products `J(JᵀΩ)` without ever
//! building K). [`KernelOp`] names exactly those primitives, so
//!
//! * the optimizers (`EngdW`, `Spring`, `EngdDense`, `HessianFree`) and all
//!   four [`crate::config::run::SolveMode`] branches are written once
//!   against `&dyn KernelOp`,
//! * the Nyström builders consume the operator + a [`Workspace`] instead of
//!   a concrete `&Matrix`,
//! * and a sharded or PJRT-backed operator (jtv/jv artifacts, ROADMAP) can
//!   drop in later without touching any optimizer.
//!
//! Two implementations ship today: [`JacobianKernel`] (dense row-major J —
//! the decomposed training path) and [`DenseKernel`] (an explicit PSD
//! matrix — tests, Appendix-B micro-benchmarks).

use crate::backend::NumericsMode;
use crate::linalg::{Matrix, Workspace};

/// A symmetric PSD kernel operator `K ∈ R^{N×N}` of Gram form `K = J Jᵀ`
/// with `J ∈ R^{N×P}`, exposed through the primitives the optimizer suite
/// needs. All dense outputs are drawn from the caller's [`Workspace`].
///
/// Every allocating primitive has a pooled `*_into` twin that writes into a
/// caller-provided buffer; the iterative solvers (`nystrom_pcg`, CG,
/// Hessian-free) run their matvec loops exclusively on the pooled forms so
/// that steady-state iterations allocate nothing. The defaults fall back to
/// the allocating methods, so external implementations keep working; the
/// shipped kernels override them with genuinely allocation-free paths that
/// match the allocating methods bitwise.
pub trait KernelOp {
    /// Kernel dimension N (number of residuals / collocation points).
    fn size(&self) -> usize;

    /// Parameter dimension P.
    fn params(&self) -> usize;

    /// `K v = J (Jᵀ v)` — the sample-space operator application (PCG
    /// matvecs, eq. 9's iterative alternative).
    fn apply(&self, v: &[f64]) -> Vec<f64>;

    /// `Jᵀ a` — map a kernel-space solution back to parameter space
    /// (the φ = Jᵀa step of eq. 5 / Algorithm 1 line 8).
    fn apply_t(&self, a: &[f64]) -> Vec<f64>;

    /// `J w` — parameter→sample push-forward (SPRING's ζ shift, line 6;
    /// Hessian-free's Gauss–Newton products).
    fn apply_j(&self, w: &[f64]) -> Vec<f64>;

    /// Pooled `K v` into `out` (length N); interior scratch comes from
    /// `ws`. Bitwise-equal to [`KernelOp::apply`].
    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let _ = ws;
        out.copy_from_slice(&self.apply(v));
    }

    /// Pooled `Jᵀ a` into `out` (length P). Bitwise-equal to
    /// [`KernelOp::apply_t`].
    fn apply_t_into(&self, a: &[f64], out: &mut [f64]) {
        out.copy_from_slice(&self.apply_t(a));
    }

    /// Pooled `J w` into `out` (length N). Bitwise-equal to
    /// [`KernelOp::apply_j`].
    fn apply_j_into(&self, w: &[f64], out: &mut [f64]) {
        out.copy_from_slice(&self.apply_j(w));
    }

    /// Densify `K = J Jᵀ` into a workspace buffer (the exact path of
    /// eq. 5). Recycle the returned matrix when done.
    fn gram(&self, ws: &mut Workspace) -> Matrix;

    /// Densify the parameter-space Gramian `G = Jᵀ J` (dense ENGD, eq. 1)
    /// into a workspace buffer.
    fn gram_t(&self, ws: &mut Workspace) -> Matrix;

    /// Sketch `Y = K Ω` into a workspace buffer, without forming K: two
    /// tall products `J (Jᵀ Ω)` — O(NPℓ), the whole point of eq. 9.
    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix;
}

/// The dense-Jacobian kernel operator: `K = J Jᵀ` for a row-major
/// N×P Jacobian produced by the `residuals_jacobian` artifact.
///
/// Under [`NumericsMode::Fast`] the dense Gram/sketch products run on the
/// f32-compute/f64-accumulate tier ([`Matrix::gram_into_fast`] and
/// friends); [`NumericsMode::Bitwise`] (the default) keeps every product on
/// the deterministic f64 kernels.
pub struct JacobianKernel<'a> {
    j: &'a Matrix,
    numerics: NumericsMode,
}

impl<'a> JacobianKernel<'a> {
    pub fn new(j: &'a Matrix) -> Self {
        Self::with_numerics(j, NumericsMode::Bitwise)
    }

    /// Wrap a Jacobian with an explicit numerics tier (the trainer threads
    /// the run's `--numerics` mode through [`crate::optim::StepEnv`]).
    pub fn with_numerics(j: &'a Matrix, numerics: NumericsMode) -> Self {
        JacobianKernel { j, numerics }
    }

    /// The underlying Jacobian.
    pub fn jacobian(&self) -> &Matrix {
        self.j
    }
}

impl KernelOp for JacobianKernel<'_> {
    fn size(&self) -> usize {
        self.j.rows()
    }

    fn params(&self) -> usize {
        self.j.cols()
    }

    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let jtv = self.j.tr_matvec(v);
        self.j.matvec(&jtv)
    }

    fn apply_t(&self, a: &[f64]) -> Vec<f64> {
        self.j.tr_matvec(a)
    }

    fn apply_j(&self, w: &[f64]) -> Vec<f64> {
        self.j.matvec(w)
    }

    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let mut jtv = ws.take_scratch(self.j.cols());
        self.j.tr_matvec_into(v, &mut jtv);
        self.j.matvec_into(&jtv, out);
        ws.recycle(jtv);
    }

    fn apply_t_into(&self, a: &[f64], out: &mut [f64]) {
        self.j.tr_matvec_into(a, out);
    }

    fn apply_j_into(&self, w: &[f64], out: &mut [f64]) {
        self.j.matvec_into(w, out);
    }

    fn gram(&self, ws: &mut Workspace) -> Matrix {
        let n = self.j.rows();
        let mut k = ws.take_matrix_scratch(n, n);
        match self.numerics {
            NumericsMode::Fast => self.j.gram_into_fast(&mut k, ws),
            NumericsMode::Bitwise => self.j.gram_into(&mut k),
        }
        k
    }

    fn gram_t(&self, ws: &mut Workspace) -> Matrix {
        let p = self.j.cols();
        let mut g = ws.take_matrix_scratch(p, p);
        match self.numerics {
            NumericsMode::Fast => self.j.gram_t_into_fast(&mut g, ws),
            NumericsMode::Bitwise => self.j.gram_t_into(&mut g),
        }
        g
    }

    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix {
        let ell = omega.cols();
        let mut jt_omega = ws.take_matrix_scratch(self.j.cols(), ell);
        let mut y = ws.take_matrix_scratch(self.j.rows(), ell);
        match self.numerics {
            NumericsMode::Fast => {
                self.j.matmul_tn_into_fast(omega, &mut jt_omega, ws);
                self.j.matmul_into_fast(&jt_omega, &mut y, ws);
            }
            NumericsMode::Bitwise => {
                self.j.matmul_tn_into(omega, &mut jt_omega);
                self.j.matmul_into(&jt_omega, &mut y);
            }
        }
        ws.recycle_matrix(jt_omega);
        y
    }
}

/// An explicit symmetric PSD kernel (already-formed `A ≈ J Jᵀ`): the
/// operator the Appendix-B Nyström micro-benchmarks and the linalg tests
/// exercise, where no Jacobian factorization is available. `params()`
/// equals `size()` (J is implicitly A^{1/2}).
pub struct DenseKernel<'a> {
    a: &'a Matrix,
}

impl<'a> DenseKernel<'a> {
    /// Wrap a square symmetric PSD matrix.
    pub fn new(a: &'a Matrix) -> Self {
        assert_eq!(
            a.rows(),
            a.cols(),
            "DenseKernel needs a square matrix, got {}x{}",
            a.rows(),
            a.cols()
        );
        DenseKernel { a }
    }
}

impl KernelOp for DenseKernel<'_> {
    fn size(&self) -> usize {
        self.a.rows()
    }

    fn params(&self) -> usize {
        self.a.rows()
    }

    fn apply(&self, v: &[f64]) -> Vec<f64> {
        self.a.matvec(v)
    }

    fn apply_t(&self, a: &[f64]) -> Vec<f64> {
        // Symmetric: Aᵀ = A.
        self.a.matvec(a)
    }

    fn apply_j(&self, w: &[f64]) -> Vec<f64> {
        self.a.matvec(w)
    }

    fn apply_into(&self, v: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let _ = ws;
        self.a.matvec_into(v, out);
    }

    fn apply_t_into(&self, a: &[f64], out: &mut [f64]) {
        self.a.matvec_into(a, out);
    }

    fn apply_j_into(&self, w: &[f64], out: &mut [f64]) {
        self.a.matvec_into(w, out);
    }

    fn gram(&self, ws: &mut Workspace) -> Matrix {
        let n = self.a.rows();
        let mut k = ws.take_matrix_scratch(n, n);
        k.data_mut().copy_from_slice(self.a.data());
        k
    }

    fn gram_t(&self, ws: &mut Workspace) -> Matrix {
        self.gram(ws)
    }

    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut y = ws.take_matrix_scratch(self.a.rows(), omega.cols());
        self.a.matmul_into(omega, &mut y);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    /// Naive O(nmp) reference for AᵀA / AAᵀ products (kept transpose-free:
    /// this module is part of the no-materialized-transpose zone).
    fn naive_gram(j: &Matrix, of_columns: bool) -> Matrix {
        let dim = if of_columns { j.cols() } else { j.rows() };
        Matrix::from_fn(dim, dim, |a, b| {
            if of_columns {
                (0..j.rows()).map(|k| j[(k, a)] * j[(k, b)]).sum()
            } else {
                (0..j.cols()).map(|k| j[(a, k)] * j[(b, k)]).sum()
            }
        })
    }

    #[test]
    fn jacobian_kernel_matches_explicit_products() {
        let mut rng = Rng::seed_from(1);
        let j = random_matrix(&mut rng, 12, 30);
        let op = JacobianKernel::new(&j);
        assert_eq!((op.size(), op.params()), (12, 30));

        let mut ws = Workspace::new();
        let k = op.gram(&mut ws);
        let k_ref = naive_gram(&j, false);
        assert!(k.max_abs_diff(&k_ref) < 1e-10);

        let g = op.gram_t(&mut ws);
        let g_ref = naive_gram(&j, true);
        assert!(g.max_abs_diff(&g_ref) < 1e-10);

        let mut v = vec![0.0; 12];
        rng.fill_normal(&mut v);
        let kv = op.apply(&v);
        let kv_ref = k_ref.matvec(&v);
        for (a, b) in kv.iter().zip(&kv_ref) {
            assert!((a - b).abs() < 1e-9);
        }

        let omega = random_matrix(&mut rng, 12, 5);
        let y = op.sketch_y(&omega, &mut ws);
        let y_ref = k_ref.matmul(&omega);
        assert!(y.max_abs_diff(&y_ref) < 1e-9);
    }

    #[test]
    fn dense_kernel_sketch_matches_direct_product() {
        let mut rng = Rng::seed_from(2);
        let base = random_matrix(&mut rng, 10, 10);
        let a = base.gram();
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let omega = random_matrix(&mut rng, 10, 4);
        let y = op.sketch_y(&omega, &mut ws);
        assert!(y.max_abs_diff(&a.matmul(&omega)) < 1e-10);
        let k = op.gram(&mut ws);
        assert_eq!(k.max_abs_diff(&a), 0.0);
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn pooled_applies_match_allocating_bitwise() {
        let mut rng = Rng::seed_from(4);
        let j = random_matrix(&mut rng, 14, 22);
        let op = JacobianKernel::new(&j);
        let mut ws = Workspace::new();
        let mut v = vec![0.0; 14];
        rng.fill_normal(&mut v);
        let mut w = vec![0.0; 22];
        rng.fill_normal(&mut w);

        let mut kv = vec![0.0; 14];
        op.apply_into(&v, &mut kv, &mut ws);
        assert_eq!(bits(&kv), bits(&op.apply(&v)));

        let mut jta = vec![0.0; 22];
        op.apply_t_into(&v, &mut jta);
        assert_eq!(bits(&jta), bits(&op.apply_t(&v)));

        let mut jw = vec![0.0; 14];
        op.apply_j_into(&w, &mut jw);
        assert_eq!(bits(&jw), bits(&op.apply_j(&w)));

        // Steady state: a second pooled apply draws all scratch from the pool.
        let fresh = ws.stats().fresh_allocs;
        op.apply_into(&v, &mut kv, &mut ws);
        assert_eq!(ws.stats().fresh_allocs, fresh, "apply_into allocated");

        // The dense kernel's pooled forms agree bitwise too.
        let base = random_matrix(&mut rng, 10, 10);
        let a = base.gram();
        let dop = DenseKernel::new(&a);
        let mut dv = vec![0.0; 10];
        rng.fill_normal(&mut dv);
        let mut av = vec![0.0; 10];
        dop.apply_into(&dv, &mut av, &mut ws);
        assert_eq!(bits(&av), bits(&dop.apply(&dv)));
    }

    #[test]
    fn fast_numerics_gram_and_sketch_stay_within_tolerance() {
        use crate::backend::NumericsMode;
        let mut rng = Rng::seed_from(5);
        let j = random_matrix(&mut rng, 24, 18);
        let exact = JacobianKernel::new(&j);
        let fast = JacobianKernel::with_numerics(&j, NumericsMode::Fast);
        let mut ws = Workspace::new();

        let k = exact.gram(&mut ws);
        let kf = fast.gram(&mut ws);
        assert!(kf.max_abs_diff(&k) < 1e-3, "fast gram drifted");
        ws.recycle_matrix(k);
        ws.recycle_matrix(kf);

        let omega = random_matrix(&mut rng, 24, 5);
        let y = exact.sketch_y(&omega, &mut ws);
        let yf = fast.sketch_y(&omega, &mut ws);
        assert!(yf.max_abs_diff(&y) < 1e-3, "fast sketch drifted");
        ws.recycle_matrix(y);
        ws.recycle_matrix(yf);
    }

    #[test]
    fn sketch_y_reuses_workspace_buffers_across_calls() {
        let mut rng = Rng::seed_from(3);
        let j = random_matrix(&mut rng, 16, 40);
        let op = JacobianKernel::new(&j);
        let mut ws = Workspace::new();
        let omega = random_matrix(&mut rng, 16, 6);

        let y1 = op.sketch_y(&omega, &mut ws);
        ws.recycle_matrix(y1);
        let fresh_after_first = ws.stats().fresh_allocs;

        let y2 = op.sketch_y(&omega, &mut ws);
        ws.recycle_matrix(y2);
        assert_eq!(
            ws.stats().fresh_allocs,
            fresh_after_first,
            "second sketch must be served entirely from the pool"
        );
        assert!(ws.stats().reuses >= 2);
    }
}
