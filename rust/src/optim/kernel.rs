//! The kernel-operator abstraction: `K = J Jᵀ` as an operator, not a matrix.
//!
//! Every second-order path in the paper touches the kernel only through a
//! handful of primitives — apply it (`Kv`), map back (`Jᵀa`), push forward
//! (`Jw`), densify it (eq. 5's exact solve), or sketch it (`Y = KΩ`,
//! eq. 9 / Algorithm 2, formed as two tall products `J(JᵀΩ)` without ever
//! building K). [`KernelOp`] names exactly those primitives, so
//!
//! * the optimizers (`EngdW`, `Spring`, `EngdDense`, `HessianFree`) and all
//!   four [`crate::config::run::SolveMode`] branches are written once
//!   against `&dyn KernelOp`,
//! * the Nyström builders consume the operator + a [`Workspace`] instead of
//!   a concrete `&Matrix`,
//! * and a sharded or PJRT-backed operator (jtv/jv artifacts, ROADMAP) can
//!   drop in later without touching any optimizer.
//!
//! Two implementations ship today: [`JacobianKernel`] (dense row-major J —
//! the decomposed training path) and [`DenseKernel`] (an explicit PSD
//! matrix — tests, Appendix-B micro-benchmarks).

use crate::linalg::{Matrix, Workspace};

/// A symmetric PSD kernel operator `K ∈ R^{N×N}` of Gram form `K = J Jᵀ`
/// with `J ∈ R^{N×P}`, exposed through the primitives the optimizer suite
/// needs. All dense outputs are drawn from the caller's [`Workspace`].
pub trait KernelOp {
    /// Kernel dimension N (number of residuals / collocation points).
    fn size(&self) -> usize;

    /// Parameter dimension P.
    fn params(&self) -> usize;

    /// `K v = J (Jᵀ v)` — the sample-space operator application (PCG
    /// matvecs, eq. 9's iterative alternative).
    fn apply(&self, v: &[f64]) -> Vec<f64>;

    /// `Jᵀ a` — map a kernel-space solution back to parameter space
    /// (the φ = Jᵀa step of eq. 5 / Algorithm 1 line 8).
    fn apply_t(&self, a: &[f64]) -> Vec<f64>;

    /// `J w` — parameter→sample push-forward (SPRING's ζ shift, line 6;
    /// Hessian-free's Gauss–Newton products).
    fn apply_j(&self, w: &[f64]) -> Vec<f64>;

    /// Densify `K = J Jᵀ` into a workspace buffer (the exact path of
    /// eq. 5). Recycle the returned matrix when done.
    fn gram(&self, ws: &mut Workspace) -> Matrix;

    /// Densify the parameter-space Gramian `G = Jᵀ J` (dense ENGD, eq. 1)
    /// into a workspace buffer.
    fn gram_t(&self, ws: &mut Workspace) -> Matrix;

    /// Sketch `Y = K Ω` into a workspace buffer, without forming K: two
    /// tall products `J (Jᵀ Ω)` — O(NPℓ), the whole point of eq. 9.
    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix;
}

/// The dense-Jacobian kernel operator: `K = J Jᵀ` for a row-major
/// N×P Jacobian produced by the `residuals_jacobian` artifact.
pub struct JacobianKernel<'a> {
    j: &'a Matrix,
}

impl<'a> JacobianKernel<'a> {
    pub fn new(j: &'a Matrix) -> Self {
        JacobianKernel { j }
    }

    /// The underlying Jacobian.
    pub fn jacobian(&self) -> &Matrix {
        self.j
    }
}

impl KernelOp for JacobianKernel<'_> {
    fn size(&self) -> usize {
        self.j.rows()
    }

    fn params(&self) -> usize {
        self.j.cols()
    }

    fn apply(&self, v: &[f64]) -> Vec<f64> {
        let jtv = self.j.tr_matvec(v);
        self.j.matvec(&jtv)
    }

    fn apply_t(&self, a: &[f64]) -> Vec<f64> {
        self.j.tr_matvec(a)
    }

    fn apply_j(&self, w: &[f64]) -> Vec<f64> {
        self.j.matvec(w)
    }

    fn gram(&self, ws: &mut Workspace) -> Matrix {
        let n = self.j.rows();
        let mut k = ws.take_matrix_scratch(n, n);
        self.j.gram_into(&mut k);
        k
    }

    fn gram_t(&self, ws: &mut Workspace) -> Matrix {
        let p = self.j.cols();
        let mut g = ws.take_matrix_scratch(p, p);
        self.j.gram_t_into(&mut g);
        g
    }

    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix {
        let ell = omega.cols();
        let mut jt_omega = ws.take_matrix_scratch(self.j.cols(), ell);
        self.j.matmul_tn_into(omega, &mut jt_omega);
        let mut y = ws.take_matrix_scratch(self.j.rows(), ell);
        self.j.matmul_into(&jt_omega, &mut y);
        ws.recycle_matrix(jt_omega);
        y
    }
}

/// An explicit symmetric PSD kernel (already-formed `A ≈ J Jᵀ`): the
/// operator the Appendix-B Nyström micro-benchmarks and the linalg tests
/// exercise, where no Jacobian factorization is available. `params()`
/// equals `size()` (J is implicitly A^{1/2}).
pub struct DenseKernel<'a> {
    a: &'a Matrix,
}

impl<'a> DenseKernel<'a> {
    /// Wrap a square symmetric PSD matrix.
    pub fn new(a: &'a Matrix) -> Self {
        assert_eq!(
            a.rows(),
            a.cols(),
            "DenseKernel needs a square matrix, got {}x{}",
            a.rows(),
            a.cols()
        );
        DenseKernel { a }
    }
}

impl KernelOp for DenseKernel<'_> {
    fn size(&self) -> usize {
        self.a.rows()
    }

    fn params(&self) -> usize {
        self.a.rows()
    }

    fn apply(&self, v: &[f64]) -> Vec<f64> {
        self.a.matvec(v)
    }

    fn apply_t(&self, a: &[f64]) -> Vec<f64> {
        // Symmetric: Aᵀ = A.
        self.a.matvec(a)
    }

    fn apply_j(&self, w: &[f64]) -> Vec<f64> {
        self.a.matvec(w)
    }

    fn gram(&self, ws: &mut Workspace) -> Matrix {
        let n = self.a.rows();
        let mut k = ws.take_matrix_scratch(n, n);
        k.data_mut().copy_from_slice(self.a.data());
        k
    }

    fn gram_t(&self, ws: &mut Workspace) -> Matrix {
        self.gram(ws)
    }

    fn sketch_y(&self, omega: &Matrix, ws: &mut Workspace) -> Matrix {
        let mut y = ws.take_matrix_scratch(self.a.rows(), omega.cols());
        self.a.matmul_into(omega, &mut y);
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_matrix(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    /// Naive O(nmp) reference for AᵀA / AAᵀ products (kept transpose-free:
    /// this module is part of the no-materialized-transpose zone).
    fn naive_gram(j: &Matrix, of_columns: bool) -> Matrix {
        let dim = if of_columns { j.cols() } else { j.rows() };
        Matrix::from_fn(dim, dim, |a, b| {
            if of_columns {
                (0..j.rows()).map(|k| j[(k, a)] * j[(k, b)]).sum()
            } else {
                (0..j.cols()).map(|k| j[(a, k)] * j[(b, k)]).sum()
            }
        })
    }

    #[test]
    fn jacobian_kernel_matches_explicit_products() {
        let mut rng = Rng::seed_from(1);
        let j = random_matrix(&mut rng, 12, 30);
        let op = JacobianKernel::new(&j);
        assert_eq!((op.size(), op.params()), (12, 30));

        let mut ws = Workspace::new();
        let k = op.gram(&mut ws);
        let k_ref = naive_gram(&j, false);
        assert!(k.max_abs_diff(&k_ref) < 1e-10);

        let g = op.gram_t(&mut ws);
        let g_ref = naive_gram(&j, true);
        assert!(g.max_abs_diff(&g_ref) < 1e-10);

        let mut v = vec![0.0; 12];
        rng.fill_normal(&mut v);
        let kv = op.apply(&v);
        let kv_ref = k_ref.matvec(&v);
        for (a, b) in kv.iter().zip(&kv_ref) {
            assert!((a - b).abs() < 1e-9);
        }

        let omega = random_matrix(&mut rng, 12, 5);
        let y = op.sketch_y(&omega, &mut ws);
        let y_ref = k_ref.matmul(&omega);
        assert!(y.max_abs_diff(&y_ref) < 1e-9);
    }

    #[test]
    fn dense_kernel_sketch_matches_direct_product() {
        let mut rng = Rng::seed_from(2);
        let base = random_matrix(&mut rng, 10, 10);
        let a = base.gram();
        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let omega = random_matrix(&mut rng, 10, 4);
        let y = op.sketch_y(&omega, &mut ws);
        assert!(y.max_abs_diff(&a.matmul(&omega)) < 1e-10);
        let k = op.gram(&mut ws);
        assert_eq!(k.max_abs_diff(&a), 0.0);
    }

    #[test]
    fn sketch_y_reuses_workspace_buffers_across_calls() {
        let mut rng = Rng::seed_from(3);
        let j = random_matrix(&mut rng, 16, 40);
        let op = JacobianKernel::new(&j);
        let mut ws = Workspace::new();
        let omega = random_matrix(&mut rng, 16, 6);

        let y1 = op.sketch_y(&omega, &mut ws);
        ws.recycle_matrix(y1);
        let fresh_after_first = ws.stats().fresh_allocs;

        let y2 = op.sketch_y(&omega, &mut ws);
        ws.recycle_matrix(y2);
        assert_eq!(
            ws.stats().fresh_allocs,
            fresh_after_first,
            "second sketch must be served entirely from the pool"
        );
        assert!(ws.stats().reuses >= 2);
    }
}
