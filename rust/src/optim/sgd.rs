//! SGD with momentum — first-order baseline (paper §4, Appendix A.1 tunes
//! learning rate and momentum).

use anyhow::Result;

use super::{Optimizer, StepEnv, StepInfo};
use crate::config::OptimizerConfig;

pub struct Sgd {
    lr: f64,
    momentum: f64,
    velocity: Vec<f64>,
}

impl Sgd {
    pub fn new(o: &OptimizerConfig) -> Self {
        Sgd {
            lr: o.lr,
            momentum: o.momentum,
            velocity: Vec::new(),
        }
    }
}

impl Optimizer for Sgd {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let (loss, grad) = env.loss_and_grad(theta)?;
        if self.velocity.is_empty() {
            // First-step lazy init only; the buffer persists across steps.
            self.velocity = vec![0.0; theta.len()]; // lint: allow(alloc)
        }
        for ((v, g), t) in self.velocity.iter_mut().zip(&grad).zip(theta.iter_mut()) {
            *v = self.momentum * *v + g;
            *t -= self.lr * *v;
        }
        Ok(StepInfo {
            loss,
            lr_used: self.lr,
            // Reporting tuple handed to the metrics logger, not kernel math.
            extra: vec![("grad_norm".into(), crate::linalg::norm2(&grad))], // lint: allow(alloc)
        })
    }

    /// Checkpoint layout: the momentum velocity buffer (empty until the
    /// first step) — sufficient for bit-exact resume since batches and
    /// gradients are step-keyed by the trainer.
    fn state(&self) -> Vec<f64> {
        self.velocity.clone()
    }

    fn restore_state(&mut self, state: Vec<f64>) {
        self.velocity = state;
    }

    fn describe(&self) -> String {
        format!("sgd(lr={:.3e}, momentum={})", self.lr, self.momentum)
    }
}
