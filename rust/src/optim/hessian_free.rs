//! Hessian-free optimization (Martens 2010) — the matrix-free second-order
//! baseline of the paper (§4 "Implementation"): truncated conjugate-gradient
//! iterations on the damped Gauss–Newton system
//!
//! `(JᵀJ + λI) φ = ∇L`
//!
//! with exact Gramian-vector products `v ↦ Jᵀ(J v) + λ v`. Includes the
//! standard Levenberg–Marquardt damping adaptation (Appendix A.1 tunes
//! "whether to adapt damping over time"; the best 5d run adapts).
//!
//! The paper's point (§2 "Scalability") is that CG suffers under the
//! Gramian's ill-conditioning — our Fig. 2 bench shows the resulting gap to
//! ENGD-W.

use anyhow::Result;

use super::{grid_line_search, JacobianKernel, KernelOp, Optimizer, StepEnv, StepInfo};
use crate::config::OptimizerConfig;
use crate::linalg::cg_solve_warm_pooled;

pub struct HessianFree {
    cfg: OptimizerConfig,
    /// Current (possibly adapted) damping.
    lambda: f64,
    /// Adapt damping via the LM reduction ratio.
    adapt: bool,
    /// Previous step's CG solution — the warm-start iterate (Martens 2010
    /// §4.8). Empty before the first step; checkpointed for bit-exact
    /// resume.
    phi_prev: Vec<f64>,
}

impl HessianFree {
    pub fn new(o: &OptimizerConfig) -> Self {
        HessianFree {
            cfg: o.clone(),
            lambda: o.damping,
            adapt: true,
            phi_prev: Vec::new(),
        }
    }

    /// Disable Levenberg–Marquardt damping adaptation (A.1's "constant
    /// damping: yes" arm).
    pub fn with_constant_damping(mut self) -> Self {
        self.adapt = false;
        self
    }
}

impl Optimizer for HessianFree {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let (r, j) = env.residuals_jacobian(theta)?;
        let loss = 0.5 * crate::linalg::dot(&r, &r);
        let n = theta.len();
        let op = JacobianKernel::new(&j);
        let mut grad = env.ws.take_scratch(n);
        op.apply_t_into(&r, &mut grad);
        let lambda = self.lambda;

        // One pooled batch-space buffer serves every Gauss–Newton matvec in
        // the CG loop (and the LM model's Jφ below); checked out up front so
        // the closure owns it and `env.ws` stays free for the CG vectors.
        let mut jv = env.ws.take_scratch(r.len());
        let warm = (!self.phi_prev.is_empty()).then_some(self.phi_prev.as_slice());
        let out = cg_solve_warm_pooled(
            |v, jtjv| {
                // Gauss–Newton product (JᵀJ + λI)v through the operator.
                op.apply_j_into(v, &mut jv);
                op.apply_t_into(&jv, jtjv);
                for (x, vi) in jtjv.iter_mut().zip(v) {
                    *x += lambda * vi;
                }
            },
            &grad,
            warm,
            self.cfg.cg_iters,
            self.cfg.cg_tol,
            env.ws,
        );
        let (cg_iters, cg_rel_res) = (out.iterations, out.rel_residual);
        let phi = out.x;

        let eta = if self.cfg.line_search {
            match grid_line_search(env, theta, &phi, loss, self.cfg.ls_eta_max, self.cfg.ls_grid) {
                Ok(ls) => ls.eta,
                Err(e) => {
                    // Error paths recycle live checkouts (engd-lint R6).
                    drop(op);
                    env.ws.recycle_matrix(j);
                    env.ws.recycle(phi);
                    env.ws.recycle(jv);
                    env.ws.recycle(grad);
                    return Err(e);
                }
            }
        } else {
            self.cfg.lr
        };
        let mut trial = env.ws.take_scratch(n);
        trial.copy_from_slice(theta);
        for (t, d) in trial.iter_mut().zip(&phi) {
            *t -= eta * d;
        }

        if self.adapt {
            // LM ratio ρ = (actual reduction)/(predicted reduction), with the
            // quadratic model m(φ) = L − η gᵀφ + ½η² φᵀ(G+λI)φ.
            let new_loss = match env.eval_loss(&trial) {
                Ok(v) => v,
                Err(e) => {
                    // Error paths recycle live checkouts (engd-lint R6).
                    drop(op);
                    env.ws.recycle_matrix(j);
                    env.ws.recycle(phi);
                    env.ws.recycle(trial);
                    env.ws.recycle(jv);
                    env.ws.recycle(grad);
                    return Err(e);
                }
            };
            let g_phi = crate::linalg::dot(&grad, &phi);
            op.apply_j_into(&phi, &mut jv);
            let quad = crate::linalg::dot(&jv, &jv) + lambda * crate::linalg::dot(&phi, &phi);
            let predicted = eta * g_phi - 0.5 * eta * eta * quad;
            if predicted > 0.0 {
                let rho = (loss - new_loss) / predicted;
                if rho > 0.75 {
                    self.lambda *= 2.0 / 3.0;
                } else if rho < 0.25 {
                    self.lambda *= 1.5;
                }
            } else {
                self.lambda *= 1.5;
            }
            self.lambda = self.lambda.clamp(1e-12, 1e6);
        }
        drop(op);
        env.ws.recycle_matrix(j);

        theta.copy_from_slice(&trial);
        // φ_prev is persistent checkpoint state, so keep it owned: copy the
        // pooled solution in and return the scratch to the pool.
        self.phi_prev.clear();
        self.phi_prev.extend_from_slice(&phi);
        env.ws.recycle(phi);
        env.ws.recycle(trial);
        env.ws.recycle(jv);
        env.ws.recycle(grad);
        Ok(StepInfo {
            loss,
            lr_used: eta,
            // Reporting tuples handed to the metrics logger, not kernel math.
            extra: vec![ // lint: allow(alloc)
                ("cg_iters".into(), cg_iters as f64),
                ("cg_rel_res".into(), cg_rel_res),
                ("damping".into(), lambda),
            ],
        })
    }

    /// Checkpoint layout: `[λ, φ_prev…]` — the adapted LM damping plus the
    /// CG warm-start vector, so a resumed run replays the uninterrupted
    /// trajectory bit-for-bit.
    fn state(&self) -> Vec<f64> {
        let mut s = Vec::with_capacity(1 + self.phi_prev.len());
        s.push(self.lambda);
        s.extend_from_slice(&self.phi_prev);
        s
    }

    fn restore_state(&mut self, state: Vec<f64>) {
        if state.is_empty() {
            return;
        }
        self.lambda = state[0];
        self.phi_prev = state[1..].to_vec();
    }

    fn describe(&self) -> String {
        format!(
            "hessian_free(λ0={:.3e}, cg_iters={}, adapt={})",
            self.cfg.damping, self.cfg.cg_iters, self.adapt
        )
    }
}
