//! Line searches on the loss artifact.
//!
//! The original ENGD uses an "expensive line search" (paper §4; ENGD-W and
//! SPRING in Appendix A.2 "make use of the inherited ENGD line search"): the
//! step size is chosen by evaluating the loss at a geometric grid of
//! candidate η and taking the argmin. Each probe is one `loss`-artifact
//! execution, so the cost is `grid` extra forward passes per step — exactly
//! the overhead SPRING's fixed-lr mode removes.
//!
//! Both searches are generic over a loss oracle `Fn(η) -> Result<f64>` so
//! they are unit-testable without a PJRT runtime; [`StepEnv`]-based wrappers
//! adapt them to the artifact world.

use anyhow::Result;

use super::StepEnv;

/// Outcome of a line search.
#[derive(Debug, Clone, Copy)]
pub struct LineSearchResult {
    pub eta: f64,
    pub loss: f64,
    /// Number of loss evaluations spent.
    pub evals: usize,
}

/// Geometric-grid search over `η ∈ {eta_max · 2⁻ᵏ : k = 0..grid}` with η = 0
/// as the safeguard: if every probe increases the loss the step is skipped
/// (mirroring ENGD's stall behaviour under bad damping rather than
/// diverging).
pub fn grid_search(
    mut loss_at: impl FnMut(f64) -> Result<f64>,
    base_loss: f64,
    eta_max: f64,
    grid: usize,
) -> Result<LineSearchResult> {
    let mut best = LineSearchResult {
        eta: 0.0,
        loss: base_loss,
        evals: 0,
    };
    let mut eta = eta_max;
    let mut evals = 0;
    for _ in 0..grid {
        let loss = loss_at(eta)?;
        evals += 1;
        if loss.is_finite() && loss < best.loss {
            best.eta = eta;
            best.loss = loss;
        }
        eta *= 0.5;
    }
    best.evals = evals;
    Ok(best)
}

/// Golden-section refinement around a bracketing interval `[lo, hi]`:
/// assumes unimodality locally (valid near a Gauss–Newton direction) and
/// narrows to `tol`-relative width. Used by `refine = true` callers to
/// squeeze the last factor after the grid bracket.
///
/// Non-finite probe losses (a diverged trial iterate returning NaN/∞) are
/// treated as +∞ so the interval contracts away from the blow-up instead of
/// the NaN poisoning the `f1 <= f2` comparisons — a NaN compares false
/// against everything, which used to steer the bracket *toward* the
/// divergence and could return a NaN "minimum".
pub fn golden_section(
    mut loss_at: impl FnMut(f64) -> Result<f64>,
    mut lo: f64,
    mut hi: f64,
    iters: usize,
) -> Result<LineSearchResult> {
    const INV_PHI: f64 = 0.618_033_988_749_894_9;
    let finite_or_inf = |f: f64| if f.is_finite() { f } else { f64::INFINITY };
    let mut evals = 0;
    let mut x1 = hi - (hi - lo) * INV_PHI;
    let mut x2 = lo + (hi - lo) * INV_PHI;
    let mut f1 = finite_or_inf(loss_at(x1)?);
    let mut f2 = finite_or_inf(loss_at(x2)?);
    evals += 2;
    for _ in 0..iters {
        if f1 <= f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - (hi - lo) * INV_PHI;
            f1 = finite_or_inf(loss_at(x1)?);
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + (hi - lo) * INV_PHI;
            f2 = finite_or_inf(loss_at(x2)?);
        }
        evals += 1;
    }
    let (eta, loss) = if f1 <= f2 { (x1, f1) } else { (x2, f2) };
    Ok(LineSearchResult { eta, loss, evals })
}

/// Artifact-backed grid line search over `loss(θ − η φ)` (the optimizers'
/// entry point). The θ-sized trial iterate is drawn from the step
/// workspace — every element is overwritten before each probe — so a
/// warmed-up line-search step allocates nothing, upholding the
/// steady-state zero-allocation invariant the workspace tests assert.
pub fn grid_line_search(
    env: &mut StepEnv,
    theta: &[f64],
    phi: &[f64],
    base_loss: f64,
    eta_max: f64,
    grid: usize,
) -> Result<LineSearchResult> {
    let mut trial = env.ws.take_scratch(theta.len());
    let out = grid_search(
        |eta| {
            for (t, (&th, &ph)) in trial.iter_mut().zip(theta.iter().zip(phi)) {
                *t = th - eta * ph;
            }
            env.eval_loss(&trial)
        },
        base_loss,
        eta_max,
        grid,
    );
    env.ws.recycle(trial);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_finds_the_best_scale_of_a_quadratic() {
        // loss(η) = (η − 0.25)²: best grid point starting from 2 is 0.25.
        let f = |eta: f64| Ok((eta - 0.25).powi(2));
        let out = grid_search(f, 0.25f64.powi(2) + 1.0, 2.0, 12).unwrap();
        assert!((out.eta - 0.25).abs() < 1e-12);
        assert_eq!(out.evals, 12);
    }

    #[test]
    fn grid_skips_step_when_nothing_improves() {
        // Monotonically better at η = 0 (base loss 1.0; everything else worse).
        let f = |eta: f64| Ok(1.0 + eta);
        let out = grid_search(f, 1.0, 1.0, 8).unwrap();
        assert_eq!(out.eta, 0.0);
        assert_eq!(out.loss, 1.0);
    }

    #[test]
    fn grid_ignores_non_finite_probes() {
        let f = |eta: f64| {
            Ok(if eta > 0.5 {
                f64::INFINITY
            } else {
                (eta - 0.25).powi(2)
            })
        };
        let out = grid_search(f, 1.0, 2.0, 10).unwrap();
        assert!((out.eta - 0.25).abs() < 1e-12);
    }

    #[test]
    fn golden_section_narrows_to_the_minimum() {
        let f = |eta: f64| Ok((eta - 0.3).powi(2) + 2.0);
        let out = golden_section(f, 0.0, 1.0, 30).unwrap();
        assert!((out.eta - 0.3).abs() < 1e-5, "eta = {}", out.eta);
        assert!((out.loss - 2.0).abs() < 1e-9);
    }

    #[test]
    fn golden_section_handles_edge_minimum() {
        let f = |eta: f64| Ok(eta); // minimum at the lo edge
        let out = golden_section(f, 0.0, 1.0, 25).unwrap();
        assert!(out.eta < 1e-4);
    }

    #[test]
    fn golden_section_contracts_away_from_nan_probes() {
        // Divergence past η = 0.5 yields NaN losses; the bracket must
        // retreat toward the finite valley at 0.3 and never return NaN.
        let f = |eta: f64| {
            Ok(if eta > 0.5 {
                f64::NAN
            } else {
                (eta - 0.3).powi(2)
            })
        };
        let out = golden_section(f, 0.0, 1.0, 40).unwrap();
        assert!(out.loss.is_finite(), "loss = {}", out.loss);
        assert!((out.eta - 0.3).abs() < 1e-4, "eta = {}", out.eta);
    }
}
