//! SPRING for PINNs (paper §3.2, eqs. 7–8, Algorithm 1).
//!
//! The momentum-shifted Tikhonov problem
//!
//! `φ_k = argmin_φ ‖J φ − r‖² + λ‖φ − μ φ_{k−1}‖²`
//!
//! has the closed form (eq. 8)
//!
//! `φ_k = μ φ_{k−1} + Jᵀ (J Jᵀ + λI)⁻¹ (r − μ J φ_{k−1})`
//!
//! to which the paper adds the Adam-style bias correction `1/√(1−μ^{2k})`
//! (Algorithm 1 line 8). `BiasMode` selects between the Adam-style reading
//! (correction scales the θ update; raw φ is carried — our default), the
//! Algorithm-1-literal reading (corrected φ is also carried), and no
//! correction (original SPRING); `benches/ablations` compares them.

use anyhow::Result;

use super::{
    grid_line_search, kernel_solve, JacobianKernel, KernelOp, Optimizer, StepEnv, StepInfo,
};
use crate::config::run::{BiasMode, ExecPath, SolveMode};
use crate::config::OptimizerConfig;

pub struct Spring {
    cfg: OptimizerConfig,
    /// φ_{k−1} (allocated on first step).
    phi: Vec<f64>,
}

impl Spring {
    pub fn new(o: &OptimizerConfig) -> Self {
        Spring {
            cfg: o.clone(),
            phi: Vec::new(),
        }
    }

    fn bias_factor(&self, k: usize) -> f64 {
        match self.cfg.bias {
            BiasMode::None => 1.0,
            _ => {
                let mu2k = self.cfg.momentum.powi(2 * k as i32);
                1.0 / (1.0 - mu2k).sqrt()
            }
        }
    }

    /// Finish a step given the raw direction: apply bias, line search or
    /// fixed lr, update θ, store the configured φ state. `phi_raw` may live
    /// in pooled storage — it is recycled into `env.ws` here, and the φ
    /// momentum state stays an owned, persistent vector (never a pool
    /// buffer), so checkpointing and the pool's steady state both hold.
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn apply(
        &mut self,
        theta: &mut [f64],
        env: &mut StepEnv,
        phi_raw: Vec<f64>,
        loss: f64,
        mut extra: Vec<(String, f64)>,
    ) -> Result<StepInfo> {
        let bias = self.bias_factor(env.k);
        let mut step_dir = env.ws.take_scratch(phi_raw.len());
        for (s, p) in step_dir.iter_mut().zip(&phi_raw) {
            *s = p * bias;
        }
        let eta = if self.cfg.line_search {
            let ls = match grid_line_search(
                env,
                theta,
                &step_dir,
                loss,
                self.cfg.ls_eta_max,
                self.cfg.ls_grid,
            ) {
                Ok(ls) => ls,
                Err(e) => {
                    // Error paths recycle live checkouts (engd-lint R6).
                    env.ws.recycle(step_dir);
                    env.ws.recycle(phi_raw);
                    return Err(e);
                }
            };
            extra.push(("ls_evals".into(), ls.evals as f64));
            ls.eta
        } else {
            self.cfg.lr
        };
        for (t, p) in theta.iter_mut().zip(&step_dir) {
            *t -= eta * p;
        }
        self.phi.clear();
        match self.cfg.bias {
            BiasMode::Overwrite => self.phi.extend_from_slice(&step_dir),
            _ => self.phi.extend_from_slice(&phi_raw),
        }
        env.ws.recycle(step_dir);
        env.ws.recycle(phi_raw);
        extra.push(("bias".into(), bias));
        extra.push(("phi_norm".into(), crate::linalg::norm2(&self.phi)));
        Ok(StepInfo {
            loss,
            lr_used: eta,
            extra,
        })
    }

    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn fused_step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        let p = env.problem.n_params;
        if self.phi.is_empty() {
            // First-step lazy init only; φ persists across steps.
            self.phi = vec![0.0; p]; // lint: allow(alloc)
        }
        if !self.cfg.line_search && self.cfg.bias != BiasMode::Overwrite {
            // Fully fused single-artifact hot path (Algorithm 1 lines 4–9).
            let art = env.artifact("spring_step")?;
            let bias = self.bias_factor(env.k);
            let out = art.call(&[
                theta,
                &self.phi,
                env.x_int,
                env.x_bnd,
                &[self.cfg.damping],
                &[self.cfg.momentum],
                &[self.cfg.lr],
                &[bias],
            ])?;
            theta.copy_from_slice(&out[0]);
            // PJRT-only path: the artifact owns `out`; φ must outlive it.
            self.phi = out[1].clone(); // lint: allow(alloc)
            return Ok(StepInfo {
                loss: out[2][0],
                lr_used: self.cfg.lr,
                // Reporting tuple for the metrics logger, not kernel math.
                extra: vec![("bias".into(), bias)], // lint: allow(alloc)
            });
        }
        // Direction artifact; bias/line-search applied in Rust.
        let art = env.artifact("spring_dir")?;
        let out = art.call(&[
            theta,
            &self.phi,
            env.x_int,
            env.x_bnd,
            &[self.cfg.damping],
            &[self.cfg.momentum],
        ])?;
        let phi_raw = out[0].clone(); // lint: allow(alloc) — PJRT artifact owns `out`
        let loss = out[1][0];
        self.apply(theta, env, phi_raw, loss, vec![]) // lint: allow(alloc) — empty reporting vec
    }

    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn decomposed_step(
        &mut self,
        theta: &mut [f64],
        env: &mut StepEnv,
    ) -> Result<StepInfo> {
        let (r, j) = env.residuals_jacobian(theta)?;
        if self.phi.is_empty() {
            // First-step lazy init only; φ persists across steps.
            self.phi = vec![0.0; j.cols()]; // lint: allow(alloc)
        }
        let loss = 0.5 * crate::linalg::dot(&r, &r);
        let op = JacobianKernel::with_numerics(&j, env.numerics);
        // ζ = r − μ J φ_{k−1}  (Algorithm 1 line 6); the J φ buffer is
        // rewritten into ζ in place, same per-element expression.
        let mut zeta = env.ws.take_scratch(r.len());
        op.apply_j_into(&self.phi, &mut zeta);
        let mu = self.cfg.momentum;
        for (z, ri) in zeta.iter_mut().zip(&r) {
            *z = ri - mu * *z;
        }
        // a = (K̂+λI)⁻¹ ζ  (line 7, Woodbury form; K̂ exact or Nyström)
        let (a, extra) =
            match kernel_solve(&op, &zeta, &self.cfg, env.rng, env.ws, env.diagnostics) {
                Ok(out) => out,
                Err(e) => {
                    // Error paths recycle live checkouts (engd-lint R6).
                    env.ws.recycle(zeta);
                    drop(op);
                    env.ws.recycle_matrix(j);
                    return Err(e);
                }
            };
        env.ws.recycle(zeta);
        // φ_raw = μ φ_{k−1} + Jᵀ a, accumulated over the Jᵀa buffer.
        let mut phi_raw = env.ws.take_scratch(self.phi.len());
        op.apply_t_into(&a, &mut phi_raw);
        env.ws.recycle(a);
        drop(op);
        env.ws.recycle_matrix(j);
        for (q, p) in phi_raw.iter_mut().zip(&self.phi) {
            *q = mu * p + *q;
        }
        self.apply(theta, env, phi_raw, loss, extra)
    }
}

impl Optimizer for Spring {
    // lint: hot-path — steady-state steps must not allocate (engd-lint R4).
    fn step(&mut self, theta: &mut [f64], env: &mut StepEnv) -> Result<StepInfo> {
        match self.cfg.path {
            // Fused artifacts are PJRT-only; the decomposed path computes
            // the identical update (eq. 8) on every backend.
            ExecPath::Fused if env.fused_available() => self.fused_step(theta, env),
            _ => self.decomposed_step(theta, env),
        }
    }

    fn state(&self) -> Vec<f64> {
        self.phi.clone()
    }

    fn restore_state(&mut self, state: Vec<f64>) {
        self.phi = state;
    }

    fn describe(&self) -> String {
        let solve = match self.cfg.solve {
            SolveMode::Exact => "exact".to_string(),
            m => format!("{}@{:.0}%N", m.name(), self.cfg.sketch_ratio * 100.0),
        };
        format!(
            "spring(λ={:.3e}, μ={}, {}, {})",
            self.cfg.damping,
            self.cfg.momentum,
            if self.cfg.line_search {
                "line-search".to_string()
            } else {
                format!("lr={:.3e}", self.cfg.lr)
            },
            solve
        )
    }
}
