//! TOML-subset parser (substrate — the `toml` crate is unavailable offline).
//!
//! Supports the subset used by `configs/*.toml`:
//!   * `[table]` and `[table.subtable]` headers
//!   * `key = value` with string / float / integer / boolean / array values
//!   * `#` comments, blank lines
//!
//! Values are stored as `JsonValue` so the config layer has one value model.

use anyhow::{bail, Context, Result};

use super::json::JsonValue;

/// Parse TOML text into a nested `JsonValue::Object`.
pub fn parse(text: &str) -> Result<JsonValue> {
    let mut root: Vec<(String, JsonValue)> = Vec::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(stripped) = line.strip_prefix('[') {
            let inner = stripped
                .strip_suffix(']')
                .with_context(|| format!("line {}: unterminated table header", lineno + 1))?;
            if inner.starts_with('[') {
                bail!("line {}: array-of-tables is not supported", lineno + 1);
            }
            current_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            ensure_table(&mut root, &current_path)?;
        } else {
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected 'key = value'", lineno + 1))?;
            let key = unquote_key(key.trim());
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            let table = navigate(&mut root, &current_path)?;
            if table.iter().any(|(k, _)| k == &key) {
                bail!("line {}: duplicate key '{}'", lineno + 1, key);
            }
            table.push((key, value));
        }
    }
    Ok(JsonValue::Object(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote_key(key: &str) -> String {
    key.trim_matches('"').to_string()
}

fn ensure_table(root: &mut Vec<(String, JsonValue)>, path: &[String]) -> Result<()> {
    navigate(root, path).map(|_| ())
}

fn navigate<'a>(
    root: &'a mut Vec<(String, JsonValue)>,
    path: &[String],
) -> Result<&'a mut Vec<(String, JsonValue)>> {
    match path.split_first() {
        None => Ok(root),
        Some((part, rest)) => {
            let idx = match root.iter().position(|(k, _)| k == part) {
                Some(i) => i,
                None => {
                    root.push((part.clone(), JsonValue::Object(Vec::new())));
                    root.len() - 1
                }
            };
            match &mut root[idx].1 {
                JsonValue::Object(entries) => navigate(entries, rest),
                _ => bail!("'{}' is not a table", part),
            }
        }
    }
}

fn parse_value(s: &str) -> Result<JsonValue> {
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .with_context(|| format!("unterminated string: {s}"))?;
        return Ok(JsonValue::String(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|x| x.strip_suffix(']'))
            .with_context(|| format!("unterminated array: {s}"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(JsonValue::Array(items));
    }
    match s {
        "true" => return Ok(JsonValue::Bool(true)),
        "false" => return Ok(JsonValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(x) = cleaned.parse::<f64>() {
        return Ok(JsonValue::Number(x));
    }
    bail!("cannot parse TOML value: {s}")
}

/// Split an array body on top-level commas (no nested-array commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_values() {
        let v = parse(
            r#"
# run config
name = "demo"
steps = 500

[optimizer]
kind = "spring"
damping = 1e-8        # tuned
momentum = 0.9
lr_grid = [0.01, 0.1, 1.0]

[optimizer.line_search]
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("demo"));
        assert_eq!(v.get("steps").unwrap().as_f64(), Some(500.0));
        let opt = v.get("optimizer").unwrap();
        assert_eq!(opt.get("damping").unwrap().as_f64(), Some(1e-8));
        assert_eq!(opt.get("lr_grid").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            opt.get("line_search").unwrap().get("enabled").unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("a = @nope").is_err());
        assert!(parse("[unclosed").is_err());
    }

    #[test]
    fn numbers_with_underscores() {
        let v = parse("n = 10_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_f64(), Some(10000.0));
    }

    #[test]
    fn comment_inside_string_is_kept() {
        let v = parse(r##"s = "a # b""##).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # b"));
    }
}
