//! Typed run configuration, parsed from `configs/*.toml` (or built in code).

use anyhow::{anyhow, bail, Result};

use super::json::JsonValue;
use crate::backend::NumericsMode;

/// Which optimizer drives the run (paper §4 evaluates all of these).
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizerKind {
    Sgd,
    Adam,
    /// Original dense ENGD: forms the P×P Gramian (Müller–Zeinhofer 2023).
    EngdDense,
    /// ENGD via the Woodbury/kernel form (paper eq. 5).
    EngdW,
    /// SPRING: Woodbury + Kaczmarz momentum (paper Alg. 1).
    Spring,
    /// Hessian-free: truncated CG on the Gauss–Newton system (Martens 2010).
    HessianFree,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => Self::Sgd,
            "adam" => Self::Adam,
            "engd" | "engd_dense" => Self::EngdDense,
            "engd_w" => Self::EngdW,
            "spring" => Self::Spring,
            "hessian_free" | "hf" => Self::HessianFree,
            _ => bail!("unknown optimizer kind '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Adam => "adam",
            Self::EngdDense => "engd_dense",
            Self::EngdW => "engd_w",
            Self::Spring => "spring",
            Self::HessianFree => "hessian_free",
        }
    }
}

/// Kernel-solve strategy for ENGD-W / SPRING.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveMode {
    /// Exact damped Cholesky solve of (JJᵀ + λI).
    Exact,
    /// GPU-efficient randomized Nyström (paper Algorithm 2) sketch-and-solve.
    NystromGpu,
    /// Standard stable Nyström (Frangella–Tropp–Udell alg. 2.1) baseline.
    NystromStable,
    /// Sketch-and-precondition: Nyström-preconditioned CG (paper §3.3's
    /// discussed-and-rejected alternative; kept for the ablation bench).
    NystromPcg,
}

impl SolveMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "exact" => Self::Exact,
            "nystrom" | "nystrom_gpu" | "gpu" => Self::NystromGpu,
            "nystrom_stable" | "stable" => Self::NystromStable,
            "nystrom_pcg" | "pcg" => Self::NystromPcg,
            _ => bail!("unknown solve mode '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::NystromGpu => "nystrom_gpu",
            Self::NystromStable => "nystrom_stable",
            Self::NystromPcg => "nystrom_pcg",
        }
    }
}

/// Sketch-rank policy for the randomized solves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankPolicy {
    /// Paper default: sketch = sketch_ratio · N, fixed for the whole run.
    Fixed,
    /// Paper §5 future work: grow the sketch until the captured spectral
    /// tail reaches the damping floor (see `nystrom::adaptive`).
    Adaptive,
}

impl RankPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fixed" => Self::Fixed,
            "adaptive" => Self::Adaptive,
            _ => bail!("unknown rank policy '{s}'"),
        })
    }
}

/// How SPRING applies the paper's 1/√(1−μ^{2k}) bias correction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BiasMode {
    /// Adam-style: correction scales the θ update, raw φ is stored (default).
    Adam,
    /// Algorithm-1-literal: the corrected φ is also the stored state.
    Overwrite,
    /// No correction (original SPRING of Goldshlager et al.).
    None,
}

impl BiasMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "adam" => Self::Adam,
            "overwrite" => Self::Overwrite,
            "none" => Self::None,
            _ => bail!("unknown bias mode '{s}'"),
        })
    }
}

/// Execution path for natural-gradient optimizers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecPath {
    /// One fused XLA artifact per step (hot path).
    Fused,
    /// Rust-side linear algebra over (J, r) from `residuals_jacobian`
    /// (required for Nyström / effective-dimension experiments).
    Decomposed,
}

impl ExecPath {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fused" => Self::Fused,
            "decomposed" => Self::Decomposed,
            _ => bail!("unknown exec path '{s}'"),
        })
    }
}

/// Full optimizer configuration (superset across optimizers; each reads the
/// fields it needs — mirrors the paper's per-optimizer hyperparameter lists
/// in Appendix A.1).
#[derive(Debug, Clone)]
pub struct OptimizerConfig {
    pub kind: OptimizerKind,
    pub damping: f64,
    pub momentum: f64,
    pub lr: f64,
    pub line_search: bool,
    pub solve: SolveMode,
    /// Nyström sketch size as a fraction of N (paper uses 0.10).
    pub sketch_ratio: f64,
    /// Max CG iterations for Hessian-free.
    pub cg_iters: usize,
    /// CG relative-residual tolerance for Hessian-free.
    pub cg_tol: f64,
    /// Exponential-moving-average factor on the dense Gramian (ENGD).
    pub ema: f64,
    /// Initialize the dense Gramian accumulator to identity (ENGD).
    pub gramian_identity_init: bool,
    pub bias: BiasMode,
    pub path: ExecPath,
    /// Sketch-rank policy (fixed = paper default).
    pub rank_policy: RankPolicy,
    /// Adaptive policy: cap on sketch size as a fraction of N.
    pub sketch_max_ratio: f64,
    /// Line-search grid depth (number of halvings from `ls_eta_max`).
    pub ls_grid: usize,
    /// Largest step size probed by the line search.
    pub ls_eta_max: f64,
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 1e-8,
            momentum: 0.9,
            lr: 0.05,
            line_search: false,
            solve: SolveMode::Exact,
            sketch_ratio: 0.10,
            cg_iters: 100,
            cg_tol: 1e-10,
            ema: 0.0,
            gramian_identity_init: true,
            bias: BiasMode::Adam,
            path: ExecPath::Fused,
            rank_policy: RankPolicy::Fixed,
            sketch_max_ratio: 0.5,
            ls_grid: 18,
            ls_eta_max: 2.0,
        }
    }
}

impl OptimizerConfig {
    pub fn from_value(v: &JsonValue) -> Result<Self> {
        let mut c = OptimizerConfig::default();
        let obj = v
            .as_object()
            .ok_or_else(|| anyhow!("[optimizer] must be a table"))?;
        for (k, val) in obj {
            match k.as_str() {
                "kind" => {
                    c.kind = OptimizerKind::parse(
                        val.as_str().ok_or_else(|| anyhow!("kind must be a string"))?,
                    )?
                }
                "damping" => c.damping = num(val, k)?,
                "momentum" => c.momentum = num(val, k)?,
                "lr" => c.lr = num(val, k)?,
                "line_search" => c.line_search = boolean(val, k)?,
                "solve" => {
                    c.solve = SolveMode::parse(
                        val.as_str().ok_or_else(|| anyhow!("solve must be a string"))?,
                    )?
                }
                "sketch_ratio" => c.sketch_ratio = num(val, k)?,
                "cg_iters" => c.cg_iters = num(val, k)? as usize,
                "cg_tol" => c.cg_tol = num(val, k)?,
                "ema" => c.ema = num(val, k)?,
                "gramian_identity_init" => c.gramian_identity_init = boolean(val, k)?,
                "bias" => {
                    c.bias = BiasMode::parse(
                        val.as_str().ok_or_else(|| anyhow!("bias must be a string"))?,
                    )?
                }
                "rank_policy" => {
                    c.rank_policy = RankPolicy::parse(
                        val.as_str().ok_or_else(|| anyhow!("rank_policy must be a string"))?,
                    )?
                }
                "sketch_max_ratio" => c.sketch_max_ratio = num(val, k)?,
                "ls_grid" => c.ls_grid = num(val, k)? as usize,
                "ls_eta_max" => c.ls_eta_max = num(val, k)?,
                "path" => {
                    c.path = ExecPath::parse(
                        val.as_str().ok_or_else(|| anyhow!("path must be a string"))?,
                    )?
                }
                _ => bail!("unknown [optimizer] key '{k}'"),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.damping < 0.0 {
            bail!("damping must be >= 0");
        }
        if !(0.0..1.0).contains(&self.momentum) && self.momentum != 0.0 {
            if self.momentum >= 1.0 {
                bail!("momentum must be < 1");
            }
        }
        if self.sketch_ratio <= 0.0 || self.sketch_ratio > 1.0 {
            bail!("sketch_ratio must be in (0, 1]");
        }
        if self.solve != SolveMode::Exact && self.path == ExecPath::Fused {
            bail!("randomized solves require path = \"decomposed\"");
        }
        Ok(())
    }
}

/// Top-level run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub name: String,
    pub problem: String,
    /// Evaluation backend: "pjrt", "native", "sharded[:n]" (thread-sharded
    /// composite), "process[:n]" (out-of-process shard workers) — both
    /// bitwise-identical to native — or "auto" (PJRT when a usable
    /// artifact manifest exists, native otherwise). Shard counts must be
    /// at least 1; `sharded:0` / `process:0` are rejected at parse time.
    pub backend: String,
    pub artifacts_dir: String,
    pub steps: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// Wall-clock budget in seconds (0 = unlimited) — the paper gives each
    /// run a fixed time budget (7000 s / 10000 s); ours are scaled.
    pub time_budget_s: f64,
    pub out_dir: String,
    /// Save a checkpoint every N steps (0 = off).
    pub checkpoint_every: usize,
    /// Resume θ/φ/step from this checkpoint file.
    pub resume_from: Option<String>,
    /// Native-kernel numerics tier: `bitwise` (default, bit-reproducible)
    /// or `fast` (runtime-dispatched SIMD/FMA kernels, rounding-level
    /// drift). Defaults from `ENGD_NUMERICS`; `--numerics` / the
    /// `numerics` TOML key override it. Recorded in checkpoints — resume
    /// refuses a mismatch.
    pub numerics: NumericsMode,
    pub optimizer: OptimizerConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            name: "run".into(),
            problem: "poisson5d".into(),
            backend: "auto".into(),
            artifacts_dir: "artifacts".into(),
            steps: 200,
            seed: 42,
            eval_every: 10,
            time_budget_s: 0.0,
            out_dir: "results".into(),
            checkpoint_every: 0,
            resume_from: None,
            numerics: NumericsMode::from_env(),
            optimizer: OptimizerConfig::default(),
        }
    }
}

fn num(v: &JsonValue, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("'{key}' must be a number"))
}

fn boolean(v: &JsonValue, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("'{key}' must be a boolean"))
}

impl RunConfig {
    pub fn from_value(v: &JsonValue) -> Result<Self> {
        let mut c = RunConfig::default();
        let obj = v.as_object().ok_or_else(|| anyhow!("config must be a table"))?;
        for (k, val) in obj {
            match k.as_str() {
                "name" => c.name = req_str(val, k)?,
                "problem" => c.problem = req_str(val, k)?,
                "backend" => c.backend = req_str(val, k)?,
                "artifacts" | "artifacts_dir" => c.artifacts_dir = req_str(val, k)?,
                "steps" => c.steps = num(val, k)? as usize,
                "seed" => c.seed = num(val, k)? as u64,
                "eval_every" => c.eval_every = num(val, k)? as usize,
                "time_budget_s" => c.time_budget_s = num(val, k)?,
                "out_dir" => c.out_dir = req_str(val, k)?,
                "checkpoint_every" => c.checkpoint_every = num(val, k)? as usize,
                "resume_from" => c.resume_from = Some(req_str(val, k)?),
                "numerics" => c.numerics = NumericsMode::parse(&req_str(val, k)?)?,
                "optimizer" => c.optimizer = OptimizerConfig::from_value(val)?,
                _ => bail!("unknown config key '{k}'"),
            }
        }
        // Fail malformed backend selectors (sharded:0, process:0, typos)
        // here at parse time, not when the backend is first constructed.
        crate::backend::validate_backend(&c.backend)?;
        Ok(c)
    }

    pub fn from_toml_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        Self::from_value(&super::toml::parse(&text)?)
    }
}

fn req_str(v: &JsonValue, key: &str) -> Result<String> {
    Ok(v.as_str()
        .ok_or_else(|| anyhow!("'{key}' must be a string"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let v = crate::config::toml::parse(
            r#"
name = "spring-5d"
problem = "poisson5d"
steps = 300
seed = 7

[optimizer]
kind = "spring"
damping = 2e-10
momentum = 0.31
lr = 0.06
"#,
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.name, "spring-5d");
        assert_eq!(c.steps, 300);
        assert_eq!(c.optimizer.kind, OptimizerKind::Spring);
        assert_eq!(c.optimizer.damping, 2e-10);
        assert_eq!(c.optimizer.momentum, 0.31);
    }

    #[test]
    fn rejects_randomized_fused() {
        let v = crate::config::toml::parse(
            r#"
[optimizer]
kind = "engd_w"
solve = "nystrom"
path = "fused"
"#,
        )
        .unwrap();
        assert!(RunConfig::from_value(&v).is_err());
    }

    #[test]
    fn unknown_key_is_an_error() {
        let v = crate::config::toml::parse("bogus = 1").unwrap();
        assert!(RunConfig::from_value(&v).is_err());
    }

    #[test]
    fn rejects_zero_shard_backends() {
        for bad in ["sharded:0", "process:0"] {
            let v = crate::config::toml::parse(&format!(r#"backend = "{bad}""#)).unwrap();
            let err = RunConfig::from_value(&v).unwrap_err().to_string();
            assert!(err.contains("at least 1"), "{bad}: {err}");
        }
        for good in ["native", "sharded:2", "process:4", "auto"] {
            let v = crate::config::toml::parse(&format!(r#"backend = "{good}""#)).unwrap();
            assert_eq!(RunConfig::from_value(&v).unwrap().backend, good);
        }
    }

    #[test]
    fn parses_numerics_key() {
        let v = crate::config::toml::parse(r#"numerics = "fast""#).unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.numerics, NumericsMode::Fast);
        let v = crate::config::toml::parse(r#"numerics = "bitwise""#).unwrap();
        assert_eq!(RunConfig::from_value(&v).unwrap().numerics, NumericsMode::Bitwise);
        let v = crate::config::toml::parse(r#"numerics = "sloppy""#).unwrap();
        assert!(RunConfig::from_value(&v).is_err());
    }
}
