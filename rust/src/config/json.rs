//! Minimal JSON parser (substrate — serde is unavailable offline).
//!
//! Supports the full JSON grammar we emit from `aot.py` and the sweep/metrics
//! tooling: objects, arrays, strings (with escapes), numbers, booleans, null.
//! Insertion order of object keys is preserved via a Vec-backed map so
//! round-tripping stays stable.

use std::fmt;

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(Vec<(String, JsonValue)>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(entries) => entries
                .iter()
                .find_map(|(k, v)| if k == key { Some(v) } else { None }),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, JsonValue)]> {
        match self {
            JsonValue::Object(o) => Some(o),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "json: expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("json: unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("json: bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(entries));
                }
                other => bail!(
                    "json: expected ',' or '}}' at byte {}, found {:?}",
                    self.pos,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                other => bail!(
                    "json: expected ',' or ']' at byte {}, found {:?}",
                    self.pos,
                    other.map(|b| b as char)
                ),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("json: unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("json: bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume a full UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(JsonValue::Number(text.parse()?))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<JsonValue> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("json: trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

/// Serialize a value back to compact JSON (used by metrics/sweep output).
pub fn to_string(v: &JsonValue) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &JsonValue, out: &mut String) {
    match v {
        JsonValue::Null => out.push_str("null"),
        JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        JsonValue::Number(x) => {
            if !x.is_finite() {
                // JSON has no NaN/Inf; emit null (matches Python's json for
                // our NaN-means-not-evaluated convention).
                out.push_str("null");
            } else if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        JsonValue::String(s) => write_escaped(s, out),
        JsonValue::Array(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        JsonValue::Object(entries) => {
            out.push('{');
            for (i, (k, val)) in entries.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let v = parse(
            r#"{"dtype":"f64","problems":{"p":{"dim":5,"arch":[5,64,1],
                "artifacts":{"loss":{"file":"p/loss.hlo.txt",
                "args":[{"name":"theta","shape":[10]}],
                "outputs":[{"name":"loss","shape":[]}]}}}}}"#,
        )
        .unwrap();
        assert_eq!(v.get("dtype").unwrap().as_str(), Some("f64"));
        let p = v.get("problems").unwrap().get("p").unwrap();
        assert_eq!(p.get("dim").unwrap().as_f64(), Some(5.0));
        assert_eq!(p.get("arch").unwrap().as_array().unwrap().len(), 3);
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("3.5e2").unwrap().as_f64(), Some(350.0));
        assert_eq!(parse("-0.25").unwrap().as_f64(), Some(-0.25));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse(r#""a\nbA""#).unwrap().as_str(), Some("a\nbA"));
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":false}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), JsonValue::Object(vec![]));
    }
}
