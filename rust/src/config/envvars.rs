//! Registry of every `ENGD_*` environment variable the tree reads.
//!
//! This table is the single source of truth for the env-var surface:
//!
//! * `engd-lint` rule **R3** (`env-reg`) scans this file for the declared
//!   names and flags any `ENGD_*` string literal elsewhere in `rust/src`,
//!   `benches`, or `examples` that is missing here — an env var can no
//!   longer ship undocumented;
//! * [`render_markdown_table`] renders the README's "Environment
//!   variables" table, and a test below asserts the README copy between
//!   the `<!-- envvar-table:begin/end -->` markers matches it byte for
//!   byte (on drift, the test prints the expected block to paste in).

/// One registered environment variable.
pub struct EnvVar {
    /// The exact name read from the environment (`ENGD_…`).
    pub name: &'static str,
    /// Human-readable default (what happens when the variable is unset).
    pub default: &'static str,
    /// What the variable controls and who reads it.
    pub purpose: &'static str,
}

/// Every `ENGD_*` variable, sorted by name. Keep sorted — the lint's
/// registry scan is order-insensitive, but the rendered README table and
/// `lookup`'s binary search are not.
pub const REGISTRY: &[EnvVar] = &[
    EnvVar {
        name: "ENGD_APPB_ITERS",
        default: "20",
        purpose: "Appendix-B Nyström micro-bench: timed iterations per arm.",
    },
    EnvVar {
        name: "ENGD_APPB_N",
        default: "896",
        purpose: "Appendix-B Nyström micro-bench: kernel size N.",
    },
    EnvVar {
        name: "ENGD_APPB_SKETCH",
        default: "N/2",
        purpose: "Appendix-B Nyström micro-bench: sketch size ℓ.",
    },
    EnvVar {
        name: "ENGD_BACKEND",
        default: "auto",
        // No `|` in purposes: render_markdown_table does not escape cells.
        purpose: "Bench-harness backend: auto / pjrt / native / sharded:<n> / process:<n>.",
    },
    EnvVar {
        name: "ENGD_BENCH_BUDGET",
        default: "per-bench (20 s)",
        purpose: "Wall-clock budget in seconds given to each bench arm (paper §4 protocol).",
    },
    EnvVar {
        name: "ENGD_NUMERICS",
        default: "bitwise",
        purpose: "Kernel numerics tier: bitwise (scalar-order FP, trajectories reproducible \
                  bit for bit) or fast (FMA + reassociated reductions, tolerance-level).",
    },
    EnvVar {
        name: "ENGD_PROP_SEED",
        default: "0x5EED",
        purpose: "Base seed of the property-test generator (override to explore new regions).",
    },
    EnvVar {
        name: "ENGD_SHARD_FAULT",
        default: "unset",
        purpose: "Fault injection for tests: after=<n> makes a shard worker process exit \
                  mid-protocol after n requests.",
    },
    EnvVar {
        name: "ENGD_SHARD_SCHEDULE",
        default: "steal",
        purpose: "Shard work-assignment policy: steal (work-stealing range queue) or static \
                  (fixed equal splits, for A/B runs).",
    },
    EnvVar {
        name: "ENGD_SHARD_TIMEOUT_S",
        default: "30",
        purpose: "Seconds a shard worker process may go silent before the supervisor declares \
                  it hung, kills it, and respawns.",
    },
    EnvVar {
        name: "ENGD_SIMD",
        default: "auto-detect",
        purpose: "Fast-tier instruction-set override: scalar / avx2 / avx512 / neon (clamped \
                  to what the CPU supports).",
    },
    EnvVar {
        name: "ENGD_THREADS",
        default: "available cores",
        purpose: "Worker-pool width; also fixes the reduction chunk grid, so trajectories are \
                  comparable only at equal ENGD_THREADS.",
    },
    EnvVar {
        name: "ENGD_WORKER_EXE",
        default: "current executable",
        purpose: "Executable spawned as the --shard-worker process for the process:<n> backend.",
    },
];

/// Look up a registered variable by exact name.
pub fn lookup(name: &str) -> Option<&'static EnvVar> {
    REGISTRY
        .binary_search_by(|v| v.name.cmp(name))
        .ok()
        .map(|i| &REGISTRY[i])
}

/// Read a registered variable from the process environment.
///
/// The single sanctioned read path: engd-lint rule R9 (`env-read`) bans
/// raw `std::env::var` outside this file, so every lookup passes through
/// the registry assert below — an undeclared read fails loudly at the
/// call site instead of shipping as an undocumented knob. Returns `None`
/// when the variable is unset (or not valid Unicode).
pub fn read(name: &str) -> Option<String> {
    assert!(
        lookup(name).is_some(),
        "env var `{name}` is not declared in config::envvars::REGISTRY"
    );
    std::env::var(name).ok()
}

/// [`read`] for values that may not be Unicode (executable paths).
pub fn read_os(name: &str) -> Option<std::ffi::OsString> {
    assert!(
        lookup(name).is_some(),
        "env var `{name}` is not declared in config::envvars::REGISTRY"
    );
    std::env::var_os(name)
}

/// Render the registry as the README's GitHub-flavored markdown table.
pub fn render_markdown_table() -> String {
    let mut out = String::new();
    out.push_str("| Variable | Default | Purpose |\n");
    out.push_str("| --- | --- | --- |\n");
    for v in REGISTRY {
        // The long purpose strings carry continuation whitespace from the
        // source literals; collapse runs so the table stays one line per
        // variable.
        let purpose: String = v.purpose.split_whitespace().collect::<Vec<_>>().join(" ");
        out.push_str(&format!("| `{}` | {} | {} |\n", v.name, v.default, purpose));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_unique() {
        for w in REGISTRY.windows(2) {
            assert!(
                w[0].name < w[1].name,
                "registry must stay sorted/unique: {} !< {}",
                w[0].name,
                w[1].name
            );
        }
        for v in REGISTRY {
            assert!(lookup(v.name).is_some());
        }
        // Lowercase on purpose: engd-lint scrapes every ENGD_*-shaped string
        // literal in this file as "registered", so a shaped miss here would
        // silently widen the registry.
        assert!(lookup("ENGD_not_a_var").is_none());
    }

    #[test]
    fn read_accepts_registered_names_only() {
        // Registered names read without panicking whether set or not.
        let _ = read("ENGD_APPB_ITERS");
        let _ = read_os("ENGD_WORKER_EXE");
        let err = std::panic::catch_unwind(|| read("ENGD_not_a_var"));
        assert!(err.is_err(), "undeclared reads must panic");
    }

    #[test]
    fn readme_env_table_matches_registry() {
        let readme_path = concat!(env!("CARGO_MANIFEST_DIR"), "/README.md");
        let readme = std::fs::read_to_string(readme_path).expect("README.md readable");
        let begin = "<!-- envvar-table:begin -->";
        let end = "<!-- envvar-table:end -->";
        let b = readme.find(begin).expect("README missing envvar-table:begin marker");
        let e = readme.find(end).expect("README missing envvar-table:end marker");
        let actual = readme[b + begin.len()..e].trim();
        let expected = render_markdown_table();
        assert!(
            actual == expected.trim(),
            "README env-var table is stale; paste this between the markers:\n\n{expected}"
        );
    }
}
