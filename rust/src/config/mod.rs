//! Configuration substrate: JSON + TOML-subset parsers and typed run configs.
//!
//! serde/toml are unavailable offline, so both parsers are implemented here
//! (see DESIGN.md "Offline-dependency constraint").

pub mod envvars;
pub mod json;
pub mod toml;

pub mod run;

pub use run::{OptimizerConfig, RunConfig};
