//! Persistent worker-pool substrate (rayon is unavailable offline).
//!
//! Every hot loop in the dense linear-algebra layer and the native autodiff
//! backend is chunked fork-join over index ranges. The first generation of
//! this module spawned fresh scoped threads per call, which meant each
//! native `loss`/`loss_and_grad`/`residuals_jacobian` evaluation paid a
//! thread spawn *and* rebuilt its per-thread `Tape` buffers (multi-MB on
//! poisson100d) — pathological under line search, where one training step
//! evaluates the loss a dozen times. This generation keeps a long-lived
//! pool of parked workers instead.
//!
//! ## Lifecycle
//!
//! `num_threads() − 1` workers are spawned lazily on the first parallel
//! call and then live for the whole process, parked on a per-worker
//! mailbox (`Mutex<Option<Task>>` + `Condvar`). A dispatch hands each
//! worker a `(job, slot)` pair through its mailbox; the **calling thread
//! always executes slot 0** itself, so `ENGD_THREADS=1` never touches the
//! pool and a warm pool adds only a wake/park round-trip per call. The
//! caller blocks on a latch until every helper slot has finished, which is
//! what makes it sound to run borrowed (non-`'static`) closures on the
//! pool. Worker panics are caught, flagged on the latch, and re-raised on
//! the calling thread after the barrier.
//!
//! If the pool is busy — a nested parallel call from inside a pool job, or
//! a second dispatching thread (`cargo test` runs tests concurrently) —
//! the dispatch falls back to running every slot inline on the caller.
//! This degrades parallelism, never correctness, and cannot deadlock.
//!
//! ## Determinism
//!
//! * `par_chunks(n, f)` builds the same chunk grid for a given
//!   `ENGD_THREADS`: `workers = num_threads().min(n)` contiguous chunks,
//!   balanced to within one element, chunk `w` on slot `w`. (Under the
//!   test-only [`with_thread_limit`] cap the grid follows the narrowed
//!   width — which is why a per-chunk f64 reduction through `par_chunks`
//!   alone is NOT width-independent.)
//! * Kernels that write each output element from exactly one slot
//!   (`matmul`, `gram`, `tr_matvec`, `par_map`, Jacobian rows) are bitwise
//!   deterministic for *any* execution width; `rust/tests/pool.rs` asserts
//!   this across widths.
//! * Callers that reduce floating-point partials must key their partial
//!   layout off [`num_threads`] themselves — the native backend's
//!   `thread_chunks` grid does exactly this — so the reduction order, and
//!   hence the f64 sum, is a pure function of `ENGD_THREADS` no matter how
//!   many threads actually execute.
//! * `par_dynamic` steals work in nondeterministic order and is reserved
//!   for callers whose per-item writes are disjoint and order-free.
//!
//! ## Scratch slots
//!
//! [`with_scratch`] gives each worker (and the calling thread) a typed,
//! thread-local slot that persists across dispatches — this is how the
//! native backend keeps one `Tape` per worker alive across evaluations.
//! Slots own their value's full sizing: the blocked tape allocates its
//! point-block panels (≈ `max(64, d)` dual lanes per layer) once at slot
//! construction, so steady-state dispatches neither grow nor reallocate
//! scratch.
//! Safety contract: the slot is keyed by `TypeId` per thread, so a value
//! never migrates between threads (hence only `T: Send` is required, not
//! `Sync`), and re-entrant use of the *same* type on the same thread sees
//! a fresh default value (the outer value is checked back in afterwards).
//! Callers must therefore treat the slot as a cache, never as an owner of
//! state that is expensive to lose or that must be unique process-wide.

use std::any::{Any, TypeId};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Number of worker slots: `ENGD_THREADS` env override, else available
/// parallelism, clamped to [1, 64]. Fixed for the process lifetime; this is
/// both the pool capacity and the deterministic chunk-grid width.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Some(s) = crate::config::envvars::read("ENGD_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.clamp(1, 64);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(1, 64)
    })
}

/// Test-only execution-width cap (0 = none). Narrows how many slots run
/// concurrently; per-element kernels and reductions keyed off
/// [`num_threads`] stay bitwise-identical at every width (a reduction
/// keyed off `par_chunks`'s own grid would not — see the module docs).
static WIDTH_LIMIT: AtomicUsize = AtomicUsize::new(0);

/// Execution width for the next dispatch: `num_threads()` unless narrowed
/// by [`with_thread_limit`].
fn active_threads() -> usize {
    match WIDTH_LIMIT.load(Ordering::Relaxed) {
        0 => num_threads(),
        w => w.min(num_threads()),
    }
}

/// Run `f` with at most `width` slots executing concurrently. Per-element
/// kernels, and reductions whose partial grids are keyed off
/// [`num_threads`] (the native backend's), produce bitwise-identical
/// results at every width — the pool test suite relies on this.
/// Process-global: callers serialize their own use.
pub fn with_thread_limit<R>(width: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            WIDTH_LIMIT.store(self.0, Ordering::Relaxed);
        }
    }
    let _restore = Restore(WIDTH_LIMIT.swap(width.max(1), Ordering::Relaxed));
    f()
}

/// Pool observability counters (tests assert steady-state: after warmup a
/// training step must not grow `threads_spawned`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// OS threads ever spawned by the pool (grows once, at first use).
    pub threads_spawned: usize,
    /// Dispatches served by the parked workers.
    pub dispatches: usize,
    /// Dispatches that ran inline because the pool was busy (nested
    /// parallelism or a concurrent dispatcher).
    pub serial_fallbacks: usize,
}

static SPAWNED: AtomicUsize = AtomicUsize::new(0);
static DISPATCHES: AtomicUsize = AtomicUsize::new(0);
static SERIAL_FALLBACKS: AtomicUsize = AtomicUsize::new(0);

/// Current pool counters.
pub fn pool_stats() -> PoolStats {
    PoolStats {
        threads_spawned: SPAWNED.load(Ordering::Relaxed),
        dispatches: DISPATCHES.load(Ordering::Relaxed),
        serial_fallbacks: SERIAL_FALLBACKS.load(Ordering::Relaxed),
    }
}

/// One unit of handed-off work: run `job(slot)`, then release the latch.
/// The `'static` on the closure reference is a lifetime erasure, upheld by
/// the dispatch protocol: the dispatcher blocks on the latch (even while
/// unwinding) before the borrowed closure leaves scope.
struct Task {
    job: &'static (dyn Fn(usize) + Sync),
    slot: usize,
    latch: Arc<Latch>,
}

/// What a worker hands back when its job unwinds: the caught panic
/// payload, re-raised on the dispatching thread so caller diagnostics (the
/// failing assertion message, not a generic string) survive the pool —
/// matching what the old scoped-thread substrate propagated.
type PanicPayload = Box<dyn std::any::Any + Send>;

/// Completion barrier for one dispatch. The remaining count and the first
/// panic payload live under the mutex so the final count-down and the
/// waiter's wake-up are fully serialized; workers hold an `Arc`, so the
/// latch cannot be freed while a worker is still inside `count_down`.
struct Latch {
    state: Mutex<(usize, Option<PanicPayload>)>,
    cv: Condvar,
}

impl Latch {
    fn new(remaining: usize) -> Arc<Self> {
        Arc::new(Latch {
            state: Mutex::new((remaining, None)),
            cv: Condvar::new(),
        })
    }

    fn count_down(&self, panicked: Option<PanicPayload>) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        if g.1.is_none() {
            g.1 = panicked;
        }
        if g.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every helper finished; returns the first panic payload.
    fn wait(&self) -> Option<PanicPayload> {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.cv.wait(g).unwrap();
        }
        g.1.take()
    }
}

/// A parked worker's mailbox.
struct Mailbox {
    slot: Mutex<Option<Task>>,
    cv: Condvar,
}

fn worker_loop(mb: Arc<Mailbox>) {
    loop {
        let task = {
            let mut g = mb.slot.lock().unwrap();
            loop {
                if let Some(t) = g.take() {
                    break t;
                }
                g = mb.cv.wait(g).unwrap();
            }
        };
        let job = task.job;
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            job(task.slot)
        }))
        .err();
        task.latch.count_down(panicked);
    }
}

/// The process-wide pool: one mailbox per helper worker plus a dispatch
/// lease that serializes dispatchers (and detects nested parallelism).
struct Pool {
    mailboxes: Vec<Arc<Mailbox>>,
    lease: Mutex<()>,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let helpers = num_threads().saturating_sub(1);
        let mut mailboxes = Vec::with_capacity(helpers);
        for w in 0..helpers {
            let mb = Arc::new(Mailbox {
                slot: Mutex::new(None),
                cv: Condvar::new(),
            });
            let mb2 = Arc::clone(&mb);
            std::thread::Builder::new()
                .name(format!("engd-pool-{w}"))
                .spawn(move || worker_loop(mb2))
                .expect("spawning pool worker");
            SPAWNED.fetch_add(1, Ordering::Relaxed);
            mailboxes.push(mb);
        }
        Pool {
            mailboxes,
            lease: Mutex::new(()),
        }
    })
}

/// Execute `job(w)` for every slot `w < slots`, helpers on the pool and
/// slot 0 on the calling thread; returns after all slots finish.
fn run_job(slots: usize, job: &(dyn Fn(usize) + Sync)) {
    if slots <= 1 {
        job(0);
        return;
    }
    let pool = pool();
    debug_assert!(slots <= pool.mailboxes.len() + 1, "slots exceed pool capacity");
    // Busy pool (nested call, or a concurrent dispatcher): run every slot
    // inline. Same work, same outputs, no deadlock. A *poisoned* lease is
    // recovered, not treated as busy — it guards no data, and a panic that
    // unwound through a previous dispatch (e.g. a failed test assertion
    // inside a pool job) must not silently serialize the rest of the
    // process.
    let _lease = match pool.lease.try_lock() {
        Ok(guard) => guard,
        Err(std::sync::TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        Err(std::sync::TryLockError::WouldBlock) => {
            SERIAL_FALLBACKS.fetch_add(1, Ordering::Relaxed);
            for w in 0..slots {
                job(w);
            }
            return;
        }
    };
    DISPATCHES.fetch_add(1, Ordering::Relaxed);
    let latch = Latch::new(slots - 1);
    // SAFETY: lifetime erasure only — the latch wait below (which runs even
    // if slot 0 unwinds) guarantees no worker touches `job` after this
    // frame ends, so the borrow never actually outlives the closure.
    let job_erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(job) };
    for w in 1..slots {
        let task = Task {
            job: job_erased,
            slot: w,
            latch: Arc::clone(&latch),
        };
        let mb = &pool.mailboxes[w - 1];
        let mut g = mb.slot.lock().unwrap();
        *g = Some(task);
        mb.cv.notify_one();
    }
    // Wait even if slot 0 panics: the helpers borrow `job` from this stack
    // frame, so unwinding past them would be a use-after-free. (The guard
    // discards any helper payload — slot 0's own panic is already in
    // flight.)
    struct WaitGuard<'a>(&'a Latch);
    impl Drop for WaitGuard<'_> {
        fn drop(&mut self) {
            self.0.wait();
        }
    }
    let guard = WaitGuard(&*latch);
    job(0);
    // Normal path: defuse the guard (it holds no resources) and do the
    // barrier wait ourselves so the helper payload isn't consumed twice.
    std::mem::forget(guard);
    if let Some(payload) = latch.wait() {
        // Re-raise the helper's panic on the dispatching thread with its
        // original payload (assertion text and all).
        std::panic::resume_unwind(payload);
    }
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on the worker pool.
///
/// Chunks are contiguous and balanced to within one element; the grid has
/// one chunk per executing slot (`ENGD_THREADS`, unless narrowed by
/// [`with_thread_limit`]), so callers needing a width-independent
/// reduction layout must build their own grid from [`num_threads`]. `f`
/// must be `Sync` since all slots share it.
pub fn par_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = active_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    run_job(workers, &move |w| {
        let start = w * chunk;
        let end = ((w + 1) * chunk).min(n);
        if start < end {
            f(start, end);
        }
    });
}

/// Dynamic work-stealing variant for unevenly-sized items: each slot pulls
/// the next index from a shared atomic counter. Used where per-item cost
/// varies wildly (e.g. triangular Gram panels); item order is
/// nondeterministic, so callers must write disjoint, order-free outputs.
pub fn par_dynamic<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = active_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    run_job(workers, &|_w| loop {
        let i = counter.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        f(i);
    });
}

/// Parallel map producing a Vec in input order (each slot written by
/// exactly one thread — bitwise deterministic at every execution width).
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SendPtr(out.as_mut_ptr());
        par_chunks(n, |start, end| {
            for i in start..end {
                // SAFETY: chunks are disjoint, so each slot is written by
                // exactly one thread; the Vec outlives the dispatch.
                unsafe { *slots.get().add(i) = f(i) };
            }
        });
    }
    out
}

thread_local! {
    /// Per-thread scratch slots, one per type (see [`with_scratch`]).
    // BTreeMap, not HashMap: the bitwise-contract dirs ban nondeterministic
    // iteration orders outright (engd-lint R8) — lookup-only here, but the
    // ordered map keeps the invariant uniform.
    static SCRATCH: RefCell<BTreeMap<TypeId, Box<dyn Any>>> =
        RefCell::new(BTreeMap::new());
}

/// Borrow this thread's persistent scratch slot of type `T`, creating it
/// with `Default` on first use. On a pool worker the slot survives across
/// dispatches — the native backend stores its `Tape` here so steady-state
/// evaluations rebuild nothing.
///
/// Contract: the value never leaves its thread (`T: Send` only marks that
/// constructing it on a pool thread is sound); the slot is taken out of
/// the registry while `f` runs, so re-entrant use of the same `T` on the
/// same thread sees a fresh default and the outer value wins afterwards.
/// Treat the slot strictly as a rebuildable cache.
pub fn with_scratch<T, R>(f: impl FnOnce(&mut T) -> R) -> R
where
    T: Default + Send + 'static,
{
    SCRATCH.with(|cell| {
        let mut slot: Box<T> = {
            let mut map = cell.borrow_mut();
            // TypeId keying makes the downcast infallible; a fresh default
            // is the safe fallback either way. The borrow ends with this
            // block, so `f` may itself call with_scratch.
            match map.remove(&TypeId::of::<T>()).map(|b| b.downcast::<T>()) {
                Some(Ok(b)) => b,
                _ => Box::<T>::default(),
            }
        };
        let out = f(&mut slot);
        cell.borrow_mut().insert(TypeId::of::<T>(), slot);
        out
    })
}

/// Pointer wrapper that lets disjoint-index writes cross the dispatch
/// boundary. Shared by every blocked kernel in `linalg` (matmul, gram,
/// Cholesky) — each user is responsible for keeping its writes disjoint
/// per slot.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: SendPtr is a bare address; sharing it across threads is sound
// because every user partitions its writes into disjoint index ranges per
// worker (the pool's chunk grids) and the pointee outlives the dispatch
// (the latch barrier in `run_job` joins before the borrow ends).
unsafe impl<T> Sync for SendPtr<T> {}
// SAFETY: as above — moving the address between threads adds no capability
// beyond the disjoint-write contract documented on the struct.
unsafe impl<T> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Method (not field) access, so closures capture the `Sync` wrapper.
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn par_chunks_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_chunks(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_dynamic_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        par_dynamic(777, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(257, |i| i * i);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        par_chunks(0, |s, e| assert_eq!(s, e, "n=0 must yield an empty range"));
        let v = par_map(1, |i| i + 1);
        assert_eq!(v, vec![1]);
    }

    #[test]
    fn nested_parallel_calls_fall_back_serially() {
        // A parallel call from inside a pool job must not deadlock and must
        // still cover every index exactly once.
        let hits: Vec<AtomicU64> = (0..300).map(|_| AtomicU64::new(0)).collect();
        par_chunks(3, |s, e| {
            for block in s..e {
                par_dynamic(100, |i| {
                    hits[block * 100 + i].fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scratch_persists_on_the_calling_thread() {
        #[derive(Default)]
        struct Counter(u64);
        let first = with_scratch::<Counter, _>(|c| {
            c.0 += 1;
            c.0
        });
        let second = with_scratch::<Counter, _>(|c| {
            c.0 += 1;
            c.0
        });
        assert!(second > first, "scratch slot was not persisted ({first}, {second})");
    }

    #[test]
    fn scratch_reentrancy_same_type_is_isolated() {
        #[derive(Default)]
        struct Slot(u64);
        with_scratch::<Slot, _>(|outer| {
            outer.0 = 7;
            // Same type re-entered: sees a fresh default, not an alias.
            with_scratch::<Slot, _>(|inner| assert_eq!(inner.0, 0));
            assert_eq!(outer.0, 7);
        });
        // The outer value is what survives.
        with_scratch::<Slot, _>(|s| assert_eq!(s.0, 7));
    }

    #[test]
    fn with_thread_limit_restores_width() {
        let before = active_threads();
        with_thread_limit(1, || assert_eq!(active_threads(), 1));
        assert_eq!(active_threads(), before);
    }
}
