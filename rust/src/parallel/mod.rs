//! Thread-parallel helpers (substrate — rayon is unavailable offline).
//!
//! Built on `std::thread::scope`: no task queue, just chunked fork-join over
//! index ranges, which is exactly the shape of every hot loop in the dense
//! linear-algebra substrate (row-block matmul, Gram accumulation, column
//! sweeps).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads: `ENGD_THREADS` env override, else available
/// parallelism, clamped to [1, 64].
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(s) = std::env::var("ENGD_THREADS") {
            if let Ok(n) = s.parse::<usize>() {
                return n.clamp(1, 64);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .clamp(1, 64)
    })
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on the thread pool.
///
/// Chunks are contiguous and balanced to within one element. `f` must be
/// `Sync` since all threads share it.
pub fn par_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Dynamic work-stealing variant for unevenly-sized items: each worker pulls
/// the next index from a shared atomic counter. Used where per-item cost
/// varies wildly (e.g. per-column Jacobi rotations).
pub fn par_dynamic<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let f = &f;
            let counter = &counter;
            scope.spawn(move || loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map producing a Vec in input order.
pub fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots = SendPtr(out.as_mut_ptr());
        par_chunks(n, |start, end| {
            for i in start..end {
                // SAFETY: chunks are disjoint, so each slot is written by
                // exactly one thread; the Vec outlives the scope.
                unsafe { *slots.get().add(i) = f(i) };
            }
        });
    }
    out
}

/// Pointer wrapper that lets disjoint-index writes cross the scope boundary.
/// Shared by every blocked kernel in `linalg` (matmul, gram, Cholesky) —
/// each user is responsible for keeping its writes disjoint per thread.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Method (not field) access, so closures capture the `Sync` wrapper.
    #[inline]
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn par_chunks_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        par_chunks(1000, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_dynamic_covers_every_index_once() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        par_dynamic(777, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let v = par_map(257, |i| i * i);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_and_single() {
        par_chunks(0, |s, e| assert_eq!(s, e, "n=0 must yield an empty range"));
        let v = par_map(1, |i| i + 1);
        assert_eq!(v, vec![1]);
    }
}
