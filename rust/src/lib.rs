//! # engd — Energy Natural Gradient Descent, improved
//!
//! Full-system reproduction of *"Improving Energy Natural Gradient Descent
//! through Woodbury, Momentum, and Randomization"* (NeurIPS 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas Gram/matmul kernels (`python/compile/kernels/`),
//! * **L2** — the JAX PINN model and fused optimizer steps
//!   (`python/compile/model.py`), AOT-lowered to HLO text,
//! * **L3** — this crate: the training coordinator, the optimizer suite
//!   (ENGD, ENGD-W, SPRING, Nyström variants, SGD/Adam/Hessian-free
//!   baselines), a complete dense/randomized linear-algebra substrate, and
//!   the benchmark harness reproducing every figure of the paper.
//!
//! Python never runs at training time: the Rust binary loads the AOT
//! artifacts through the PJRT C API and owns the entire hot path.
//!
//! ## The backend seam
//!
//! Model evaluation goes through [`backend::Evaluator`], with two
//! implementations:
//!
//! * **PJRT** ([`runtime::Runtime`]) — executes the AOT artifacts; the
//!   paper-faithful path, and the only one with fused single-artifact
//!   optimizer steps;
//! * **native** ([`backend::NativeBackend`]) — pure-Rust evaluation of the
//!   tanh-MLP and its PDE operators through coordinate-blocked,
//!   point-batched SIMD tape kernels: per-coordinate forward duals (to the
//!   order each coordinate needs — the operator's [`pde::DualOrder`]
//!   mask) for the Laplacian/heat operators, hand-rolled reverse mode for
//!   per-sample Jacobian rows, point blocks amortizing the per-layer
//!   weight-panel setup, parallelized over collocation points. The kernels
//!   come in two numerics tiers (`--numerics bitwise|fast`, or
//!   `ENGD_NUMERICS`): the default **bitwise** tier preserves the scalar
//!   per-point FP operation order in every lane, so blocking changes no
//!   trajectory bit; the opt-in **fast** tier trades that contract for
//!   speed — explicit FMA, multi-accumulator reassociated lane reductions,
//!   wider point blocks — dispatched at runtime to the best supported
//!   instruction set (AVX2+FMA / NEON / scalar-fast, `ENGD_SIMD`
//!   overridable), still per-point deterministic and within rounding-level
//!   tolerance of the scalar reference. No artifacts, no PJRT client — the
//!   full ENGD-W/SPRING/Nyström pipeline trains and is tested offline
//!   (`--backend native`, the default wherever no artifact manifest
//!   exists).
//!
//! On top of the seam sit two sharded execution tiers, both built on the
//! native backend's range-granular `shard_*` protocol and the
//! work-stealing range scheduler in [`backend::sharded`]:
//!
//! * [`backend::ShardedEvaluator`] (`--backend sharded:<n>`) — the
//!   collocation batch served as sub-ranges by inner native evaluators on
//!   the in-process worker pool;
//! * [`backend::ProcessEvaluator`] (`--backend process:<n>`) — the same
//!   dispatch shipped to `n` worker *processes* (this binary re-entered
//!   through the hidden `--shard-worker` flag) over a length-prefixed
//!   frame protocol on stdio pipes; a crashed or hung worker is respawned
//!   and its in-flight ranges requeued.
//!
//! Every range writes into a fixed slot of the shared workspace output and
//! reductions run in the unsharded chunk order, so both tiers are
//! **bitwise-identical** to the unsharded native backend for any shard
//! count, either schedule, and any completion order — even across worker
//! crashes (`rust/tests/pool.rs`, `rust/tests/process.rs`).
//!
//! ## The execution substrate
//!
//! All parallel work — blocked linalg kernels, native AD over collocation
//! points, shard dispatch — runs on [`parallel`]'s persistent worker pool:
//! `ENGD_THREADS − 1` parked workers fed per-call through mailbox/condvar
//! handoff, with a thread-local scratch-slot API
//! ([`parallel::with_scratch`]) that keeps each worker's AD `Tape` alive
//! across evaluations. A warmed-up training step — line-search loss probes
//! included — spawns zero threads and rebuilds zero tape buffers
//! (`rust/tests/pool.rs` asserts both), and the loss/gradient reduction
//! grids depend only on `ENGD_THREADS`, so trajectories are bitwise
//! reproducible per thread-count setting.
//!
//! ## The kernel-operator layer
//!
//! The L3 hot path is organized around three pieces introduced by the
//! kernel-operator refactor:
//!
//! * [`linalg::ops`] — fused transpose products (`matmul_tn` = AᵀB,
//!   `matmul_nt` = ABᵀ, `gram_t` = AᵀA) with `*_into` variants; no
//!   `transpose()` copy ever appears on the training path.
//! * [`linalg::Workspace`] — a step-buffer pool owned by the
//!   [`coordinator::Trainer`] and threaded through [`optim::StepEnv`];
//!   Gram matrices, sketches, Nyström factors, and (via
//!   `thin_qr_into`/`eigh_into`) the stable-Nyström QR/eigendecomposition
//!   interiors are all recycled across steps, so steady-state steps
//!   allocate none of their dense temporaries.
//! * [`optim::kernel::KernelOp`] — the kernel `K = JJᵀ` as an operator
//!   (`apply`, `apply_t`, `apply_j`, `gram`, `gram_t`, `sketch_y`). Every
//!   optimizer and every `SolveMode` branch (exact Cholesky, both Nyström
//!   variants, sketch-and-precondition CG) consumes `&dyn KernelOp`, which
//!   is the seam where a sharded or PJRT-backed operator drops in without
//!   touching the optimizers.
//!
//! ## Static contracts (`// lint:` comments)
//!
//! Source-level invariants are enforced by `tools/engd-lint` (run as part
//! of `cargo test -q` via `rust/tests/lint.rs`; rules and rationale in the
//! README's "Static contracts" table). The lint is steered by structured
//! comments:
//!
//! * `// lint: hot-path` — arms the next `fn`: its body may not call
//!   `Vec::new` / `vec![..]` / `.to_vec()` / `.clone()` (rule `alloc`);
//!   steady-state steps draw from [`linalg::Workspace`] instead. The
//!   contract is interprocedural: a hot-path `fn` also may not call an
//!   in-crate callee that allocates (rule `hot-path-prop`), and functions
//!   reached only from hot paths inherit the contract automatically.
//! * `// lint: fast-tier` — in `tape.rs`, marks the next `fn` as a
//!   fast-tier kernel where FMA contraction and reassociated reductions
//!   are allowed (rule `bitwise` forbids them elsewhere in the file).
//! * `// lint: allow(<rule>)` — suppresses one rule on its line; used
//!   sparingly and with a trailing justification (e.g. a lazy first-step
//!   buffer init inside a hot-path `fn`).
//! * a file-level `fixture` pragma (the `// lint:` prefix followed by
//!   the word `fixture`) — anywhere in a file's comments, opts the whole
//!   file out of every rule (how `rust/tests/lint.rs`, whose fixture
//!   strings are deliberate violations, lives inside the walked tree).
//!
//! Two dataflow-backed contracts need no marker at all: every `let`-bound
//! `ws.take*` checkout must reach a `recycle*`/move/return sink on every
//! path — an early `return` or `?` while the buffer is live is a leak
//! (rule `ws-leak`) — and `backend/`, `linalg/`, and `parallel/` may not
//! use `HashMap`/`HashSet`/`RandomState`, whose iteration order breaks
//! shard==native bitwise identity (rule `det-iter`).
//!
//! Every `ENGD_*` environment variable read anywhere in the tree must be
//! declared in [`config::envvars::REGISTRY`] (rule `env-reg`), and read
//! through [`config::envvars::read`]/[`config::envvars::read_os`], the
//! registry-checked lookup helpers (rule `env-read`) — so the README's
//! env-var table, rendered from the registry, is complete by
//! construction.
//!
//! Quickstart (after `make artifacts`):
//! ```bash
//! cargo run --release -- train --problem poisson5d --opt spring --steps 300 --echo
//! ```

// Numeric-kernel style: index-heavy loops over row-major buffers are the
// idiom here (they mirror the blocked BLAS structure); these pedantic lints
// fight that idiom without making the kernels clearer.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy
)]

pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod linalg;
pub mod metrics;
pub mod nystrom;
pub mod optim;
pub mod parallel;
pub mod pde;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod sweep;
