//! # engd — Energy Natural Gradient Descent, improved
//!
//! Full-system reproduction of *"Improving Energy Natural Gradient Descent
//! through Woodbury, Momentum, and Randomization"* (NeurIPS 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas Gram/matmul kernels (`python/compile/kernels/`),
//! * **L2** — the JAX PINN model and fused optimizer steps
//!   (`python/compile/model.py`), AOT-lowered to HLO text,
//! * **L3** — this crate: the training coordinator, the optimizer suite
//!   (ENGD, ENGD-W, SPRING, Nyström variants, SGD/Adam/Hessian-free
//!   baselines), a complete dense/randomized linear-algebra substrate, and
//!   the benchmark harness reproducing every figure of the paper.
//!
//! Python never runs at training time: the Rust binary loads the AOT
//! artifacts through the PJRT C API and owns the entire hot path.
//!
//! Quickstart (after `make artifacts`):
//! ```bash
//! cargo run --release -- train --problem poisson5d --opt spring --steps 300 --echo
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod linalg;
pub mod metrics;
pub mod nystrom;
pub mod optim;
pub mod parallel;
pub mod pde;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod sweep;
