//! Worker-pool behaviour + sharded-evaluator cross-checks.
//!
//! Three contracts from the pool refactor are asserted here:
//!
//! 1. **Pool reuse** — threads are spawned once (first parallel call) and
//!    reused forever after; a warmed-up native training step — including
//!    its line-search loss re-evaluations — spawns zero new threads and
//!    rebuilds zero `Tape` buffers.
//! 2. **Determinism** — the per-element kernels (matmul, gram, tr_matvec,
//!    Cholesky, Jacobian rows, predictions) and the chunk-grid reductions
//!    (native loss/gradient) are bitwise identical no matter how many
//!    threads actually execute, because chunk grids depend only on
//!    `ENGD_THREADS` (CI runs this suite under `ENGD_THREADS=1` and `=4`).
//! 3. **Sharding transparency** — `ShardedEvaluator` is bitwise identical
//!    to the unsharded `NativeBackend` for any shard count, on every
//!    evaluation entry point and over whole training trajectories.
//!
//! The tests serialize on one mutex: they read process-global counters
//! (spawns, tape builds) and flip the global execution-width limit, which
//! concurrent tests would race on.

use std::sync::Mutex;

use engd::backend::{Evaluator, NativeBackend, Schedule, ShardedEvaluator};
use engd::config::run::{ExecPath, OptimizerKind};
use engd::config::RunConfig;
use engd::coordinator::{train, Trainer};
use engd::linalg::{Cholesky, Matrix, Workspace};
use engd::parallel::{self, num_threads, pool_stats, with_thread_limit};
use engd::pde::{init_params, Sampler};
use engd::rng::Rng;

/// Counter- and width-sensitive tests must not interleave.
static SERIAL: Mutex<()> = Mutex::new(());

fn serialized() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poison| poison.into_inner())
}

fn out_dir(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("engd-pool-{}-{tag}", std::process::id()))
        .display()
        .to_string()
}

/// A problem's batch + parameters, deterministically seeded.
fn problem_inputs(
    be: &dyn Evaluator,
    name: &str,
    seed: u64,
) -> (engd::pde::ProblemSpec, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let p = be.problem(name).unwrap();
    let mut rng = Rng::seed_from(seed);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, seed ^ 0xD15C);
    let x_int = sampler.interior(p.n_interior);
    let x_bnd = sampler.boundary(p.n_boundary);
    let x_eval = sampler.eval_set(64);
    (p, theta, x_int, x_bnd, x_eval)
}

// ---------------------------------------------------------------------------
// 1. Pool reuse
// ---------------------------------------------------------------------------

#[test]
fn pool_spawns_once_then_only_reuses() {
    let _guard = serialized();
    // Warm the pool.
    parallel::par_chunks(1024, |_s, _e| {});
    let spawned = pool_stats().threads_spawned;
    assert!(
        spawned <= num_threads().saturating_sub(1),
        "pool spawned {spawned} threads for {} slots",
        num_threads()
    );
    let before = pool_stats();
    for i in 0..100 {
        parallel::par_chunks(512 + i, |_s, _e| {});
        parallel::par_dynamic(64, |_i| {});
        let v = parallel::par_map(33, |j| j + i);
        assert_eq!(v[32], 32 + i);
    }
    let after = pool_stats();
    assert_eq!(
        after.threads_spawned, before.threads_spawned,
        "steady-state dispatches spawned threads: {before:?} -> {after:?}"
    );
    if num_threads() > 1 {
        assert!(
            after.dispatches > before.dispatches,
            "no dispatch reached the pool ({before:?} -> {after:?})"
        );
    }
}

#[test]
fn pool_thread_ids_stay_bounded_across_calls() {
    let _guard = serialized();
    // Collect every distinct executing thread over many dispatches: a
    // persistent pool shows at most num_threads() ids (caller + workers);
    // the old spawn-per-call substrate would show hundreds.
    let mut seen = std::collections::HashSet::new();
    for _ in 0..50 {
        let ids = parallel::par_map(num_threads(), |_| {
            Some(std::thread::current().id())
        });
        seen.extend(ids.into_iter().flatten());
    }
    assert!(
        seen.len() <= num_threads(),
        "{} distinct threads executed pool work (cap {})",
        seen.len(),
        num_threads()
    );
}

#[test]
fn warmed_up_training_step_spawns_nothing_and_rebuilds_no_tapes() {
    let _guard = serialized();
    let be = NativeBackend::new();
    let dir = out_dir("steady");
    let mut cfg = RunConfig {
        name: "steady".into(),
        problem: "poisson1d".into(),
        backend: "native".into(),
        steps: 1,
        seed: 5,
        eval_every: 1,
        out_dir: dir.clone(),
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.path = ExecPath::Decomposed;
    cfg.optimizer.damping = 1e-6;
    cfg.optimizer.momentum = 0.8;
    // Line search on: each step re-evaluates the loss many times — the
    // exact pattern that used to respawn threads and rebuild tapes.
    cfg.optimizer.line_search = true;
    cfg.optimizer.ls_grid = 8;

    // One-step warmup populates every worker's tape slot for this arch.
    let mut warm = Trainer::new(cfg.clone(), &be).unwrap();
    warm.run(false).unwrap();

    let spawned = pool_stats().threads_spawned;
    let tapes = engd::backend::native::tape_builds();

    // Three more full steps (fresh trainer, same problem/arch), each with
    // line-search probes and an L2 evaluation.
    cfg.steps = 3;
    cfg.name = "steady-more".into();
    let mut more = Trainer::new(cfg, &be).unwrap();
    let report = more.run(false).unwrap();
    assert_eq!(report.steps_done, 3);

    assert_eq!(
        pool_stats().threads_spawned,
        spawned,
        "warmed-up training steps spawned new threads"
    );
    assert_eq!(
        engd::backend::native::tape_builds(),
        tapes,
        "warmed-up training steps rebuilt tape buffers"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 2. Determinism across execution widths
// ---------------------------------------------------------------------------

#[test]
fn kernels_and_reductions_are_bitwise_deterministic_across_widths() {
    let _guard = serialized();
    let mut rng = Rng::seed_from(77);
    let mut a = Matrix::zeros(130, 70);
    rng.fill_normal(a.data_mut());
    let mut b = Matrix::zeros(70, 40);
    rng.fill_normal(b.data_mut());
    let mut v = vec![0.0; 130];
    rng.fill_normal(&mut v);
    let mut w = vec![0.0; 70];
    rng.fill_normal(&mut w);
    let spd = {
        let mut g = Matrix::zeros(300, 150);
        rng.fill_normal(g.data_mut());
        g.gram().add_diag(300.0)
    };

    let be = NativeBackend::new();
    let (p, theta, x_int, x_bnd, x_eval) = problem_inputs(&be, "poisson2d", 9);

    let run_all = || {
        let mut ws = Workspace::new();
        let (r, j) = be.residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws).unwrap();
        (
            a.matmul(&b),
            a.gram(),
            a.gram_t(),
            a.tr_matvec(&v),
            a.matvec(&w),
            Cholesky::factor(&spd).unwrap().into_factor(),
            be.loss(&p, &theta, &x_int, &x_bnd).unwrap(),
            be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap(),
            be.u_pred(&p, &theta, &x_eval).unwrap(),
            (r, j),
            engd::linalg::thin_qr(&a),
        )
    };

    let serial = with_thread_limit(1, run_all);
    let parallel_run = run_all();

    let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(serial.0.data()), bits(parallel_run.0.data()), "matmul");
    assert_eq!(bits(serial.1.data()), bits(parallel_run.1.data()), "gram");
    assert_eq!(bits(serial.2.data()), bits(parallel_run.2.data()), "gram_t");
    assert_eq!(bits(&serial.3), bits(&parallel_run.3), "tr_matvec");
    assert_eq!(bits(&serial.4), bits(&parallel_run.4), "matvec");
    assert_eq!(bits(serial.5.data()), bits(parallel_run.5.data()), "cholesky");
    assert_eq!(serial.6.to_bits(), parallel_run.6.to_bits(), "native loss");
    assert_eq!(serial.7 .0.to_bits(), parallel_run.7 .0.to_bits(), "native loss (grad path)");
    assert_eq!(bits(&serial.7 .1), bits(&parallel_run.7 .1), "native grad");
    assert_eq!(bits(&serial.8), bits(&parallel_run.8), "u_pred");
    assert_eq!(bits(&serial.9 .0), bits(&parallel_run.9 .0), "residuals");
    assert_eq!(
        bits(serial.9 .1.data()),
        bits(parallel_run.9 .1.data()),
        "jacobian"
    );
    assert_eq!(bits(serial.10.data()), bits(parallel_run.10.data()), "thin_qr");
}

/// The blocked panel kernels behind the large-batch solve path — panel
/// Cholesky (serial diagonal panel + pool-dispatched trailing-row sweep),
/// the per-column Householder fan-out in thin QR, and the pooled matvec
/// twins — are bitwise identical at every intermediate execution width,
/// not just serial vs full (chunk grids depend only on `ENGD_THREADS`).
#[test]
fn panel_factorizations_are_bitwise_identical_at_every_width() {
    let _guard = serialized();
    let mut rng = Rng::seed_from(41);
    // Big enough that the Cholesky trailing sweep (> 64 rows below a panel)
    // and the QR reflector fan-out (> 16k elements) take their parallel
    // branches at full width.
    let spd = {
        let mut g = Matrix::zeros(260, 200);
        rng.fill_normal(g.data_mut());
        g.gram().add_diag(260.0)
    };
    let mut tall = Matrix::zeros(240, 90);
    rng.fill_normal(tall.data_mut());
    let mut v = vec![0.0; 240];
    rng.fill_normal(&mut v);
    let mut w = vec![0.0; 90];
    rng.fill_normal(&mut w);

    let run_all = || {
        let mut y = vec![0.0; 240];
        tall.matvec_into(&w, &mut y);
        let mut yt = vec![0.0; 90];
        tall.tr_matvec_into(&v, &mut yt);
        (
            Cholesky::factor(&spd).unwrap().into_factor(),
            engd::linalg::thin_qr(&tall),
            y,
            yt,
        )
    };

    let reference = with_thread_limit(1, run_all);
    let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    for width in [2usize, 3, num_threads().max(1)] {
        let got = with_thread_limit(width, run_all);
        assert_eq!(
            bits(reference.0.data()),
            bits(got.0.data()),
            "cholesky @ width {width}"
        );
        assert_eq!(
            bits(reference.1.data()),
            bits(got.1.data()),
            "thin_qr @ width {width}"
        );
        assert_eq!(bits(&reference.2), bits(&got.2), "matvec_into @ width {width}");
        assert_eq!(bits(&reference.3), bits(&got.3), "tr_matvec_into @ width {width}");
    }
}

// ---------------------------------------------------------------------------
// 3. Sharding transparency
// ---------------------------------------------------------------------------

#[test]
fn sharded_evaluator_is_bitwise_identical_to_native() {
    let _guard = serialized();
    let native = NativeBackend::new();
    for problem in ["poisson1d", "poisson2d", "heat2d"] {
        let (p, theta, x_int, x_bnd, x_eval) = problem_inputs(&native, problem, 31);
        let mut ws = Workspace::new();
        let loss_ref = native.loss(&p, &theta, &x_int, &x_bnd).unwrap();
        let (lg_ref, grad_ref) = native.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
        let (r_ref, j_ref) = native
            .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws)
            .unwrap();
        let u_ref = native.u_pred(&p, &theta, &x_eval).unwrap();

        for shards in [1usize, 2, 3, 8] {
            let sharded = ShardedEvaluator::new(shards);
            let tag = format!("{problem} x{shards}");

            let loss = sharded.loss(&p, &theta, &x_int, &x_bnd).unwrap();
            assert_eq!(loss.to_bits(), loss_ref.to_bits(), "{tag}: loss");

            let (lg, grad) = sharded.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
            assert_eq!(lg.to_bits(), lg_ref.to_bits(), "{tag}: loss (grad path)");
            for (i, (g, gr)) in grad.iter().zip(&grad_ref).enumerate() {
                assert_eq!(g.to_bits(), gr.to_bits(), "{tag}: grad[{i}]");
            }

            let mut ws_s = Workspace::new();
            let (r, j) = sharded
                .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws_s)
                .unwrap();
            for (i, (x, y)) in r.iter().zip(&r_ref).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: r[{i}]");
            }
            assert_eq!((j.rows(), j.cols()), (j_ref.rows(), j_ref.cols()), "{tag}");
            for (i, (x, y)) in j.data().iter().zip(j_ref.data()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: J[{i}]");
            }

            let u = sharded.u_pred(&p, &theta, &x_eval).unwrap();
            for (i, (x, y)) in u.iter().zip(&u_ref).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{tag}: u[{i}]");
            }
        }
    }
}

/// The sharded evaluator's reduction partials come from its scratch pool:
/// after the first loss / loss-and-grad evaluation the pool is warm and
/// further steps (same problem, and line-search-style repeated losses)
/// allocate no fresh partial buffers — the same steady-state
/// zero-allocation contract the `Workspace` tests assert everywhere else.
#[test]
fn sharded_loss_grad_partials_are_pooled() {
    let _guard = serialized();
    let sharded = ShardedEvaluator::new(3);
    let (p, theta, x_int, x_bnd, _) = problem_inputs(&sharded, "poisson2d", 23);

    // Warm-up: first calls may draw fresh pool buffers.
    sharded.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
    sharded.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    let fresh = sharded.scratch_stats().fresh_allocs;
    assert!(fresh > 0, "partials never touched the scratch pool");

    // Steady state: repeated loss/grad steps must only reuse.
    for _ in 0..5 {
        sharded.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
        sharded.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    }
    let stats = sharded.scratch_stats();
    assert_eq!(
        stats.fresh_allocs, fresh,
        "steady-state sharded loss/grad drew fresh partial buffers: {stats:?}"
    );
    assert!(stats.reuses > 0, "pool never reused: {stats:?}");
}

/// The unsharded native `loss_and_grad` draws its per-chunk gradient
/// partials from the backend's scratch pool too (same contract as the
/// sharded reduction partials): warm once, then steady-state steps must
/// only reuse — `scratch_stats().fresh_allocs` frozen.
#[test]
fn native_loss_grad_partials_are_pooled() {
    let _guard = serialized();
    let be = NativeBackend::new();
    let (p, theta, x_int, x_bnd, _) = problem_inputs(&be, "poisson2d", 29);

    // Warm-up: the first call may draw fresh pool buffers.
    be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
    let fresh = be.scratch_stats().fresh_allocs;
    assert!(fresh > 0, "partials never touched the scratch pool");

    // Steady state: repeated grad steps must only reuse.
    for _ in 0..5 {
        be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
    }
    let stats = be.scratch_stats();
    assert_eq!(
        stats.fresh_allocs, fresh,
        "steady-state native loss_and_grad drew fresh partial buffers: {stats:?}"
    );
    assert!(stats.reuses > 0, "pool never reused: {stats:?}");
}

#[test]
fn sharded_training_trajectory_is_bitwise_identical_to_native() {
    let _guard = serialized();
    let mk_cfg = |tag: &str, dir: &str| {
        let mut cfg = RunConfig {
            name: tag.to_string(),
            problem: "poisson1d".into(),
            steps: 4,
            seed: 17,
            eval_every: 2,
            out_dir: dir.to_string(),
            ..RunConfig::default()
        };
        cfg.optimizer.kind = OptimizerKind::Spring;
        cfg.optimizer.path = ExecPath::Decomposed;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.momentum = 0.8;
        cfg.optimizer.line_search = true;
        cfg.optimizer.ls_grid = 8;
        cfg
    };

    let dir = out_dir("traj");
    let native = NativeBackend::new();
    let base = train(mk_cfg("traj-native", &dir), &native, false).unwrap();

    for shards in [2usize, 5] {
        let sharded = ShardedEvaluator::new(shards);
        let run = train(
            mk_cfg(&format!("traj-sharded{shards}"), &dir),
            &sharded,
            false,
        )
        .unwrap();
        assert_eq!(run.backend, "sharded");
        assert_eq!(base.losses.len(), run.losses.len());
        for (k, (a, b)) in base.losses.iter().zip(&run.losses).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{shards} shards, step {}: native loss {a:.17e} != sharded {b:.17e}",
                k + 1
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Both range schedules are bitwise-invisible: work stealing may move
/// ranges between shards, but every range lands in its fixed output slot
/// and the reductions run in the unsharded chunk order.
#[test]
fn thread_tier_schedules_are_bitwise_invisible_and_counted() {
    let _guard = serialized();
    let native = NativeBackend::new();
    let (p, theta, x_int, x_bnd, _) = problem_inputs(&native, "poisson2d", 57);
    let loss_ref = native.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    let (_, grad_ref) = native.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();

    for schedule in [Schedule::Static, Schedule::WorkSteal] {
        let sharded = ShardedEvaluator::new(4).with_schedule(schedule);
        assert_eq!(sharded.schedule(), schedule);
        for round in 0..3 {
            let loss = sharded.loss(&p, &theta, &x_int, &x_bnd).unwrap();
            assert_eq!(
                loss.to_bits(),
                loss_ref.to_bits(),
                "{} round {round}: loss",
                schedule.name()
            );
            let (_, grad) = sharded.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
            for (i, (g, gr)) in grad.iter().zip(&grad_ref).enumerate() {
                assert_eq!(g.to_bits(), gr.to_bits(), "{}: grad[{i}]", schedule.name());
            }
        }
        let snap = sharded.sched_stats().unwrap();
        assert!(snap.ranges > 0, "{}: no ranges dispatched", schedule.name());
        assert_eq!(snap.shard_busy_s.len(), 4);
        assert_eq!((snap.requeues, snap.respawns), (0, 0), "thread tier never requeues");
        if schedule == Schedule::Static {
            assert_eq!(snap.steals, 0, "static schedule must never steal");
        }
    }
}

#[test]
fn backend_select_understands_sharded() {
    let _guard = serialized();
    let be = engd::backend::select("sharded:3", "artifacts").unwrap();
    assert_eq!(be.backend_name(), "sharded");
    assert!(be.problem("poisson1d").is_ok());

    let default = engd::backend::select("sharded", "artifacts").unwrap();
    assert_eq!(default.backend_name(), "sharded");

    assert!(engd::backend::select("sharded:0", "artifacts").is_err());
    assert!(engd::backend::select("sharded:x", "artifacts").is_err());
    assert!(engd::backend::select("bogus", "artifacts").is_err());
}

/// Process-tier *selection* from libtest: construction is lazy (workers
/// only spawn on the first evaluation), so no worker processes are born
/// here — the spawning tests live in the harness-free
/// `rust/tests/process.rs` suite, which owns its stdout.
#[test]
fn backend_select_understands_process() {
    let _guard = serialized();
    let be = engd::backend::select("process:3", "artifacts").unwrap();
    assert_eq!(be.backend_name(), "process");
    assert!(be.problem("poisson1d").is_ok());
    assert!(be.sched_stats().is_some());

    let default = engd::backend::select("process", "artifacts").unwrap();
    assert_eq!(default.backend_name(), "process");

    assert!(engd::backend::select("process:0", "artifacts").is_err());
    assert!(engd::backend::select("process:x", "artifacts").is_err());
    assert!(engd::backend::validate_backend("process:0").is_err());
    assert!(engd::backend::validate_backend("process:2").is_ok());
}
