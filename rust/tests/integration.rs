//! Integration tests over the full stack: PJRT runtime + artifacts + Rust
//! linalg, cross-validating the fused (XLA) and decomposed (Rust) paths.
//!
//! These tests need `make artifacts` to have run; they skip gracefully (with
//! a loud message) when the artifact directory is missing so `cargo test`
//! works in a fresh checkout.

use engd::backend::NumericsMode;
use engd::config::run::{ExecPath, OptimizerKind, SolveMode};
use engd::config::RunConfig;
use engd::linalg::{Cholesky, Matrix, Workspace};
use engd::optim::{build_from_opt, StepEnv};
use engd::pde::{exact_solution, init_params, mlp_forward, Sampler};
use engd::rng::Rng;
use engd::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

/// The `u_pred` artifact must agree with the independent Rust MLP oracle —
/// this pins the flat-parameter layout across the Python/Rust boundary.
#[test]
fn u_pred_artifact_matches_rust_forward_oracle() {
    let Some(rt) = runtime() else { return };
    let p = rt.manifest().problem("poisson2d").unwrap();
    let mut rng = Rng::seed_from(123);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 9);
    let xs = sampler.eval_set(p.n_eval);

    let art = rt.artifact("poisson2d", "u_pred").unwrap();
    let out = art.call(&[&theta, &xs]).unwrap();
    let u_artifact = &out[0];

    for (i, x) in xs.chunks_exact(p.dim).enumerate().take(64) {
        let u_rust = mlp_forward(&theta, &p.arch, x);
        assert!(
            (u_artifact[i] - u_rust).abs() < 1e-10,
            "point {i}: artifact {} vs rust {}",
            u_artifact[i],
            u_rust
        );
    }
}

/// Woodbury exactness across the stack: the fused `engd_w_dir` artifact, the
/// Rust decomposed solve, and the dense P×P ENGD solve must all agree
/// (paper eq. 5 — the central exactness claim).
#[test]
fn fused_decomposed_and_dense_engd_agree() {
    let Some(rt) = runtime() else { return };
    let pname = "poisson2d";
    let p = rt.manifest().problem(pname).unwrap();
    let mut rng = Rng::seed_from(7);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 11);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);
    let lam = 1e-6;

    // Fused path.
    let art = rt.artifact(pname, "engd_w_dir").unwrap();
    let out = art.call(&[&theta, &xi, &xb, &[lam]]).unwrap();
    let phi_fused = &out[0];

    // Decomposed path: (r, J) artifact + Rust kernel solve.
    let art = rt.artifact(pname, "residuals_jacobian").unwrap();
    let mut jr = art.call(&[&theta, &xi, &xb]).unwrap();
    let j = Matrix::from_vec(p.n_total(), p.n_params, jr.pop().unwrap());
    let r = jr.pop().unwrap();
    let k = j.gram();
    let a = Cholesky::factor(&k.add_diag(lam)).unwrap().solve(&r);
    let phi_rust = j.tr_matvec(&a);

    // Dense ENGD: (JᵀJ + λI)φ = Jᵀr.
    let g = j.transpose().gram();
    let grad = j.tr_matvec(&r);
    let phi_dense = Cholesky::factor(&g.add_diag(lam)).unwrap().solve(&grad);

    let norm: f64 = phi_fused.iter().map(|x| x * x).sum::<f64>().sqrt();
    for i in 0..p.n_params {
        assert!(
            (phi_fused[i] - phi_rust[i]).abs() < 1e-6 * norm.max(1.0),
            "fused vs rust at {i}: {} vs {}",
            phi_fused[i],
            phi_rust[i]
        );
        assert!(
            (phi_fused[i] - phi_dense[i]).abs() < 1e-6 * norm.max(1.0),
            "fused vs dense at {i}: {} vs {}",
            phi_fused[i],
            phi_dense[i]
        );
    }
}

/// The `kernel` artifact (Pallas gram inside XLA) must match Rust's gram of
/// the Jacobian from `residuals_jacobian` — L1 vs L3 cross-validation.
#[test]
fn pallas_kernel_matches_rust_gram() {
    let Some(rt) = runtime() else { return };
    let pname = "poisson2d";
    let p = rt.manifest().problem(pname).unwrap();
    let mut rng = Rng::seed_from(21);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 13);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);

    let mut out = rt
        .artifact(pname, "kernel")
        .unwrap()
        .call(&[&theta, &xi, &xb])
        .unwrap();
    let r_k = out.pop().unwrap();
    let k_art = Matrix::from_vec(p.n_total(), p.n_total(), out.pop().unwrap());

    let mut jr = rt
        .artifact(pname, "residuals_jacobian")
        .unwrap()
        .call(&[&theta, &xi, &xb])
        .unwrap();
    let j = Matrix::from_vec(p.n_total(), p.n_params, jr.pop().unwrap());
    let r_j = jr.pop().unwrap();
    let k_rust = j.gram();

    assert!(k_art.max_abs_diff(&k_rust) < 1e-8, "kernel mismatch");
    for (a, b) in r_k.iter().zip(&r_j) {
        assert!((a - b).abs() < 1e-12);
    }
}

/// jtv / jv artifacts against explicit J.
#[test]
fn jtv_jv_artifacts_match_explicit_jacobian() {
    let Some(rt) = runtime() else { return };
    let pname = "poisson2d";
    let p = rt.manifest().problem(pname).unwrap();
    let mut rng = Rng::seed_from(31);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 17);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);

    let mut jr = rt
        .artifact(pname, "residuals_jacobian")
        .unwrap()
        .call(&[&theta, &xi, &xb])
        .unwrap();
    let j = Matrix::from_vec(p.n_total(), p.n_params, jr.pop().unwrap());

    let mut v = vec![0.0; p.n_total()];
    rng.fill_normal(&mut v);
    let jtv = rt
        .artifact(pname, "jtv")
        .unwrap()
        .call(&[&theta, &xi, &xb, &v])
        .unwrap();
    let want = j.tr_matvec(&v);
    for (a, b) in jtv[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }

    let mut w = vec![0.0; p.n_params];
    rng.fill_normal(&mut w);
    let jv = rt
        .artifact(pname, "jv")
        .unwrap()
        .call(&[&theta, &xi, &xb, &w])
        .unwrap();
    let want = j.matvec(&w);
    for (a, b) in jv[0].iter().zip(&want) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}

/// SPRING fused vs decomposed step equivalence over several iterations
/// (state φ must evolve identically).
#[test]
fn spring_fused_and_decomposed_paths_agree() {
    let Some(rt) = runtime() else { return };
    let pname = "poisson2d";
    let p = rt.manifest().problem(pname).unwrap().clone();
    let mut rng = Rng::seed_from(77);
    let theta0 = init_params(&p.arch, &mut rng);

    let mut base = engd::config::OptimizerConfig::default();
    base.kind = OptimizerKind::Spring;
    base.damping = 1e-3;
    base.momentum = 0.85;
    base.lr = 0.01;
    base.line_search = false;

    let mut fused_cfg = base.clone();
    fused_cfg.path = ExecPath::Fused;
    let mut dec_cfg = base.clone();
    dec_cfg.path = ExecPath::Decomposed;

    let mut fused = build_from_opt(&fused_cfg).unwrap();
    let mut dec = build_from_opt(&dec_cfg).unwrap();

    let mut theta_f = theta0.clone();
    let mut theta_d = theta0.clone();
    let mut ws_f = Workspace::new();
    let mut ws_d = Workspace::new();
    let mut sampler = Sampler::new(p.dim, 19);
    for k in 1..=3 {
        let xi = sampler.interior(p.n_interior);
        let xb = sampler.boundary(p.n_boundary);
        let mut rng_f = Rng::seed_from(1000 + k as u64);
        let mut env = StepEnv {
            eval: &rt,
            problem: &p,
            x_int: &xi,
            x_bnd: &xb,
            k,
            rng: &mut rng_f,
            ws: &mut ws_f,
            diagnostics: false,
            numerics: NumericsMode::Bitwise,
        };
        let inf = fused.step(&mut theta_f, &mut env).unwrap();
        let mut rng_d = Rng::seed_from(1000 + k as u64);
        let mut env = StepEnv {
            eval: &rt,
            problem: &p,
            x_int: &xi,
            x_bnd: &xb,
            k,
            rng: &mut rng_d,
            ws: &mut ws_d,
            diagnostics: false,
            numerics: NumericsMode::Bitwise,
        };
        let ind = dec.step(&mut theta_d, &mut env).unwrap();
        assert!(
            (inf.loss - ind.loss).abs() < 1e-6 * (1.0 + inf.loss.abs()),
            "step {k} loss: {} vs {}",
            inf.loss,
            ind.loss
        );
        let scale: f64 = theta_f.iter().map(|x| x.abs()).fold(0.0, f64::max);
        for i in 0..theta_f.len() {
            assert!(
                (theta_f[i] - theta_d[i]).abs() < 1e-5 * scale.max(1.0),
                "step {k}, θ[{i}]: {} vs {}",
                theta_f[i],
                theta_d[i]
            );
        }
    }
}

/// Short end-to-end training runs for every optimizer kind: loss must stay
/// finite and the L2 error must not be garbage (coordinator-level invariant).
#[test]
fn every_optimizer_trains_without_diverging() {
    let Some(rt) = runtime() else { return };
    let kinds: &[(&str, OptimizerKind)] = &[
        ("sgd", OptimizerKind::Sgd),
        ("adam", OptimizerKind::Adam),
        ("engd_dense", OptimizerKind::EngdDense),
        ("engd_w", OptimizerKind::EngdW),
        ("spring", OptimizerKind::Spring),
        ("hessian_free", OptimizerKind::HessianFree),
    ];
    for (tag, kind) in kinds {
        let mut cfg = RunConfig {
            name: format!("itest-{tag}"),
            problem: "poisson2d".into(),
            steps: 5,
            eval_every: 5,
            out_dir: std::env::temp_dir()
                .join("engd-itest")
                .display()
                .to_string(),
            ..RunConfig::default()
        };
        cfg.optimizer.kind = kind.clone();
        cfg.optimizer.line_search = true;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.lr = 1e-3;
        if matches!(kind, OptimizerKind::Sgd | OptimizerKind::Adam) {
            cfg.optimizer.line_search = false;
        }
        let report = engd::coordinator::train(cfg, &rt, false)
            .unwrap_or_else(|e| panic!("{tag} failed: {e:#}"));
        assert_eq!(report.steps_done, 5, "{tag}");
        assert!(report.final_loss.is_finite(), "{tag} diverged");
        assert!(report.best_l2.is_finite(), "{tag} produced non-finite L2");
    }
}

/// Randomized ENGD-W (both Nyström variants) must roughly track the exact
/// direction at a generous sketch size (paper eq. 9 sanity): cosine
/// similarity of the step directions stays high.
#[test]
fn randomized_solves_track_exact_at_large_sketch() {
    let Some(rt) = runtime() else { return };
    let pname = "poisson2d";
    let p = rt.manifest().problem(pname).unwrap().clone();
    let mut rng = Rng::seed_from(5);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 23);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);

    let mut ws = Workspace::new();
    let mut phis: Vec<Vec<f64>> = Vec::new();
    for solve in [
        SolveMode::Exact,
        SolveMode::NystromGpu,
        SolveMode::NystromStable,
    ] {
        let mut o = engd::config::OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-4,
            line_search: false,
            lr: 0.0, // direction only: lr 0 keeps θ fixed
            solve,
            sketch_ratio: 0.9,
            path: ExecPath::Decomposed,
            ..Default::default()
        };
        o.validate().unwrap();
        let mut opt = build_from_opt(&o).unwrap();
        let mut theta_copy = theta.clone();
        let mut rng_s = Rng::seed_from(99);
        let mut env = StepEnv {
            eval: &rt,
            problem: &p,
            x_int: &xi,
            x_bnd: &xb,
            k: 1,
            rng: &mut rng_s,
            ws: &mut ws,
            diagnostics: false,
            numerics: NumericsMode::Bitwise,
        };
        let info = opt.step(&mut theta_copy, &mut env).unwrap();
        assert!(info.loss.is_finite());
        // θ unchanged at lr=0; recover φ by re-running the solve by hand is
        // overkill — instead compare losses after a probe step below.
        phis.push(theta_copy);
    }

    // Probe: apply one line-searched step per variant and require the
    // randomized losses to be within a factor of the exact one.
    let mut losses = Vec::new();
    for solve in [
        SolveMode::Exact,
        SolveMode::NystromGpu,
        SolveMode::NystromStable,
    ] {
        let mut o = engd::config::OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-4,
            line_search: true,
            solve,
            sketch_ratio: 0.9,
            path: ExecPath::Decomposed,
            ..Default::default()
        };
        o.validate().unwrap();
        let mut opt = build_from_opt(&o).unwrap();
        let mut theta_copy = theta.clone();
        let mut rng_s = Rng::seed_from(99);
        let mut env = StepEnv {
            eval: &rt,
            problem: &p,
            x_int: &xi,
            x_bnd: &xb,
            k: 1,
            rng: &mut rng_s,
            ws: &mut ws,
            diagnostics: false,
            numerics: NumericsMode::Bitwise,
        };
        opt.step(&mut theta_copy, &mut env).unwrap();
        let env = StepEnv {
            eval: &rt,
            problem: &p,
            x_int: &xi,
            x_bnd: &xb,
            k: 2,
            rng: &mut rng_s,
            ws: &mut ws,
            diagnostics: false,
            numerics: NumericsMode::Bitwise,
        };
        losses.push(env.eval_loss(&theta_copy).unwrap());
    }
    let exact = losses[0];
    for (i, l) in losses.iter().enumerate().skip(1) {
        assert!(
            *l <= exact * 3.0 + 1.0,
            "variant {i}: post-step loss {l} far above exact {exact}"
        );
    }
}

/// The trainer's step-buffer pool must reach steady state after step 1: a
/// two-step decomposed run may not allocate any fresh workspace buffer in
/// its second step (same problem ⇒ same shapes ⇒ pure reuse).
#[test]
fn trainer_workspace_is_reused_not_regrown_across_steps() {
    let Some(rt) = runtime() else { return };
    for solve in [SolveMode::Exact, SolveMode::NystromGpu] {
        let mut cfg = RunConfig {
            name: format!("itest-ws-{}", solve.name()),
            problem: "poisson2d".into(),
            steps: 1,
            // NB: the final step always evaluates (k == steps), so both runs
            // end with one diagnostics step; diagnostics allocate outside
            // the workspace, leaving the pool comparison valid.
            eval_every: 100,
            out_dir: std::env::temp_dir()
                .join("engd-itest")
                .display()
                .to_string(),
            ..RunConfig::default()
        };
        cfg.optimizer.kind = OptimizerKind::EngdW;
        cfg.optimizer.path = ExecPath::Decomposed;
        cfg.optimizer.solve = solve;
        cfg.optimizer.line_search = false;
        cfg.optimizer.lr = 1e-3;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.validate().unwrap();

        let mut one = engd::coordinator::Trainer::new(cfg.clone(), &rt).unwrap();
        one.run(false).unwrap();
        let after_one = one.workspace_stats();

        cfg.steps = 2;
        let mut two = engd::coordinator::Trainer::new(cfg, &rt).unwrap();
        two.run(false).unwrap();
        let after_two = two.workspace_stats();

        assert_eq!(
            (after_two.fresh_allocs, after_two.grown),
            (after_one.fresh_allocs, after_one.grown),
            "{}: step 2 allocated or regrew buffers instead of reusing the \
             pool (after one step {after_one:?}, after two {after_two:?})",
            solve.name()
        );
        assert!(
            after_two.reuses > after_one.reuses,
            "{}: step 2 did not draw from the pool ({after_two:?})",
            solve.name()
        );
    }
}

/// The exact-solution tags in the manifest all resolve.
#[test]
fn manifest_pde_tags_resolve() {
    let Some(rt) = runtime() else { return };
    for (name, p) in &rt.manifest().problems {
        exact_solution(&p.pde).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert_eq!(p.arch[0], p.dim, "{name}: arch[0] != dim");
        assert_eq!(*p.arch.last().unwrap(), 1, "{name}: arch must end at 1");
    }
}

/// Cross-backend agreement: the native backend's `u_pred`, `loss`, and
/// `(r, J)` must match the PJRT artifacts on the same inputs — the seam
/// contract of `backend::Evaluator`. (Artifact-free native correctness is
/// covered by `rust/tests/native.rs`; this pins the two implementations to
/// each other whenever artifacts exist.)
#[test]
fn native_backend_matches_pjrt_artifacts() {
    use engd::backend::{Evaluator, NativeBackend};

    let Some(rt) = runtime() else { return };
    let native = NativeBackend::new();
    let p = Evaluator::problem(&rt, "poisson2d").unwrap();
    let mut rng = Rng::seed_from(2024);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, 31);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);

    // u_pred.
    let xs = sampler.eval_set(64);
    let u_pjrt = rt.u_pred(&p, &theta, &xs).unwrap();
    let u_nat = native.u_pred(&p, &theta, &xs).unwrap();
    for (a, b) in u_pjrt.iter().zip(&u_nat) {
        assert!((a - b).abs() < 1e-9, "u_pred: {a} vs {b}");
    }

    // loss.
    let l_pjrt = Evaluator::loss(&rt, &p, &theta, &xi, &xb).unwrap();
    let l_nat = Evaluator::loss(&native, &p, &theta, &xi, &xb).unwrap();
    assert!(
        (l_pjrt - l_nat).abs() < 1e-8 * (1.0 + l_pjrt.abs()),
        "loss: {l_pjrt} vs {l_nat}"
    );

    // (r, J).
    let mut ws = Workspace::new();
    let (r_p, j_p) = rt.residuals_jacobian(&p, &theta, &xi, &xb, &mut ws).unwrap();
    let (r_n, j_n) = native
        .residuals_jacobian(&p, &theta, &xi, &xb, &mut ws)
        .unwrap();
    for (a, b) in r_p.iter().zip(&r_n) {
        assert!((a - b).abs() < 1e-8, "r: {a} vs {b}");
    }
    assert!(
        j_p.max_abs_diff(&j_n) < 1e-6,
        "J mismatch: {:.3e}",
        j_p.max_abs_diff(&j_n)
    );
}
