//! Process-tier integration suite (`--backend process:<n>`).
//!
//! This target is `harness = false` on purpose: the supervisor spawns
//! *this very binary* with `--shard-worker` to get its worker processes,
//! and the libtest harness owns stdout (it even prints slow-test warnings
//! there), which would corrupt the frame protocol. `main` below therefore
//! answers `--shard-worker` first and otherwise runs a minimal sequential
//! test runner.
//!
//! Contracts asserted here:
//!
//! 1. **Bitwise transparency** — `process:n` equals `sharded:n` equals the
//!    unsharded native backend, bit for bit, on every evaluation entry
//!    point, for n ∈ {1, 2, 4}, on poisson2d and heat2d.
//! 2. **Trajectory identity** — a full poisson2d training run through
//!    worker processes reproduces the native loss trajectory exactly, and
//!    the metrics CSV carries the scheduler columns.
//! 3. **Fault tolerance** — a worker killed mid-evaluation (both by
//!    injected crash and by external SIGKILL) is respawned, its in-flight
//!    ranges are requeued, and the results are still bitwise native.
//! 4. **Config hygiene** — `process:0` is rejected at selector- and
//!    TOML-parse time.

use engd::backend::{
    Evaluator, NativeBackend, ProcessEvaluator, ProcessOptions, ShardedEvaluator,
};
use engd::config::run::{ExecPath, OptimizerKind};
use engd::config::RunConfig;
use engd::coordinator::train;
use engd::linalg::Workspace;
use engd::pde::{init_params, Sampler};
use engd::rng::Rng;

fn out_dir(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("engd-process-{}-{tag}", std::process::id()))
        .display()
        .to_string()
}

/// A problem's batch + parameters, deterministically seeded (the same
/// helper `rust/tests/pool.rs` uses).
fn problem_inputs(
    be: &dyn Evaluator,
    name: &str,
    seed: u64,
) -> (engd::pde::ProblemSpec, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let p = be.problem(name).unwrap();
    let mut rng = Rng::seed_from(seed);
    let theta = init_params(&p.arch, &mut rng);
    let mut sampler = Sampler::new(p.dim, seed ^ 0xD15C);
    let x_int = sampler.interior(p.n_interior);
    let x_bnd = sampler.boundary(p.n_boundary);
    let x_eval = sampler.eval_set(64);
    (p, theta, x_int, x_bnd, x_eval)
}

fn assert_bits(tag: &str, got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{tag}[{i}]: {g:.17e} != {w:.17e}");
    }
}

/// Every evaluation entry point of `ev`, bitwise against the native
/// reference.
fn assert_matches_native(tag: &str, ev: &dyn Evaluator, native: &NativeBackend, problem: &str) {
    let (p, theta, x_int, x_bnd, x_eval) = problem_inputs(native, problem, 31);
    let mut ws = Workspace::new();

    let loss_ref = native.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    let loss = ev.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    assert_eq!(loss.to_bits(), loss_ref.to_bits(), "{tag}: loss");

    let (lg_ref, grad_ref) = native.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
    let (lg, grad) = ev.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap();
    assert_eq!(lg.to_bits(), lg_ref.to_bits(), "{tag}: loss (grad path)");
    assert_bits(&format!("{tag}: grad"), &grad, &grad_ref);

    let (r_ref, j_ref) = native
        .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws)
        .unwrap();
    let mut ws_e = Workspace::new();
    let (r, j) = ev
        .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws_e)
        .unwrap();
    assert_bits(&format!("{tag}: r"), &r, &r_ref);
    assert_eq!((j.rows(), j.cols()), (j_ref.rows(), j_ref.cols()), "{tag}: J shape");
    assert_bits(&format!("{tag}: J"), j.data(), j_ref.data());

    let u_ref = native.u_pred(&p, &theta, &x_eval).unwrap();
    let u = ev.u_pred(&p, &theta, &x_eval).unwrap();
    assert_bits(&format!("{tag}: u"), &u, &u_ref);
}

// ---------------------------------------------------------------------------
// 1. Bitwise transparency
// ---------------------------------------------------------------------------

fn process_tier_is_bitwise_identical_to_threads_and_native() {
    let native = NativeBackend::new();
    for problem in ["poisson2d", "heat2d"] {
        for n in [1usize, 2, 4] {
            let threads = ShardedEvaluator::new(n);
            assert_matches_native(&format!("{problem} sharded:{n}"), &threads, &native, problem);
            let procs = ProcessEvaluator::new(n);
            assert_matches_native(&format!("{problem} process:{n}"), &procs, &native, problem);
            let snap = procs.sched_stats().unwrap();
            assert!(snap.ranges > 0, "{problem} process:{n}: no ranges dispatched");
            assert_eq!(snap.shard_busy_s.len(), n, "{problem} process:{n}: busy vector");
        }
    }
}

// ---------------------------------------------------------------------------
// 2. Trajectory identity + scheduler metrics
// ---------------------------------------------------------------------------

fn training_through_worker_processes_matches_native_and_logs_sched() {
    let mk_cfg = |tag: &str, backend: &str, dir: &str| {
        let mut cfg = RunConfig {
            name: tag.to_string(),
            problem: "poisson2d".into(),
            backend: backend.to_string(),
            steps: 3,
            seed: 17,
            eval_every: 2,
            out_dir: dir.to_string(),
            ..RunConfig::default()
        };
        cfg.optimizer.kind = OptimizerKind::Spring;
        cfg.optimizer.path = ExecPath::Decomposed;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.momentum = 0.8;
        cfg.optimizer.line_search = true;
        cfg.optimizer.ls_grid = 6;
        cfg
    };

    let dir = out_dir("traj");
    let native = NativeBackend::new();
    let base = train(mk_cfg("traj-native", "native", &dir), &native, false).unwrap();

    let procs = ProcessEvaluator::new(2);
    let run = train(mk_cfg("traj-process2", "process:2", &dir), &procs, false).unwrap();
    assert_eq!(run.backend, "process");
    assert_eq!(base.losses.len(), run.losses.len());
    for (k, (a, b)) in base.losses.iter().zip(&run.losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {}: native loss {a:.17e} != process {b:.17e}",
            k + 1
        );
    }

    // The per-step scheduler deltas landed as CSV extras.
    let csv =
        std::fs::read_to_string(std::path::Path::new(&dir).join("traj-process2.csv")).unwrap();
    let header = csv.lines().next().unwrap();
    for col in ["sched_ranges", "sched_steals", "sched_requeues", "sched_respawns", "shard0_s"] {
        assert!(header.contains(col), "missing CSV column {col}: {header}");
    }
    // And the native run's CSV carries none of them.
    let csv_n =
        std::fs::read_to_string(std::path::Path::new(&dir).join("traj-native.csv")).unwrap();
    assert!(!csv_n.lines().next().unwrap().contains("sched_ranges"));
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// 3. Fault tolerance
// ---------------------------------------------------------------------------

fn injected_worker_crash_is_respawned_requeued_and_bitwise_invisible() {
    let native = NativeBackend::new();
    let (p, theta, x_int, x_bnd, _) = problem_inputs(&native, "poisson2d", 43);
    let mut ws = Workspace::new();
    let loss_ref = native.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    let (r_ref, j_ref) = native
        .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws)
        .unwrap();

    // Worker 0's first incarnation dies abruptly the moment its first
    // range request arrives — with a range in flight, mid-evaluation.
    let procs = ProcessEvaluator::with_options(ProcessOptions {
        workers: 2,
        fault_once: Some((0, 0)),
        ..ProcessOptions::default()
    });
    // Several evaluations (the Jacobian one hands worker 0 four sub-ranges
    // of its own), so worker 0 claims work — and dies — no matter how
    // stealing interleaves the cheap loss dispatches.
    for round in 0..3 {
        let loss = procs.loss(&p, &theta, &x_int, &x_bnd).unwrap();
        assert_eq!(loss.to_bits(), loss_ref.to_bits(), "round {round}: loss");
    }
    let mut ws_p = Workspace::new();
    let (r, j) = procs
        .residuals_jacobian(&p, &theta, &x_int, &x_bnd, &mut ws_p)
        .unwrap();
    assert_bits("faulted r", &r, &r_ref);
    assert_bits("faulted J", j.data(), j_ref.data());

    let snap = procs.sched_stats().unwrap();
    assert!(snap.respawns >= 1, "crash never triggered a respawn: {snap:?}");
    assert!(snap.requeues >= 1, "crash never requeued a range: {snap:?}");
}

fn externally_killed_worker_recovers_between_evaluations() {
    let native = NativeBackend::new();
    let (p, theta, x_int, x_bnd, _) = problem_inputs(&native, "poisson2d", 47);
    let loss_ref = native.loss(&p, &theta, &x_int, &x_bnd).unwrap();

    let procs = ProcessEvaluator::new(2);
    let loss = procs.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    assert_eq!(loss.to_bits(), loss_ref.to_bits(), "pre-kill loss");
    assert!(
        procs.worker_pids().iter().any(|pid| pid.is_some()),
        "no worker alive after an evaluation"
    );

    // SIGKILL one worker out from under the supervisor; the next
    // evaluation must respawn it (and re-ship the context) transparently.
    procs.kill_worker(0);
    let loss = procs.loss(&p, &theta, &x_int, &x_bnd).unwrap();
    assert_eq!(loss.to_bits(), loss_ref.to_bits(), "post-kill loss");
    let snap = procs.sched_stats().unwrap();
    assert!(snap.respawns >= 1, "external kill never counted a respawn: {snap:?}");
}

// ---------------------------------------------------------------------------
// 4. Selection + config hygiene
// ---------------------------------------------------------------------------

fn selector_and_config_reject_zero_workers() {
    // Selection is lazy: building process:2 spawns nothing until the first
    // evaluation, so this is cheap.
    let be = engd::backend::select("process:2", "artifacts").unwrap();
    assert_eq!(be.backend_name(), "process");
    assert!(be.problem("poisson2d").is_ok());

    assert!(engd::backend::select("process:0", "artifacts").is_err());
    assert!(engd::backend::select("process:x", "artifacts").is_err());
    assert!(engd::backend::validate_backend("process:4").is_ok());
    assert!(engd::backend::validate_backend("process").is_ok());
    assert!(engd::backend::validate_backend("process:0").is_err());
    assert!(engd::backend::validate_backend("sharded:0").is_err());

    for bad in [r#"backend = "process:0""#, r#"backend = "sharded:0""#] {
        let v = engd::config::toml::parse(bad).unwrap();
        assert!(RunConfig::from_value(&v).is_err(), "accepted {bad}");
    }
    let v = engd::config::toml::parse(r#"backend = "process:2""#).unwrap();
    assert_eq!(RunConfig::from_value(&v).unwrap().backend, "process:2");
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

const TESTS: &[(&str, fn())] = &[
    (
        "process_tier_is_bitwise_identical_to_threads_and_native",
        process_tier_is_bitwise_identical_to_threads_and_native,
    ),
    (
        "training_through_worker_processes_matches_native_and_logs_sched",
        training_through_worker_processes_matches_native_and_logs_sched,
    ),
    (
        "injected_worker_crash_is_respawned_requeued_and_bitwise_invisible",
        injected_worker_crash_is_respawned_requeued_and_bitwise_invisible,
    ),
    (
        "externally_killed_worker_recovers_between_evaluations",
        externally_killed_worker_recovers_between_evaluations,
    ),
    (
        "selector_and_config_reject_zero_workers",
        selector_and_config_reject_zero_workers,
    ),
];

fn main() {
    // Worker mode first: the supervisor spawns this binary for its shard
    // workers, and nothing may touch stdout before the frame protocol.
    if std::env::args().any(|a| a == "--shard-worker") {
        std::process::exit(match engd::backend::process::worker_main() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("shard worker error: {e:#}");
                1
            }
        });
    }

    // Minimal sequential runner: first non-flag argument is a substring
    // filter, libtest-style.
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let mut ran = 0usize;
    let mut failed = 0usize;
    for (name, test) in TESTS {
        if let Some(f) = &filter {
            if !name.contains(f.as_str()) {
                continue;
            }
        }
        ran += 1;
        match std::panic::catch_unwind(test) {
            Ok(()) => println!("test {name} ... ok"),
            Err(_) => {
                failed += 1;
                println!("test {name} ... FAILED");
            }
        }
    }
    let verdict = if failed == 0 { "ok" } else { "FAILED" };
    println!("\ntest result: {verdict}. {} passed; {failed} failed", ran - failed);
    if failed > 0 {
        std::process::exit(1);
    }
}
