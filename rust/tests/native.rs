//! Artifact-free end-to-end + property suite for the native backend.
//!
//! This is the coverage the PJRT-only stack could never run offline: real
//! training loops (every optimizer, every solve mode), convergence to the
//! paper's accuracy regime on the small Poisson problems, checkpoint
//! resume reproducing trajectories bit-for-bit, and the native AD engine
//! cross-checked against the independent `mlp_forward` oracle and central
//! finite differences on random tiny networks.

use engd::backend::{Evaluator, NativeBackend, NumericsMode};
use engd::config::run::{ExecPath, OptimizerKind, SolveMode};
use engd::config::RunConfig;
use engd::coordinator::train;
use engd::linalg::Workspace;
use engd::pde::{init_params, mlp_forward, param_count, PdeOperator, ProblemSpec, Sampler};
use engd::proptest::run_prop;
use engd::rng::Rng;

fn out_dir(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("engd-native-{}-{tag}", std::process::id()))
        .display()
        .to_string()
}

/// A throwaway problem spec for property tests on tiny networks.
fn tiny_problem(
    dim: usize,
    hidden: usize,
    n_int: usize,
    n_bnd: usize,
    pde: &str,
    operator: PdeOperator,
) -> ProblemSpec {
    let arch = vec![dim, hidden, hidden.max(2), 1];
    ProblemSpec {
        name: format!("tiny-{pde}-{dim}d"),
        dim,
        n_params: param_count(&arch),
        arch,
        n_interior: n_int,
        n_boundary: n_bnd,
        n_eval: 8,
        interior_weight: 1.0,
        boundary_weight: 1.0,
        pde: pde.to_string(),
        operator,
    }
}

// ---------------------------------------------------------------------------
// Property tests: the native AD vs independent oracles
// ---------------------------------------------------------------------------

/// `u_pred` must agree with the independent `mlp_forward` oracle for
/// random architectures, parameters, and points.
#[test]
fn prop_native_u_pred_matches_forward_oracle() {
    run_prop("native u_pred == mlp_forward", 24, |g| {
        let dim = g.usize_in(1, 4);
        let hidden = g.usize_in(2, 7);
        let p = tiny_problem(dim, hidden, 3, 2, "sine_product", PdeOperator::Poisson);
        let be = NativeBackend::with_problems(vec![p.clone()]);
        let mut rng = Rng::seed_from(g.usize_in(0, 1 << 30) as u64);
        let theta = init_params(&p.arch, &mut rng);
        let m = g.usize_in(1, 9);
        let mut xs = vec![0.0; m * dim];
        rng.fill_uniform(&mut xs, 0.0, 1.0);
        let u = be.u_pred(&p, &theta, &xs).map_err(|e| e.to_string())?;
        for (i, x) in xs.chunks_exact(dim).enumerate() {
            let want = mlp_forward(&theta, &p.arch, x);
            if (u[i] - want).abs() > 1e-12 * (1.0 + want.abs()) {
                return Err(format!("point {i}: {} vs oracle {want}", u[i]));
            }
        }
        Ok(())
    });
}

/// Central finite differences of the residual vector must reproduce the
/// Jacobian columns, and FD of the loss must reproduce `Jᵀr`, to 1e-6
/// relative — on random tiny networks over every operator family.
#[test]
fn prop_native_jacobian_matches_finite_differences() {
    run_prop("native (r, J) vs central differences", 12, |g| {
        // Alternate Poisson (sine_product) and heat (heat_product) cases.
        let heat = g.bool();
        let (dim, pde, operator) = if heat {
            (3, "heat_product", PdeOperator::Heat)
        } else {
            (g.usize_in(1, 3), "sine_product", PdeOperator::Poisson)
        };
        let hidden = g.usize_in(3, 6);
        let p = tiny_problem(dim, hidden, 4, 3, pde, operator);
        let be = NativeBackend::with_problems(vec![p.clone()]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let mut rng = Rng::seed_from(seed);
        let theta = init_params(&p.arch, &mut rng);
        let mut sampler = Sampler::new(dim, seed ^ 0xBEEF);
        let xi = sampler.interior(p.n_interior);
        let xb = sampler.boundary(p.n_boundary);
        let mut ws = Workspace::new();

        let (r0, j) = be
            .residuals_jacobian(&p, &theta, &xi, &xb, &mut ws)
            .map_err(|e| e.to_string())?;
        let n = p.n_total();
        let np = p.n_params;
        if j.rows() != n || j.cols() != np {
            return Err(format!("J is {}x{}, want {n}x{np}", j.rows(), j.cols()));
        }

        let eps = 1e-6;
        // Tolerance tiers: truncation O(eps²) + roundoff O(ulp/eps) leave
        // ~1e-9 absolute noise; the acceptance bar is 1e-6 relative.
        let tol = |scale: f64| 1e-6 * (1.0 + scale.abs());

        // Every column for the smallest nets, a seeded sample otherwise.
        let cols: Vec<usize> = if np <= 40 {
            (0..np).collect()
        } else {
            (0..24).map(|_| g.usize_in(0, np - 1)).collect()
        };
        for &jj in &cols {
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            tp[jj] += eps;
            tm[jj] -= eps;
            let (rp, jp) = be
                .residuals_jacobian(&p, &tp, &xi, &xb, &mut ws)
                .map_err(|e| e.to_string())?;
            let (rm, jm) = be
                .residuals_jacobian(&p, &tm, &xi, &xb, &mut ws)
                .map_err(|e| e.to_string())?;
            for i in 0..n {
                let fd = (rp[i] - rm[i]) / (2.0 * eps);
                let an = j[(i, jj)];
                if (fd - an).abs() > tol(fd) {
                    return Err(format!(
                        "J[{i},{jj}] ({pde}): analytic {an:.9e} vs fd {fd:.9e}"
                    ));
                }
            }
            ws.recycle_matrix(jp);
            ws.recycle_matrix(jm);

            // Gradient check: FD of the loss vs (Jᵀr)[jj].
            let lp = be.loss(&p, &tp, &xi, &xb).map_err(|e| e.to_string())?;
            let lm = be.loss(&p, &tm, &xi, &xb).map_err(|e| e.to_string())?;
            let fd_grad = (lp - lm) / (2.0 * eps);
            let an_grad: f64 = (0..n).map(|i| j[(i, jj)] * r0[i]).sum();
            if (fd_grad - an_grad).abs() > tol(fd_grad) {
                return Err(format!(
                    "grad[{jj}] ({pde}): Jᵀr {an_grad:.9e} vs fd {fd_grad:.9e}"
                ));
            }
        }
        Ok(())
    });
}

/// `loss_and_grad` must agree with `loss` and with `Jᵀr` from the
/// Jacobian path (two independent reverse-pass seedings).
#[test]
fn prop_native_loss_and_grad_consistent() {
    run_prop("native loss_and_grad == (½‖r‖², Jᵀr)", 16, |g| {
        let dim = g.usize_in(1, 3);
        let p = tiny_problem(dim, g.usize_in(2, 6), 5, 2, "sine_product", PdeOperator::Poisson);
        let be = NativeBackend::with_problems(vec![p.clone()]);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let mut rng = Rng::seed_from(seed);
        let theta = init_params(&p.arch, &mut rng);
        let mut sampler = Sampler::new(dim, seed ^ 0xF00D);
        let xi = sampler.interior(p.n_interior);
        let xb = sampler.boundary(p.n_boundary);
        let mut ws = Workspace::new();
        let (r, j) = be
            .residuals_jacobian(&p, &theta, &xi, &xb, &mut ws)
            .map_err(|e| e.to_string())?;
        let (loss, grad) = be
            .loss_and_grad(&p, &theta, &xi, &xb)
            .map_err(|e| e.to_string())?;
        let want_loss = 0.5 * r.iter().map(|x| x * x).sum::<f64>();
        if (loss - want_loss).abs() > 1e-12 * (1.0 + want_loss) {
            return Err(format!("loss {loss} vs ½‖r‖² {want_loss}"));
        }
        let want_grad = j.tr_matvec(&r);
        let scale = want_grad.iter().fold(1.0f64, |m, x| m.max(x.abs()));
        for (a, b) in grad.iter().zip(&want_grad) {
            if (a - b).abs() > 1e-10 * scale {
                return Err(format!("grad: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// End-to-end convergence (the previously artifact-gated coverage)
// ---------------------------------------------------------------------------

fn convergence_cfg(problem: &str, opt: OptimizerKind, steps: usize, tag: &str) -> RunConfig {
    let mut cfg = RunConfig {
        name: format!("conv-{tag}"),
        problem: problem.into(),
        backend: "native".into(),
        steps,
        eval_every: 10,
        out_dir: out_dir("conv"),
        ..RunConfig::default()
    };
    cfg.optimizer.kind = opt;
    cfg.optimizer.path = ExecPath::Decomposed;
    cfg.optimizer.line_search = true;
    // Modest grid keeps debug-mode line searches cheap; the safeguarded
    // search still never increases the batch loss.
    cfg.optimizer.ls_grid = 10;
    cfg
}

#[test]
fn engd_w_converges_on_poisson_1d_and_2d() {
    let be = NativeBackend::new();
    for (problem, steps) in [("poisson1d", 80), ("poisson2d", 120)] {
        let tag = format!("engdw-{problem}");
        let mut cfg = convergence_cfg(problem, OptimizerKind::EngdW, steps, &tag);
        cfg.optimizer.damping = 1e-8;
        let report = train(cfg, &be, false).unwrap();
        assert_eq!(report.backend, "native");
        assert!(report.final_loss.is_finite(), "{problem}: loss diverged");
        assert!(
            report.best_l2 <= 1e-2,
            "{problem}: ENGD-W reached only L2 = {:.3e} in {} steps",
            report.best_l2,
            report.steps_done
        );
    }
}

#[test]
fn spring_converges_on_poisson_1d_and_2d() {
    let be = NativeBackend::new();
    for (problem, steps) in [("poisson1d", 80), ("poisson2d", 120)] {
        let tag = format!("spring-{problem}");
        let mut cfg = convergence_cfg(problem, OptimizerKind::Spring, steps, &tag);
        // Validated settings: λ = 1e-8, μ = 0.8 reaches L2 ≈ 3e-5 on both
        // problems (λ = 1e-6 stalls SPRING on 2d under the line search).
        cfg.optimizer.damping = 1e-8;
        cfg.optimizer.momentum = 0.8;
        let report = train(cfg, &be, false).unwrap();
        assert!(report.final_loss.is_finite(), "{problem}: loss diverged");
        assert!(
            report.best_l2 <= 1e-2,
            "{problem}: SPRING reached only L2 = {:.3e} in {} steps",
            report.best_l2,
            report.steps_done
        );
    }
}

/// All four kernel-solve modes must train natively with finite, decreasing
/// loss — the randomized pipeline of paper eq. 9 end-to-end, no artifacts.
#[test]
fn every_solve_mode_trains_natively() {
    let be = NativeBackend::new();
    for solve in [
        SolveMode::Exact,
        SolveMode::NystromGpu,
        SolveMode::NystromStable,
        SolveMode::NystromPcg,
    ] {
        let mut cfg = convergence_cfg(
            "poisson1d",
            OptimizerKind::EngdW,
            25,
            &format!("solve-{}", solve.name()),
        );
        cfg.optimizer.solve = solve;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.sketch_ratio = 0.6;
        cfg.optimizer.cg_iters = 50;
        let report = train(cfg, &be, false).unwrap();
        assert_eq!(report.steps_done, 25, "{}", solve.name());
        assert!(
            report.losses.iter().all(|l| l.is_finite()),
            "{}: non-finite loss in {:?}",
            solve.name(),
            report.losses
        );
        let first = report.losses.first().copied().unwrap();
        let last = report.losses.last().copied().unwrap();
        assert!(
            last < first * 0.9,
            "{}: loss did not decrease ({first:.3e} -> {last:.3e})",
            solve.name()
        );
    }
}

/// Every optimizer kind completes a short native run with finite loss and
/// L2 — the coverage `integration.rs` can only run when artifacts exist.
#[test]
fn every_optimizer_trains_natively() {
    let be = NativeBackend::new();
    let kinds = [
        OptimizerKind::Sgd,
        OptimizerKind::Adam,
        OptimizerKind::EngdDense,
        OptimizerKind::EngdW,
        OptimizerKind::Spring,
        OptimizerKind::HessianFree,
    ];
    for kind in kinds {
        let tag = kind.name().to_string();
        let first_order = matches!(kind, OptimizerKind::Sgd | OptimizerKind::Adam);
        let mut cfg = convergence_cfg("poisson1d", kind, 3, &format!("all-{tag}"));
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.lr = 1e-3;
        cfg.optimizer.cg_iters = 30;
        if first_order {
            cfg.optimizer.line_search = false;
        }
        let report = train(cfg, &be, false).unwrap_or_else(|e| panic!("{tag} failed: {e:#}"));
        assert_eq!(report.steps_done, 3, "{tag}");
        assert!(report.final_loss.is_finite(), "{tag} diverged");
        assert!(report.best_l2.is_finite(), "{tag} produced non-finite L2");
    }
}

// ---------------------------------------------------------------------------
// Checkpoint save/resume: bit-for-bit trajectory reproduction
// ---------------------------------------------------------------------------

/// Run 7 steps checkpointing at step 4, resume for the last 3, and demand
/// the resumed losses match the uninterrupted run bit-for-bit. This is the
/// `Optimizer::state`/`restore_state` contract: whatever auxiliary state
/// the optimizer carries (SPRING's φ, Adam's (t, m, v), SGD's velocity,
/// Hessian-free's adapted λ + CG warm start) must round-trip through the
/// checkpoint exactly.
fn assert_resume_is_bitwise(tag: &str, tune: impl Fn(&mut RunConfig)) {
    let be = NativeBackend::new();
    let dir = out_dir(&format!("resume-{tag}"));
    let base = {
        let mut cfg = RunConfig {
            name: format!("resume-{tag}"),
            problem: "poisson1d".into(),
            backend: "native".into(),
            // 7 steps with checkpoint_every = 4: exactly ONE checkpoint is
            // written (step 4) — a multiple of 4 at the end would overwrite
            // it and the resume would start from the wrong step.
            steps: 7,
            seed: 91,
            eval_every: 1,
            out_dir: dir.clone(),
            ..RunConfig::default()
        };
        cfg.optimizer.path = ExecPath::Decomposed;
        tune(&mut cfg);
        cfg
    };

    // Uninterrupted 7-step run (checkpointing at step 4 along the way).
    let mut full_cfg = base.clone();
    full_cfg.checkpoint_every = 4;
    let full = train(full_cfg, &be, false).unwrap();
    assert_eq!(full.losses.len(), 7, "{tag}");

    // Resume from the step-4 checkpoint and run the remaining 3 steps.
    let ckpt = std::path::Path::new(&dir).join(format!("resume-{tag}.ckpt"));
    assert!(ckpt.exists(), "{tag}: checkpoint was not written");
    let mut resumed_cfg = base.clone();
    resumed_cfg.name = format!("resume-{tag}-tail");
    resumed_cfg.steps = 3;
    resumed_cfg.resume_from = Some(ckpt.display().to_string());
    let tail = train(resumed_cfg, &be, false).unwrap();
    assert_eq!(tail.steps_done, 7, "{tag}: resume must continue at step 5..=7");
    assert_eq!(tail.losses.len(), 3, "{tag}");

    for (i, (a, b)) in full.losses[4..].iter().zip(&tail.losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag} step {}: uninterrupted loss {a:.17e} != resumed loss {b:.17e}",
            i + 5
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_resume_reproduces_loss_trajectory_bitwise() {
    assert_resume_is_bitwise("spring", |cfg| {
        cfg.optimizer.kind = OptimizerKind::Spring;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.momentum = 0.85;
        cfg.optimizer.line_search = true;
        cfg.optimizer.ls_grid = 8;
    });
}

#[test]
fn checkpoint_resume_is_bitwise_for_sgd() {
    assert_resume_is_bitwise("sgd", |cfg| {
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.optimizer.lr = 1e-3;
        cfg.optimizer.momentum = 0.9;
        cfg.optimizer.line_search = false;
    });
}

#[test]
fn checkpoint_resume_is_bitwise_for_adam() {
    assert_resume_is_bitwise("adam", |cfg| {
        cfg.optimizer.kind = OptimizerKind::Adam;
        cfg.optimizer.lr = 1e-2;
        cfg.optimizer.line_search = false;
    });
}

#[test]
fn checkpoint_resume_is_bitwise_for_hessian_free() {
    assert_resume_is_bitwise("hf", |cfg| {
        cfg.optimizer.kind = OptimizerKind::HessianFree;
        // Adapted damping + the CG warm-start vector both live in the
        // checkpoint; a lost warm start would shift every later CG solve.
        cfg.optimizer.damping = 1.0;
        cfg.optimizer.cg_iters = 15;
        cfg.optimizer.line_search = false;
        cfg.optimizer.lr = 0.5;
    });
}

#[test]
fn checkpoint_resume_is_bitwise_for_engd_w() {
    // Stateless optimizer: resume exactness rests on the step-keyed
    // batch/RNG streams alone.
    assert_resume_is_bitwise("engdw", |cfg| {
        cfg.optimizer.kind = OptimizerKind::EngdW;
        cfg.optimizer.damping = 1e-6;
        cfg.optimizer.line_search = true;
        cfg.optimizer.ls_grid = 8;
    });
}

#[test]
fn checkpoint_resume_is_bitwise_for_engd_dense() {
    // The EMA Gramian accumulator is part of the trajectory: without the
    // `[P, G]` state vector a resumed dense-ENGD run silently restarts the
    // EMA recursion from scratch and drifts off the uninterrupted losses.
    assert_resume_is_bitwise("engd-dense", |cfg| {
        cfg.optimizer.kind = OptimizerKind::EngdDense;
        cfg.optimizer.damping = 1e-4;
        cfg.optimizer.ema = 0.9;
        cfg.optimizer.gramian_identity_init = true;
        cfg.optimizer.line_search = false;
        cfg.optimizer.lr = 0.2;
    });
}

/// A checkpoint records its numerics mode, and resume refuses a silent
/// bitwise↔fast switch: a fast-tier trajectory is not bitwise-continuable
/// under bitwise kernels (and vice versa). Both sides pin the mode
/// explicitly so the test means the same thing under `ENGD_NUMERICS=fast`
/// CI jobs.
#[test]
fn resume_refuses_numerics_mode_switch() {
    let dir = out_dir("resume-numerics");
    let make_cfg = |numerics: NumericsMode, steps: usize| {
        let mut cfg = RunConfig {
            name: "resume-numerics".into(),
            problem: "poisson1d".into(),
            backend: "native".into(),
            steps,
            seed: 17,
            eval_every: 1,
            out_dir: dir.clone(),
            numerics,
            ..RunConfig::default()
        };
        cfg.optimizer.kind = OptimizerKind::Sgd;
        cfg.optimizer.lr = 1e-3;
        cfg.optimizer.line_search = false;
        cfg
    };

    let be = NativeBackend::with_numerics(NumericsMode::Fast);
    let mut head = make_cfg(NumericsMode::Fast, 2);
    head.checkpoint_every = 2;
    train(head, &be, false).unwrap();
    let ckpt = std::path::Path::new(&dir).join("resume-numerics.ckpt");
    assert!(ckpt.exists(), "checkpoint was not written");

    // Same mode: resumes fine.
    let be_bitwise = NativeBackend::with_numerics(NumericsMode::Bitwise);
    let mut ok = make_cfg(NumericsMode::Fast, 1);
    ok.name = "resume-numerics-tail".into();
    ok.resume_from = Some(ckpt.display().to_string());
    train(ok.clone(), &be, false).unwrap();

    // Mode switch: refused with an actionable message.
    let mut bad = ok;
    bad.numerics = NumericsMode::Bitwise;
    let err = train(bad, &be_bitwise, false).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("--numerics"),
        "expected a numerics-mismatch error, got: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// End-to-end `--numerics fast`: an ENGD-W run whose Gram/sketch panels
/// take the f32-compute/f64-accumulate tier (through the kernel operator's
/// numerics mode) must track the bitwise trajectory within tolerance over a
/// few fixed-lr steps — the fast tier trades bits, not correctness.
#[test]
fn fast_sketch_tier_tracks_bitwise_training_within_tolerance() {
    let dir = out_dir("fastnum");
    let mk = |numerics: NumericsMode, name: &str, solve: SolveMode| {
        let mut cfg = RunConfig {
            name: name.into(),
            problem: "poisson1d".into(),
            backend: "native".into(),
            steps: 3,
            seed: 11,
            eval_every: 10,
            out_dir: dir.clone(),
            numerics,
            ..RunConfig::default()
        };
        cfg.optimizer.kind = OptimizerKind::EngdW;
        cfg.optimizer.path = ExecPath::Decomposed;
        cfg.optimizer.solve = solve;
        cfg.optimizer.damping = 1e-3;
        cfg.optimizer.line_search = false;
        cfg.optimizer.lr = 1e-3;
        cfg
    };
    let be_bit = NativeBackend::with_numerics(NumericsMode::Bitwise);
    let be_fast = NativeBackend::with_numerics(NumericsMode::Fast);
    // Exact exercises the fast Gram panel; NystromGpu the fast sketch.
    for solve in [SolveMode::Exact, SolveMode::NystromGpu] {
        let bit = train(
            mk(NumericsMode::Bitwise, &format!("fn-bit-{}", solve.name()), solve),
            &be_bit,
            false,
        )
        .unwrap();
        let fast = train(
            mk(NumericsMode::Fast, &format!("fn-fast-{}", solve.name()), solve),
            &be_fast,
            false,
        )
        .unwrap();
        assert_eq!(bit.losses.len(), fast.losses.len());
        for (k, (a, b)) in bit.losses.iter().zip(&fast.losses).enumerate() {
            assert!(
                a.is_finite() && b.is_finite() && (a - b).abs() <= 5e-2 * (1.0 + a.abs()),
                "{} step {}: bitwise loss {a:.6e} vs fast {b:.6e}",
                solve.name(),
                k + 1
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Appendix A.1 regression: with `ema > 0` and the *zero* Gramian init,
/// step 1 must use `G₁ = (1−ema)·G_batch` — before the fix it used the raw
/// batch Gramian, making zero-init indistinguishable from `ema = 0` (and
/// from nothing) on the first step.
#[test]
fn engd_dense_first_step_respects_the_ema_init() {
    use engd::config::OptimizerConfig;
    use engd::optim::{EngdDense, Optimizer, StepEnv};

    let p = tiny_problem(2, 4, 6, 3, "sine_product", PdeOperator::Poisson);
    let be = NativeBackend::with_problems(vec![p.clone()]);
    let mut rng0 = Rng::seed_from(21);
    let theta0 = init_params(&p.arch, &mut rng0);
    let mut sampler = Sampler::new(p.dim, 77);
    let xi = sampler.interior(p.n_interior);
    let xb = sampler.boundary(p.n_boundary);

    // Two fixed-lr steps on identical inputs; returns θ after each step.
    let run_two_steps = |ema: f64, identity: bool| -> (Vec<f64>, Vec<f64>) {
        let o = OptimizerConfig {
            kind: OptimizerKind::EngdDense,
            ema,
            gramian_identity_init: identity,
            damping: 1e-3,
            line_search: false,
            lr: 0.1,
            ..OptimizerConfig::default()
        };
        let mut opt = EngdDense::new(&o);
        let mut theta = theta0.clone();
        let mut after_first = Vec::new();
        let mut ws = Workspace::new();
        for k in 1..=2usize {
            let mut rng = Rng::seed_from(5);
            let mut env = StepEnv {
                eval: &be,
                problem: &p,
                x_int: &xi,
                x_bnd: &xb,
                k,
                rng: &mut rng,
                ws: &mut ws,
                diagnostics: false,
                numerics: NumericsMode::Bitwise,
            };
            opt.step(&mut theta, &mut env).unwrap();
            if k == 1 {
                after_first = theta.clone();
            }
        }
        (after_first, theta)
    };

    let (zero1, zero2) = run_two_steps(0.5, false);
    let (id1, id2) = run_two_steps(0.5, true);
    let (raw1, _) = run_two_steps(0.0, false);

    let differs = |a: &[f64], b: &[f64]| a.iter().zip(b).any(|(x, y)| x != y);
    assert!(
        differs(&zero1, &raw1),
        "zero-init EMA step 1 equals the raw-Gramian (ema = 0) step — the \
         (1−ema) scaling was skipped"
    );
    assert!(
        differs(&zero1, &id1),
        "identity and zero Gramian inits agree on step 1 — A.1's choice is a no-op"
    );
    assert!(zero2.iter().all(|v| v.is_finite()), "zero-init EMA diverged");
    assert!(id2.iter().all(|v| v.is_finite()), "identity-init EMA diverged");
    assert!(differs(&zero2, &id2), "the init choice washed out after one step");
}

/// A resumed run continues the checkpoint's wall clock: the checkpoint
/// records cumulative seconds, the resumed run's `wall_s` column starts
/// at/above them (monotone continuation, not a restart at zero), and
/// `time_budget_s` counts pre-resume time — a budget below the seconds
/// already spent runs zero further steps.
#[test]
fn resumed_run_continues_wall_clock_and_honors_time_budget() {
    let be = NativeBackend::new();
    let dir = out_dir("resume-clock");
    let mut cfg = RunConfig {
        name: "clock".into(),
        problem: "poisson1d".into(),
        backend: "native".into(),
        steps: 2,
        seed: 7,
        eval_every: 1,
        out_dir: dir.clone(),
        checkpoint_every: 2,
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Sgd;
    cfg.optimizer.path = ExecPath::Decomposed;
    cfg.optimizer.lr = 1e-3;
    cfg.optimizer.line_search = false;
    train(cfg.clone(), &be, false).unwrap();

    let ckpt_path = std::path::Path::new(&dir).join("clock.ckpt");
    let mut ck = engd::coordinator::Checkpoint::load(&ckpt_path).unwrap();
    assert!(
        ck.wall_s > 0.0,
        "checkpoint must record cumulative wall seconds, got {}",
        ck.wall_s
    );
    // Pin the pre-resume time to a large, unambiguous value.
    ck.wall_s = 1000.0;
    ck.save(&ckpt_path).unwrap();

    // Budget below the seconds already spent: zero further steps.
    let mut spent = cfg.clone();
    spent.name = "clock-spent".into();
    spent.steps = 3;
    spent.checkpoint_every = 0;
    spent.resume_from = Some(ckpt_path.display().to_string());
    spent.time_budget_s = 500.0;
    let r = train(spent, &be, false).unwrap();
    assert_eq!(
        r.steps_done, 0,
        "time budget ignored the checkpoint's {}s of pre-resume time",
        1000
    );

    // Unlimited budget: wall_s continues monotonically from 1000s.
    let mut cont = cfg.clone();
    cont.name = "clock-cont".into();
    cont.steps = 2;
    cont.checkpoint_every = 0;
    cont.resume_from = Some(ckpt_path.display().to_string());
    let r = train(cont, &be, false).unwrap();
    assert_eq!(r.steps_done, 4, "resume must run steps 3..=4");
    assert!(r.wall_s >= 1000.0, "report clock restarted at {}", r.wall_s);
    let csv =
        std::fs::read_to_string(std::path::Path::new(&dir).join("clock-cont.csv")).unwrap();
    let mut prev = 1000.0;
    let mut rows = 0;
    for line in csv.lines().skip(1) {
        let wall: f64 = line.split(',').nth(1).unwrap().parse().unwrap();
        assert!(
            wall >= prev,
            "wall_s column not monotone across the resume boundary:\n{csv}"
        );
        prev = wall;
        rows += 1;
    }
    assert_eq!(rows, 2);
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming with a different optimizer than the one that wrote the
/// checkpoint must be refused: the flat state vector's layout is
/// optimizer-specific (SPRING's φ read as Adam's [t, m, v] would silently
/// corrupt the run).
#[test]
fn checkpoint_resume_rejects_optimizer_mismatch() {
    let be = NativeBackend::new();
    let dir = out_dir("resume-mismatch");
    let mut cfg = RunConfig {
        name: "mismatch".into(),
        problem: "poisson1d".into(),
        backend: "native".into(),
        steps: 4,
        seed: 3,
        eval_every: 10,
        out_dir: dir.clone(),
        checkpoint_every: 4,
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.path = ExecPath::Decomposed;
    cfg.optimizer.damping = 1e-6;
    cfg.optimizer.line_search = false;
    cfg.optimizer.lr = 1e-3;
    train(cfg.clone(), &be, false).unwrap();

    let ckpt = std::path::Path::new(&dir).join("mismatch.ckpt");
    assert!(ckpt.exists());
    cfg.optimizer.kind = OptimizerKind::Adam;
    cfg.resume_from = Some(ckpt.display().to_string());
    cfg.checkpoint_every = 0;
    let err = engd::coordinator::Trainer::new(cfg, &be)
        .err()
        .expect("adam resume from a spring checkpoint must be refused");
    let msg = format!("{err:#}");
    assert!(msg.contains("spring") && msg.contains("adam"), "unhelpful error: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The trainer's step-buffer pool reaches steady state natively too: J,
/// Gram, sketch — and, with the line search enabled, the per-probe trial
/// iterate — are recycled, so a second step allocates no fresh
/// pool-tracked buffer. Covers ENGD-W, SPRING (whose ζ/Jᵀa/step-direction
/// pipeline draws from the pool while the φ momentum state stays owned),
/// and Hessian-free (pooled CG loop vectors + the Gauss–Newton matvec
/// scratch).
#[test]
fn native_trainer_reuses_workspace_across_steps() {
    let be = NativeBackend::new();
    for (kind, solve, line_search) in [
        (OptimizerKind::EngdW, SolveMode::Exact, false),
        (OptimizerKind::EngdW, SolveMode::NystromGpu, false),
        // Line-search probes draw their θ-sized trial vector from the
        // pool: a warmed-up searching step must allocate nothing either.
        (OptimizerKind::EngdW, SolveMode::Exact, true),
        (OptimizerKind::Spring, SolveMode::Exact, false),
        (OptimizerKind::Spring, SolveMode::NystromGpu, true),
        (OptimizerKind::HessianFree, SolveMode::Exact, false),
    ] {
        let mut cfg = RunConfig {
            name: format!("ws-{:?}-{}-ls{}", kind, solve.name(), line_search as u8),
            problem: "poisson1d".into(),
            backend: "native".into(),
            steps: 1,
            eval_every: 100,
            out_dir: out_dir("ws"),
            ..RunConfig::default()
        };
        cfg.optimizer.kind = kind;
        cfg.optimizer.path = ExecPath::Decomposed;
        cfg.optimizer.solve = solve;
        cfg.optimizer.line_search = line_search;
        cfg.optimizer.ls_grid = 6;
        cfg.optimizer.lr = 1e-3;
        cfg.optimizer.damping = 1e-6;

        let mut one = engd::coordinator::Trainer::new(cfg.clone(), &be).unwrap();
        one.run(false).unwrap();
        let after_one = one.workspace_stats();

        cfg.steps = 2;
        let mut two = engd::coordinator::Trainer::new(cfg, &be).unwrap();
        two.run(false).unwrap();
        let after_two = two.workspace_stats();

        assert_eq!(
            (after_two.fresh_allocs, after_two.grown),
            (after_one.fresh_allocs, after_one.grown),
            "{}: step 2 allocated instead of reusing the pool \
             (after one {after_one:?}, after two {after_two:?})",
            solve.name()
        );
        assert!(
            after_two.reuses > after_one.reuses,
            "{}: step 2 did not draw from the pool ({after_two:?})",
            solve.name()
        );
    }
}
