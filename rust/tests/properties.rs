//! Property-based tests on the coordinator/optimizer invariants, using the
//! in-tree `engd::proptest` mini-framework (the `proptest` crate is
//! unavailable offline; see DESIGN.md).
//!
//! These properties are artifact-free: they exercise the Rust linear-algebra
//! and randomization substrates over randomized shapes/seeds/dampings.

use engd::config::run::SolveMode;
use engd::config::OptimizerConfig;
use engd::linalg::{cg_solve, dot, eigh, thin_qr, Cholesky, Matrix, Workspace};
use engd::nystrom::{
    effective_dimension, effective_dimension_spectral, GpuNystrom, NystromApprox,
    StableNystrom,
};
use engd::optim::{kernel_solve, DenseKernel, JacobianKernel};
use engd::proptest::{assert_close, run_prop, Gen};
use engd::rng::Rng;

fn random_jacobian(g: &mut Gen, n: usize, p: usize) -> Matrix {
    let data = g.vec_normal(n * p);
    Matrix::from_vec(n, p, data)
}

/// Paper eq. 5 — Woodbury/push-through exactness on random Jacobians:
/// (JᵀJ+λI)⁻¹Jᵀr == Jᵀ(JJᵀ+λI)⁻¹r for every shape and damping.
#[test]
fn prop_woodbury_identity() {
    run_prop("woodbury identity", 40, |g| {
        let n = g.usize_in(1, 40);
        let p = g.usize_in(1, 60);
        let lam = g.log_uniform(1e-6, 1e2);
        let j = random_jacobian(g, n, p);
        let r = g.vec_normal(n);

        // Kernel form (ENGD-W).
        let k = j.gram().add_diag(lam);
        let a = Cholesky::factor(&k).map_err(|e| e.to_string())?.solve(&r);
        let phi_w = j.tr_matvec(&a);

        // Dense form (original ENGD).
        let gmat = j.transpose().gram().add_diag(lam);
        let grad = j.tr_matvec(&r);
        let phi_dense = Cholesky::factor(&gmat)
            .map_err(|e| e.to_string())?
            .solve(&grad);

        let scale = phi_dense.iter().map(|x| x.abs()).fold(0.0, f64::max);
        assert_close(&phi_w, &phi_dense, 1e-7 * (1.0 + scale))
    });
}

/// SPRING closed form (eq. 8) minimizes the variational problem (eq. 7):
/// first-order optimality Jᵀ(Jφ−r) + λ(φ−μφ₋) = 0.
#[test]
fn prop_spring_first_order_optimality() {
    run_prop("spring optimality", 30, |g| {
        let n = g.usize_in(1, 30);
        let p = g.usize_in(1, 40);
        let lam = g.log_uniform(1e-5, 1e1);
        let mu = g.f64_in(0.0, 0.999);
        let j = random_jacobian(g, n, p);
        let r = g.vec_normal(n);
        let phi_prev = g.vec_normal(p);

        // φ = μφ₋ + Jᵀ(JJᵀ+λI)⁻¹(r − μJφ₋)
        let j_phi_prev = j.matvec(&phi_prev);
        let zeta: Vec<f64> = r
            .iter()
            .zip(&j_phi_prev)
            .map(|(ri, ji)| ri - mu * ji)
            .collect();
        let k = j.gram().add_diag(lam);
        let a = Cholesky::factor(&k).map_err(|e| e.to_string())?.solve(&zeta);
        let jta = j.tr_matvec(&a);
        let phi: Vec<f64> = phi_prev
            .iter()
            .zip(&jta)
            .map(|(pp, q)| mu * pp + q)
            .collect();

        // Gradient of ‖Jφ−r‖² + λ‖φ−μφ₋‖² at φ (×½).
        let jphi = j.matvec(&phi);
        let resid: Vec<f64> = jphi.iter().zip(&r).map(|(a, b)| a - b).collect();
        let mut grad = j.tr_matvec(&resid);
        for i in 0..p {
            grad[i] += lam * (phi[i] - mu * phi_prev[i]);
        }
        let scale = phi.iter().map(|x| x.abs()).fold(1.0, f64::max);
        assert_close(&grad, &vec![0.0; p], 1e-7 * scale * (1.0 + lam))
    });
}

/// Nyström approximations never exceed the matrix they approximate
/// (0 ⪯ Â ⪯ A+ν) and their inverse application is SPD-consistent
/// (vᵀ(Â+λI)⁻¹v > 0).
#[test]
fn prop_nystrom_psd_sandwich() {
    run_prop("nystrom psd sandwich", 20, |g| {
        let n = g.usize_in(4, 28);
        let rank = g.usize_in(1, n);
        let sketch = g.usize_in(1, n);
        let lam = g.log_uniform(1e-6, 1.0);
        let low = random_jacobian(g, n, rank);
        let a = low.gram(); // PSD, rank ≤ rank

        let mut rng = Rng::seed_from(g.usize_in(0, 1 << 30) as u64);
        let mut ws = Workspace::new();
        let nys = GpuNystrom::build(&DenseKernel::new(&a), sketch, lam, &mut rng, &mut ws)
            .map_err(|e| e.to_string())?;
        let approx = nys.dense_approx();

        // PSD-ness of Â.
        let e = eigh(&approx);
        if e.eigenvalues.iter().any(|&w| w < -1e-7) {
            return Err(format!("Â has negative eigenvalue {:?}", e.eigenvalues[0]));
        }
        // Â ⪯ A (+ slack for the ν shift).
        let mut resid = a.clone();
        resid.add_scaled(&approx, -1.0);
        let er = eigh(&resid);
        if er.eigenvalues.iter().any(|&w| w < -1e-5 * (1.0 + a.frobenius_norm())) {
            return Err(format!(
                "Â ⪯̸ A: min residual eigenvalue {}",
                er.eigenvalues[0]
            ));
        }
        // Inverse application is positive definite.
        let v = g.vec_normal(n);
        let quad = dot(&v, &nys.inv_apply(&v));
        (quad > 0.0)
            .then_some(())
            .ok_or_else(|| format!("vᵀ(Â+λI)⁻¹v = {quad} ≤ 0"))
    });
}

/// Effective dimension: both computation paths agree and d_eff ∈ [0, n],
/// decreasing in λ (paper §3.4).
#[test]
fn prop_effective_dimension() {
    run_prop("effective dimension", 25, |g| {
        let n = g.usize_in(2, 30);
        let rank = g.usize_in(1, n);
        let j = random_jacobian(g, n, rank);
        let k = j.gram();
        let lam1 = g.log_uniform(1e-8, 1e-2);
        let lam2 = lam1 * g.f64_in(2.0, 100.0);

        let d1 = effective_dimension(&k, lam1).map_err(|e| e.to_string())?;
        let d2 = effective_dimension(&k, lam2).map_err(|e| e.to_string())?;
        let d1s = effective_dimension_spectral(&k, lam1);

        if !(0.0..=n as f64 + 1e-9).contains(&d1) {
            return Err(format!("d_eff {d1} outside [0, {n}]"));
        }
        if d2 > d1 + 1e-6 * (1.0 + d1) {
            return Err(format!("d_eff not decreasing: {d1} -> {d2}"));
        }
        if (d1 - d1s).abs() > 1e-5 * (1.0 + d1) {
            return Err(format!("paths disagree: {d1} vs {d1s}"));
        }
        Ok(())
    });
}

/// CG on an SPD operator converges to the Cholesky solution.
#[test]
fn prop_cg_matches_direct_solve() {
    run_prop("cg vs cholesky", 25, |g| {
        let n = g.usize_in(1, 40);
        let j = random_jacobian(g, n, n + 5);
        let a = j.gram().add_diag(g.log_uniform(1e-2, 1e1));
        let b = g.vec_normal(n);
        let direct = Cholesky::factor(&a).map_err(|e| e.to_string())?.solve(&b);
        let out = cg_solve(|v| a.matvec(v), &b, 4 * n + 20, 1e-12);
        let scale = direct.iter().map(|x| x.abs()).fold(1.0, f64::max);
        assert_close(&out.x, &direct, 1e-6 * scale)
    });
}

/// QR: Q has orthonormal columns and preserves the column space, for all
/// tall shapes.
#[test]
fn prop_qr_orthonormal() {
    run_prop("qr orthonormal", 25, |g| {
        let n = g.usize_in(1, 50);
        let m = n + g.usize_in(0, 30);
        let a = random_jacobian(g, m, n);
        let q = thin_qr(&a);
        let qtq = q.transpose().matmul(&q);
        let diff = qtq.max_abs_diff(&Matrix::identity(n));
        if diff > 1e-9 {
            return Err(format!("QᵀQ − I = {diff}"));
        }
        let proj = q.matmul(&q.transpose().matmul(&a));
        let err = proj.max_abs_diff(&a);
        (err < 1e-8 * (1.0 + a.frobenius_norm()))
            .then_some(())
            .ok_or_else(|| format!("projection error {err}"))
    });
}

/// Stable and GPU-efficient Nyström agree when the sketch covers the rank.
#[test]
fn prop_nystrom_variants_agree_at_full_rank() {
    run_prop("nystrom variants agree", 15, |g| {
        let n = g.usize_in(4, 24);
        let rank = g.usize_in(1, n / 2 + 1);
        let low = random_jacobian(g, n, rank);
        let a = low.gram();
        let lam = g.log_uniform(1e-4, 1e-1);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let sketch = (rank + 3).min(n);

        let op = DenseKernel::new(&a);
        let mut ws = Workspace::new();
        let mut r1 = Rng::seed_from(seed);
        let gpu =
            GpuNystrom::build(&op, sketch, lam, &mut r1, &mut ws).map_err(|e| e.to_string())?;
        let mut r2 = Rng::seed_from(seed.wrapping_add(1));
        let stable = StableNystrom::build(&op, sketch, lam, &mut r2, &mut ws)
            .map_err(|e| e.to_string())?;

        // With sketch > rank both recover A (whp): compare inverse actions.
        let v = g.vec_normal(n);
        let x1 = gpu.inv_apply(&v);
        let x2 = stable.inv_apply(&v);
        let scale = x1.iter().map(|x| x.abs()).fold(1.0, f64::max);
        assert_close(&x1, &x2, 1e-4 * scale)
    });
}

/// Batch sampling: shapes, ranges, boundary membership — for all dims/sizes.
#[test]
fn prop_sampler_invariants() {
    run_prop("sampler invariants", 30, |g| {
        let d = g.usize_in(1, 16);
        let n = g.usize_in(1, 64);
        let seed = g.usize_in(0, 1 << 30) as u64;
        let mut s = engd::pde::Sampler::new(d, seed);
        let int = s.interior(n);
        if int.len() != n * d {
            return Err("interior shape".into());
        }
        if !int.iter().all(|&x| (0.0..1.0).contains(&x)) {
            return Err("interior out of cube".into());
        }
        let bnd = s.boundary(n);
        for row in bnd.chunks_exact(d) {
            if !row.iter().any(|&x| x == 0.0 || x == 1.0) {
                return Err(format!("boundary row {row:?} not on a face"));
            }
        }
        Ok(())
    });
}

/// The fused transpose products must agree with the materialized
/// `transpose()+matmul` references on every shape — including the extreme
/// aspect ratios of the training path (N ≪ P wide Jacobians and P ≪ N tall
/// sketches), where panel/blocking edge cases live.
#[test]
fn prop_fused_transpose_products_match_materialized() {
    run_prop("fused tn/nt/gram_t vs materialized", 30, |g| {
        // Draw one dimension small and one large to hit both regimes.
        let small = g.usize_in(1, 4);
        let large = g.usize_in(1, 90);
        let (rows, cols) = if g.bool() {
            (small, large)
        } else {
            (large, small)
        };
        let inner = g.usize_in(1, 24);

        // AᵀB with A: rows×cols, B: rows×inner.
        let a = random_jacobian(g, rows, cols);
        let b = random_jacobian(g, rows, inner);
        let tn = a.matmul_tn(&b);
        let tn_ref = a.transpose().matmul(&b);
        let scale = 1.0 + tn_ref.frobenius_norm();
        if tn.max_abs_diff(&tn_ref) > 1e-10 * scale {
            return Err(format!(
                "matmul_tn diverged at ({rows}x{cols})ᵀ({rows}x{inner})"
            ));
        }

        // ABᵀ with A: cols×rows, B: inner×rows.
        let a2 = random_jacobian(g, cols, rows);
        let b2 = random_jacobian(g, inner, rows);
        let nt = a2.matmul_nt(&b2);
        let nt_ref = a2.matmul(&b2.transpose());
        if nt.max_abs_diff(&nt_ref) > 1e-10 * (1.0 + nt_ref.frobenius_norm()) {
            return Err(format!(
                "matmul_nt diverged at ({cols}x{rows})({inner}x{rows})ᵀ"
            ));
        }

        // AᵀA and the `_into` path through a dirty reused buffer.
        let gt = a.gram_t();
        let gt_ref = a.transpose().matmul(&a);
        if gt.max_abs_diff(&gt_ref) > 1e-10 * (1.0 + gt_ref.frobenius_norm()) {
            return Err(format!("gram_t diverged at ({rows}x{cols})"));
        }
        let mut dirty = Matrix::from_fn(rows, rows, |_, _| f64::NAN);
        a.gram_into(&mut dirty);
        let k_ref = a.matmul(&a.transpose());
        if dirty.max_abs_diff(&k_ref) > 1e-10 * (1.0 + k_ref.frobenius_norm()) {
            return Err(format!("gram_into diverged at ({rows}x{cols})"));
        }
        Ok(())
    });
}

/// The unified solve path must serve every `SolveMode` from the workspace
/// pool at steady state: a second identically-shaped solve may not allocate
/// a single fresh buffer. This is the harness-level statement of the
/// trainer invariant (the trainer holds one `Workspace` for the whole run),
/// checked here without needing PJRT artifacts.
///
/// Since the `thin_qr_into`/`eigh_into` refactor this covers the stable
/// mode in full: the QR of the test matrix and the eigendecomposition of
/// BᵀB draw their interiors from the same pool, so `fresh_allocs` freezing
/// proves no dense temporary on the stable path escapes the accounting.
#[test]
fn prop_kernel_solve_reuses_workspace() {
    run_prop("kernel_solve workspace reuse", 8, |g| {
        let n = g.usize_in(8, 24);
        let p = n + g.usize_in(1, 20); // full-row-rank J w.h.p.: no ν retries
        let j = random_jacobian(g, n, p);
        let rhs = g.vec_normal(n);
        let op = JacobianKernel::new(&j);
        let mut rng = Rng::seed_from(g.usize_in(0, 1 << 30) as u64);

        for solve in [
            SolveMode::Exact,
            SolveMode::NystromGpu,
            SolveMode::NystromStable,
            SolveMode::NystromPcg,
        ] {
            let o = OptimizerConfig {
                solve,
                damping: 1e-2,
                sketch_ratio: 0.5,
                ..OptimizerConfig::default()
            };
            let mut ws = Workspace::new();
            let (x1, _) = kernel_solve(&op, &rhs, &o, &mut rng, &mut ws, false)
                .map_err(|e| e.to_string())?;
            if !x1.iter().all(|v| v.is_finite()) {
                return Err(format!("{}: non-finite solution", solve.name()));
            }
            // The solution lives in pooled storage — recycling it is part
            // of the caller contract the optimizers follow.
            ws.recycle(x1);
            let after_first = ws.stats();
            let (x2, _) = kernel_solve(&op, &rhs, &o, &mut rng, &mut ws, false)
                .map_err(|e| e.to_string())?;
            if !x2.iter().all(|v| v.is_finite()) {
                return Err(format!("{}: non-finite solution", solve.name()));
            }
            ws.recycle(x2);
            let after_second = ws.stats();

            // `grown` must freeze too: a pool that keeps reallocating an
            // undersized buffer every step is a hidden per-step allocation
            // even though fresh_allocs stays flat.
            if after_second.fresh_allocs != after_first.fresh_allocs
                || after_second.grown != after_first.grown
            {
                return Err(format!(
                    "{}: second solve allocated or regrew buffers \
                     (first {after_first:?}, second {after_second:?})",
                    solve.name()
                ));
            }
            if after_second.reuses <= after_first.reuses {
                return Err(format!(
                    "{}: second solve did not draw from the pool ({after_second:?})",
                    solve.name()
                ));
            }
        }
        Ok(())
    });
}

/// The stable-Nyström builder itself (not just the solve wrapper) reaches
/// pool steady state: a rebuild of the same shape — QR, sketch, core
/// factorization, eigendecomposition and all — allocates nothing fresh.
#[test]
fn prop_stable_nystrom_interiors_are_pooled() {
    run_prop("stable nystrom pooled interiors", 10, |g| {
        let n = g.usize_in(8, 28);
        let p = n + g.usize_in(1, 16); // full row rank w.h.p.: no ν retries
        let sketch = g.usize_in(2, n);
        let j = random_jacobian(g, n, p);
        let op = JacobianKernel::new(&j);
        let mut rng = Rng::seed_from(g.usize_in(0, 1 << 30) as u64);
        let mut ws = Workspace::new();

        let first = StableNystrom::build(&op, sketch, 1e-2, &mut rng, &mut ws)
            .map_err(|e| e.to_string())?;
        first.recycle(&mut ws);
        let after_first = ws.stats();

        let second = StableNystrom::build(&op, sketch, 1e-2, &mut rng, &mut ws)
            .map_err(|e| e.to_string())?;
        second.recycle(&mut ws);
        let after_second = ws.stats();

        if after_second.fresh_allocs != after_first.fresh_allocs
            || after_second.grown != after_first.grown
        {
            return Err(format!(
                "stable rebuild allocated (first {after_first:?}, second {after_second:?})"
            ));
        }
        Ok(())
    });
}

/// Routing the exact solve through `KernelOp` + workspace must be
/// numerically identical to the hand-rolled Woodbury solve it replaced.
#[test]
fn prop_kernel_solve_exact_matches_direct_woodbury() {
    run_prop("kernel_solve exact vs direct", 25, |g| {
        let n = g.usize_in(1, 30);
        let p = g.usize_in(1, 45);
        let lam = g.log_uniform(1e-5, 1e1);
        let j = random_jacobian(g, n, p);
        let r = g.vec_normal(n);

        let o = OptimizerConfig {
            solve: SolveMode::Exact,
            damping: lam,
            ..OptimizerConfig::default()
        };
        let mut ws = Workspace::new();
        let mut rng = Rng::seed_from(1);
        let op = JacobianKernel::new(&j);
        let (a_ws, _) = kernel_solve(&op, &r, &o, &mut rng, &mut ws, false)
            .map_err(|e| e.to_string())?;
        let phi_ws = op.apply_t(&a_ws);

        let k = j.gram().add_diag(lam);
        let a_direct = Cholesky::factor(&k).map_err(|e| e.to_string())?.solve(&r);
        let phi_direct = j.tr_matvec(&a_direct);

        let scale = phi_direct.iter().map(|x| x.abs()).fold(1.0, f64::max);
        assert_close(&phi_ws, &phi_direct, 1e-9 * scale)
    });
}

/// Line-search-style invariant at the linalg level: the exact ENGD-W step
/// with a small enough η decreases the *quadratic model* (Gauss–Newton
/// guarantee) — guards sign conventions end-to-end.
#[test]
fn prop_engd_direction_is_descent() {
    run_prop("engd-w direction is descent", 30, |g| {
        let n = g.usize_in(2, 30);
        let p = g.usize_in(2, 40);
        let lam = g.log_uniform(1e-6, 1e-1);
        let j = random_jacobian(g, n, p);
        let r = g.vec_normal(n);
        let k = j.gram().add_diag(lam);
        let a = Cholesky::factor(&k).map_err(|e| e.to_string())?.solve(&r);
        let phi = j.tr_matvec(&a);
        // ∇L = Jᵀr; descent requires ∇Lᵀφ > 0 (since θ ← θ − ηφ).
        let grad = j.tr_matvec(&r);
        let slope = dot(&grad, &phi);
        (slope > 0.0)
            .then_some(())
            .ok_or_else(|| format!("∇Lᵀφ = {slope} ≤ 0: not a descent direction"))
    });
}
