//! engd-lint self-check and per-rule fixtures.
//!
//! The fixtures pin each rule's semantics (positive detection with exact
//! `file:line` + rule id, a negative that must stay clean, and pragma
//! suppression); the self-check runs the real tree walk over this checkout
//! and demands zero findings — `cargo test -q` fails the moment a
//! contract-violating line lands anywhere under `rust/src`, `benches`,
//! `examples`, or `rust/tests`. A Python mirror of the same walk lives at
//! `python/tools/lint_oracle.py` for toolchain-free environments.
//!
//! This file itself is in the walk (rust/tests is covered), and its fixture
//! strings are deliberate violations — the file-level pragma below opts it
//! out, which is also the pragma's own integration test: were it ignored,
//! `repo_tree_is_lint_clean` would fail on this file's fixtures.

// lint: fixture

use std::collections::BTreeSet;
use std::path::Path;

use engd_lint::{lint_source, lint_tree, registry_names, render_json, Finding, RULES};

fn registry() -> BTreeSet<String> {
    ["ENGD_THREADS", "ENGD_NUMERICS"].iter().map(|s| s.to_string()).collect()
}

fn run(src: &str) -> Vec<Finding> {
    lint_source("fixture.rs", src, &registry())
}

/// `(line, rule)` pairs, the shape every positive fixture asserts on.
fn hits(findings: &[Finding]) -> Vec<(usize, &'static str)> {
    findings.iter().map(|f| (f.line, f.rule)).collect()
}

// ---------------------------------------------------------------------------
// R1 nan-ord
// ---------------------------------------------------------------------------

#[test]
fn nan_ord_flags_partial_cmp_unwrap() {
    let f = run("fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n");
    assert_eq!(hits(&f), vec![(2, "nan-ord")]);
    assert_eq!(f[0].file, "fixture.rs");
}

#[test]
fn nan_ord_flags_multiline_chain() {
    let f = run("fn f() {\n    a.partial_cmp(&b)\n        .unwrap();\n}\n");
    // Diagnostic anchors on the `partial_cmp` line.
    assert_eq!(hits(&f), vec![(2, "nan-ord")]);
}

#[test]
fn nan_ord_accepts_unwrap_or_total_key() {
    let clean = "fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| {\n        let key = |x: &f64| (x.is_nan(), *x);\n        key(a).partial_cmp(&key(b)).unwrap_or(std::cmp::Ordering::Equal)\n    });\n}\n";
    assert!(run(clean).is_empty());
    // `unwrap_or_else` is a longer identifier, not a bare `unwrap()`.
    assert!(run("fn f() { a.partial_cmp(b).unwrap_or_else(|| x); }\n").is_empty());
}

#[test]
fn nan_ord_pragma_suppresses() {
    let src = "fn f() {\n    a.partial_cmp(b).unwrap(); // lint: allow(nan-ord)\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R2 unsafe-doc
// ---------------------------------------------------------------------------

#[test]
fn unsafe_doc_flags_undocumented_block() {
    let f = run("fn f() {\n    let x = 1;\n    unsafe { g() }\n}\n");
    assert_eq!(hits(&f), vec![(3, "unsafe-doc")]);
}

#[test]
fn unsafe_doc_accepts_preceding_safety_comment() {
    assert!(run("fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}\n")
        .is_empty());
    // Same-line trailing comment also documents the site.
    assert!(run("fn f() { unsafe { g() } // SAFETY: trivially fine\n}\n").is_empty());
}

#[test]
fn unsafe_doc_walks_over_continuations_and_attributes() {
    // The `let x: T =\n unsafe {…}` idiom: SAFETY sits above the binding.
    let src = "// SAFETY: slice bounds checked by caller.\nlet row: &mut [f64] =\n    unsafe { s.get_unchecked_mut(a..b) };\n";
    assert!(run(src).is_empty());
    let attr = "// SAFETY: caller proves AVX2 support.\n#[target_feature(enable = \"avx2\")]\nunsafe fn kernel() {}\n";
    assert!(run(attr).is_empty());
}

#[test]
fn unsafe_doc_ignores_strings_and_comments() {
    assert!(run("fn f() { let s = \"unsafe\"; } // unsafe in prose\n").is_empty());
}

#[test]
fn unsafe_doc_pragma_suppresses() {
    assert!(run("fn f() {\n    unsafe { g() } // lint: allow(unsafe-doc)\n}\n").is_empty());
}

// ---------------------------------------------------------------------------
// R3 env-reg
// ---------------------------------------------------------------------------

#[test]
fn env_reg_flags_unregistered_var() {
    // The raw read also fires R9 — the two rules compose on one line.
    let f = run("fn f() {\n    std::env::var(\"ENGD_BOGUS\").ok();\n}\n");
    assert_eq!(hits(&f), vec![(2, "env-read"), (2, "env-reg")]);
    assert!(f[1].message.contains("ENGD_BOGUS"));
}

#[test]
fn env_reg_accepts_registered_and_unshaped() {
    // The sanctioned read path: the name literal is still R3-checked.
    assert!(run("fn f() { crate::config::envvars::read(\"ENGD_THREADS\"); }\n").is_empty());
    // Lowercase tail is not env-var-shaped; neither are foreign prefixes.
    assert!(run("fn f() { let s = \"ENGD_lowercase\"; let t = \"OTHER_VAR\"; }\n").is_empty());
}

#[test]
fn env_reg_pragma_suppresses() {
    let src = "fn f() {\n    std::env::var(\"ENGD_BOGUS\").ok(); \
               // lint: allow(env-reg) lint: allow(env-read)\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R4 alloc
// ---------------------------------------------------------------------------

#[test]
fn alloc_flags_allocations_only_inside_marked_fns() {
    let src = "// lint: hot-path\nfn step(&mut self) {\n    let v = Vec::new();\n    let w = x.to_vec();\n}\n\nfn cold() {\n    let v = Vec::new();\n}\n";
    let f = run(src);
    assert_eq!(hits(&f), vec![(3, "alloc"), (4, "alloc")]);
}

#[test]
fn alloc_flags_vec_macro_and_clone() {
    let src = "// lint: hot-path\nfn step() {\n    let v = vec![0.0; 8];\n    let c = buf.clone();\n}\n";
    assert_eq!(hits(&run(src)), vec![(3, "alloc"), (4, "alloc")]);
}

#[test]
fn alloc_pragma_suppresses_per_line() {
    let src = "// lint: hot-path\nfn step() {\n    let v = vec![0.0; 8]; // lint: allow(alloc) — one-time lazy init\n    let w = Vec::new();\n}\n";
    assert_eq!(hits(&run(src)), vec![(4, "alloc")]);
}

#[test]
fn alloc_region_ends_at_fn_close_brace() {
    // Closure braces inside the body must not end the region early.
    let src = "// lint: hot-path\nfn step() {\n    let f = |x: usize| { x + 1 };\n    let v = Vec::new();\n}\nfn after() {\n    let v = Vec::new();\n}\n";
    assert_eq!(hits(&run(src)), vec![(4, "alloc")]);
}

// ---------------------------------------------------------------------------
// R5 bitwise
// ---------------------------------------------------------------------------

#[test]
fn bitwise_applies_only_to_tape_rs() {
    let src = "fn f(a: f64, b: f64, c: f64) -> f64 {\n    a.mul_add(b, c)\n}\n";
    assert!(lint_source("rust/src/linalg/matrix.rs", src, &registry()).is_empty());
    let f = lint_source("rust/src/backend/native/tape.rs", src, &registry());
    assert_eq!(hits(&f), vec![(2, "bitwise")]);
}

#[test]
fn bitwise_flags_reductions_outside_fast_tier() {
    let src = "// lint: fast-tier\nfn forward_fast(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>()\n}\nfn forward_bitwise(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, b| a + b)\n}\n";
    let f = lint_source("tape.rs", src, &registry());
    assert_eq!(hits(&f), vec![(6, "bitwise")]);
}

#[test]
fn bitwise_pragma_suppresses() {
    let src = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>() // lint: allow(bitwise)\n}\n";
    assert!(lint_source("tape.rs", src, &registry()).is_empty());
}

// ---------------------------------------------------------------------------
// R6 ws-leak
// ---------------------------------------------------------------------------

#[test]
fn ws_leak_flags_never_recycled_checkout() {
    // A deliberately leaked checkout: filled, read, never returned to the
    // pool. The finding anchors on the take line.
    let src = "fn f(ws: &mut Workspace) {\n    let mut v = ws.take_scratch(8);\n    \
               fill(&mut v);\n    read(&v);\n}\n";
    let f = run(src);
    assert_eq!(hits(&f), vec![(2, "ws-leak")]);
    assert!(f[0].message.contains("`v`"));
}

#[test]
fn ws_leak_flags_question_mark_and_early_return_exits() {
    let q = "fn f(ws: &mut Workspace) -> Result<()> {\n    let v = ws.take(8);\n    \
             fallible()?;\n    ws.recycle(v);\n    Ok(())\n}\n";
    let f = run(q);
    assert_eq!(hits(&f), vec![(3, "ws-leak")]);
    assert!(f[0].message.contains("`?` exit"));
    let r = "fn f(ws: &mut Workspace, bad: bool) -> usize {\n    let v = ws.take(8);\n    \
             if bad {\n        return 0;\n    }\n    ws.recycle(v);\n    1\n}\n";
    let f = run(r);
    assert_eq!(hits(&f), vec![(4, "ws-leak")]);
    assert!(f[0].message.contains("early `return`"));
}

#[test]
fn ws_leak_accepts_recycle_rename_and_documented_return() {
    let recycled = "fn f(ws: &mut Workspace) {\n    let mut v = ws.take_scratch(8);\n    \
                    v[0] = 1.0;\n    ws.recycle(v);\n}\n";
    assert!(run(recycled).is_empty());
    // `let w = v;` transfers tracking; recycling the new name closes it.
    let renamed = "fn f(ws: &mut Workspace) {\n    let v = ws.take(8);\n    let w = v;\n    \
                   ws.recycle(w);\n}\n";
    assert!(run(renamed).is_empty());
    // Returning the buffer hands the contract to the caller.
    let returned = "fn f(ws: &mut Workspace) -> Vec<f64> {\n    let v = ws.take(8);\n    v\n}\n";
    assert!(run(returned).is_empty());
    // `Option::take` on a non-`ws` receiver is not a checkout.
    assert!(run("fn f(&mut self) {\n    let g = self.gramian.take();\n    let _ = g;\n}\n")
        .is_empty());
}

#[test]
fn ws_leak_pragma_suppresses() {
    let src = "fn f(ws: &mut Workspace) {\n    \
               let v = ws.take(8); // lint: allow(ws-leak) — handed off via raw ptr\n    \
               let n = v.len();\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R7 hot-path-prop
// ---------------------------------------------------------------------------

#[test]
fn hot_path_prop_flags_allocating_callee() {
    // The canonical chain: a hot-path fn calls an in-crate callee that
    // allocates. The finding lands on the call site.
    let src = "// lint: hot-path\nfn step() {\n    helper();\n}\n\nfn helper() {\n    \
               let v = Vec::new();\n}\n";
    let f = run(src);
    assert_eq!(hits(&f), vec![(3, "hot-path-prop")]);
    assert!(f[0].message.contains("`helper`"));
    assert!(f[0].message.contains("Vec::new"));
}

#[test]
fn hot_path_prop_propagates_through_hot_assumed_intermediary() {
    // `mid` is reached only from a hot path, so it is hot-assumed and its
    // own call into the allocating leaf is the finding.
    let src = "// lint: hot-path\nfn step() {\n    mid();\n}\n\nfn mid() {\n    leaf();\n}\n\n\
               fn leaf() {\n    let v = vec![0.0; 8];\n}\n";
    assert_eq!(hits(&run(src)), vec![(7, "hot-path-prop")]);
}

#[test]
fn hot_path_prop_cold_caller_blocks_assumption() {
    // A cold caller (here: a test-shaped free fn) keeps `mid` out of the
    // hot-assumed set, so the chain below it is not propagated into.
    let src = "// lint: hot-path\nfn step() {\n    mid();\n}\n\nfn mid() {\n    leaf();\n}\n\n\
               fn leaf() {\n    let v = vec![0.0; 8];\n}\n\nfn test_mid() {\n    mid();\n}\n";
    assert!(run(src).is_empty());
}

#[test]
fn hot_path_prop_resolves_methods_and_skips_explicit_hot_callees() {
    // Method-call resolution inside an impl block.
    let m = "impl Foo {\n    // lint: hot-path\n    fn step(&mut self) {\n        \
             self.helper();\n    }\n    fn helper(&self) {\n        let v = Vec::new();\n    \
             }\n}\n";
    assert_eq!(hits(&run(m)), vec![(4, "hot-path-prop")]);
    // An explicitly hot callee is R4's job, line by line — not a repeat
    // finding at every call site.
    let owned = "// lint: hot-path\nfn step() {\n    helper();\n}\n\n// lint: hot-path\n\
                 fn helper() {\n    let v = Vec::new(); // lint: allow(alloc)\n}\n";
    assert!(run(owned).is_empty());
    // Foreign CamelCase qualifiers resolve to no in-crate item: no edge.
    assert!(run("// lint: hot-path\nfn step() {\n    let x = Other::make();\n}\n").is_empty());
}

#[test]
fn hot_path_prop_pragma_suppresses_at_call_site() {
    let src = "// lint: hot-path\nfn step() {\n    \
               helper(); // lint: allow(hot-path-prop) — cold setup branch\n}\n\nfn helper() {\n    \
               let v = Vec::new();\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R8 det-iter
// ---------------------------------------------------------------------------

#[test]
fn det_iter_flags_hash_collections_in_contract_dirs() {
    let src = "use std::collections::HashMap;\n";
    let f = lint_source("rust/src/backend/cache.rs", src, &registry());
    assert_eq!(hits(&f), vec![(1, "det-iter")]);
    let f = lint_source("rust/src/linalg/pool.rs", "fn f(s: RandomState) {}\n", &registry());
    assert_eq!(hits(&f), vec![(1, "det-iter")]);
}

#[test]
fn det_iter_scopes_to_contract_dirs_and_accepts_btree() {
    // Outside backend/ linalg/ parallel/, hash collections are fine.
    let src = "use std::collections::HashMap;\n";
    assert!(lint_source("rust/src/runtime/client.rs", src, &registry()).is_empty());
    // Ordered collections are always fine.
    let b = "use std::collections::BTreeMap;\nfn f(m: &BTreeMap<u32, u32>) {}\n";
    assert!(lint_source("rust/src/parallel/mod.rs", b, &registry()).is_empty());
    // `HashMapLike` is a different identifier — word-boundary match only.
    let w = "fn f(m: HashMapLike) {}\n";
    assert!(lint_source("rust/src/backend/x.rs", w, &registry()).is_empty());
}

#[test]
fn det_iter_pragma_suppresses() {
    let src = "use std::collections::HashMap; // lint: allow(det-iter) — lookup-only\n";
    assert!(lint_source("rust/src/backend/cache.rs", src, &registry()).is_empty());
}

// ---------------------------------------------------------------------------
// R9 env-read
// ---------------------------------------------------------------------------

#[test]
fn env_read_flags_raw_var_and_var_os() {
    // Registered name, so R3 stays quiet — the raw read path is the issue.
    let f = run("fn f() {\n    std::env::var(\"ENGD_THREADS\").ok();\n}\n");
    assert_eq!(hits(&f), vec![(2, "env-read")]);
    let f = run("fn f() {\n    std::env::var_os(\"ENGD_THREADS\");\n}\n");
    assert_eq!(hits(&f), vec![(2, "env-read")]);
}

#[test]
fn env_read_accepts_vars_iter_and_registry_module() {
    // `env::vars()` enumerates, it does not read one variable.
    assert!(run("fn f() {\n    for (k, v) in std::env::vars() {\n        drop((k, v));\n    }\n}\n")
        .is_empty());
    // The registry module is the one sanctioned home for the raw read.
    let raw = "pub fn read(name: &str) -> Option<String> {\n    std::env::var(name).ok()\n}\n";
    assert!(lint_source(engd_lint::REGISTRY_FILE, raw, &registry()).is_empty());
}

#[test]
fn env_read_pragma_suppresses() {
    let src =
        "fn f() {\n    std::env::var(\"ENGD_THREADS\").ok(); // lint: allow(env-read)\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// File-level fixture pragma
// ---------------------------------------------------------------------------

#[test]
fn fixture_pragma_skips_the_whole_file() {
    // Every rule would fire on this source; the pragma silences the file.
    let src = "// lint: fixture\n// lint: hot-path\nfn step(ws: &mut Workspace) {\n    \
               let v = ws.take(8);\n    let w = Vec::new();\n    unsafe { g() }\n    \
               std::env::var(\"ENGD_BOGUS\").ok();\n}\n";
    assert!(run(src).is_empty());
    // Without it, the same source is loud.
    assert!(!run(&src.replace("// lint: fixture\n", "")).is_empty());
}

// ---------------------------------------------------------------------------
// Semantic layer: item tree and call edges on adversarial token streams
// ---------------------------------------------------------------------------

#[test]
fn item_tree_spans_and_calls_survive_adversarial_streams() {
    use engd_lint::semantic::items_from_source;
    let src = "fn outer() {\n    let s = \"fn fake() { inner_fake(); }\";\n    \
               let c = '{';\n    let f = |x: usize| { helper(x) };\n    inner();\n    \
               fn inner() {}\n}\n\nimpl Foo {\n    fn method(&self) -> Vec<usize> {\n        \
               self.call_a::<f64>();\n        Self::call_b();\n        vec![]\n    }\n}\n";
    let fns = items_from_source(src, &[]);
    let mut names: Vec<&str> = fns.iter().map(|f| f.name.as_str()).collect();
    names.sort();
    assert_eq!(names, vec!["inner", "method", "outer"]);

    // Spans: outer runs line 1..=7 (0-based 0..=6) despite the brace in a
    // string, the `{` char literal, and the closure braces.
    let outer = fns.iter().find(|f| f.name == "outer").unwrap();
    assert_eq!((outer.sig_line, outer.end_line), (0, 6));
    // Calls: the closure body counts, the string contents and the nested
    // `fn inner` *declaration* do not.
    let calls: Vec<&str> = outer.calls.iter().map(|c| c.name.as_str()).collect();
    assert_eq!(calls, vec!["helper", "inner"]);

    let method = fns.iter().find(|f| f.name == "method").unwrap();
    assert_eq!(method.owner.as_deref(), Some("Foo"));
    let mc: Vec<(&str, bool)> =
        method.calls.iter().map(|c| (c.name.as_str(), c.method)).collect();
    // `vec![]` is a macro, not a call; the turbofish method call and the
    // `Self::` path call both survive.
    assert_eq!(mc, vec![("call_a", true), ("call_b", false)]);
    assert_eq!(method.calls[1].qual.as_deref(), Some("Self"));
}

// ---------------------------------------------------------------------------
// Baselines
// ---------------------------------------------------------------------------

#[test]
fn baseline_round_trips_and_masks_only_recorded_findings() {
    let old = run("fn f(ws: &mut Workspace) {\n    let v = ws.take(8);\n    let n = v.len();\n}\n");
    assert_eq!(hits(&old), vec![(2, "ws-leak")]);
    let text = engd_lint::render_baseline(&old);
    assert!(text.starts_with('#'), "baseline carries a self-describing header");
    let accepted = engd_lint::parse_baseline(&text);
    // Every recorded finding round-trips through its key…
    assert!(old.iter().all(|f| accepted.contains(&engd_lint::baseline_key(f))));
    // …and a finding at any other location is new.
    let new = run("fn g(ws: &mut Workspace) {\n    fallible()?;\n    \
                   let v = ws.take(8);\n    let n = v.len();\n}\n");
    assert!(new.iter().all(|f| !accepted.contains(&engd_lint::baseline_key(f))));
}

#[test]
fn baseline_render_is_sorted_and_deduped() {
    let mut findings = run("fn f(ws: &mut Workspace) {\n    let v = ws.take(8);\n    \
                            let n = v.len();\n}\n");
    let dup = findings[0].clone();
    findings.push(dup);
    let text = engd_lint::render_baseline(&findings);
    let keys: Vec<&str> = text.lines().filter(|l| !l.starts_with('#')).collect();
    assert_eq!(keys, vec!["fixture.rs:2: [ws-leak]"]);
}

// ---------------------------------------------------------------------------
// Machine-readable output
// ---------------------------------------------------------------------------

#[test]
fn json_report_escapes_and_counts() {
    let findings = run("fn f() {\n    unsafe { g() }\n}\n");
    let report = engd_lint::Report {
        findings,
        files_scanned: 1,
        registry: registry(),
    };
    let json = render_json(&report);
    assert!(json.contains("\"finding_count\": 1"));
    assert!(json.contains("\"rule\": \"unsafe-doc\""));
    assert!(json.contains("\"file\": \"fixture.rs\""));
    assert!(json.contains("\"line\": 2"));
    // The message quotes `unsafe` in backticks and must survive escaping.
    assert!(json.contains("`unsafe`"));
}

// ---------------------------------------------------------------------------
// Repo self-check
// ---------------------------------------------------------------------------

#[test]
fn repo_registry_matches_envvars_module() {
    // The lexer-scraped registry and the compiled REGISTRY must agree —
    // this is what lets engd-lint stay dependency-free.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let scraped = registry_names(root).expect("scan registry file");
    let compiled: BTreeSet<String> =
        engd::config::envvars::REGISTRY.iter().map(|v| v.name.to_string()).collect();
    assert_eq!(scraped, compiled);
}

#[test]
fn repo_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("walk tree");
    assert!(report.files_scanned > 50, "walk looks truncated: {} files", report.files_scanned);
    assert!(!report.registry.is_empty(), "registry scan came up empty");
    if !report.findings.is_empty() {
        for f in &report.findings {
            eprintln!("{f}");
        }
        panic!(
            "engd-lint: {} finding(s) in this checkout (rules: {})",
            report.findings.len(),
            RULES.join(", ")
        );
    }
}
