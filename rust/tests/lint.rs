//! engd-lint self-check and per-rule fixtures.
//!
//! The fixtures pin each rule's semantics (positive detection with exact
//! `file:line` + rule id, a negative that must stay clean, and pragma
//! suppression); the self-check runs the real tree walk over this checkout
//! and demands zero findings — `cargo test -q` fails the moment a
//! contract-violating line lands anywhere under `rust/src`, `benches`, or
//! `examples`. A Python mirror of the same walk lives at
//! `python/tools/lint_oracle.py` for toolchain-free environments.

use std::collections::BTreeSet;
use std::path::Path;

use engd_lint::{lint_source, lint_tree, registry_names, render_json, Finding, RULES};

fn registry() -> BTreeSet<String> {
    ["ENGD_THREADS", "ENGD_NUMERICS"].iter().map(|s| s.to_string()).collect()
}

fn run(src: &str) -> Vec<Finding> {
    lint_source("fixture.rs", src, &registry())
}

/// `(line, rule)` pairs, the shape every positive fixture asserts on.
fn hits(findings: &[Finding]) -> Vec<(usize, &'static str)> {
    findings.iter().map(|f| (f.line, f.rule)).collect()
}

// ---------------------------------------------------------------------------
// R1 nan-ord
// ---------------------------------------------------------------------------

#[test]
fn nan_ord_flags_partial_cmp_unwrap() {
    let f = run("fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n");
    assert_eq!(hits(&f), vec![(2, "nan-ord")]);
    assert_eq!(f[0].file, "fixture.rs");
}

#[test]
fn nan_ord_flags_multiline_chain() {
    let f = run("fn f() {\n    a.partial_cmp(&b)\n        .unwrap();\n}\n");
    // Diagnostic anchors on the `partial_cmp` line.
    assert_eq!(hits(&f), vec![(2, "nan-ord")]);
}

#[test]
fn nan_ord_accepts_unwrap_or_total_key() {
    let clean = "fn f(v: &mut [f64]) {\n    v.sort_by(|a, b| {\n        let key = |x: &f64| (x.is_nan(), *x);\n        key(a).partial_cmp(&key(b)).unwrap_or(std::cmp::Ordering::Equal)\n    });\n}\n";
    assert!(run(clean).is_empty());
    // `unwrap_or_else` is a longer identifier, not a bare `unwrap()`.
    assert!(run("fn f() { a.partial_cmp(b).unwrap_or_else(|| x); }\n").is_empty());
}

#[test]
fn nan_ord_pragma_suppresses() {
    let src = "fn f() {\n    a.partial_cmp(b).unwrap(); // lint: allow(nan-ord)\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R2 unsafe-doc
// ---------------------------------------------------------------------------

#[test]
fn unsafe_doc_flags_undocumented_block() {
    let f = run("fn f() {\n    let x = 1;\n    unsafe { g() }\n}\n");
    assert_eq!(hits(&f), vec![(3, "unsafe-doc")]);
}

#[test]
fn unsafe_doc_accepts_preceding_safety_comment() {
    assert!(run("fn f() {\n    // SAFETY: g has no preconditions here.\n    unsafe { g() }\n}\n")
        .is_empty());
    // Same-line trailing comment also documents the site.
    assert!(run("fn f() { unsafe { g() } // SAFETY: trivially fine\n}\n").is_empty());
}

#[test]
fn unsafe_doc_walks_over_continuations_and_attributes() {
    // The `let x: T =\n unsafe {…}` idiom: SAFETY sits above the binding.
    let src = "// SAFETY: slice bounds checked by caller.\nlet row: &mut [f64] =\n    unsafe { s.get_unchecked_mut(a..b) };\n";
    assert!(run(src).is_empty());
    let attr = "// SAFETY: caller proves AVX2 support.\n#[target_feature(enable = \"avx2\")]\nunsafe fn kernel() {}\n";
    assert!(run(attr).is_empty());
}

#[test]
fn unsafe_doc_ignores_strings_and_comments() {
    assert!(run("fn f() { let s = \"unsafe\"; } // unsafe in prose\n").is_empty());
}

#[test]
fn unsafe_doc_pragma_suppresses() {
    assert!(run("fn f() {\n    unsafe { g() } // lint: allow(unsafe-doc)\n}\n").is_empty());
}

// ---------------------------------------------------------------------------
// R3 env-reg
// ---------------------------------------------------------------------------

#[test]
fn env_reg_flags_unregistered_var() {
    let f = run("fn f() {\n    std::env::var(\"ENGD_BOGUS\").ok();\n}\n");
    assert_eq!(hits(&f), vec![(2, "env-reg")]);
    assert!(f[0].message.contains("ENGD_BOGUS"));
}

#[test]
fn env_reg_accepts_registered_and_unshaped() {
    assert!(run("fn f() { std::env::var(\"ENGD_THREADS\").ok(); }\n").is_empty());
    // Lowercase tail is not env-var-shaped; neither are foreign prefixes.
    assert!(run("fn f() { let s = \"ENGD_lowercase\"; let t = \"OTHER_VAR\"; }\n").is_empty());
}

#[test]
fn env_reg_pragma_suppresses() {
    let src = "fn f() {\n    std::env::var(\"ENGD_BOGUS\").ok(); // lint: allow(env-reg)\n}\n";
    assert!(run(src).is_empty());
}

// ---------------------------------------------------------------------------
// R4 alloc
// ---------------------------------------------------------------------------

#[test]
fn alloc_flags_allocations_only_inside_marked_fns() {
    let src = "// lint: hot-path\nfn step(&mut self) {\n    let v = Vec::new();\n    let w = x.to_vec();\n}\n\nfn cold() {\n    let v = Vec::new();\n}\n";
    let f = run(src);
    assert_eq!(hits(&f), vec![(3, "alloc"), (4, "alloc")]);
}

#[test]
fn alloc_flags_vec_macro_and_clone() {
    let src = "// lint: hot-path\nfn step() {\n    let v = vec![0.0; 8];\n    let c = buf.clone();\n}\n";
    assert_eq!(hits(&run(src)), vec![(3, "alloc"), (4, "alloc")]);
}

#[test]
fn alloc_pragma_suppresses_per_line() {
    let src = "// lint: hot-path\nfn step() {\n    let v = vec![0.0; 8]; // lint: allow(alloc) — one-time lazy init\n    let w = Vec::new();\n}\n";
    assert_eq!(hits(&run(src)), vec![(4, "alloc")]);
}

#[test]
fn alloc_region_ends_at_fn_close_brace() {
    // Closure braces inside the body must not end the region early.
    let src = "// lint: hot-path\nfn step() {\n    let f = |x: usize| { x + 1 };\n    let v = Vec::new();\n}\nfn after() {\n    let v = Vec::new();\n}\n";
    assert_eq!(hits(&run(src)), vec![(4, "alloc")]);
}

// ---------------------------------------------------------------------------
// R5 bitwise
// ---------------------------------------------------------------------------

#[test]
fn bitwise_applies_only_to_tape_rs() {
    let src = "fn f(a: f64, b: f64, c: f64) -> f64 {\n    a.mul_add(b, c)\n}\n";
    assert!(lint_source("rust/src/linalg/matrix.rs", src, &registry()).is_empty());
    let f = lint_source("rust/src/backend/native/tape.rs", src, &registry());
    assert_eq!(hits(&f), vec![(2, "bitwise")]);
}

#[test]
fn bitwise_flags_reductions_outside_fast_tier() {
    let src = "// lint: fast-tier\nfn forward_fast(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>()\n}\nfn forward_bitwise(xs: &[f64]) -> f64 {\n    xs.iter().fold(0.0, |a, b| a + b)\n}\n";
    let f = lint_source("tape.rs", src, &registry());
    assert_eq!(hits(&f), vec![(6, "bitwise")]);
}

#[test]
fn bitwise_pragma_suppresses() {
    let src = "fn f(xs: &[f64]) -> f64 {\n    xs.iter().sum::<f64>() // lint: allow(bitwise)\n}\n";
    assert!(lint_source("tape.rs", src, &registry()).is_empty());
}

// ---------------------------------------------------------------------------
// Machine-readable output
// ---------------------------------------------------------------------------

#[test]
fn json_report_escapes_and_counts() {
    let findings = run("fn f() {\n    unsafe { g() }\n}\n");
    let report = engd_lint::Report {
        findings,
        files_scanned: 1,
        registry: registry(),
    };
    let json = render_json(&report);
    assert!(json.contains("\"finding_count\": 1"));
    assert!(json.contains("\"rule\": \"unsafe-doc\""));
    assert!(json.contains("\"file\": \"fixture.rs\""));
    assert!(json.contains("\"line\": 2"));
    // The message quotes `unsafe` in backticks and must survive escaping.
    assert!(json.contains("`unsafe`"));
}

// ---------------------------------------------------------------------------
// Repo self-check
// ---------------------------------------------------------------------------

#[test]
fn repo_registry_matches_envvars_module() {
    // The lexer-scraped registry and the compiled REGISTRY must agree —
    // this is what lets engd-lint stay dependency-free.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let scraped = registry_names(root).expect("scan registry file");
    let compiled: BTreeSet<String> =
        engd::config::envvars::REGISTRY.iter().map(|v| v.name.to_string()).collect();
    assert_eq!(scraped, compiled);
}

#[test]
fn repo_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("walk tree");
    assert!(report.files_scanned > 50, "walk looks truncated: {} files", report.files_scanned);
    assert!(!report.registry.is_empty(), "registry scan came up empty");
    if !report.findings.is_empty() {
        for f in &report.findings {
            eprintln!("{f}");
        }
        panic!(
            "engd-lint: {} finding(s) in this checkout (rules: {})",
            report.findings.len(),
            RULES.join(", ")
        );
    }
}
