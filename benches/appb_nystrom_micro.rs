//! Appendix B: microbenchmark of the standard stable Nyström vs the paper's
//! GPU-efficient Algorithm 2.
//!
//! Paper setup: N = 3500, sketch S = 1750, λ = 1e-7, 100 timed iterations
//! after 10 warm-ups, on an RTX 6000. Ours is the same protocol scaled for
//! CPU (N and iteration count via env; defaults N = 896, S = N/2, 20 iters),
//! with the SVD-class step realized as Jacobi eigh (DESIGN.md
//! §Substitutions). Expected shape: the GPU-efficient variant is an order of
//! magnitude faster because it replaces QR + SVD with two small Choleskys.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use engd::linalg::{Matrix, Workspace};
use engd::metrics::Summary;
use engd::nystrom::{GpuNystrom, NystromApprox, StableNystrom};
use engd::optim::DenseKernel;
use engd::rng::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    engd::config::envvars::read(key)
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("ENGD_APPB_N", 896);
    let sketch = env_usize("ENGD_APPB_SKETCH", n / 2);
    let warmup = 3;
    let iters = env_usize("ENGD_APPB_ITERS", 20);
    let lambda = 1e-7;

    println!(
        "Appendix B protocol (scaled): N = {n}, sketch = {sketch}, lambda = {lambda:.0e}, \
         {iters} timed iterations after {warmup} warm-ups"
    );

    // Paper: "randomly drawn matrix ... squared to create a low-rank square
    // matrix" — G Gᵀ with G of width P' < N gives the low-rank PSD test case.
    let mut rng = Rng::seed_from(42);
    let mut g = Matrix::zeros(n, n / 2);
    rng.fill_normal(g.data_mut());
    let a = g.gram();
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);

    let op = DenseKernel::new(&a);
    let mut time_variant = |tag: &str, f: &mut dyn FnMut(&mut Rng) -> Vec<f64>| {
        let mut samples = Vec::with_capacity(iters);
        for i in 0..warmup + iters {
            let t0 = Instant::now();
            let out = f(&mut rng);
            let dt = t0.elapsed().as_secs_f64();
            assert!(out.iter().all(|x| x.is_finite()), "{tag} produced non-finite");
            if i >= warmup {
                samples.push(dt);
            }
        }
        let s = Summary::of(&samples);
        println!("{tag:<22} {s}");
        s
    };

    // Each variant keeps one workspace across iterations, mirroring the
    // trainer: the first iteration allocates, the rest run from the pool.
    let mut ws_stable = Workspace::new();
    let stable = time_variant("stable (QR+eigh-SVD)", &mut |rng| {
        let nys = StableNystrom::build(&op, sketch, lambda, rng, &mut ws_stable).unwrap();
        let x = nys.inv_apply(&v);
        nys.recycle(&mut ws_stable);
        x
    });
    let mut ws_gpu = Workspace::new();
    let gpu = time_variant("gpu-efficient (Alg 2)", &mut |rng| {
        let nys = GpuNystrom::build(&op, sketch, lambda, rng, &mut ws_gpu).unwrap();
        let x = nys.inv_apply(&v);
        nys.recycle(&mut ws_gpu);
        x
    });
    println!(
        "workspace reuse: stable {:?}, gpu {:?}",
        ws_stable.stats(),
        ws_gpu.stats()
    );

    println!(
        "\nspeedup (stable / gpu-efficient) at the median: {:.1}x \
         (paper: ~10x on GPU at N=3500, S=1750)",
        stable.median / gpu.median
    );

    // Accuracy check at this sketch size: both approximations should agree
    // with each other far better than either agrees with the exact solve.
    let mut ws = Workspace::new();
    let mut r1 = Rng::seed_from(7);
    let nys_g = GpuNystrom::build(&op, sketch, lambda, &mut r1, &mut ws).unwrap();
    let mut r2 = Rng::seed_from(7);
    let nys_s = StableNystrom::build(&op, sketch, lambda, &mut r2, &mut ws).unwrap();
    let xg = nys_g.inv_apply(&v);
    let xs = nys_s.inv_apply(&v);
    let rel: f64 = xg
        .iter()
        .zip(&xs)
        .map(|(p, q)| (p - q).abs())
        .fold(0.0, f64::max)
        / xg.iter().map(|x| x.abs()).fold(1e-300, f64::max);
    println!("max relative divergence between variants: {rel:.2e}");
    Ok(())
}
