//! Ablations on the design choices DESIGN.md calls out:
//!
//! 1. SPRING bias correction mode — the paper's Algorithm 1 prints the
//!    correction overwriting the carried φ; the Adam convention stores the
//!    raw moment. We compare `adam` / `overwrite` / `none`.
//! 2. Fused XLA step vs decomposed Rust-linalg step — same math, different
//!    execution path; measures the coordinator overhead.
//! 3. Sketch-size sweep for Nyström ENGD-W (the paper's "no speedup above
//!    25% of N" remark and the fixed-rank limitation in §5).

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, Arm};
use engd::config::run::{BiasMode, ExecPath, OptimizerKind, SolveMode};
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(15.0);

    // --- 1: bias-correction mode ---
    let spring = OptimizerConfig {
        kind: OptimizerKind::Spring,
        damping: 2.086287e-10,
        momentum: 8.26966e-1,
        line_search: true, // robust at our scaled batch (DESIGN.md)
        ..OptimizerConfig::default()
    };
    let arms = vec![
        Arm::new("bias-adam", "poisson5d", OptimizerConfig {
            bias: BiasMode::Adam,
            ..spring.clone()
        }),
        Arm::new("bias-overwrite", "poisson5d", OptimizerConfig {
            bias: BiasMode::Overwrite,
            ..spring.clone()
        }),
        Arm::new("bias-none", "poisson5d", OptimizerConfig {
            bias: BiasMode::None,
            ..spring.clone()
        }),
    ];
    let reports = run_arms("ablation-bias", backend.as_ref(), &arms, budget, 100_000);
    print_table(
        "Ablation 1 — SPRING bias correction (Algorithm 1 line 8 readings)",
        &arms,
        &reports,
    );

    // --- 2: fused vs decomposed execution path ---
    let arms = vec![
        Arm::new("engd_w-fused", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-8,
            line_search: true,
            path: ExecPath::Fused,
            ..OptimizerConfig::default()
        }),
        Arm::new("engd_w-decomposed", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-8,
            line_search: true,
            path: ExecPath::Decomposed,
            ..OptimizerConfig::default()
        }),
    ];
    let reports = run_arms("ablation-path", backend.as_ref(), &arms, budget, 100_000);
    print_table(
        "Ablation 2 — fused XLA step vs decomposed Rust-linalg step \
         (same update; step-rate gap = J-transfer + Rust solve overhead)",
        &arms,
        &reports,
    );
    if let [Some(fused), Some(dec)] = &reports[..] {
        let rf = fused.steps_done as f64 / fused.wall_s.max(1e-9);
        let rd = dec.steps_done as f64 / dec.wall_s.max(1e-9);
        println!("step rate: fused {rf:.2}/s vs decomposed {rd:.2}/s ({:.2}x)", rf / rd);
    }

    // --- 3: sketch-size sweep (paper: 10% helps early, >25% no speedup) ---
    let mut arms = Vec::new();
    for ratio in [0.05, 0.10, 0.25, 0.50] {
        arms.push(Arm::new(
            &format!("sketch-{:02.0}%", ratio * 100.0),
            "poisson5d_n1024",
            OptimizerConfig {
                kind: OptimizerKind::EngdW,
                damping: 1e-6,
                line_search: true,
                solve: SolveMode::NystromGpu,
                sketch_ratio: ratio,
                path: ExecPath::Decomposed,
                ..OptimizerConfig::default()
            },
        ));
    }
    arms.push(Arm::new("sketch-exact", "poisson5d_n1024", OptimizerConfig {
        kind: OptimizerKind::EngdW,
        damping: 1e-6,
        line_search: true,
        solve: SolveMode::Exact,
        path: ExecPath::Decomposed,
        ..OptimizerConfig::default()
    }));
    let reports = run_arms("ablation-sketch", backend.as_ref(), &arms, budget, 100_000);
    print_table(
        "Ablation 3 — Nyström sketch-size sweep on N=1024 (paper §4: speedup \
         at 10%, none above 25%)",
        &arms,
        &reports,
    );
    Ok(())
}
