//! Figure 2 (+ Fig. 7, and the §5 "up to 75× faster" headline):
//! optimizer comparison on the 5d Poisson problem.
//!
//! Arms: SGD, Adam, Hessian-free, original dense ENGD, ENGD-W — each with
//! the paper's tuned hyperparameters (Appendix A.2) and an equal wall-clock
//! budget. Expected shape (paper): ENGD-W takes ~30× more steps than dense
//! ENGD in the same budget and dominates every baseline in final L2; the
//! first-order methods plateau orders of magnitude higher.

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, speedup_at_equal_l2, Arm};
use engd::config::run::{ExecPath, OptimizerKind};
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(30.0);
    let problem = "poisson5d";

    let base = OptimizerConfig::default();
    let arms = vec![
        Arm::new("sgd", problem, OptimizerConfig {
            kind: OptimizerKind::Sgd,
            lr: 2.895360e-3, // paper A.2 best
            momentum: 0.3,
            ..base.clone()
        }),
        Arm::new("adam", problem, OptimizerConfig {
            kind: OptimizerKind::Adam,
            lr: 2.808451e-4, // paper A.2 best
            ..base.clone()
        }),
        Arm::new("hessian_free", problem, OptimizerConfig {
            kind: OptimizerKind::HessianFree,
            damping: 1e-1, // paper A.2 best (GGN, adaptive damping)
            cg_iters: 100, // scaled from 350 (CPU budget)
            line_search: true,
            path: ExecPath::Decomposed,
            ..base.clone()
        }),
        Arm::new("engd_dense", problem, OptimizerConfig {
            kind: OptimizerKind::EngdDense,
            damping: 1e-8, // paper A.2 best
            ema: 0.0,
            gramian_identity_init: true,
            line_search: true,
            path: ExecPath::Decomposed,
            ..base.clone()
        }),
        Arm::new("engd_w", problem, OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 3.173212e-12, // paper A.2 best
            line_search: true,
            ..base.clone()
        }),
    ];

    let reports = run_arms("fig2", backend.as_ref(), &arms, budget, 100_000);
    print_table(
        "Fig. 2 — 5d Poisson, equal time budget (paper: ENGD-W wins, dense ENGD \
         step-starved, first-order plateaus)",
        &arms,
        &reports,
    );

    // Headline: ENGD (dense) vs ENGD-W time-to-equal-L2.
    if let (Some(Some(dense)), Some(Some(w))) = (reports.get(3), reports.get(4)) {
        println!("\n--- §5 headline: time-to-equal-L2, dense ENGD vs ENGD-W ---");
        match speedup_at_equal_l2(dense, w) {
            Some((thr, factor)) => println!(
                "at L2 <= {thr:.0e}: ENGD-W is {factor:.1}x faster than dense ENGD \
                 (paper reports up to 75x at sub-1e-3 on a 7000s GPU budget)"
            ),
            None => {
                // Fall back to steps-per-second — the structural claim.
                let sps_dense = dense.steps_done as f64 / dense.wall_s.max(1e-9);
                let sps_w = w.steps_done as f64 / w.wall_s.max(1e-9);
                println!(
                    "no common L2 threshold reached in budget; step-rate ratio \
                     ENGD-W/dense = {:.1}x (paper: >30x more steps)",
                    sps_w / sps_dense.max(1e-12)
                );
            }
        }
    }
    Ok(())
}
