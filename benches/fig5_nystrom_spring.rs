//! Figure 5 (+ Fig. 15): Nyström-randomized vs exact SPRING on the 100d
//! Poisson problem.
//!
//! Expected shape (paper): randomization gives *no* speedup here — in high
//! dimension the differentiation through the operator dominates per-step
//! cost, so accelerating the kernel solve barely matters, while the sketch
//! loses accuracy (d_eff/N stays above 50%, Fig. 6b).

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, Arm};
use engd::config::run::{ExecPath, OptimizerKind, SolveMode};
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(25.0);
    let problem = "poisson100d";

    let mk = |tag: &str, solve: SolveMode| {
        Arm::new(tag, problem, OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 3.0116e-2, // paper A.4 best (line-search setup)
            momentum: 6.76335e-1,
            line_search: true,
            solve,
            sketch_ratio: 0.10,
            path: if solve == SolveMode::Exact {
                ExecPath::Fused
            } else {
                ExecPath::Decomposed
            },
            ..OptimizerConfig::default()
        })
    };
    let arms = vec![
        mk("spring-exact", SolveMode::Exact),
        // Also run the exact solve on the decomposed path so the
        // exact-vs-sketched comparison is apples-to-apples in Rust.
        {
            let mut a = mk("spring-exact-decomposed", SolveMode::Exact);
            a.optimizer.path = ExecPath::Decomposed;
            a
        },
        mk("spring-nystrom_gpu", SolveMode::NystromGpu),
        mk("spring-nystrom_stable", SolveMode::NystromStable),
    ];
    let reports = run_arms("fig5", backend.as_ref(), &arms, budget, 100_000);
    print_table(
        "Fig. 5 — 100d SPRING: exact vs randomized (paper: randomized ≈ or \
         worse than exact; operator differentiation dominates)",
        &arms,
        &reports,
    );
    Ok(())
}
