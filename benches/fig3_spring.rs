//! Figure 3 (+ Figs. 8, 11–14): ENGD-W vs SPRING on the 5d and 100d Poisson
//! problems (and 10d via `--problem`/env).
//!
//! Expected shape (paper): SPRING ≥ ENGD-W everywhere, with a decisive gap
//! on the 100d problem; SPRING needs no line search.

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, Arm};
use engd::config::run::OptimizerKind;
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(30.0);
    let base = OptimizerConfig::default();

    // --- 5d: line-search arms are the paper's primary A.2 setup; the
    // fixed-lr arms reproduce A.2.1 (at our scaled batch/step budget the
    // fixed-lr variants progress much more slowly — they need the paper's
    // tens-of-thousands of steps; see EXPERIMENTS.md).
    let arms5 = vec![
        Arm::new("engd_w-5d-ls", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-8,
            line_search: true,
            ..base.clone()
        }),
        Arm::new("spring-5d-ls", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 2.086287e-10,
            momentum: 3.11542e-1,
            line_search: true,
            ..base.clone()
        }),
        Arm::new("engd_w-5d-fixed", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 6.804474e-8,
            lr: 5.2289e-2,
            ..base.clone()
        }),
        Arm::new("spring-5d-fixed", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 6.811585e-10,
            momentum: 8.26966e-1,
            lr: 6.3502e-2,
            ..base.clone()
        }),
    ];
    let reports5 = run_arms("fig3-5d", backend.as_ref(), &arms5, budget, 100_000);
    print_table(
        "Fig. 3 (left) — 5d: SPRING vs ENGD-W (paper: SPRING converges faster, \
         no line search needed)",
        &arms5,
        &reports5,
    );

    // --- 10d (paper A.3 line-search bests) ---
    let arms10 = vec![
        Arm::new("engd_w-10d", "poisson10d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 3.9e-7,
            line_search: true,
            ..base.clone()
        }),
        Arm::new("spring-10d", "poisson10d", OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 1.7e-7,
            momentum: 9.05328e-1,
            line_search: true,
            ..base.clone()
        }),
    ];
    let reports10 = run_arms("fig3-10d", backend.as_ref(), &arms10, budget, 100_000);
    print_table("Fig. 11/12 — 10d: SPRING vs ENGD-W", &arms10, &reports10);

    // --- 100d (paper A.4 line-search bests) ---
    let arms100 = vec![
        Arm::new("engd_w-100d", "poisson100d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 4.7772e-3,
            line_search: true,
            ..base.clone()
        }),
        Arm::new("spring-100d", "poisson100d", OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 3.0116e-2,
            momentum: 6.76335e-1,
            line_search: true,
            ..base.clone()
        }),
    ];
    let reports100 = run_arms("fig3-100d", backend.as_ref(), &arms100, budget, 100_000);
    print_table(
        "Fig. 3 (right) — 100d: SPRING vs ENGD-W (paper: SPRING reaches L2 \
         errors 'not previously seen')",
        &arms100,
        &reports100,
    );
    Ok(())
}
