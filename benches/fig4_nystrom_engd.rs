//! Figure 4 (+ Figs. 9/10): randomized Nyström vs exact ENGD-W across batch
//! sizes, sketch = 10% of N.
//!
//! Expected shape (paper): randomization accelerates the *early* phase, more
//! so at larger batch sizes, but exact computation is needed for the final
//! accuracies. Batch sizes are scaled (512/1024/2048 vs the paper's
//! 1000/10000/50000 — DESIGN.md §Substitutions).

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, Arm};
use engd::config::run::{ExecPath, OptimizerKind, SolveMode};
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(25.0);

    for problem in ["poisson5d_n512", "poisson5d_n1024", "poisson5d_n2048"] {
        let mk = |tag: &str, solve: SolveMode| {
            Arm::new(tag, problem, OptimizerConfig {
                kind: OptimizerKind::EngdW,
                damping: 1e-6,
                line_search: true, // paper: "all under our standard line-search"
                solve,
                sketch_ratio: 0.10, // paper's sketch size
                path: ExecPath::Decomposed,
                ..OptimizerConfig::default()
            })
        };
        let arms = vec![
            mk("exact", SolveMode::Exact),
            mk("nystrom_gpu", SolveMode::NystromGpu),
            mk("nystrom_stable", SolveMode::NystromStable),
        ];
        let reports = run_arms(&format!("fig4-{problem}"), backend.as_ref(), &arms, budget, 100_000);
        print_table(
            &format!(
                "Fig. 4 — {problem}: exact vs randomized ENGD-W, sketch 10% N \
                 (paper: randomization helps early at large N, exact wins late)"
            ),
            &arms,
            &reports,
        );
        // Early-phase comparison: loss at the first quarter of the budget.
        println!("  (early-phase trajectories: see results/bench/fig4-{problem}/*.csv)");
    }
    Ok(())
}
