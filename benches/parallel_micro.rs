//! Worker-pool microbenchmarks — the §Perf harness for the execution
//! substrate itself.
//!
//! Three questions the execution-substrate refactors must answer with
//! numbers:
//!
//! 1. **Dispatch overhead**: what does handing a job to parked workers cost
//!    versus spawning fresh scoped threads per call (the previous
//!    substrate), across job granularities?
//! 2. **Tape reuse**: what does keeping per-worker `Tape` state alive
//!    across calls buy on repeated native `loss_and_grad` / line-search
//!    style `loss` evaluations (cold first call vs steady state)?
//! 3. **Blocked tape kernels**: what do the coordinate-blocked SIMD
//!    kernels and the point-batched entry points buy over the scalar
//!    per-(point, coordinate) loops (`ScalarTape`, the pre-blocking
//!    implementation kept in-tree as the reference) on the Jacobian
//!    forward+reverse workload — single thread, single-point and
//!    point-block entries, Poisson 2d/10d + heat?
//! 4. **Fused backward panels**: with the forward state already in place,
//!    what does the layer-outer/point-inner fused `backward_batch`
//!    (adjoint panels; weight rows loaded once per layer per block) buy
//!    over per-point `backward` calls on the same blocks — reverse pass
//!    only? The PR-5 acceptance case is the wide poisson2d net at batch
//!    512 (fused ≥ 1.5× per-point, rows bitwise identical).
//! 5. **Fast numerics tier**: what do the relaxed-numerics SIMD kernels
//!    (FMA, reassociated panel reductions, wider blocks) buy over the
//!    bitwise blocked kernels on the same workloads? The PR-6 acceptance
//!    case is poisson2d at batch 512, forward+reverse: fast ≥ 1.3× the
//!    bitwise blocked arm, rows within 1e-9 relative of the scalar
//!    reference.
//!
//! Besides the stdout table, every tape/backward arm is appended to
//! `BENCH_parallel_micro.json` (case, arm, ns/iter, speedup vs the
//! bitwise blocked arm of the same case) for machine consumption.

use std::hint::black_box;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use engd::backend::native::{ScalarTape, Tape};
use engd::backend::{Evaluator, NativeBackend, NumericsMode, SimdTier};
use engd::config::json::{self, JsonValue};
use engd::metrics::Summary;
use engd::pde::{init_params, param_count, DualOrder, PdeOperator, Sampler};
use engd::rng::Rng;

fn time_reps(reps: usize, mut f: impl FnMut()) -> Summary {
    f(); // warm-up
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// One machine-readable bench record: `speedup_vs_bitwise` is the bitwise
/// blocked arm's median over this arm's (so the bitwise arm itself reads
/// 1.0 and faster arms read > 1.0).
fn record(case: &str, arm: &str, t: &Summary, bitwise: &Summary) -> JsonValue {
    JsonValue::Object(vec![
        ("case".into(), JsonValue::String(case.into())),
        ("arm".into(), JsonValue::String(arm.into())),
        ("ns_per_iter".into(), JsonValue::Number(t.median * 1e9)),
        ("speedup_vs_bitwise".into(), JsonValue::Number(bitwise.median / t.median.max(1e-12))),
    ])
}

/// Largest relative elementwise deviation of `got` from `want`
/// (denominator floored at 1 so near-zero entries compare absolutely).
fn max_rel_err(got: &[f64], want: &[f64]) -> f64 {
    got.iter()
        .zip(want)
        .map(|(a, b)| (a - b).abs() / b.abs().max(1.0))
        .fold(0.0, f64::max)
}

/// The fast tier trades bitwise reproducibility for speed, not accuracy:
/// per-lane contractions stay in ascending index order, so deviation from
/// the scalar reference is rounding-level. The bench refuses to report a
/// speedup for rows that drift beyond this.
const FAST_REL_TOL: f64 = 1e-9;

/// One blocked-vs-scalar tape case: the Jacobian workload (dual-carrying
/// forward + row-seeded reverse per point) over `n_pts` points on one
/// thread, via the scalar reference, the blocked single-point entry, and
/// the point-block entry. Seeds mirror the interior residual rows:
/// `γ ≡ −1` on the order-2 coordinates, `β_t = 1` for heat.
fn bench_tape_case(
    label: &str,
    arch: &[usize],
    n_pts: usize,
    orders: DualOrder,
    heat: bool,
    reps: usize,
    records: &mut Vec<JsonValue>,
) {
    let np = param_count(arch);
    let d = arch[0];
    let (nc, nc2) = (orders.first, orders.second);
    let mut rng = Rng::seed_from(0xB10C);
    let theta = init_params(arch, &mut rng);
    let mut xs = vec![0.0; n_pts * d];
    rng.fill_uniform(&mut xs, 0.05, 0.95);

    let alpha = vec![0.0; n_pts];
    let mut beta = vec![0.0; n_pts * nc];
    let gamma = vec![-1.0; n_pts * nc2];
    if heat {
        for b in 0..n_pts {
            beta[b * nc + nc - 1] = 1.0;
        }
    }
    // Scalar API carries full second order on all nc coordinates; the
    // dual-order mask is emulated with zero γ padding.
    let mut gref = vec![0.0; nc];
    gref[..nc2].fill(-1.0);

    let mut j = vec![0.0; n_pts * np];
    let mut scalar = ScalarTape::new(arch);
    let mut tape = Tape::new(arch);

    // Bitwise cross-check once, outside the timed loops.
    let mut j_ref = vec![0.0; n_pts * np];
    for b in 0..n_pts {
        scalar.forward(&theta, &xs[b * d..(b + 1) * d], nc);
        scalar.backward(
            &theta,
            0.0,
            &beta[b * nc..(b + 1) * nc],
            &gref,
            &mut j_ref[b * np..(b + 1) * np],
        );
    }
    let block = tape.block_points(orders);
    let mut p = 0;
    while p < n_pts {
        let n = block.min(n_pts - p);
        tape.forward_batch(&theta, &xs[p * d..(p + n) * d], n, orders);
        tape.backward_batch(
            &theta,
            n,
            &alpha[p..p + n],
            &beta[p * nc..(p + n) * nc],
            &gamma[p * nc2..(p + n) * nc2],
            &mut j[p * np..(p + n) * np],
        );
        p += n;
    }
    let bitwise = j.iter().zip(&j_ref).all(|(a, b)| a.to_bits() == b.to_bits());
    let cross_check = if bitwise {
        "rows bitwise==scalar"
    } else {
        "ROWS DIVERGE FROM SCALAR"
    };

    let scalar_t = time_reps(reps, || {
        j.fill(0.0);
        for b in 0..n_pts {
            scalar.forward(&theta, &xs[b * d..(b + 1) * d], nc);
            scalar.backward(
                &theta,
                0.0,
                &beta[b * nc..(b + 1) * nc],
                &gref,
                &mut j[b * np..(b + 1) * np],
            );
        }
        black_box(j[0]);
    });
    let single_t = time_reps(reps, || {
        j.fill(0.0);
        for b in 0..n_pts {
            tape.forward(&theta, &xs[b * d..(b + 1) * d], orders);
            tape.backward(
                &theta,
                0,
                0.0,
                &beta[b * nc..(b + 1) * nc],
                &gamma[b * nc2..(b + 1) * nc2],
                &mut j[b * np..(b + 1) * np],
            );
        }
        black_box(j[0]);
    });
    let batch_t = time_reps(reps, || {
        j.fill(0.0);
        let mut p = 0;
        while p < n_pts {
            let n = block.min(n_pts - p);
            tape.forward_batch(&theta, &xs[p * d..(p + n) * d], n, orders);
            tape.backward_batch(
                &theta,
                n,
                &alpha[p..p + n],
                &beta[p * nc..(p + n) * nc],
                &gamma[p * nc2..(p + n) * nc2],
                &mut j[p * np..(p + n) * np],
            );
            p += n;
        }
        black_box(j[0]);
    });

    // Fast-tier arm: same workload through the relaxed-numerics kernels
    // (FMA + reassociated panel reductions, wider point-blocks), checked
    // against the scalar reference within tolerance rather than bitwise.
    let mut fast = Tape::with_numerics(arch, NumericsMode::Fast);
    let fast_block = fast.block_points(orders);
    let run_fast = |fast: &mut Tape, jf: &mut [f64]| {
        let mut p = 0;
        while p < n_pts {
            let n = fast_block.min(n_pts - p);
            fast.forward_batch(&theta, &xs[p * d..(p + n) * d], n, orders);
            fast.backward_batch(
                &theta,
                n,
                &alpha[p..p + n],
                &beta[p * nc..(p + n) * nc],
                &gamma[p * nc2..(p + n) * nc2],
                &mut jf[p * np..(p + n) * np],
            );
            p += n;
        }
    };
    let mut jf = vec![0.0; n_pts * np];
    run_fast(&mut fast, &mut jf);
    let fast_err = max_rel_err(&jf, &j_ref);
    let fast_check = if fast_err <= FAST_REL_TOL {
        format!("fast rel err {fast_err:.1e}")
    } else {
        format!("FAST ROWS DRIFT ({fast_err:.1e} > {FAST_REL_TOL:.0e})")
    };
    let fast_t = time_reps(reps, || {
        jf.fill(0.0);
        run_fast(&mut fast, &mut jf);
        black_box(jf[0]);
    });

    println!(
        "tape {label:<16} scalar {:>8.3}ms  single {:>8.3}ms ({:.2}x)  \
         block[{block}] {:>8.3}ms ({:.2}x)  fast[{fast_block}/{}] {:>8.3}ms \
         ({:.2}x vs block)  {cross_check}, {fast_check}",
        scalar_t.median * 1e3,
        single_t.median * 1e3,
        scalar_t.median / single_t.median.max(1e-12),
        batch_t.median * 1e3,
        scalar_t.median / batch_t.median.max(1e-12),
        fast.tier().name(),
        fast_t.median * 1e3,
        batch_t.median / fast_t.median.max(1e-12),
    );
    let case = format!("tape/{label}");
    records.push(record(&case, "scalar", &scalar_t, &batch_t));
    records.push(record(&case, "single", &single_t, &batch_t));
    records.push(record(&case, "block", &batch_t, &batch_t));
    if fast_err <= FAST_REL_TOL {
        records.push(record(&case, "fast", &fast_t, &batch_t));
    }
}

/// One fused-vs-per-point *backward* case: forward state is prepared once
/// per block (outside the timed region, one tape per block), then the
/// timed loops run only the reverse passes — per-point [`Tape::backward`]
/// calls vs one fused [`Tape::backward_batch`] adjoint-panel sweep per
/// block, writing the same contiguous J sub-blocks. Seeds mirror the
/// interior residual rows (`γ ≡ −1`, `β_t = 1` for heat).
fn bench_backward_case(
    label: &str,
    arch: &[usize],
    n_pts: usize,
    orders: DualOrder,
    heat: bool,
    reps: usize,
    records: &mut Vec<JsonValue>,
) {
    let np = param_count(arch);
    let d = arch[0];
    let (nc, nc2) = (orders.first, orders.second);
    let mut rng = Rng::seed_from(0xFACE);
    let theta = init_params(arch, &mut rng);
    let mut xs = vec![0.0; n_pts * d];
    rng.fill_uniform(&mut xs, 0.05, 0.95);

    let alpha = vec![0.0; n_pts];
    let mut beta = vec![0.0; n_pts * nc];
    let gamma = vec![-1.0; n_pts * nc2];
    if heat {
        for b in 0..n_pts {
            beta[b * nc + nc - 1] = 1.0;
        }
    }

    // One tape per block, forwarded once: the timed region is reverse-only.
    let block = Tape::new(arch).block_points(orders);
    let mut blocks: Vec<(usize, usize, Tape)> = Vec::new();
    let mut p = 0;
    while p < n_pts {
        let n = block.min(n_pts - p);
        let mut tape = Tape::new(arch);
        tape.forward_batch(&theta, &xs[p * d..(p + n) * d], n, orders);
        blocks.push((p, n, tape));
        p += n;
    }

    // Bitwise cross-check once, outside the timed loops.
    let mut j = vec![0.0; n_pts * np];
    let mut j_ref = vec![0.0; n_pts * np];
    for (p0, n, tape) in blocks.iter_mut() {
        for b in 0..*n {
            let r = *p0 + b;
            tape.backward(
                &theta,
                b,
                alpha[r],
                &beta[r * nc..(r + 1) * nc],
                &gamma[r * nc2..(r + 1) * nc2],
                &mut j_ref[r * np..(r + 1) * np],
            );
        }
        tape.backward_batch(
            &theta,
            *n,
            &alpha[*p0..*p0 + *n],
            &beta[*p0 * nc..(*p0 + *n) * nc],
            &gamma[*p0 * nc2..(*p0 + *n) * nc2],
            &mut j[*p0 * np..(*p0 + *n) * np],
        );
    }
    let bitwise = j.iter().zip(&j_ref).all(|(a, b)| a.to_bits() == b.to_bits());
    let cross_check = if bitwise {
        "rows bitwise==per-point"
    } else {
        "ROWS DIVERGE FROM PER-POINT"
    };

    let per_point_t = time_reps(reps, || {
        j.fill(0.0);
        for (p0, n, tape) in blocks.iter_mut() {
            for b in 0..*n {
                let r = *p0 + b;
                tape.backward(
                    &theta,
                    b,
                    alpha[r],
                    &beta[r * nc..(r + 1) * nc],
                    &gamma[r * nc2..(r + 1) * nc2],
                    &mut j[r * np..(r + 1) * np],
                );
            }
        }
        black_box(j[0]);
    });
    let fused_t = time_reps(reps, || {
        j.fill(0.0);
        for (p0, n, tape) in blocks.iter_mut() {
            tape.backward_batch(
                &theta,
                *n,
                &alpha[*p0..*p0 + *n],
                &beta[*p0 * nc..(*p0 + *n) * nc],
                &gamma[*p0 * nc2..(*p0 + *n) * nc2],
                &mut j[*p0 * np..(*p0 + *n) * np],
            );
        }
        black_box(j[0]);
    });

    // Fast-tier fused arm: forwarded once through the fast kernels (its
    // wider blocks re-partition the batch), timed reverse-only like the
    // bitwise arms, checked against the per-point rows within tolerance.
    let mut fast_blocks: Vec<(usize, usize, Tape)> = Vec::new();
    let fast_block = Tape::with_numerics(arch, NumericsMode::Fast).block_points(orders);
    let mut p = 0;
    while p < n_pts {
        let n = fast_block.min(n_pts - p);
        let mut tape = Tape::with_numerics(arch, NumericsMode::Fast);
        tape.forward_batch(&theta, &xs[p * d..(p + n) * d], n, orders);
        fast_blocks.push((p, n, tape));
        p += n;
    }
    let mut jf = vec![0.0; n_pts * np];
    let mut run_fast = |jf: &mut [f64]| {
        for (p0, n, tape) in fast_blocks.iter_mut() {
            tape.backward_batch(
                &theta,
                *n,
                &alpha[*p0..*p0 + *n],
                &beta[*p0 * nc..(*p0 + *n) * nc],
                &gamma[*p0 * nc2..(*p0 + *n) * nc2],
                &mut jf[*p0 * np..(*p0 + *n) * np],
            );
        }
    };
    run_fast(&mut jf);
    let fast_err = max_rel_err(&jf, &j_ref);
    let fast_check = if fast_err <= FAST_REL_TOL {
        format!("fast rel err {fast_err:.1e}")
    } else {
        format!("FAST ROWS DRIFT ({fast_err:.1e} > {FAST_REL_TOL:.0e})")
    };
    let fast_t = time_reps(reps, || {
        jf.fill(0.0);
        run_fast(&mut jf);
        black_box(jf[0]);
    });

    println!(
        "backward {label:<20} per-point {:>8.3}ms  fused[{block}] {:>8.3}ms  ({:.2}x)  \
         fast[{fast_block}] {:>8.3}ms ({:.2}x vs fused)  {cross_check}, {fast_check}",
        per_point_t.median * 1e3,
        fused_t.median * 1e3,
        per_point_t.median / fused_t.median.max(1e-12),
        fast_t.median * 1e3,
        fused_t.median / fast_t.median.max(1e-12),
    );
    let case = format!("backward/{label}");
    records.push(record(&case, "per-point", &per_point_t, &fused_t));
    records.push(record(&case, "fused", &fused_t, &fused_t));
    if fast_err <= FAST_REL_TOL {
        records.push(record(&case, "fused-fast", &fast_t, &fused_t));
    }
}

/// The previous substrate, reproduced as a baseline: fresh scoped threads
/// per call, same chunk grid as `parallel::par_chunks`.
fn scoped_spawn_chunks(n: usize, workers: usize, f: impl Fn(usize, usize) + Sync) {
    if workers <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

fn main() {
    let threads = engd::parallel::num_threads();
    println!("threads: {threads}  (fast tier dispatches {})", SimdTier::detect().name());
    let mut records: Vec<JsonValue> = Vec::new();

    // --- dispatch overhead: pool vs scoped spawn -------------------------
    //
    // Work item: sum a strided range (enough arithmetic that the compiler
    // can't erase it, little enough that dispatch cost dominates at small n).
    for n in [1_000usize, 100_000, 10_000_000] {
        let acc = AtomicUsize::new(0);
        let body = |s: usize, e: usize| {
            let mut local = 0usize;
            for i in s..e {
                local = local.wrapping_add(i ^ (i >> 3));
            }
            acc.fetch_add(local, Ordering::Relaxed);
        };
        let reps = if n >= 10_000_000 { 20 } else { 500 };
        let pool = time_reps(reps, || engd::parallel::par_chunks(n, body));
        let scoped = time_reps(reps, || scoped_spawn_chunks(n, threads, body));
        black_box(acc.load(Ordering::Relaxed));
        println!(
            "par_chunks n={n:<9} pool {:>10.2}us  scoped-spawn {:>10.2}us  ({:.1}x)",
            pool.median * 1e6,
            scoped.median * 1e6,
            scoped.median / pool.median.max(1e-12),
        );
    }
    let stats = engd::parallel::pool_stats();
    println!(
        "pool stats: {} threads spawned, {} dispatches, {} serial fallbacks",
        stats.threads_spawned, stats.dispatches, stats.serial_fallbacks
    );

    // --- tape reuse on the native backend --------------------------------
    //
    // Steady-state repeated evaluations (line-search pattern). The first
    // call per problem pays the tape builds; every later call must reuse.
    let be = NativeBackend::new();
    for problem in ["poisson2d", "poisson10d"] {
        let p = be.problem(problem).unwrap();
        let mut rng = Rng::seed_from(42);
        let theta = init_params(&p.arch, &mut rng);
        let mut sampler = Sampler::new(p.dim, 7);
        let x_int = sampler.interior(p.n_interior);
        let x_bnd = sampler.boundary(p.n_boundary);

        let builds_before = engd::backend::native::tape_builds();
        let t0 = Instant::now();
        black_box(be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap());
        let cold = t0.elapsed().as_secs_f64();
        let cold_builds = engd::backend::native::tape_builds() - builds_before;

        let after_cold = engd::backend::native::tape_builds();
        let warm_grad = time_reps(10, || {
            black_box(be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap());
        });
        let warm_loss = time_reps(20, || {
            black_box(be.loss(&p, &theta, &x_int, &x_bnd).unwrap());
        });
        let steady_builds = engd::backend::native::tape_builds() - after_cold;
        println!(
            "{problem:<12} loss_and_grad cold {:>9.3}ms ({cold_builds} tape builds)  \
             warm {:>9.3}ms  loss warm {:>9.3}ms  (steady-state builds: {steady_builds})",
            cold * 1e3,
            warm_grad.median * 1e3,
            warm_loss.median * 1e3,
        );
    }

    // --- blocked vs scalar tape kernels (single thread) ------------------
    //
    // The Jacobian workload per point: dual-carrying forward + row-seeded
    // reverse. The PR-4 acceptance case is the [2, 64, 64, 1] net at batch
    // 512 (blocked batch must be ≥ 2× the scalar tape).
    let arch10d: &[usize] = &[10, 96, 96, 64, 64, 1];
    let heat_orders = PdeOperator::Heat.dual_orders(3);
    let r = &mut records;
    bench_tape_case("poisson2d-b512", &[2, 64, 64, 1], 512, DualOrder::full(2), false, 20, r);
    bench_tape_case("poisson10d-b128", arch10d, 128, DualOrder::full(10), false, 5, r);
    bench_tape_case("heat2d-b192", &[3, 48, 48, 1], 192, heat_orders, true, 20, r);

    // --- fused vs per-point backward (reverse pass only) -----------------
    //
    // The PR-5 acceptance case is the wide poisson2d net at batch 512:
    // the fused adjoint-panel backward must be ≥ 1.5× the per-point
    // blocked backward with bitwise-identical Jacobian rows.
    bench_backward_case("poisson2d-b512", &[2, 64, 64, 1], 512, DualOrder::full(2), false, 20, r);
    bench_backward_case(
        "poisson2d-b512-wide",
        &[2, 128, 128, 1],
        512,
        DualOrder::full(2),
        false,
        10,
        r,
    );
    bench_backward_case("poisson10d-b128", arch10d, 128, DualOrder::full(10), false, 5, r);
    bench_backward_case("heat2d-b192", &[3, 48, 48, 1], 192, heat_orders, true, 20, r);

    // --- machine-readable dump -------------------------------------------
    let out = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("parallel_micro".into())),
        ("threads".into(), JsonValue::Number(threads as f64)),
        ("simd_tier".into(), JsonValue::String(SimdTier::detect().name().into())),
        ("records".into(), JsonValue::Array(records)),
    ]);
    let path = "BENCH_parallel_micro.json";
    match std::fs::write(path, json::to_string(&out) + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
