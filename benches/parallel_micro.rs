//! Worker-pool microbenchmarks — the §Perf harness for the execution
//! substrate itself.
//!
//! Two questions the pool refactor must answer with numbers:
//!
//! 1. **Dispatch overhead**: what does handing a job to parked workers cost
//!    versus spawning fresh scoped threads per call (the previous
//!    substrate), across job granularities?
//! 2. **Tape reuse**: what does keeping per-worker `Tape` state alive
//!    across calls buy on repeated native `loss_and_grad` / line-search
//!    style `loss` evaluations (cold first call vs steady state)?

use std::hint::black_box;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use engd::backend::{Evaluator, NativeBackend};
use engd::metrics::Summary;
use engd::pde::{init_params, Sampler};
use engd::rng::Rng;

fn time_reps(reps: usize, mut f: impl FnMut()) -> Summary {
    f(); // warm-up
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// The previous substrate, reproduced as a baseline: fresh scoped threads
/// per call, same chunk grid as `parallel::par_chunks`.
fn scoped_spawn_chunks(n: usize, workers: usize, f: impl Fn(usize, usize) + Sync) {
    if workers <= 1 || n == 0 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

fn main() {
    let threads = engd::parallel::num_threads();
    println!("threads: {threads}");

    // --- dispatch overhead: pool vs scoped spawn -------------------------
    //
    // Work item: sum a strided range (enough arithmetic that the compiler
    // can't erase it, little enough that dispatch cost dominates at small n).
    for n in [1_000usize, 100_000, 10_000_000] {
        let acc = AtomicUsize::new(0);
        let body = |s: usize, e: usize| {
            let mut local = 0usize;
            for i in s..e {
                local = local.wrapping_add(i ^ (i >> 3));
            }
            acc.fetch_add(local, Ordering::Relaxed);
        };
        let reps = if n >= 10_000_000 { 20 } else { 500 };
        let pool = time_reps(reps, || engd::parallel::par_chunks(n, body));
        let scoped = time_reps(reps, || scoped_spawn_chunks(n, threads, body));
        black_box(acc.load(Ordering::Relaxed));
        println!(
            "par_chunks n={n:<9} pool {:>10.2}us  scoped-spawn {:>10.2}us  ({:.1}x)",
            pool.median * 1e6,
            scoped.median * 1e6,
            scoped.median / pool.median.max(1e-12),
        );
    }
    let stats = engd::parallel::pool_stats();
    println!(
        "pool stats: {} threads spawned, {} dispatches, {} serial fallbacks",
        stats.threads_spawned, stats.dispatches, stats.serial_fallbacks
    );

    // --- tape reuse on the native backend --------------------------------
    //
    // Steady-state repeated evaluations (line-search pattern). The first
    // call per problem pays the tape builds; every later call must reuse.
    let be = NativeBackend::new();
    for problem in ["poisson2d", "poisson10d"] {
        let p = be.problem(problem).unwrap();
        let mut rng = Rng::seed_from(42);
        let theta = init_params(&p.arch, &mut rng);
        let mut sampler = Sampler::new(p.dim, 7);
        let x_int = sampler.interior(p.n_interior);
        let x_bnd = sampler.boundary(p.n_boundary);

        let builds_before = engd::backend::native::tape_builds();
        let t0 = Instant::now();
        black_box(be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap());
        let cold = t0.elapsed().as_secs_f64();
        let cold_builds = engd::backend::native::tape_builds() - builds_before;

        let after_cold = engd::backend::native::tape_builds();
        let warm_grad = time_reps(10, || {
            black_box(be.loss_and_grad(&p, &theta, &x_int, &x_bnd).unwrap());
        });
        let warm_loss = time_reps(20, || {
            black_box(be.loss(&p, &theta, &x_int, &x_bnd).unwrap());
        });
        let steady_builds = engd::backend::native::tape_builds() - after_cold;
        println!(
            "{problem:<12} loss_and_grad cold {:>9.3}ms ({cold_builds} tape builds)  \
             warm {:>9.3}ms  loss warm {:>9.3}ms  (steady-state builds: {steady_builds})",
            cold * 1e3,
            warm_grad.median * 1e3,
            warm_loss.median * 1e3,
        );
    }
}
