//! Figure 6: effective dimension of the regularized kernel over training.
//!
//! (a) ENGD-W on the 5d problem and (b) SPRING on the 100d problem, tracking
//! d_eff(K)/N at the paper's tuned dampings. Expected shape (paper): the
//! ratio plateaus above ~50% of N — too high for a 10% sketch to be
//! accurate, which is the paper's explanation for randomization's limits.

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, run_arms, Arm};
use engd::config::run::{ExecPath, OptimizerKind};
use engd::config::OptimizerConfig;

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(25.0);

    let arms = vec![
        // Fig. 6a: ENGD-W, 5d, line search (paper damping 3.17e-12 makes the
        // kernel essentially unregularized — d_eff ≈ N; we report the paper's
        // plot damping 1e-8 alongside in the CSV via diagnostics).
        Arm::new("fig6a-engd_w-5d", "poisson5d", OptimizerConfig {
            kind: OptimizerKind::EngdW,
            damping: 1e-8,
            line_search: true,
            path: ExecPath::Decomposed,
            ..OptimizerConfig::default()
        }),
        // Fig. 6b: SPRING, 100d (N = 160 here vs the paper's 150).
        Arm::new("fig6b-spring-100d", "poisson100d", OptimizerConfig {
            kind: OptimizerKind::Spring,
            damping: 3.0116e-2,
            momentum: 6.76335e-1,
            line_search: true,
            path: ExecPath::Decomposed,
            ..OptimizerConfig::default()
        }),
    ];
    let reports = run_arms("fig6", backend.as_ref(), &arms, budget, 100_000);

    println!("\n=== Fig. 6 — d_eff/N over training (diagnostics every 5 steps) ===");
    for (arm, rep) in arms.iter().zip(&reports) {
        let Some(_r) = rep else { continue };
        let path = format!("results/bench/fig6/{}.csv", arm.tag);
        let text = std::fs::read_to_string(&path)?;
        let mut ratios = Vec::new();
        let mut header_cols: Vec<String> = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let cols: Vec<&str> = line.split(',').collect();
            if i == 0 {
                header_cols = cols.iter().map(|s| s.to_string()).collect();
                continue;
            }
            if let Some(idx) = header_cols.iter().position(|c| c == "d_eff_ratio") {
                if let Some(v) = cols.get(idx).and_then(|s| s.parse::<f64>().ok()) {
                    let step: usize = cols[0].parse().unwrap_or(0);
                    ratios.push((step, v));
                }
            }
        }
        println!("\n{} — d_eff/N trajectory ({} samples):", arm.tag, ratios.len());
        for (step, v) in &ratios {
            let bar = "#".repeat((v * 40.0).round() as usize);
            println!("  step {step:>5}  {v:>6.3}  {bar}");
        }
        if let Some((_, last)) = ratios.last() {
            println!(
                "  final d_eff/N = {last:.3} (paper: plateaus above 0.5 — a 10% \
                 sketch cannot capture the kernel)"
            );
        }
    }
    Ok(())
}
