//! Shared bench harness (criterion is unavailable offline; `cargo bench`
//! runs these as `harness = false` binaries).
//!
//! Conventions:
#![allow(dead_code)] // shared across several bench binaries; not all use every helper
//! * every bench gives each optimizer arm the SAME wall-clock budget, the
//!   paper's protocol (§4: "each optimizer is given an equal compute time
//!   budget on the same fixed PINN task");
//! * budgets scale via `ENGD_BENCH_BUDGET` (seconds per arm, default 20);
//! * each arm's full trajectory lands in `results/bench/<bench>/<arm>.csv`,
//!   and the bench prints the paper-figure summary table to stdout.

use std::time::Instant;

use engd::backend::Evaluator;
use engd::config::{OptimizerConfig, RunConfig};
use engd::coordinator::{train, TrainReport};

pub fn budget_seconds(default: f64) -> f64 {
    engd::config::envvars::read("ENGD_BENCH_BUDGET")
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// The bench backend: `ENGD_BACKEND` env override
/// (pjrt|native|sharded[:n]|auto), else auto — PJRT over `artifacts/` when
/// a usable manifest exists, otherwise the pure-Rust native backend (so
/// every bench runs offline too). `sharded:n` exercises the batch-sharded
/// composite, bitwise-identical to native.
pub fn backend() -> anyhow::Result<Box<dyn Evaluator>> {
    let kind = engd::config::envvars::read("ENGD_BACKEND").unwrap_or_else(|| "auto".into());
    let be = engd::backend::select(&kind, "artifacts")?;
    println!("[bench] backend: {}", be.backend_name());
    Ok(be)
}

/// One bench arm: a named optimizer config on a problem.
pub struct Arm {
    pub tag: String,
    pub problem: String,
    pub optimizer: OptimizerConfig,
}

impl Arm {
    pub fn new(tag: &str, problem: &str, optimizer: OptimizerConfig) -> Self {
        Arm {
            tag: tag.to_string(),
            problem: problem.to_string(),
            optimizer,
        }
    }
}

/// Run every arm under an equal time budget; returns reports in arm order.
/// Arms that fail (e.g. OOM-guard refusals) are reported as None with the
/// error printed — a legitimate outcome (the paper's dense ENGD also OOMs).
pub fn run_arms(
    bench: &str,
    eval: &dyn Evaluator,
    arms: &[Arm],
    budget_s: f64,
    max_steps: usize,
) -> Vec<Option<TrainReport>> {
    let mut out = Vec::new();
    for arm in arms {
        let cfg = RunConfig {
            name: arm.tag.clone(),
            problem: arm.problem.clone(),
            steps: max_steps,
            eval_every: 5,
            time_budget_s: budget_s,
            out_dir: format!("results/bench/{bench}"),
            optimizer: arm.optimizer.clone(),
            ..RunConfig::default()
        };
        cfg.optimizer.validate().expect("arm config");
        println!("\n--- arm: {} on {} (budget {budget_s:.0}s) ---", arm.tag, arm.problem);
        let t0 = Instant::now();
        match train(cfg, eval, false) {
            Ok(r) => {
                println!(
                    "    {} steps in {:.1}s — best L2 {:.3e}, final loss {:.3e}",
                    r.steps_done,
                    t0.elapsed().as_secs_f64(),
                    r.best_l2,
                    r.final_loss
                );
                out.push(Some(r));
            }
            Err(e) => {
                println!("    FAILED (recorded as such): {e:#}");
                out.push(None);
            }
        }
    }
    out
}

/// Print the standard comparison table for a set of finished arms.
pub fn print_table(title: &str, arms: &[Arm], reports: &[Option<TrainReport>]) {
    println!("\n=== {title} ===");
    println!(
        "{:<26} {:>7} {:>9} {:>11} {:>11} {:>12}",
        "arm", "steps", "wall[s]", "best L2", "t(L2<=1e-1)", "t(L2<=1e-2)"
    );
    for (arm, rep) in arms.iter().zip(reports) {
        match rep {
            Some(r) => {
                let t1 = time_to(r, 1e-1);
                let t2 = time_to(r, 1e-2);
                println!(
                    "{:<26} {:>7} {:>9.1} {:>11.3e} {:>11} {:>12}",
                    arm.tag,
                    r.steps_done,
                    r.wall_s,
                    r.best_l2,
                    t1.map_or("-".into(), |t| format!("{t:.1}s")),
                    t2.map_or("-".into(), |t| format!("{t:.1}s")),
                );
            }
            None => println!("{:<26} {:>7}", arm.tag, "FAILED"),
        }
    }
}

pub fn time_to(r: &TrainReport, thr: f64) -> Option<f64> {
    r.time_to
        .iter()
        .find(|(t, _)| (*t - thr).abs() < 1e-12)
        .map(|(_, s)| *s)
}

/// Speedup factor between two arms at the tightest threshold both reached —
/// the §5 headline metric ("same L2 error up to 75× faster").
pub fn speedup_at_equal_l2(slow: &TrainReport, fast: &TrainReport) -> Option<(f64, f64)> {
    for thr in [1e-4, 1e-3, 1e-2, 1e-1] {
        if let (Some(ts), Some(tf)) = (time_to(slow, thr), time_to(fast, thr)) {
            if tf > 0.0 {
                return Some((thr, ts / tf));
            }
        }
    }
    None
}
