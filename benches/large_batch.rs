//! Large-batch dual-space solve scaling: poisson2d at batch sizes up to
//! 40960 — 10× the previous `gpu_efficient` ceiling (4096).
//!
//! The paper's Woodbury move (eq. 5) puts the solve in sample space, so the
//! batch size N is the axis that stresses it. This bench trains the scaled
//! `poisson2d_n{N}` ladder through the pooled matrix-free tier — Nyström
//! sketches `Y = J(JᵀΩ)` and PCG matvecs `J(Jᵀv)` never form the N×N
//! kernel, and every loop buffer is drawn from the step workspace — and
//! reports wall-clock scaling (seconds/step vs N) for
//!
//! * ENGD-W + GPU-efficient Nyström (sketch-and-solve, Alg. 2), and
//! * SPRING + Nyström-PCG (sketch-and-precondition, §3.3),
//!
//! writing the machine-readable summary to `BENCH_large_batch.json`.
//! The sketch size is capped at 512 columns so the tall factors stay
//! O(N·ℓ) as N grows; per-arm budgets scale via `ENGD_BENCH_BUDGET`.

#[path = "common/mod.rs"]
mod common;

use common::{budget_seconds, print_table, run_arms, Arm};
use engd::config::json::{self, JsonValue};
use engd::config::run::{ExecPath, OptimizerKind, SolveMode};
use engd::config::OptimizerConfig;

/// Sketch ℓ ≈ min(10% of N, 512) expressed as the ratio the config wants.
fn capped_sketch_ratio(n: usize) -> f64 {
    let ell = (n / 10).clamp(64, 512);
    ell as f64 / n as f64
}

fn main() -> anyhow::Result<()> {
    let backend = common::backend()?;
    let budget = budget_seconds(15.0);
    let ladder = [4096usize, 8192, 16384, 40960];

    let mut records: Vec<JsonValue> = Vec::new();
    for &n in &ladder {
        let problem = format!("poisson2d_n{n}");
        let ratio = capped_sketch_ratio(n);
        let arms = vec![
            Arm::new(
                "engd_w-nystrom_gpu",
                &problem,
                OptimizerConfig {
                    kind: OptimizerKind::EngdW,
                    damping: 1e-6,
                    line_search: true,
                    solve: SolveMode::NystromGpu,
                    sketch_ratio: ratio,
                    path: ExecPath::Decomposed,
                    ..OptimizerConfig::default()
                },
            ),
            Arm::new(
                "spring-nystrom_pcg",
                &problem,
                OptimizerConfig {
                    kind: OptimizerKind::Spring,
                    damping: 1e-6,
                    momentum: 0.9,
                    line_search: true,
                    solve: SolveMode::NystromPcg,
                    sketch_ratio: ratio,
                    cg_iters: 20,
                    cg_tol: 1e-8,
                    path: ExecPath::Decomposed,
                    ..OptimizerConfig::default()
                },
            ),
        ];
        let tag = format!("large-batch-{problem}");
        let reports = run_arms(&tag, backend.as_ref(), &arms, budget, 100_000);
        print_table(
            &format!(
                "Large batch — {problem} (N = {n}, sketch ℓ ≈ {:.0}): pooled \
                 dual-space solves, wall-clock scaling",
                ratio * n as f64
            ),
            &arms,
            &reports,
        );
        for (arm, rep) in arms.iter().zip(&reports) {
            let mut rec = vec![
                ("problem".into(), JsonValue::String(problem.clone())),
                ("batch".into(), JsonValue::Number(n as f64)),
                ("arm".into(), JsonValue::String(arm.tag.clone())),
                ("sketch_ratio".into(), JsonValue::Number(ratio)),
            ];
            match rep {
                Some(r) => {
                    let s_per_step = if r.steps_done > 0 {
                        r.wall_s / r.steps_done as f64
                    } else {
                        f64::NAN
                    };
                    rec.push(("steps".into(), JsonValue::Number(r.steps_done as f64)));
                    rec.push(("wall_s".into(), JsonValue::Number(r.wall_s)));
                    rec.push(("s_per_step".into(), JsonValue::Number(s_per_step)));
                    rec.push(("best_l2".into(), JsonValue::Number(r.best_l2)));
                    rec.push(("final_loss".into(), JsonValue::Number(r.final_loss)));
                }
                None => rec.push(("failed".into(), JsonValue::Bool(true))),
            }
            records.push(JsonValue::Object(rec));
        }
    }

    // Wall-clock scaling summary: seconds/step vs batch, per arm.
    println!("\n=== wall-clock scaling (s/step vs N) ===");
    for rec in &records {
        let num = |k: &str| rec.get(k).and_then(JsonValue::as_f64);
        if let (Some(arm), Some(n), Some(sps)) = (
            rec.get("arm").and_then(JsonValue::as_str),
            num("batch"),
            num("s_per_step"),
        ) {
            println!("{arm:<22} N={n:>6.0}  {sps:>9.4} s/step");
        }
    }

    let out = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("large_batch".into())),
        (
            "ladder".into(),
            JsonValue::Array(ladder.iter().map(|&n| JsonValue::Number(n as f64)).collect()),
        ),
        ("records".into(), JsonValue::Array(records)),
    ]);
    let path = "BENCH_large_batch.json";
    match std::fs::write(path, json::to_string(&out) + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    Ok(())
}
