//! Shard-executor scaling: work stealing vs static range splits, threads
//! vs worker processes, on uniform and boundary-heavy collocation batches.
//!
//! The batch layout is interior-rows-then-boundary-rows, and interior rows
//! (second-order duals for the Laplacian) cost an order of magnitude more
//! than boundary rows (a plain forward pass). A *static* contiguous split
//! therefore piles all the expensive rows onto the first shard(s) of a
//! boundary-heavy batch and stalls on that straggler, while the
//! work-stealing scheduler lets drained shards pull the straggler's
//! sub-ranges. This bench times `residuals_jacobian` (the N×P row sweep
//! that dominates ENGD-W/SPRING steps) across
//!
//! * batch shapes: uniform (all interior) vs boundary-heavy (1/8 interior),
//! * executor tiers: in-process threads vs out-of-process workers,
//! * schedules: static vs work stealing,
//!
//! at 8 shards, cross-checks every arm bitwise against the unsharded
//! native backend, prints the steal-vs-static speedups, and writes the
//! machine-readable summary to `BENCH_shard_scale.json`.
//!
//! Like the test suite, this binary doubles as its own shard worker: the
//! process tier respawns it with `--shard-worker`, which `main` answers
//! before any benchmarking output can touch stdout.

use std::time::Instant;

use engd::backend::{
    Evaluator, NativeBackend, ProcessEvaluator, ProcessOptions, Schedule, ShardedEvaluator,
};
use engd::config::json::{self, JsonValue};
use engd::linalg::Workspace;
use engd::pde::{init_params, param_count, PdeOperator, ProblemSpec, Sampler};
use engd::rng::Rng;

const SHARDS: usize = 8;
const TOTAL_ROWS: usize = 4096;
const REPS: usize = 3;

/// A poisson2d-family spec with an explicit interior/boundary split (the
/// spec travels with every evaluation call — and, for the process tier,
/// inside every `Eval` frame — so no backend catalogue entry is needed).
fn batch_spec(name: &str, n_interior: usize) -> ProblemSpec {
    let arch = vec![2usize, 32, 32, 1];
    ProblemSpec {
        name: name.to_string(),
        dim: 2,
        n_params: param_count(&arch),
        arch,
        n_interior,
        n_boundary: TOTAL_ROWS - n_interior,
        n_eval: 512,
        interior_weight: 1.0,
        boundary_weight: 1.0,
        pde: "sine_product".to_string(),
        operator: PdeOperator::Poisson,
    }
}

struct BatchCase {
    spec: ProblemSpec,
    theta: Vec<f64>,
    x_int: Vec<f64>,
    x_bnd: Vec<f64>,
}

fn batch_case(name: &str, n_interior: usize, seed: u64) -> BatchCase {
    let spec = batch_spec(name, n_interior);
    let mut rng = Rng::seed_from(seed);
    let theta = init_params(&spec.arch, &mut rng);
    let mut sampler = Sampler::new(spec.dim, seed ^ 0xBE7C);
    let x_int = sampler.interior(spec.n_interior);
    let x_bnd = sampler.boundary(spec.n_boundary);
    BatchCase { spec, theta, x_int, x_bnd }
}

/// One warm-up + bitwise cross-check evaluation, then `REPS` timed ones;
/// returns the best (minimum) seconds per evaluation.
fn time_arm(ev: &dyn Evaluator, case: &BatchCase, r_ref: &[f64], j_ref: &[f64]) -> f64 {
    let mut ws = Workspace::new();
    let (r, j) = ev
        .residuals_jacobian(&case.spec, &case.theta, &case.x_int, &case.x_bnd, &mut ws)
        .expect("warm-up evaluation");
    assert_eq!(r.len(), r_ref.len());
    for (i, (a, b)) in r.iter().zip(r_ref).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "r[{i}] diverges from native");
    }
    for (i, (a, b)) in j.data().iter().zip(j_ref).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "J[{i}] diverges from native");
    }
    ws.recycle_matrix(j);

    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = Instant::now();
        let (_, j) = ev
            .residuals_jacobian(&case.spec, &case.theta, &case.x_int, &case.x_bnd, &mut ws)
            .expect("timed evaluation");
        best = best.min(t0.elapsed().as_secs_f64());
        ws.recycle_matrix(j);
    }
    best
}

fn main() {
    // Worker mode first: the process tier spawns this binary for its shard
    // workers, and nothing may touch stdout before the frame protocol.
    if std::env::args().any(|a| a == "--shard-worker") {
        std::process::exit(match engd::backend::process::worker_main() {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("shard worker error: {e:#}");
                1
            }
        });
    }

    // Uniform: essentially every row is an interior row, so static slices
    // are cost-balanced. Boundary-heavy: the interior rows all land in the
    // first static slice (TOTAL_ROWS/SHARDS rows) — the straggler shape.
    let cases = [
        ("uniform", batch_case("shard_scale_uniform", TOTAL_ROWS - 32, 71)),
        ("boundary_heavy", batch_case("shard_scale_bheavy", TOTAL_ROWS / SHARDS, 72)),
    ];

    let native = NativeBackend::new();
    let mut records: Vec<JsonValue> = Vec::new();
    let mut speedups: Vec<JsonValue> = Vec::new();
    println!("shard_scale: {SHARDS} shards, {TOTAL_ROWS} rows, best of {REPS}\n");
    println!(
        "{:<16} {:<9} {:<8} {:>12} {:>10}",
        "batch", "tier", "schedule", "s/eval", "vs static"
    );

    for (batch, case) in &cases {
        let mut ws = Workspace::new();
        let (r_ref, j_ref) = native
            .residuals_jacobian(&case.spec, &case.theta, &case.x_int, &case.x_bnd, &mut ws)
            .expect("native reference");

        for tier in ["threads", "process"] {
            let mut static_s = f64::NAN;
            for schedule in [Schedule::Static, Schedule::WorkSteal] {
                let secs = match tier {
                    "threads" => {
                        let ev = ShardedEvaluator::new(SHARDS).with_schedule(schedule);
                        time_arm(&ev, case, &r_ref, j_ref.data())
                    }
                    _ => {
                        let ev = ProcessEvaluator::with_options(ProcessOptions {
                            workers: SHARDS,
                            schedule,
                            ..ProcessOptions::default()
                        });
                        time_arm(&ev, case, &r_ref, j_ref.data())
                    }
                };
                let speedup = match schedule {
                    Schedule::Static => {
                        static_s = secs;
                        f64::NAN
                    }
                    Schedule::WorkSteal => static_s / secs,
                };
                let vs = if speedup.is_nan() {
                    "-".to_string()
                } else {
                    format!("{speedup:.2}x")
                };
                println!(
                    "{batch:<16} {tier:<9} {:<8} {secs:>12.4} {vs:>10}",
                    schedule.name()
                );
                records.push(JsonValue::Object(vec![
                    ("batch".into(), JsonValue::String(batch.to_string())),
                    ("tier".into(), JsonValue::String(tier.to_string())),
                    ("schedule".into(), JsonValue::String(schedule.name().to_string())),
                    ("secs_per_eval".into(), JsonValue::Number(secs)),
                    ("reps".into(), JsonValue::Number(REPS as f64)),
                ]));
                if schedule == Schedule::WorkSteal {
                    speedups.push(JsonValue::Object(vec![
                        ("batch".into(), JsonValue::String(batch.to_string())),
                        ("tier".into(), JsonValue::String(tier.to_string())),
                        ("steal_vs_static".into(), JsonValue::Number(speedup)),
                    ]));
                }
            }
        }
        ws.recycle_matrix(j_ref);
    }

    println!("\n=== steal vs static ===");
    for s in &speedups {
        let get = |k: &str| s.get(k).and_then(JsonValue::as_str).unwrap_or("?");
        let x = s.get("steal_vs_static").and_then(JsonValue::as_f64).unwrap_or(f64::NAN);
        println!("{:<16} {:<9} {x:.2}x", get("batch"), get("tier"));
    }
    println!("(target: >= 1.3x on the boundary-heavy batch at {SHARDS} shards)");

    let out = JsonValue::Object(vec![
        ("bench".into(), JsonValue::String("shard_scale".into())),
        ("shards".into(), JsonValue::Number(SHARDS as f64)),
        ("rows".into(), JsonValue::Number(TOTAL_ROWS as f64)),
        ("records".into(), JsonValue::Array(records)),
        ("speedups".into(), JsonValue::Array(speedups)),
    ]);
    let path = "BENCH_shard_scale.json";
    match std::fs::write(path, json::to_string(&out) + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
