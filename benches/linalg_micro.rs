//! Linear-algebra substrate microbenchmarks — the §Perf tracking harness for
//! the L3 hot paths (EXPERIMENTS.md §Perf records the before/after of each
//! optimization iteration).
//!
//! Reports GFLOP/s for the kernels that dominate the decomposed optimizer
//! paths: gram (K = JJᵀ), matmul (sketch products), Cholesky (kernel solve),
//! plus tr_matvec (the Jᵀa map-back).

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use engd::linalg::{Cholesky, Matrix, Workspace};
use engd::metrics::Summary;
use engd::rng::Rng;

fn time_op(tag: &str, flops: f64, reps: usize, mut f: impl FnMut()) {
    // Warm-up.
    f();
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let s = Summary::of(&samples);
    println!(
        "{tag:<34} median {:>8.4}s  {:>7.2} GFLOP/s  (IQR [{:.4}, {:.4}])",
        s.median,
        flops / s.median / 1e9,
        s.q1,
        s.q3
    );
}

fn main() {
    let mut rng = Rng::seed_from(1);
    println!("threads: {}", engd::parallel::num_threads());

    // gram: the ENGD-W kernel build, N×P → N×N (2·N²·P/2 useful flops).
    for (n, p) in [(448, 10_065), (1024, 10_065)] {
        let mut j = Matrix::zeros(n, p);
        rng.fill_normal(j.data_mut());
        time_op(
            &format!("gram      J({n}x{p}) -> K"),
            (n * n) as f64 * p as f64, // symmetric: N²/2 dots of length P → N²P flops
            5,
            || {
                let k = j.gram();
                std::hint::black_box(&k);
            },
        );
    }

    // matmul: sketch product shapes (N×P)(P×S).
    let (n, p, s) = (1024, 10_065, 102);
    let mut a = Matrix::zeros(n, p);
    rng.fill_normal(a.data_mut());
    let mut b = Matrix::zeros(p, s);
    rng.fill_normal(b.data_mut());
    time_op(
        &format!("matmul    ({n}x{p})({p}x{s})"),
        2.0 * (n * p * s) as f64,
        5,
        || {
            let c = a.matmul(&b);
            std::hint::black_box(&c);
        },
    );

    // Cholesky: kernel-solve factorization, N×N.
    for n in [448usize, 1024, 2048] {
        let mut g = Matrix::zeros(n, n / 2);
        rng.fill_normal(g.data_mut());
        let k = g.gram().add_diag(1.0);
        time_op(
            &format!("cholesky  ({n}x{n})"),
            (n as f64).powi(3) / 3.0,
            5,
            || {
                let ch = Cholesky::factor(&k).unwrap();
                std::hint::black_box(&ch);
            },
        );
    }

    // tr_matvec: the Jᵀa map-back, N×P.
    let mut j = Matrix::zeros(1024, 10_065);
    rng.fill_normal(j.data_mut());
    let mut v = vec![0.0; 1024];
    rng.fill_normal(&mut v);
    time_op("tr_matvec Jᵀa (1024x10065)", 2.0 * (1024 * 10_065) as f64, 20, || {
        let y = j.tr_matvec(&v);
        std::hint::black_box(&y);
    });

    // matvec: Jφ (SPRING's ζ shift).
    let mut w = vec![0.0; 10_065];
    rng.fill_normal(&mut w);
    time_op("matvec    Jφ (1024x10065)", 2.0 * (1024 * 10_065) as f64, 20, || {
        let y = j.matvec(&w);
        std::hint::black_box(&y);
    });

    // --- fused vs materialized transpose products ------------------------
    //
    // The kernel-operator layer removed every `transpose()+matmul` from the
    // training path; these pairs keep the win measurable in the bench
    // trajectory. Same shapes as the eq. 9 sketch pipeline.

    // JᵀΩ: the sketch map (N×P)ᵀ(N×S) — the per-step Nyström product.
    let (n, p, s) = (1024usize, 10_065usize, 102usize);
    let mut omega = Matrix::zeros(n, s);
    rng.fill_normal(omega.data_mut());
    let flops_tn = 2.0 * (n * p * s) as f64;
    time_op("JᵀΩ fused     matmul_tn", flops_tn, 5, || {
        let c = j.matmul_tn(&omega);
        std::hint::black_box(&c);
    });
    time_op("JᵀΩ material  Jᵀ then matmul", flops_tn, 5, || {
        let c = j.transpose().matmul(&omega);
        std::hint::black_box(&c);
    });

    // BᵀB: the ℓ×ℓ Nyström core (N×S)ᵀ(N×S).
    let mut b = Matrix::zeros(n, s);
    rng.fill_normal(b.data_mut());
    let flops_core = 2.0 * (n * s * s) as f64;
    time_op("BᵀB fused     matmul_tn", flops_core, 20, || {
        let c = b.matmul_tn(&b);
        std::hint::black_box(&c);
    });
    time_op("BᵀB material  Bᵀ then matmul", flops_core, 20, || {
        let c = b.transpose().matmul(&b);
        std::hint::black_box(&c);
    });

    // JᵀJ: dense ENGD's P×P Gramian at a dense-tractable size.
    let (n2, p2) = (448usize, 2048usize);
    let mut j2 = Matrix::zeros(n2, p2);
    rng.fill_normal(j2.data_mut());
    let flops_gram_t = (n2 * p2 * p2) as f64;
    time_op("JᵀJ fused     gram_t", flops_gram_t, 5, || {
        let g = j2.gram_t();
        std::hint::black_box(&g);
    });
    time_op("JᵀJ material  Jᵀ then gram", flops_gram_t, 5, || {
        let g = j2.transpose().gram();
        std::hint::black_box(&g);
    });

    // Workspace-pooled gram vs per-call allocation (the step-reuse win).
    // Scratch checkout: gram_into overwrites every element, so the pooled
    // path pays no memset at all — same as the trainer hot path.
    let mut ws = Workspace::new();
    let k0 = ws.take_matrix_scratch(n, n);
    ws.recycle_matrix(k0); // warm the pool
    time_op("gram_into pooled (1024x10065)", (n * n) as f64 * p as f64, 5, || {
        let mut k = ws.take_matrix_scratch(n, n);
        j.gram_into(&mut k);
        std::hint::black_box(&k);
        ws.recycle_matrix(k);
    });
    println!("workspace stats after pooled gram: {:?}", ws.stats());
}
