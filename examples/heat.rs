//! Beyond the paper: a time-dependent PDE through the same optimizer stack.
//!
//! Solves the 2d heat equation `u_t = Δu` on the space-time cylinder
//! [0,1]² × [0,1] (exact solution e^{−2π²t}·sin(πx₀)sin(πx₁)) with SPRING —
//! demonstrating that the ENGD-W/SPRING machinery is operator-agnostic: the
//! L2 model swaps `−Δu − f` for `∂_t u − Δ_x u − f` and everything else
//! (kernel, Woodbury, momentum, line search) is untouched.
//!
//! ```bash
//! cargo run --release --example heat [steps] [--backend native]
//! ```

use anyhow::Result;

use engd::backend::Evaluator;
use engd::cli::Args;
use engd::config::run::OptimizerKind;
use engd::config::RunConfig;
use engd::coordinator::train;

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let steps: usize = args.leading_usize().unwrap_or(150);
    let backend = engd::backend::select_from_args(&args)?;
    let p = backend.problem("heat2d")?;
    println!(
        "heat2d: u_t = Δu on [0,1]²x[0,1], arch {:?}, P = {}",
        p.arch, p.n_params
    );

    let mut cfg = RunConfig {
        name: "heat2d-spring".into(),
        problem: "heat2d".into(),
        steps,
        eval_every: 10,
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.damping = 1e-7;
    cfg.optimizer.momentum = 0.8;
    cfg.optimizer.line_search = true;

    let report = train(cfg, backend.as_ref(), true)?;
    println!(
        "\nheat2d finished: {} steps, {:.1}s, final loss {:.3e}, best L2 {:.3e}",
        report.steps_done, report.wall_s, report.final_loss, report.best_l2
    );
    anyhow::ensure!(
        report.best_l2 < 2e-1,
        "expected L2 < 0.2, got {:.3e}",
        report.best_l2
    );
    Ok(())
}
