//! Quickstart: train a PINN on the 2d Poisson problem with SPRING.
//!
//! ```bash
//! cargo run --release --example quickstart                      # auto backend
//! cargo run --release --example quickstart -- --backend native  # no artifacts needed
//! ```
//!
//! Demonstrates the whole public API surface in ~30 lines: pick a backend
//! (PJRT artifacts or pure-Rust native AD), configure a run, train,
//! evaluate. Finishes in well under a minute on a laptop-class CPU and
//! reaches L2 error < 1e-2.

use anyhow::Result;

use engd::backend::Evaluator;
use engd::cli::Args;
use engd::config::run::OptimizerKind;
use engd::config::RunConfig;
use engd::coordinator::train;

fn main() -> Result<()> {
    let args = Args::parse(&[])?;
    let backend = engd::backend::select_from_args(&args)?;
    println!("backend: {}", backend.backend_name());

    let mut cfg = RunConfig {
        name: "quickstart".into(),
        problem: "poisson2d".into(),
        steps: 150,
        eval_every: 10,
        ..RunConfig::default()
    };
    // The paper's A.2 line-search SPRING (damping 2.09e-10, momentum 0.312)
    // — reaches L2 ≈ 5e-5 on this problem within the step budget.
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.damping = 2.086287e-10;
    cfg.optimizer.momentum = 0.311542;
    cfg.optimizer.line_search = true;

    let report = train(cfg, backend.as_ref(), true)?;

    println!(
        "\nquickstart finished ({}): {} steps, {:.1}s, final loss {:.3e}, best L2 {:.3e}",
        report.backend, report.steps_done, report.wall_s, report.final_loss, report.best_l2
    );
    anyhow::ensure!(
        report.best_l2 < 1e-2,
        "expected L2 < 1e-2, got {:.3e}",
        report.best_l2
    );
    println!("curve written to results/quickstart.csv");
    Ok(())
}
