//! Quickstart: train a PINN on the 2d Poisson problem with SPRING.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole public API surface in ~30 lines: load the PJRT
//! runtime, configure a run, train, evaluate. Finishes in well under a
//! minute on a laptop-class CPU and reaches L2 error < 5e-2.

use anyhow::Result;

use engd::config::run::OptimizerKind;
use engd::config::RunConfig;
use engd::coordinator::train;
use engd::runtime::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let mut cfg = RunConfig {
        name: "quickstart".into(),
        problem: "poisson2d".into(),
        steps: 150,
        eval_every: 10,
        ..RunConfig::default()
    };
    cfg.optimizer.kind = OptimizerKind::Spring;
    cfg.optimizer.damping = 1e-6;
    cfg.optimizer.momentum = 0.8;
    cfg.optimizer.line_search = true;

    let report = train(cfg, &rt, true)?;

    println!(
        "\nquickstart finished: {} steps, {:.1}s, final loss {:.3e}, best L2 {:.3e}",
        report.steps_done, report.wall_s, report.final_loss, report.best_l2
    );
    anyhow::ensure!(
        report.best_l2 < 5e-2,
        "expected L2 < 5e-2, got {:.3e}",
        report.best_l2
    );
    println!("curve written to results/quickstart.csv");
    Ok(())
}
